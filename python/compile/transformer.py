"""L2 JAX model: decoder-only transformer LM with the flat-parameter ABI.

Used by the end-to-end training example (examples/e2e_transformer.rs): the
paper's distributed-SGD-with-quantized-gradients loop applied to a byte-level
language model on a synthetic corpus.

Exported entry points:

    tfm_grad(params f32[P], tokens f32[B, L+1]) -> (loss f32[], grads f32[P])
    tfm_eval(params f32[P], tokens f32[B, L+1]) -> (loss_sum f32[], count f32[])

Tokens travel as f32 (cast to int inside) to keep the FFI surface f32-only.
Configs are named presets; `tfm_small` (~0.9M params) is what the recorded
e2e run uses on CPU, `tfm_100m` exists to show the pipeline is size-agnostic.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layout import ParamLayout


@dataclass(frozen=True)
class TfmConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 128
    batch: int = 8


PRESETS = {
    "tfm_small": TfmConfig(),
    "tfm_medium": TfmConfig(d_model=256, n_layers=6, n_heads=8, d_ff=1024),
    # ~100M: d=768, 12 layers, ff 3072 — compile-capable, not CPU-train-speed.
    "tfm_100m": TfmConfig(d_model=768, n_layers=12, n_heads=12, d_ff=3072,
                          seq_len=256, batch=4),
}


def tfm_layout(cfg: TfmConfig) -> ParamLayout:
    lay = ParamLayout()
    lay.add("emb.tok", (cfg.vocab, cfg.d_model), "emb")
    lay.add("emb.pos", (cfg.seq_len, cfg.d_model), "emb")
    for i in range(cfg.n_layers):
        p = f"l{i}."
        lay.add(p + "ln1.s", (cfg.d_model,), "fc")
        lay.add(p + "ln1.b", (cfg.d_model,), "fc")
        lay.add(p + "attn.wq", (cfg.d_model, cfg.d_model), "fc")
        lay.add(p + "attn.wk", (cfg.d_model, cfg.d_model), "fc")
        lay.add(p + "attn.wv", (cfg.d_model, cfg.d_model), "fc")
        lay.add(p + "attn.wo", (cfg.d_model, cfg.d_model), "fc")
        lay.add(p + "ln2.s", (cfg.d_model,), "fc")
        lay.add(p + "ln2.b", (cfg.d_model,), "fc")
        lay.add(p + "mlp.w1", (cfg.d_model, cfg.d_ff), "fc")
        lay.add(p + "mlp.b1", (cfg.d_ff,), "fc")
        lay.add(p + "mlp.w2", (cfg.d_ff, cfg.d_model), "fc")
        lay.add(p + "mlp.b2", (cfg.d_model,), "fc")
    lay.add("lnf.s", (cfg.d_model,), "fc")
    lay.add("lnf.b", (cfg.d_model,), "fc")
    lay.add("unemb", (cfg.d_model, cfg.vocab), "emb")
    return lay


def tfm_init(key, cfg: TfmConfig) -> jnp.ndarray:
    lay = tfm_layout(cfg)
    parts = []
    for e in lay.entries:
        key, sub = jax.random.split(key)
        if e.name.endswith((".s",)):
            parts.append(jnp.ones(e.shape))
        elif e.name.endswith((".b", ".b1", ".b2")) and len(e.shape) == 1:
            parts.append(jnp.zeros(e.shape))
        else:
            scale = 0.02
            if e.name.endswith(("wo", "w2")):
                # Residual-branch outputs scaled down by depth.
                scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
            parts.append(jax.random.normal(sub, e.shape) * scale)
    return jnp.concatenate([p.reshape(-1) for p in parts]).astype(jnp.float32)


def _ln(x, s, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * s + b


def tfm_forward(flat, tokens_f32, cfg: TfmConfig):
    """tokens_f32: f32[B, L] context; returns logits f32[B, L, V]."""
    p = tfm_layout(cfg).unflatten(flat)
    t = tokens_f32.astype(jnp.int32)
    B, L = t.shape
    h = p["emb.tok"][t] + p["emb.pos"][None, :L, :]
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        x = _ln(h, p[pre + "ln1.s"], p[pre + "ln1.b"])
        q = (x @ p[pre + "attn.wq"]).reshape(B, L, nh, dh).transpose(0, 2, 1, 3)
        k = (x @ p[pre + "attn.wk"]).reshape(B, L, nh, dh).transpose(0, 2, 1, 3)
        v = (x @ p[pre + "attn.wv"]).reshape(B, L, nh, dh).transpose(0, 2, 1, 3)
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(dh))
        att = jnp.where(mask[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(B, L, cfg.d_model)
        h = h + o @ p[pre + "attn.wo"]
        x = _ln(h, p[pre + "ln2.s"], p[pre + "ln2.b"])
        x = jax.nn.gelu(x @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        h = h + x @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
    h = _ln(h, p["lnf.s"], p["lnf.b"])
    return h @ p["unemb"]


def _next_token_loss(flat, tokens_f32, cfg: TfmConfig):
    """tokens_f32: f32[B, L+1]; mean CE of predicting token t+1 from 0..t."""
    ctx = tokens_f32[:, :-1]
    tgt = tokens_f32[:, 1:].astype(jnp.int32)
    logits = tfm_forward(flat, ctx, cfg)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, tgt[:, :, None], axis=2)[:, :, 0]
    return jnp.mean(nll)


def make_tfm_grad_fn(cfg: TfmConfig):
    def grad_entry(flat, tokens):
        loss, grads = jax.value_and_grad(_next_token_loss)(flat, tokens, cfg)
        return loss, grads

    return grad_entry


def make_tfm_eval_fn(cfg: TfmConfig):
    def eval_entry(flat, tokens):
        ctx = tokens[:, :-1]
        tgt = tokens[:, 1:].astype(jnp.int32)
        logits = tfm_forward(flat, ctx, cfg)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, tgt[:, :, None], axis=2)[:, :, 0]
        return jnp.sum(nll), jnp.array(float(cfg.batch * cfg.seq_len))

    return eval_entry
