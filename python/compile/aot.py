"""AOT pipeline: lower every L2/L1 entry point to HLO TEXT + manifest.json.

This is the ONLY place python runs; afterwards the rust binary is
self-contained.  Interchange format is HLO text, not a serialized
HloModuleProto — jax >= 0.5 emits 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts written to --out (default ../artifacts):

    <entry>.hlo.txt          one per entry point
    <model>_init.bin         f32-LE initial flat parameters
    manifest.json            shapes, dtypes, param layouts, quant tile size

Usage:  cd python && python -m compile.aot --out ../artifacts [--models mlp,cnn,tfm_small]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import transformer as T
from .kernels.biscaled import quantize_biscaled
from .kernels.nonuniform import quantize_codebook
from .kernels.quantize import quantize_uniform
from .kernels.stats import tail_stats

# Flat tile the rust hot path feeds the standalone quantizer artifacts with.
QUANT_TILE = 65536

TRAIN_BATCH = 32
EVAL_BATCH = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(shapes_in, shapes_out):
    return {
        "inputs": [{"name": n, "dtype": "f32", "shape": list(s)} for n, s in shapes_in],
        "outputs": [
            {"name": n, "dtype": d, "shape": list(s)} for n, d, s in shapes_out
        ],
    }


def lower_entry(out_dir, name, fn, in_specs, io, manifest):
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    manifest["artifacts"][name] = {"file": fname, **io}
    print(f"  {name:24s} -> {fname} ({len(text)} chars)")


def export_classifier(out_dir, name, manifest):
    m = M.MODELS[name]
    lay = m["layout"]()
    P = lay.total
    fwd = m["forward"]
    grad_fn = M.make_grad_fn(fwd)
    eval_fn = M.make_eval_fn(fwd)
    D = m["input_dim"]

    lower_entry(
        out_dir, f"{name}_grad", grad_fn,
        (spec((P,)), spec((TRAIN_BATCH, D)), spec((TRAIN_BATCH,))),
        _io(
            [("params", (P,)), ("x", (TRAIN_BATCH, D)), ("y", (TRAIN_BATCH,))],
            [("loss", "f32", ()), ("grads", "f32", (P,))],
        ),
        manifest,
    )
    lower_entry(
        out_dir, f"{name}_eval", eval_fn,
        (spec((P,)), spec((EVAL_BATCH, D)), spec((EVAL_BATCH,))),
        _io(
            [("params", (P,)), ("x", (EVAL_BATCH, D)), ("y", (EVAL_BATCH,))],
            [("loss_sum", "f32", ()), ("correct", "f32", ())],
        ),
        manifest,
    )

    init = np.asarray(m["init"](jax.random.PRNGKey(42)), dtype=np.float32)
    init_file = f"{name}_init.bin"
    init.tofile(os.path.join(out_dir, init_file))
    manifest["models"][name] = {
        **lay.to_manifest(),
        "kind": "classifier",
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "input_dim": D,
        "init_file": init_file,
        "grad_entry": f"{name}_grad",
        "eval_entry": f"{name}_eval",
    }


def export_transformer(out_dir, preset, manifest):
    cfg = T.PRESETS[preset]
    lay = T.tfm_layout(cfg)
    P = lay.total
    grad_fn = T.make_tfm_grad_fn(cfg)
    eval_fn = T.make_tfm_eval_fn(cfg)
    B, L = cfg.batch, cfg.seq_len

    lower_entry(
        out_dir, f"{preset}_grad", grad_fn,
        (spec((P,)), spec((B, L + 1))),
        _io(
            [("params", (P,)), ("tokens", (B, L + 1))],
            [("loss", "f32", ()), ("grads", "f32", (P,))],
        ),
        manifest,
    )
    lower_entry(
        out_dir, f"{preset}_eval", eval_fn,
        (spec((P,)), spec((B, L + 1))),
        _io(
            [("params", (P,)), ("tokens", (B, L + 1))],
            [("loss_sum", "f32", ()), ("count", "f32", ())],
        ),
        manifest,
    )

    init = np.asarray(T.tfm_init(jax.random.PRNGKey(7), cfg), dtype=np.float32)
    init_file = f"{preset}_init.bin"
    init.tofile(os.path.join(out_dir, init_file))
    manifest["models"][preset] = {
        **lay.to_manifest(),
        "kind": "lm",
        "train_batch": B,
        "eval_batch": B,
        "seq_len": L,
        "vocab": cfg.vocab,
        "init_file": init_file,
        "grad_entry": f"{preset}_grad",
        "eval_entry": f"{preset}_eval",
    }


def export_quant_kernels(out_dir, manifest):
    """Standalone L1 quantizer artifacts over a fixed QUANT_TILE.

    These exist for L1<->L3 parity benchmarking (runtime::QuantExec): the rust
    codecs are the production encode path, and these artifacts prove the
    Pallas kernel computes the identical function through PJRT.
    """
    D = QUANT_TILE
    for b in (2, 3, 4, 5):
        s = 2**b - 1
        lower_entry(
            out_dir, f"quant_uniform_b{b}",
            lambda g, u, a, s=s: quantize_uniform(g, u, a, s=s),
            (spec((D,)), spec((D,)), spec((1,))),
            _io(
                [("g", (D,)), ("u", (D,)), ("alpha", (1,))],
                [("deq", "f32", (D,)), ("idx", "i32", (D,))],
            ),
            manifest,
        )
    s3 = 7
    lower_entry(
        out_dir, "quant_nonuniform_b3",
        lambda g, u, cb: quantize_codebook(g, u, cb, s=s3),
        (spec((D,)), spec((D,)), spec((s3 + 1,))),
        _io(
            [("g", (D,)), ("u", (D,)), ("codebook", (s3 + 1,))],
            [("deq", "f32", (D,)), ("idx", "i32", (D,))],
        ),
        manifest,
    )
    # b=3 biscaled with the canonical 5-inner/2-outer split (k* near 0.5 gives
    # s_beta=5, s_alpha=2 for s=7; the rust solver may choose other splits —
    # this artifact pins one for parity testing).
    lower_entry(
        out_dir, "quant_biscaled_b3",
        lambda g, u, ab: quantize_biscaled(g, u, ab, s_beta=5, s_alpha=2),
        (spec((D,)), spec((D,)), spec((2,))),
        _io(
            [("g", (D,)), ("u", (D,)), ("alpha_beta", (2,))],
            [("deq", "f32", (D,)), ("idx", "i32", (D,))],
        ),
        manifest,
    )
    lower_entry(
        out_dir, "tail_stats",
        lambda g, gm: tail_stats(g, gm),
        (spec((D,)), spec((1,))),
        _io(
            [("g", (D,)), ("g_min", (1,))],
            [("stats", "f32", (5,))],
        ),
        manifest,
    )
    manifest["quant"] = {
        "tile": D,
        "biscaled_b3": {"s_beta": 5, "s_alpha": 2},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn,tfm_small",
                    help="comma list from {mlp, cnn, tfm_small, tfm_medium, tfm_100m}")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": {}, "models": {}}
    for name in [m for m in args.models.split(",") if m]:
        print(f"[aot] exporting {name}")
        if name in M.MODELS:
            export_classifier(args.out, name, manifest)
        elif name in T.PRESETS:
            export_transformer(args.out, name, manifest)
        else:
            raise SystemExit(f"unknown model {name!r}")
    print("[aot] exporting quantizer kernels")
    export_quant_kernels(args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
