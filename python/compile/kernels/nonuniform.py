"""L1 Pallas kernel: truncated NON-uniform stochastic quantizer (TNQSGD).

The codebook L = {l_0 < ... < l_s} realizing the optimal density
lambda_s(g) = s * p(g)^{1/3} / int p^{1/3} (Eq. 18) is built by the rust
solver (CDF inversion) and passed in as an explicit (s+1)-vector, so one
compiled artifact serves every round / every gradient distribution.

Interval lookup is a branchless comparison ladder (VPU-friendly; a
data-dependent binary search would serialize the vector unit):

    k      = sum_j [ g >= l_j ],  j = 1..s-1          (interval index)
    lower  = one_hot(k)   . L                          (tiny matmul, MXU-able)
    upper  = one_hot(k+1) . L

With s <= 31 (b <= 5) the ladder is s-1 vector compares and two
(BLOCK, s+1) x (s+1,) contractions per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _nonuniform_kernel(g_ref, u_ref, cb_ref, o_ref, i_ref, *, s: int):
    g = g_ref[...]
    u = u_ref[...]
    cb = cb_ref[...]  # (s+1,)
    g = jnp.clip(g, cb[0], cb[s])
    # Ladder over interior boundaries.
    k = jnp.zeros(g.shape, jnp.int32)
    for j in range(1, s):
        k = k + (g >= cb[j]).astype(jnp.int32)
    # Gather lower/upper via one-hot contraction (avoids dynamic gather,
    # which Mosaic handles poorly for small tables).
    levels = jnp.arange(s + 1, dtype=jnp.int32)
    onehot_lo = (k[:, None] == levels[None, :]).astype(jnp.float32)
    onehot_hi = ((k + 1)[:, None] == levels[None, :]).astype(jnp.float32)
    lower = onehot_lo @ cb
    upper = onehot_hi @ cb
    width = upper - lower
    safe = jnp.where(width > 0, width, 1.0)
    frac = jnp.where(width > 0, (g - lower) / safe, 0.0)
    up = (u < frac).astype(jnp.int32)
    idx = k + up
    onehot = (idx[:, None] == levels[None, :]).astype(jnp.float32)
    o_ref[...] = (onehot @ cb).astype(jnp.float32)
    i_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("s",))
def quantize_codebook(g, u, codebook, *, s: int):
    """Truncated non-uniform quantizer over a flat f32 vector.

    Args:
      g:        f32[d], d a multiple of BLOCK.
      u:        f32[d] uniforms in [0, 1).
      codebook: f32[s+1] strictly increasing levels; end points are the
                truncation range.
      s:        static interval count (= len(codebook) - 1).

    Returns (deq f32[d], idx i32[d]).
    """
    d = g.shape[0]
    assert d % BLOCK == 0, f"pad d={d} to a multiple of {BLOCK}"
    assert codebook.shape == (s + 1,)
    grid = (d // BLOCK,)
    return pl.pallas_call(
        functools.partial(_nonuniform_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((s + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.int32),
        ],
        interpret=True,
    )(g, u, codebook)
