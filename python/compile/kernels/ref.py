"""Pure-jnp reference oracle for the two-stage quantizer.

Everything here is the *specification*: the Pallas kernels in this package and
the rust codecs in ``rust/src/quant`` are both validated against these
functions (pytest on the python side, parity fixtures on the rust side).

The two-stage quantizer of the paper (Eqs. 3-4):

    T_alpha[g] = clip(g, -alpha, alpha)                        (truncation)
    Q[g]       = l_{k-1} w.p. 1 - p,  l_k w.p. p = (g - l_{k-1}) / |Delta_k|

where the codebook L = {l_0 < l_1 < ... < l_s} covers [-alpha, alpha] and
s = 2^b - 1.  Stochastic rounding consumes an explicit uniform u ~ U[0,1) per
element so that the oracle, the Pallas kernel and the rust codec are bit-wise
comparable given the same uniforms.
"""

from __future__ import annotations

import jax.numpy as jnp


def truncate(g, alpha):
    """Eq. (3): clip each element of ``g`` to [-alpha, alpha]."""
    return jnp.clip(g, -alpha, alpha)


def uniform_codebook(alpha, s: int):
    """Evenly spaced codebook {-alpha + k * 2 alpha / s : k = 0..s}."""
    k = jnp.arange(s + 1, dtype=jnp.float32)
    return -alpha + k * (2.0 * alpha / s)


def quantize_uniform(g, u, alpha, s: int):
    """Truncated uniform stochastic quantizer (TQSGD; Sec. IV-A).

    Args:
      g:      gradient elements, any shape, f32.
      u:      uniforms in [0, 1), same shape as g.
      alpha:  truncation threshold (scalar).
      s:      number of intervals (2^b - 1), static.

    Returns:
      (deq, idx): dequantized f32 values (elements of the codebook) and the
      integer level index in [0, s].
    """
    g = truncate(g, alpha)
    step = 2.0 * alpha / s
    # Position within the codebook; x in [0, s].
    x = (g + alpha) / step
    lo = jnp.floor(x)
    # Guard the right edge: g == +alpha gives x == s exactly.
    lo = jnp.clip(lo, 0.0, s - 1.0)
    frac = x - lo
    idx = lo + (u < frac).astype(jnp.float32)
    idx = jnp.clip(idx, 0.0, float(s))
    deq = -alpha + idx * step
    return deq.astype(jnp.float32), idx.astype(jnp.int32)


def quantize_codebook(g, u, codebook):
    """Truncated non-uniform stochastic quantizer given an explicit codebook.

    The codebook must be strictly increasing; its end points define the
    truncation range [l_0, l_s].  Used for TNQSGD (density of Eq. 18 inverted
    into level positions) and TBQSGD (piecewise-uniform codebook).

    Index selection is the branchless comparison ladder described in
    DESIGN.md (Hardware-Adaptation): k = sum_j [g >= l_j] - 1 over the
    interior boundaries.
    """
    cb = jnp.asarray(codebook, dtype=jnp.float32)
    s = cb.shape[0] - 1
    g = jnp.clip(g, cb[0], cb[s])
    # Ladder over interior boundaries l_1 .. l_{s-1}: counts how many interior
    # boundaries are <= g, giving the interval index in [0, s-1].
    interior = cb[1:s]
    k = jnp.sum(
        (g[..., None] >= interior[(None,) * g.ndim]).astype(jnp.int32), axis=-1
    )
    lower = jnp.take(cb, k)
    upper = jnp.take(cb, k + 1)
    width = upper - lower
    frac = jnp.where(width > 0, (g - lower) / jnp.where(width > 0, width, 1.0), 0.0)
    up = (u < frac).astype(jnp.int32)
    idx = k + up
    deq = jnp.take(cb, idx)
    return deq.astype(jnp.float32), idx.astype(jnp.int32)


def biscaled_codebook(alpha, beta, s_beta: int, s_alpha: int):
    """Codebook for the BiScaled quantizer (Appendix D, Eq. 25).

    The inner region [-beta, beta] is split into s_beta equal intervals and
    the two outer regions [-alpha,-beta] and [beta,alpha] share s_alpha equal
    intervals (s_alpha/2 per side, so s_alpha must be even).
    """
    assert s_alpha % 2 == 0, "s_alpha must be even for a symmetric codebook"
    half = s_alpha // 2
    inner = jnp.linspace(-beta, beta, s_beta + 1)
    left = jnp.linspace(-alpha, -beta, half + 1)[:-1]
    right = jnp.linspace(beta, alpha, half + 1)[1:]
    return jnp.concatenate([left, inner, right]).astype(jnp.float32)


def quantize_biscaled(g, u, alpha, beta, s_beta: int, s_alpha: int):
    """Truncated BiScaled stochastic quantizer (TBQSGD, Appendix D)."""
    cb = biscaled_codebook(alpha, beta, s_beta, s_alpha)
    return quantize_codebook(g, u, cb)


def tail_stats(g, g_min):
    """Sufficient statistics for the power-law tail MLE (Sec. V).

    gamma_hat = 1 + n / sum ln(|g_j| / g_min) over |g_j| > g_min.

    Returns a 5-vector: [n_tail, sum_log, sum_abs, sum_sq, abs_max].
    """
    a = jnp.abs(g)
    mask = a > g_min
    n = jnp.sum(mask.astype(jnp.float32))
    slog = jnp.sum(jnp.where(mask, jnp.log(jnp.where(mask, a, 1.0) / g_min), 0.0))
    return jnp.stack([n, slog, jnp.sum(a), jnp.sum(g * g), jnp.max(a)])


def quantization_mse(g, deq):
    """Mean squared quantization error ||Q[T[g]] - g||^2 / d (Lemma 2)."""
    e = deq - g
    return jnp.mean(e * e)
