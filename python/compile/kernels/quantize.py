"""L1 Pallas kernel: fused truncate -> uniform stochastic quantize -> dequantize.

This is the per-element hot-spot of the paper (Eqs. 3-4 with the uniform
density lambda_s = s / 2 alpha of Sec. IV-A).  The kernel streams the
flattened gradient through VMEM in BLOCK-sized tiles:

    HBM g[d], u[d]  --BlockSpec-->  VMEM tiles of BLOCK f32
    per element: clip, scale, floor, stochastic round, rescale
    VMEM tiles    --BlockSpec-->  HBM deq[d], idx[d]

TPU mapping notes (DESIGN.md Hardware-Adaptation): the body is pure VPU
element-wise work; with BLOCK = 8192 the working set is
4 buffers * 32 KiB = 128 KiB of VMEM, leaving plenty of headroom for
double-buffered prefetch of the next tile.  interpret=True everywhere in this
repo (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size: multiple of the (8, 128) f32 VPU tile, sized for VMEM headroom.
BLOCK = 8192


def _uniform_kernel(g_ref, u_ref, alpha_ref, o_ref, i_ref, *, s: int):
    """Per-tile body. alpha arrives as a (1,)-shaped scalar tile."""
    alpha = alpha_ref[0]
    g = g_ref[...]
    u = u_ref[...]
    g = jnp.clip(g, -alpha, alpha)
    step = 2.0 * alpha / s
    x = (g + alpha) / step
    lo = jnp.clip(jnp.floor(x), 0.0, s - 1.0)
    frac = x - lo
    idx = lo + (u < frac).astype(jnp.float32)
    idx = jnp.clip(idx, 0.0, float(s))
    o_ref[...] = (-alpha + idx * step).astype(jnp.float32)
    i_ref[...] = idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("s",))
def quantize_uniform(g, u, alpha, *, s: int):
    """Fused truncated uniform quantizer over a flat f32 vector.

    Args:
      g:     f32[d] flattened gradient, d a multiple of BLOCK (callers pad).
      u:     f32[d] uniforms in [0, 1).
      alpha: f32[1] truncation threshold.
      s:     static level count 2^b - 1.

    Returns (deq f32[d], idx i32[d]).
    """
    d = g.shape[0]
    assert d % BLOCK == 0, f"pad d={d} to a multiple of {BLOCK}"
    grid = (d // BLOCK,)
    return pl.pallas_call(
        functools.partial(_uniform_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.int32),
        ],
        interpret=True,
    )(g, u, alpha)
