"""L1 Pallas kernel: tail statistics for the power-law MLE (Sec. V).

Computes, per VMEM tile, the partial sufficient statistics

    [ n_tail, sum ln(|g|/g_min) over tail, sum |g|, sum g^2, max |g| ]

which the caller (or the L2 wrapper) reduces across tiles.  These feed the
paper's estimator  gamma_hat = 1 + n [ sum_j ln(g_j / g_min) ]^{-1}  and the
rho_hat = n_tail / d  mass estimate used in Eqs. (12)/(19)/(33).

The tile emits a (1, 5) partial row; the grid dimension concatenates rows so
the final jnp.sum / jnp.max over axis 0 is a trivial (grid, 5) reduction that
XLA fuses with the surrounding graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _stats_kernel(g_ref, gmin_ref, o_ref):
    g = g_ref[...]
    g_min = gmin_ref[0]
    a = jnp.abs(g)
    mask = a > g_min
    n = jnp.sum(mask.astype(jnp.float32))
    slog = jnp.sum(jnp.where(mask, jnp.log(jnp.where(mask, a, 1.0) / g_min), 0.0))
    o_ref[0, 0] = n
    o_ref[0, 1] = slog
    o_ref[0, 2] = jnp.sum(a)
    o_ref[0, 3] = jnp.sum(g * g)
    o_ref[0, 4] = jnp.max(a)


@jax.jit
def tail_stats(g, g_min):
    """Tail sufficient statistics over a flat f32 vector.

    Args:
      g:     f32[d], d a multiple of BLOCK.
      g_min: f32[1] power-law lower cutoff.

    Returns f32[5] = [n_tail, sum_log, sum_abs, sum_sq, abs_max].
    """
    d = g.shape[0]
    assert d % BLOCK == 0, f"pad d={d} to a multiple of {BLOCK}"
    grid = (d // BLOCK,)
    partial = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 5), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d // BLOCK, 5), jnp.float32),
        interpret=True,
    )(g, g_min)
    sums = jnp.sum(partial[:, :4], axis=0)
    mx = jnp.max(partial[:, 4])
    return jnp.concatenate([sums, mx[None]])
