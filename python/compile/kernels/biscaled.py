"""L1 Pallas kernel: truncated BiScaled stochastic quantizer (TBQSGD, App. D).

The BiScaled density (Eq. 25) is piecewise-constant over two regions

    |g| in [0, beta]      -> s_beta  intervals of width 2 beta / s_beta
    |g| in [beta, alpha]  -> s_alpha intervals of width 2 (alpha-beta)/s_alpha

so unlike the general codebook kernel the interval index is CLOSED FORM per
region — no ladder, just two scaled floors and a select.  This is the cheapest
of the three kernels (pure element-wise VPU work, like the uniform one).

Level indexing convention: the symmetric codebook has s_alpha/2 outer levels
per side plus s_beta inner intervals; global index

    idx in [0, s],  s = s_alpha + s_beta,
    value(idx) = piecewise-linear over the three segments.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _value_of_idx(idx_f, alpha, beta, s_beta: int, s_alpha: int):
    """Map a global level index (float) to its codebook value."""
    half = s_alpha // 2
    step_out = (alpha - beta) / half
    step_in = 2.0 * beta / s_beta
    # Segment boundaries in index space: [0, half], [half, half+s_beta],
    # [half+s_beta, s].
    left = -alpha + idx_f * step_out
    mid = -beta + (idx_f - half) * step_in
    right = beta + (idx_f - half - s_beta) * step_out
    v = jnp.where(idx_f <= half, left, jnp.where(idx_f <= half + s_beta, mid, right))
    # Exact end points where segments meet.
    v = jnp.where(idx_f == half, -beta, v)
    v = jnp.where(idx_f == half + s_beta, beta, v)
    return v


def _biscaled_kernel(g_ref, u_ref, ab_ref, o_ref, i_ref, *, s_beta: int, s_alpha: int):
    alpha = ab_ref[0]
    beta = ab_ref[1]
    half = s_alpha // 2
    s = s_alpha + s_beta
    g = jnp.clip(g_ref[...], -alpha, alpha)
    u = u_ref[...]
    step_out = (alpha - beta) / half
    step_in = 2.0 * beta / s_beta

    # Closed-form interval index per region (index of the LOWER level).
    k_left = jnp.clip(jnp.floor((g + alpha) / step_out), 0.0, half - 1.0)
    k_mid = half + jnp.clip(jnp.floor((g + beta) / step_in), 0.0, s_beta - 1.0)
    k_right = (
        half
        + s_beta
        + jnp.clip(jnp.floor((g - beta) / step_out), 0.0, half - 1.0)
    )
    k = jnp.where(g < -beta, k_left, jnp.where(g <= beta, k_mid, k_right))

    lower = _value_of_idx(k, alpha, beta, s_beta, s_alpha)
    width = jnp.where(jnp.logical_and(k >= half, k < half + s_beta), step_in, step_out)
    frac = (g - lower) / width
    idx = k + (u < frac).astype(jnp.float32)
    idx = jnp.clip(idx, 0.0, float(s))
    o_ref[...] = _value_of_idx(idx, alpha, beta, s_beta, s_alpha).astype(jnp.float32)
    i_ref[...] = idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("s_beta", "s_alpha"))
def quantize_biscaled(g, u, alpha_beta, *, s_beta: int, s_alpha: int):
    """Fused truncated BiScaled quantizer over a flat f32 vector.

    Args:
      g:          f32[d], d a multiple of BLOCK.
      u:          f32[d] uniforms in [0, 1).
      alpha_beta: f32[2] = [alpha, beta], alpha > beta > 0.
      s_beta:     static inner interval count.
      s_alpha:    static outer interval count (even; split across both sides).

    Returns (deq f32[d], idx i32[d]) with idx in [0, s_beta + s_alpha].
    """
    d = g.shape[0]
    assert d % BLOCK == 0, f"pad d={d} to a multiple of {BLOCK}"
    assert s_alpha % 2 == 0
    grid = (d // BLOCK,)
    return pl.pallas_call(
        functools.partial(_biscaled_kernel, s_beta=s_beta, s_alpha=s_alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.int32),
        ],
        interpret=True,
    )(g, u, alpha_beta)
