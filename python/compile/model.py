"""L2 JAX models: MLP and LeNet-style CNN with the flat-parameter ABI.

Exported entry points (see aot.py):

    <model>_grad(params f32[P], x, y f32[B])   -> (loss f32[], grads f32[P])
    <model>_eval(params f32[P], x, y f32[B])   -> (loss_sum f32[], correct f32[])

Labels travel as f32 and are cast to int inside the graph — this keeps the
rust FFI surface f32-only (one Literal dtype on the hot path).

The CNN mirrors the paper's conv+fc split: gradients of convolutional and
fully-connected layers have different tail behaviour (Sec. V cites TernGrad
for this), so the layout tags each tensor with its quantization group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layout import ParamLayout

# ---------------------------------------------------------------------------
# MLP: 784 -> 256 -> 128 -> 10
# ---------------------------------------------------------------------------

MLP_DIMS = (784, 256, 128, 10)


def mlp_layout() -> ParamLayout:
    lay = ParamLayout()
    for i in range(len(MLP_DIMS) - 1):
        lay.add(f"fc{i}.w", (MLP_DIMS[i], MLP_DIMS[i + 1]), "fc")
        lay.add(f"fc{i}.b", (MLP_DIMS[i + 1],), "fc")
    return lay


def mlp_init(key) -> jnp.ndarray:
    lay = mlp_layout()
    parts = []
    for e in lay.entries:
        key, sub = jax.random.split(key)
        if e.name.endswith(".w"):
            fan_in = e.shape[0]
            parts.append(
                jax.random.normal(sub, e.shape) * jnp.sqrt(2.0 / fan_in)
            )
        else:
            parts.append(jnp.zeros(e.shape))
    return jnp.concatenate([p.reshape(-1) for p in parts]).astype(jnp.float32)


def mlp_forward(flat, x):
    p = mlp_layout().unflatten(flat)
    h = x
    n = len(MLP_DIMS) - 1
    for i in range(n):
        h = h @ p[f"fc{i}.w"] + p[f"fc{i}.b"]
        if i + 1 < n:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# LeNet-style CNN: conv(5x5,8) -> pool -> conv(5x5,16) -> pool -> fc128 -> fc10
# Input 28x28x1 (NHWC), VALID convs: 28 -> 24 -> 12 -> 8 -> 4.
# ---------------------------------------------------------------------------


def cnn_layout() -> ParamLayout:
    lay = ParamLayout()
    lay.add("conv0.w", (5, 5, 1, 8), "conv")
    lay.add("conv0.b", (8,), "conv")
    lay.add("conv1.w", (5, 5, 8, 16), "conv")
    lay.add("conv1.b", (16,), "conv")
    lay.add("fc0.w", (4 * 4 * 16, 128), "fc")
    lay.add("fc0.b", (128,), "fc")
    lay.add("fc1.w", (128, 10), "fc")
    lay.add("fc1.b", (10,), "fc")
    return lay


def cnn_init(key) -> jnp.ndarray:
    lay = cnn_layout()
    parts = []
    for e in lay.entries:
        key, sub = jax.random.split(key)
        if e.name.endswith(".w"):
            if len(e.shape) == 4:
                fan_in = e.shape[0] * e.shape[1] * e.shape[2]
            else:
                fan_in = e.shape[0]
            parts.append(jax.random.normal(sub, e.shape) * jnp.sqrt(2.0 / fan_in))
        else:
            parts.append(jnp.zeros(e.shape))
    return jnp.concatenate([p.reshape(-1) for p in parts]).astype(jnp.float32)


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _avg_pool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def cnn_forward(flat, x):
    """x: f32[B, 784] (flattened 28x28 grayscale)."""
    p = cnn_layout().unflatten(flat)
    h = x.reshape(-1, 28, 28, 1)
    h = jax.nn.relu(_conv(h, p["conv0.w"], p["conv0.b"]))
    h = _avg_pool2(h)
    h = jax.nn.relu(_conv(h, p["conv1.w"], p["conv1.b"]))
    h = _avg_pool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc0.w"] + p["fc0.b"])
    return h @ p["fc1.w"] + p["fc1.b"]


# ---------------------------------------------------------------------------
# Shared losses / entry points
# ---------------------------------------------------------------------------


def _ce_loss(logits, y_f32):
    y = y_f32.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def make_grad_fn(forward):
    """(params, x, y) -> (loss, grads) for a classification model."""

    def loss_fn(flat, x, y):
        return _ce_loss(forward(flat, x), y)

    def grad_entry(flat, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
        return loss, grads

    return grad_entry


def make_eval_fn(forward):
    """(params, x, y) -> (loss_sum, correct_count) for a classification model."""

    def eval_entry(flat, x, y):
        logits = forward(flat, x)
        yi = y.astype(jnp.int32)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, yi[:, None], axis=1)[:, 0]
        pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
        correct = jnp.sum((pred == yi).astype(jnp.float32))
        return jnp.sum(nll), correct

    return eval_entry


MODELS = {
    "mlp": dict(layout=mlp_layout, init=mlp_init, forward=mlp_forward, input_dim=784),
    "cnn": dict(layout=cnn_layout, init=cnn_init, forward=cnn_forward, input_dim=784),
}
