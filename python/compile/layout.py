"""Flat-parameter ABI shared by every exported model.

The rust coordinator owns optimizer state and quantization, so every AOT
entry point exchanges parameters as ONE flat f32 vector.  `ParamLayout`
records the (name, shape, group) of each tensor; offsets are static, so
unflattening inside the jitted function lowers to zero-copy slices.

`group` is the quantization group ("conv" / "fc" / "emb" ...): the paper
(Sec. V) quantizes convolutional and fully-connected gradients independently
because their distributions differ; the rust side reads the group ranges from
manifest.json and runs one quantizer state per (client, group).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass
class ParamEntry:
    name: str
    shape: tuple
    group: str
    offset: int = 0

    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1


@dataclass
class ParamLayout:
    entries: list = field(default_factory=list)

    def add(self, name: str, shape: tuple, group: str) -> None:
        e = ParamEntry(name, tuple(int(x) for x in shape), group)
        e.offset = self.total
        self.entries.append(e)

    @property
    def total(self) -> int:
        if not self.entries:
            return 0
        last = self.entries[-1]
        return last.offset + last.size

    def unflatten(self, flat):
        """Slice the flat vector into a {name: tensor} dict (static offsets)."""
        out = {}
        for e in self.entries:
            out[e.name] = flat[e.offset : e.offset + e.size].reshape(e.shape)
        return out

    def group_ranges(self):
        """Contiguous [start, end) per group, in layout order.

        Entries of the same group may interleave with other groups; the rust
        side wants contiguous runs, so we emit one (group, start, end) triple
        per maximal run.
        """
        runs = []
        for e in self.entries:
            if runs and runs[-1][0] == e.group and runs[-1][2] == e.offset:
                runs[-1][2] = e.offset + e.size
            else:
                runs.append([e.group, e.offset, e.offset + e.size])
        return [(g, s, t) for g, s, t in runs]

    def to_manifest(self):
        return {
            "param_count": self.total,
            "groups": [
                {"group": g, "start": s, "end": t} for g, s, t in self.group_ranges()
            ],
            "entries": [
                {
                    "name": e.name,
                    "shape": list(e.shape),
                    "group": e.group,
                    "offset": e.offset,
                }
                for e in self.entries
            ],
        }
