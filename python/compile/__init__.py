"""AOT compile toolchain: JAX/Pallas models and kernels exported as HLO.

Only needed to (re)generate `artifacts/` for the rust runtime's `pjrt`
feature; the default NativeBackend trains without any of this installed.
"""
