"""Skip the AOT toolchain tests when their dependencies are absent.

CI runs `pytest python/tests -q` on a plain runner; JAX (and hypothesis) may
be uninstallable there. Missing dependencies must skip collection, not fail
it — the rust tier-1 suite does not depend on Python at all.
"""

import importlib.util
import os
import sys

# Make `compile.*` importable when running `pytest python/tests` from the
# repo root without `pip install -e python`.
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))


def _missing(mod: str) -> bool:
    return importlib.util.find_spec(mod) is None


collect_ignore = []
if _missing("jax"):
    # Everything here exercises the JAX/Pallas toolchain.
    collect_ignore = ["test_kernels.py", "test_models.py"]
elif _missing("hypothesis"):
    collect_ignore = ["test_kernels.py"]


def pytest_report_header(config):
    if collect_ignore:
        return f"tqsgd: skipping {', '.join(collect_ignore)} (missing toolchain deps)"
    return None
