"""Pallas kernels vs the pure-jnp oracle (the core L1 correctness signal)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quantize import quantize_uniform, BLOCK
from compile.kernels.nonuniform import quantize_codebook
from compile.kernels.biscaled import quantize_biscaled
from compile.kernels.stats import tail_stats


def heavy_tailed(rng, d, scale=0.01, df=3):
    """Student-t draws: heavy-tailed like real conv/fc gradients."""
    return (rng.standard_t(df, size=d) * scale).astype(np.float32)


def uniforms(rng, d):
    return rng.random(d, dtype=np.float64).astype(np.float32)


@pytest.mark.parametrize("s", [3, 7, 15, 31])
@pytest.mark.parametrize("seed", [0, 1])
def test_uniform_matches_ref(s, seed):
    rng = np.random.default_rng(seed)
    d = BLOCK * 2
    g, u = heavy_tailed(rng, d), uniforms(rng, d)
    alpha = np.float32(0.04)
    dq, ix = quantize_uniform(jnp.array(g), jnp.array(u), jnp.array([alpha]), s=s)
    rdq, rix = ref.quantize_uniform(jnp.array(g), jnp.array(u), alpha, s)
    np.testing.assert_array_equal(np.array(ix), np.array(rix))
    np.testing.assert_allclose(np.array(dq), np.array(rdq), atol=1e-7)


@pytest.mark.parametrize("seed", [0, 3])
def test_nonuniform_matches_ref(seed):
    rng = np.random.default_rng(seed)
    d = BLOCK
    g, u = heavy_tailed(rng, d), uniforms(rng, d)
    # Non-uniform codebook: cube-root-density-like spacing.
    q = np.linspace(-1.0, 1.0, 8)
    cb = (0.05 * np.sign(q) * np.abs(q) ** 1.5).astype(np.float32)
    cb = np.sort(cb)
    dq, ix = quantize_codebook(jnp.array(g), jnp.array(u), jnp.array(cb), s=7)
    rdq, rix = ref.quantize_codebook(jnp.array(g), jnp.array(u), cb)
    np.testing.assert_array_equal(np.array(ix), np.array(rix))
    np.testing.assert_allclose(np.array(dq), np.array(rdq), atol=1e-7)


@pytest.mark.parametrize("s_beta,s_alpha", [(5, 2), (3, 4), (1, 6)])
def test_biscaled_matches_ref(s_beta, s_alpha):
    rng = np.random.default_rng(s_beta * 10 + s_alpha)
    d = BLOCK
    g, u = heavy_tailed(rng, d), uniforms(rng, d)
    alpha, beta = np.float32(0.06), np.float32(0.015)
    dq, ix = quantize_biscaled(
        jnp.array(g), jnp.array(u), jnp.array([alpha, beta]),
        s_beta=s_beta, s_alpha=s_alpha,
    )
    rdq, rix = ref.quantize_biscaled(
        jnp.array(g), jnp.array(u), alpha, beta, s_beta, s_alpha
    )
    np.testing.assert_array_equal(np.array(ix), np.array(rix))
    np.testing.assert_allclose(np.array(dq), np.array(rdq), atol=1e-6)


def test_stats_matches_ref():
    rng = np.random.default_rng(5)
    d = BLOCK * 4
    g = heavy_tailed(rng, d)
    st_k = tail_stats(jnp.array(g), jnp.array([0.02], dtype=np.float32))
    st_r = ref.tail_stats(jnp.array(g), 0.02)
    np.testing.assert_allclose(np.array(st_k), np.array(st_r), rtol=1e-5)


# ---------------------------------------------------------------------------
# Statistical properties of the oracle itself (Lemma 1).
# ---------------------------------------------------------------------------


def test_unbiasedness_uniform():
    """E[Q[g]] = g (Lemma 1, Eq. 5) — Monte-Carlo over many uniforms."""
    rng = np.random.default_rng(0)
    g = np.full(200_000, 0.0123, dtype=np.float32)
    u = uniforms(rng, g.size)
    dq, _ = ref.quantize_uniform(jnp.array(g), jnp.array(u), np.float32(0.05), 7)
    assert abs(float(np.mean(np.array(dq))) - 0.0123) < 2e-4


def test_variance_bound_uniform():
    """E||Q[g]-g||^2 <= max_k |Delta_k|^2 / 4 element-wise (Lemma 1, Eq. 6)."""
    rng = np.random.default_rng(1)
    d = 100_000
    alpha, s = np.float32(0.05), 7
    g = np.clip(heavy_tailed(rng, d), -alpha, alpha)
    u = uniforms(rng, d)
    dq, _ = ref.quantize_uniform(jnp.array(g), jnp.array(u), alpha, s)
    mse = float(np.mean((np.array(dq) - g) ** 2))
    step = 2 * alpha / s
    assert mse <= step**2 / 4 + 1e-9


def test_truncation_is_clip():
    g = np.array([-1.0, -0.04, 0.0, 0.04, 1.0], dtype=np.float32)
    out = np.array(ref.truncate(jnp.array(g), 0.05))
    np.testing.assert_allclose(out, [-0.05, -0.04, 0.0, 0.04, 0.05])


@settings(max_examples=30, deadline=None)
@given(
    s=st.sampled_from([3, 7, 15]),
    alpha=st.floats(1e-3, 1.0),
    seed=st.integers(0, 2**16),
)
def test_uniform_idx_in_range_and_deq_on_codebook(s, alpha, seed):
    """Property: indices always in [0, s]; deq always a codebook point."""
    rng = np.random.default_rng(seed)
    g = heavy_tailed(rng, 4096, scale=alpha / 2)
    u = uniforms(rng, g.size)
    dq, ix = ref.quantize_uniform(jnp.array(g), jnp.array(u), np.float32(alpha), s)
    ix, dq = np.array(ix), np.array(dq)
    assert ix.min() >= 0 and ix.max() <= s
    cb = np.array(ref.uniform_codebook(np.float32(alpha), s))
    np.testing.assert_allclose(dq, cb[ix], atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([3, 7, 15, 31]))
def test_codebook_rounding_neighbours(seed, s):
    """Property: Q[g] is one of the two codebook points bracketing g."""
    rng = np.random.default_rng(seed)
    cb = np.sort(rng.normal(size=s + 1)).astype(np.float32)
    cb += np.arange(s + 1, dtype=np.float32) * 1e-3  # ensure strictly increasing
    g = rng.uniform(cb[0], cb[-1], size=2048).astype(np.float32)
    u = uniforms(rng, g.size)
    dq, ix = ref.quantize_codebook(jnp.array(g), jnp.array(u), cb)
    dq, ix = np.array(dq), np.array(ix)
    k = np.searchsorted(cb, g, side="right") - 1
    k = np.clip(k, 0, s - 1)
    ok = (np.abs(dq - cb[k]) < 1e-6) | (np.abs(dq - cb[k + 1]) < 1e-6)
    assert ok.all()
