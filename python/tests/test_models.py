"""L2 model sanity: shapes, loss decrease, gradient correctness, layout."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import transformer as T
from compile.layout import ParamLayout


def test_mlp_param_count():
    lay = M.mlp_layout()
    expect = 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10
    assert lay.total == expect


def test_cnn_param_count():
    lay = M.cnn_layout()
    expect = (5 * 5 * 1 * 8 + 8) + (5 * 5 * 8 * 16 + 16) + (256 * 128 + 128) + (
        128 * 10 + 10
    )
    assert lay.total == expect


def test_cnn_groups_are_conv_then_fc():
    runs = M.cnn_layout().group_ranges()
    assert [r[0] for r in runs] == ["conv", "fc"]
    assert runs[0][1] == 0 and runs[1][2] == M.cnn_layout().total


def test_layout_unflatten_roundtrip():
    lay = ParamLayout()
    lay.add("a", (2, 3), "x")
    lay.add("b", (4,), "y")
    flat = jnp.arange(10.0)
    p = lay.unflatten(flat)
    assert p["a"].shape == (2, 3)
    np.testing.assert_allclose(np.array(p["b"]), [6, 7, 8, 9])


@pytest.mark.parametrize("name", ["mlp", "cnn"])
def test_grad_entry_shapes(name):
    m = M.MODELS[name]
    P = m["layout"]().total
    params = m["init"](jax.random.PRNGKey(0))
    assert params.shape == (P,)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 784))
    y = jnp.array(np.arange(8) % 10, dtype=jnp.float32)
    loss, grads = M.make_grad_fn(m["forward"])(params, x, y)
    assert loss.shape == () and grads.shape == (P,)
    assert np.isfinite(float(loss)) and np.isfinite(np.array(grads)).all()


@pytest.mark.parametrize("name", ["mlp", "cnn"])
def test_sgd_reduces_loss(name):
    m = M.MODELS[name]
    params = m["init"](jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(32, 784)), dtype=jnp.float32)
    y = jnp.array(rng.integers(0, 10, 32), dtype=jnp.float32)
    grad_fn = jax.jit(M.make_grad_fn(m["forward"]))
    loss0, _ = grad_fn(params, x, y)
    for _ in range(30):
        loss, g = grad_fn(params, x, y)
        params = params - 0.05 * g
    assert float(loss) < float(loss0)


def test_eval_entry_counts():
    m = M.MODELS["mlp"]
    params = m["init"](jax.random.PRNGKey(0))
    x = jnp.zeros((16, 784))
    y = jnp.zeros((16,))
    loss_sum, correct = M.make_eval_fn(m["forward"])(params, x, y)
    assert 0.0 <= float(correct) <= 16.0
    assert float(loss_sum) > 0


def test_grad_matches_finite_difference():
    """Spot-check the value_and_grad entry against central differences."""
    m = M.MODELS["mlp"]
    params = m["init"](jax.random.PRNGKey(3))
    rng = np.random.default_rng(1)
    x = jnp.array(rng.normal(size=(4, 784)), dtype=jnp.float32)
    y = jnp.array(rng.integers(0, 10, 4), dtype=jnp.float32)
    grad_fn = M.make_grad_fn(m["forward"])
    _, g = grad_fn(params, x, y)

    def loss_at(p):
        l, _ = grad_fn(p, x, y)
        return float(l)

    eps = 1e-3
    idxs = rng.integers(0, params.shape[0], 5)
    for i in idxs:
        e = np.zeros(params.shape[0], dtype=np.float32)
        e[i] = eps
        fd = (loss_at(params + e) - loss_at(params - e)) / (2 * eps)
        assert abs(fd - float(g[i])) < 5e-2, (i, fd, float(g[i]))


# ---------------------------------------------------------------------------
# Transformer
# ---------------------------------------------------------------------------


def test_tfm_small_param_count_about_1m():
    cfg = T.PRESETS["tfm_small"]
    P = T.tfm_layout(cfg).total
    assert 5e5 < P < 2e6


def test_tfm_grad_shapes_and_finite():
    cfg = T.TfmConfig(d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=2)
    P = T.tfm_layout(cfg).total
    params = T.tfm_init(jax.random.PRNGKey(0), cfg)
    assert params.shape == (P,)
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, cfg.vocab, (2, 17)), dtype=jnp.float32)
    loss, grads = T.make_tfm_grad_fn(cfg)(params, toks)
    assert grads.shape == (P,)
    assert np.isfinite(float(loss)) and np.isfinite(np.array(grads)).all()


def test_tfm_causality():
    """Changing a future token must not change earlier logits."""
    cfg = T.TfmConfig(d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=8, batch=1)
    params = T.tfm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab, (1, 8)).astype(np.float32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % cfg.vocab
    l1 = T.tfm_forward(params, jnp.array(t1), cfg)
    l2 = T.tfm_forward(params, jnp.array(t2), cfg)
    np.testing.assert_allclose(np.array(l1)[0, :-1], np.array(l2)[0, :-1], atol=1e-5)


def test_tfm_loss_decreases():
    cfg = T.TfmConfig(d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=16, batch=4)
    params = T.tfm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, 16, (4, 17)), dtype=jnp.float32)
    grad_fn = jax.jit(T.make_tfm_grad_fn(cfg))
    loss0, _ = grad_fn(params, toks)
    for _ in range(20):
        loss, g = grad_fn(params, toks)
        params = params - 0.5 * g
    assert float(loss) < float(loss0)
