//! Offline vendored subset of the [`anyhow`](https://docs.rs/anyhow) API.
//!
//! The build image resolves dependencies without network access, so the
//! workspace vendors the small part of `anyhow` the codebase actually uses:
//!
//! * [`Error`] — an opaque, context-carrying error value,
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default param,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Semantics match the real crate where it matters for this codebase:
//! any `E: std::error::Error + Send + Sync + 'static` converts into
//! [`Error`] via `?`, `Error` itself deliberately does NOT implement
//! `std::error::Error` (that is what makes the blanket `From` impl legal),
//! and `Debug` renders the full cause chain so `fn main() -> Result<()>`
//! failures print usefully.

#![deny(unsafe_code)]

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause, if this error wraps a std error.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

// The blanket conversion that powers `?`. `Error` itself must not implement
// `std::error::Error`, otherwise this would overlap the reflexive
// `impl From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`), mirroring `anyhow::Context`.
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        match self {
            Ok(t) => Ok(t),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(t) => Ok(t),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        match self {
            Some(t) => Ok(t),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(t) => Ok(t),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "reading config: missing");
        let e2 = Error::msg("inner").context("outer");
        assert_eq!(e2.to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(none.context("empty").is_err());
        assert_eq!(Some(3u32).context("empty").unwrap(), 3);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert!(f(5).unwrap_err().to_string().contains("five"));
        assert!(f(11).unwrap_err().to_string().contains("11"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn debug_renders_chain() {
        let e: Error = Error::from(io_err()).context("opening artifacts");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("opening artifacts"));
        assert!(dbg.contains("Caused by"));
    }
}
