//! End-to-end driver: distributed training of a decoder-only transformer LM
//! (~0.9M params, byte-level Markov corpus) with TNQSGD b=4 against the
//! DSGD oracle, proving all three layers compose on a real workload:
//!
//!   L2 AOT transformer fwd/bwd (HLO via PJRT) →
//!   L3 per-group quantization (emb / fc codebooks, wire frames) →
//!   server aggregation + momentum SGD, loss curve logged.
//!
//! The loss should fall from ~ln(64) ≈ 4.16 toward the corpus entropy rate;
//! TNQSGD at 4 bits should track DSGD closely at 8x fewer uplink bytes.
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example e2e_transformer [-- --rounds 300]
//! ```

use anyhow::Result;
use tqsgd::benchkit::Table;
use tqsgd::cli::Args;
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::data::MarkovCorpus;
use tqsgd::train::Sweep;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = ExperimentConfig::preset("e2e_transformer")?;
    cfg.apply_args(&args)?;

    let corpus = MarkovCorpus::new(64, cfg.seed);
    let floor = corpus.entropy_rate();
    println!(
        "corpus: 64-symbol Markov chain, entropy rate {:.4} nats/token (uniform = {:.4})",
        floor,
        (64f64).ln()
    );

    // apply_args may have set --backend; honor it rather than auto-selecting.
    let sweep = Sweep::with_backend(tqsgd::runtime::make_backend(&cfg)?);

    println!("\n== TNQSGD b={} ==", cfg.quant.bits);
    let tnq = sweep.run(cfg.clone(), true)?;

    println!("\n== DSGD oracle ==");
    let mut dc = cfg.clone();
    dc.quant.scheme = Scheme::Dsgd;
    let dsgd = sweep.run(dc, true)?;

    println!("\n== loss curves (test NLL, nats/token) ==");
    let mut table = Table::new(&["round", "tnqsgd", "dsgd", "entropy floor"]);
    let d_map: std::collections::BTreeMap<usize, f64> = dsgd
        .log
        .records
        .iter()
        .filter_map(|r| r.test_loss.map(|l| (r.round, l)))
        .collect();
    for r in &tnq.log.records {
        if let Some(l) = r.test_loss {
            table.row(&[
                r.round.to_string(),
                format!("{l:.4}"),
                d_map.get(&r.round).map_or("—".into(), |l| format!("{l:.4}")),
                format!("{floor:.4}"),
            ]);
        }
    }
    table.print();

    println!(
        "\nuplink: TNQSGD {:.1} MB ({:.2} bits/param/round) vs DSGD {:.1} MB ({:.2}) — {:.1}x compression",
        tnq.total_bytes_up as f64 / 1e6,
        tnq.bits_per_param,
        dsgd.total_bytes_up as f64 / 1e6,
        dsgd.bits_per_param,
        dsgd.total_bytes_up as f64 / tnq.total_bytes_up as f64,
    );
    println!(
        "final test NLL: TNQSGD {:.4} vs DSGD {:.4} (floor {:.4})",
        tnq.final_test_loss, dsgd.final_test_loss, floor
    );
    Ok(())
}
