//! Heavy-tail analysis (the Fig. 1 experiment as an application).
//!
//! Trains the CNN briefly with uncompressed updates, harvests real
//! gradients, and fits power-law / Gaussian / Laplace models per layer
//! group — printing the log-density table that shows why thin-tailed
//! assumptions break, plus the optimal quantizer parameters the fitted
//! model implies.
//!
//! ```sh
//! cargo run --release --example heavy_tail_analysis [-- --model cnn --rounds 10]
//! ```

use anyhow::Result;
use tqsgd::benchkit::Table;
use tqsgd::cli::Args;
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::runtime::make_backend;
use tqsgd::solver;
use tqsgd::tail::{fit::report_to_model, fit_gaussian, fit_laplace, fit_power_law, LogHistogram};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = ExperimentConfig::default();
    cfg.model = args.str_or("model", "cnn");
    cfg.quant.scheme = Scheme::Dsgd;
    cfg.rounds = args.usize_or("rounds", 10)?;
    cfg.train_size = 2048;
    cfg.test_size = 512;

    let backend = make_backend(&cfg)?;
    let mut coord = Coordinator::new(cfg.clone(), backend.as_ref())?;
    let spec = coord.model_spec().clone();
    println!(
        "training {} for {} uncompressed rounds on the {} backend to harvest gradients...",
        cfg.model,
        cfg.rounds,
        backend.name()
    );
    for _ in 0..cfg.rounds {
        coord.step()?;
    }
    let grads = coord.last_aggregate().to_vec();

    for group in &spec.groups {
        let xs = &grads[group.start..group.end];
        println!("\n### layer group `{}` ({} parameters)", group.group, xs.len());

        let pl = fit_power_law(xs);
        let ga = fit_gaussian(xs);
        let la = fit_laplace(xs);

        let mut fits = Table::new(&["family", "parameters", "KS"]);
        if let Some(pl) = &pl {
            fits.row(&[
                "power-law (paper)".into(),
                format!(
                    "γ̂={:.2}  ĝ_min={:.2e}  ρ̂={:.3}",
                    pl.params[0], pl.params[1], pl.params[2]
                ),
                format!("{:.4}", pl.ks),
            ]);
        }
        fits.row(&[
            "gaussian".into(),
            format!("σ={:.3e}", ga.params[1]),
            format!("{:.4}", ga.ks),
        ]);
        fits.row(&[
            "laplace".into(),
            format!("b={:.3e}", la.params[1]),
            format!("{:.4}", la.ks),
        ]);
        fits.print();

        // Fig. 1: empirical density vs fitted densities on log-spaced bins.
        let sigma = ga.params[1].max(1e-12);
        let mut hist = LogHistogram::new(sigma * 0.1, sigma * 30.0, 12);
        hist.extend(xs);
        let mut dens = Table::new(&["|g|", "empirical", "power-law", "gaussian", "laplace"]);
        for (center, d) in hist.density() {
            let p_pl = pl.as_ref().map(|r| {
                let m = report_to_model(r);
                2.0 * m.pdf(center) // density of |g| folds both signs
            });
            let p_ga = 2.0 * (-0.5 * (center / sigma).powi(2)).exp()
                / (sigma * (2.0 * std::f64::consts::PI).sqrt());
            let p_la = (-(center / la.params[1]).abs()).exp() / la.params[1];
            dens.row(&[
                format!("{center:.2e}"),
                format!("{d:.3e}"),
                p_pl.map_or("—".into(), |p| format!("{p:.3e}")),
                format!("{p_ga:.3e}"),
                format!("{p_la:.3e}"),
            ]);
        }
        dens.print();

        // What the fit implies for the quantizer design.
        if let Some(pl) = &pl {
            let mut m = report_to_model(pl);
            m.gamma = m.gamma.clamp(3.05, 5.0);
            let s = 7;
            let au = solver::optimal_alpha_uniform(&m, s);
            let an = solver::optimal_alpha_nonuniform(&m, s);
            println!(
                "implied design at b=3: TQSGD α*={au:.4e}  TNQSGD α*={an:.4e}  \
                 (max|g| = {:.4e} → truncation keeps {:.2}% of the mass)",
                xs.iter().fold(0.0f32, |acc, &x| acc.max(x.abs())),
                100.0 * m.q_u(au)
            );
        }
    }
    Ok(())
}
