//! Scenario sweep: the same experiment under every degraded-round preset.
//!
//! Runs a small MLP + TNQSGD b=3 workload through the coordinator's scenario
//! engine — clean, straggler, lossy, churn, stale and non-IID — and reports
//! what each failure mode costs in loss, wire bytes, retransmissions, drops
//! and simulated round time. Every run is seeded and bit-reproducible.
//!
//! ```sh
//! cargo run --release --example scenario_sweep
//! ```

use tqsgd::benchkit::Table;
use tqsgd::config::{ExperimentConfig, ScenarioConfig};
use tqsgd::train::Sweep;

fn main() -> anyhow::Result<()> {
    let sweep = Sweep::new("artifacts")?;
    println!("backend: {}\n", sweep.backend().name());

    let mut table =
        Table::new(&["scenario", "loss", "acc", "KB up", "retx KB", "dropped", "late", "net s"]);
    for name in ScenarioConfig::preset_names() {
        let mut cfg = ExperimentConfig::preset("quickstart")?;
        cfg.model = "mlp_tiny".into();
        cfg.rounds = 20;
        cfg.eval_every = 10;
        cfg.clients = 8;
        cfg.train_size = 1024;
        cfg.test_size = 512;
        // A finite link makes straggler/retransmit time visible.
        cfg.net.bandwidth_bytes_per_sec = 1e6;
        cfg.net.latency_sec = 0.01;
        cfg.scenario = ScenarioConfig::preset(name)?;
        let r = sweep.run(cfg, false)?;

        let recs = &r.log.records;
        let retrans: u64 = recs.iter().map(|x| x.retransmitted_bytes).sum();
        let avg_dropped: f64 =
            recs.iter().map(|x| x.dropped_clients as f64).sum::<f64>() / recs.len() as f64;
        let late: u32 = recs
            .iter()
            .flat_map(|x| x.staleness_hist.iter().enumerate())
            .filter(|(s, _)| *s > 0)
            .map(|(_, &c)| c)
            .sum();
        let net: f64 = recs.iter().map(|x| x.net_secs).sum();
        table.row(&[
            name.to_string(),
            format!("{:.4}", r.final_train_loss),
            format!("{:.4}", r.final_accuracy),
            format!("{:.1}", r.total_bytes_up as f64 / 1e3),
            format!("{:.1}", retrans as f64 / 1e3),
            format!("{avg_dropped:.2}"),
            late.to_string(),
            format!("{net:.3}"),
        ]);
    }
    table.print();
    println!(
        "\nevery column above is deterministic in (seed, scenario): rerun and diff\n\
         the table to verify — only wall-clock time is excluded."
    );
    Ok(())
}
