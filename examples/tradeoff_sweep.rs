//! Communication–learning tradeoff sweep (the Fig. 4 experiment as an
//! application): final test accuracy vs uplink bytes for every scheme at
//! b ∈ {2, 3, 4, 5}, plus the DSGD anchor.
//!
//! ```sh
//! cargo run --release --example tradeoff_sweep [-- --rounds 300 --model cnn]
//! ```

use anyhow::Result;
use tqsgd::benchkit::Table;
use tqsgd::cli::Args;
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::train::Sweep;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = ExperimentConfig::default();
    cfg.model = args.str_or("model", "cnn");
    cfg.rounds = args.usize_or("rounds", 300)?;
    cfg.eval_every = cfg.rounds; // final accuracy only
    cfg.train_size = args.usize_or("train-size", 8192)?;
    cfg.test_size = args.usize_or("test-size", 2048)?;

    let sweep = Sweep::new(&cfg.artifacts_dir)?;
    let mut table = Table::new(&["scheme", "bits", "final acc", "MB uplink", "bits/param/round"]);

    // Oracle anchor.
    let mut dc = cfg.clone();
    dc.quant.scheme = Scheme::Dsgd;
    let d = sweep.run(dc, false)?;
    table.row(&[
        "dsgd".into(),
        "32".into(),
        format!("{:.4}", d.final_accuracy),
        format!("{:.1}", d.total_bytes_up as f64 / 1e6),
        format!("{:.2}", d.bits_per_param),
    ]);

    for scheme in [Scheme::Qsgd, Scheme::Nqsgd, Scheme::Tqsgd, Scheme::Tnqsgd, Scheme::Tbqsgd] {
        for bits in [2u32, 3, 4, 5] {
            let mut c = cfg.clone();
            c.quant.scheme = scheme;
            c.quant.bits = bits;
            let r = sweep.run(c, false)?;
            table.row(&[
                scheme.name().into(),
                bits.to_string(),
                format!("{:.4}", r.final_accuracy),
                format!("{:.1}", r.total_bytes_up as f64 / 1e6),
                format!("{:.2}", r.bits_per_param),
            ]);
            eprintln!("done {} b={}", scheme.name(), bits);
        }
    }
    table.print();
    Ok(())
}
