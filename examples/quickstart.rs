//! Quickstart: train a small MLP with the paper's TNQSGD quantizer at
//! b = 3 bits and compare the bytes-on-wire against the 32-bit oracle.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::train::Sweep;

fn main() -> anyhow::Result<()> {
    // One runtime, two experiments (artifacts compile once).
    let sweep = Sweep::new("artifacts")?;

    let mut cfg = ExperimentConfig::preset("quickstart")?;
    cfg.rounds = 150;
    cfg.eval_every = 25;
    cfg.train_size = 4096;
    cfg.test_size = 1024;

    println!("== TNQSGD b=3 (the paper's truncated non-uniform quantizer) ==");
    let tnq = sweep.run(cfg.clone(), true)?;

    println!("\n== DSGD oracle (uncompressed fp32) ==");
    cfg.quant.scheme = Scheme::Dsgd;
    let dsgd = sweep.run(cfg, true)?;

    println!("\n== summary ==");
    println!(
        "TNQSGD b=3: acc {:.4}, {:.1} MB uplink ({:.2} bits/param/round)",
        tnq.final_accuracy,
        tnq.total_bytes_up as f64 / 1e6,
        tnq.bits_per_param
    );
    println!(
        "DSGD fp32 : acc {:.4}, {:.1} MB uplink ({:.2} bits/param/round)",
        dsgd.final_accuracy,
        dsgd.total_bytes_up as f64 / 1e6,
        dsgd.bits_per_param
    );
    println!(
        "compression: {:.1}x fewer uplink bytes, {:.1}% accuracy gap",
        dsgd.total_bytes_up as f64 / tnq.total_bytes_up as f64,
        (dsgd.final_accuracy - tnq.final_accuracy) * 100.0
    );
    Ok(())
}
