//! Property-test hardening of the quantization stack (via `prop::check`),
//! plus golden wire-format fixtures and the error-feedback conservation
//! invariant.
//!
//! These guarantees exist so that scenario-engine failures point at the
//! scenario, not at a quantizer bug: the codecs are unbiased in expectation
//! and bounded-error per element, bit-packing is exact at every width the
//! wire format can carry, and the frame bytes themselves are pinned against
//! committed fixtures so refactors cannot silently break on-the-wire
//! compatibility.

use tqsgd::config::{QuantConfig, Scheme};
use tqsgd::coordinator::aggregate::{
    accumulate_serial, accumulate_sharded, ContributionData, WeightedContribution,
};
use tqsgd::prop;
use tqsgd::quant::bitpack;
use tqsgd::quant::error_feedback::ErrorFeedback;
use tqsgd::quant::kernels::{quantize_codebook_elem, quantize_uniform_elem};
use tqsgd::quant::make_compressor;
use tqsgd::quant::wire::{self, Payload};
use tqsgd::runtime::GroupRange;
use tqsgd::solver;
use tqsgd::tail::PowerLawModel;
use tqsgd::util::Rng;

// ---------------------------------------------------------------------------
// Codec round-trip: unbiased in expectation, bounded error per element
// ---------------------------------------------------------------------------

/// TQSGD's uniform quantizer over random (alpha, s, group size): averaging
/// many independent round-trips recovers the in-range gradient (unbiased),
/// and every single round-trip lands within one step of the truncated value
/// (bounded error).
#[test]
fn tqsgd_roundtrip_unbiased_and_bounded_error() {
    prop::check(25, |rng| {
        let bits = 2 + rng.below(4) as u32; // 2..=5
        let s = solver::levels_for_bits(bits) as u32;
        let alpha = (0.02 + rng.f64() * 0.98) as f32;
        let n = 8 + rng.below(120) as usize; // random group size
        let scale = alpha as f64;
        let step = 2.0 * scale / s as f64;
        // Mix of in-range and out-of-range (truncated) elements.
        let g: Vec<f32> = (0..n).map(|_| ((rng.f64() * 3.0 - 1.5) * scale) as f32).collect();
        let reps = 300u64;
        let mut mean = vec![0.0f64; n];
        for r in 0..reps {
            let mut rr = Rng::for_stream(0xABCD, 1, r, 0);
            for (i, (&gi, m)) in g.iter().zip(mean.iter_mut()).enumerate() {
                let idx = quantize_uniform_elem(gi, rr.f32(), alpha, s);
                if idx > s {
                    return Err(format!("index {idx} > s={s} at elem {i}"));
                }
                let deq = (-alpha + idx as f32 * (2.0 * alpha / s as f32)) as f64;
                // Bounded error per element vs the truncated gradient.
                let trunc = gi.clamp(-alpha, alpha) as f64;
                if (deq - trunc).abs() > step + 1e-6 {
                    return Err(format!(
                        "elem {i}: |{deq} - {trunc}| > step {step} (alpha={alpha}, s={s})"
                    ));
                }
                *m += deq;
            }
        }
        // Unbiasedness (for the truncated value; truncation itself is the
        // paper's analysed bias, not the quantizer's).
        let tol = 4.0 * step / (reps as f64).sqrt();
        for (i, (&gi, &m)) in g.iter().zip(&mean).enumerate() {
            let trunc = gi.clamp(-alpha, alpha) as f64;
            let err = (m / reps as f64 - trunc).abs();
            if err > tol {
                return Err(format!("elem {i}: bias {err} > tol {tol}"));
            }
        }
        Ok(())
    });
}

/// TNQSGD's codebook quantizer over random tail models (gamma, g_min, rho):
/// same two guarantees, with the per-element bound being the widest adjacent
/// codebook gap.
#[test]
fn tnqsgd_roundtrip_unbiased_and_bounded_error() {
    prop::check(25, |rng| {
        let bits = 2 + rng.below(3) as u32; // 2..=4
        let s = solver::levels_for_bits(bits);
        let gamma = 3.1 + rng.f64() * 1.8; // admissible (3, 5]
        let g_min = 0.005 + rng.f64() * 0.02;
        let rho = 0.05 + rng.f64() * 0.3;
        let model = PowerLawModel::new(gamma, g_min, rho);
        let alpha = solver::optimal_alpha_nonuniform(&model, s);
        let cb = solver::nonuniform_codebook(&model, alpha, s);
        if cb.len() != s + 1 {
            return Err(format!("codebook len {} != s+1={}", cb.len(), s + 1));
        }
        let lo = cb[0] as f64;
        let hi = cb[s] as f64;
        let max_gap = cb.windows(2).map(|w| (w[1] - w[0]) as f64).fold(0.0f64, f64::max);
        let n = 8 + rng.below(64) as usize;
        let draw = |rng: &mut Rng| rng.power_law_gradient(g_min, gamma, rho) as f32;
        let g: Vec<f32> = (0..n).map(|_| draw(rng)).collect();
        let reps = 300u64;
        let mut mean = vec![0.0f64; n];
        for r in 0..reps {
            let mut rr = Rng::for_stream(0xBEEF, 2, r, 0);
            for (i, (&gi, m)) in g.iter().zip(mean.iter_mut()).enumerate() {
                let idx = quantize_codebook_elem(gi, rr.f32(), &cb) as usize;
                if idx > s {
                    return Err(format!("index {idx} out of codebook at elem {i}"));
                }
                let deq = cb[idx] as f64;
                let trunc = (gi as f64).clamp(lo, hi);
                if (deq - trunc).abs() > max_gap + 1e-6 {
                    return Err(format!("elem {i}: |{deq} - {trunc}| > max gap {max_gap}"));
                }
                *m += deq;
            }
        }
        let tol = 4.0 * max_gap / (reps as f64).sqrt();
        for (i, (&gi, &m)) in g.iter().zip(&mean).enumerate() {
            let trunc = (gi as f64).clamp(lo, hi);
            let err = (m / reps as f64 - trunc).abs();
            if err > tol {
                return Err(format!("elem {i}: bias {err} > tol {tol}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Bitpack: exact round-trip at every width the wire can carry
// ---------------------------------------------------------------------------

#[test]
fn bitpack_roundtrip_exact_for_widths_1_to_16() {
    for bits in 1..=16u32 {
        prop::check(25, |rng| {
            let n = rng.below(1500) as usize;
            let max = 1u64 << bits;
            let vals: Vec<u32> = (0..n).map(|_| rng.below(max) as u32).collect();
            let packed = bitpack::pack(&vals, bits);
            if packed.len() != bitpack::packed_len(n, bits) {
                return Err(format!("bits={bits}: packed size off"));
            }
            prop::assert_prop(
                bitpack::unpack(&packed, bits, n) == vals,
                format!("bits={bits}: pack→unpack not exact"),
            )
        });
    }
}

// ---------------------------------------------------------------------------
// Golden wire-format fixtures: the exact bytes are a compatibility contract
// ---------------------------------------------------------------------------

#[test]
fn golden_raw_frame_bytes() {
    let p = Payload::Raw(vec![1.0, -2.0]);
    let want: Vec<u8> = vec![
        0x54, 0x51, // magic "TQ"
        0x00, // kind: raw
        0x00, // bits
        0x02, 0x00, 0x00, 0x00, // d = 2
        0x00, 0x00, 0x80, 0x3F, // 1.0f32
        0x00, 0x00, 0x00, 0xC0, // -2.0f32
    ];
    assert_eq!(p.encode(0), want);
    assert_eq!(Payload::decode(&want).unwrap(), p);
}

#[test]
fn golden_uniform_frame_bytes() {
    let p = Payload::Uniform { alpha: 1.0, s: 7, idx: vec![0, 3, 7, 5] };
    let want: Vec<u8> = vec![
        0x54, 0x51, // magic
        0x01, // kind: uniform
        0x03, // 3 bits per index
        0x04, 0x00, 0x00, 0x00, // d = 4
        0x00, 0x00, 0x80, 0x3F, // alpha = 1.0
        0x07, 0x00, // s = 7
        0xD8, 0x0B, // indices 0,3,7,5 packed LSB-first
    ];
    assert_eq!(p.encode(3), want);
    assert_eq!(Payload::decode(&want).unwrap(), p);
}

#[test]
fn golden_codebook_frame_bytes() {
    let p = Payload::Codebook { levels: vec![-0.5, 0.0, 0.5], idx: vec![2, 0, 1] };
    let want: Vec<u8> = vec![
        0x54, 0x51, // magic
        0x02, // kind: codebook
        0x02, // 2 bits per index
        0x03, 0x00, 0x00, 0x00, // d = 3
        0x03, 0x00, // 3 levels
        0x00, 0x00, 0x00, 0xBF, // -0.5f32
        0x00, 0x00, 0x00, 0x00, // 0.0f32
        0x00, 0x00, 0x00, 0x3F, // 0.5f32
        0x12, // indices 2,0,1 packed LSB-first
    ];
    assert_eq!(p.encode(2), want);
    assert_eq!(Payload::decode(&want).unwrap(), p);
}

#[test]
fn golden_sparse_frame_bytes() {
    let p = Payload::Sparse { d: 6, pairs: vec![(1, 1.5), (4, -0.25)] };
    let want: Vec<u8> = vec![
        0x54, 0x51, // magic
        0x03, // kind: sparse
        0x00, // bits
        0x06, 0x00, 0x00, 0x00, // d = 6
        0x02, 0x00, 0x00, 0x00, // k = 2
        0x01, 0x00, 0x00, 0x00, // index 1
        0x04, 0x00, 0x00, 0x00, // index 4
        0x00, 0x00, 0xC0, 0x3F, // 1.5f32
        0x00, 0x00, 0x80, 0xBE, // -0.25f32
    ];
    assert_eq!(p.encode(0), want);
    assert_eq!(Payload::decode(&want).unwrap(), p);
    // The kind byte sits at header offset 2 — the streaming pipeline peeks
    // it to keep sparse frames on the fused scatter path.
    assert_eq!(wire::frame_kind(&want), Some(wire::KIND_SPARSE));
}

#[test]
fn golden_multiscale_frame_bytes() {
    let p = Payload::Multiscale { alpha: 1.0, beta: 0.25, s_hi: 2, s_lo: 2, idx: vec![0, 4, 2] };
    let want: Vec<u8> = vec![
        0x54, 0x51, // magic
        0x04, // kind: multiscale
        0x03, // 3 bits per index
        0x03, 0x00, 0x00, 0x00, // d = 3
        0x00, 0x00, 0x80, 0x3F, // alpha = 1.0
        0x00, 0x00, 0x80, 0x3E, // beta = 0.25
        0x02, 0x00, // s_hi = 2
        0x02, 0x00, // s_lo = 2
        0xA0, 0x00, // indices 0,4,2 packed LSB-first
    ];
    assert_eq!(p.encode(3), want);
    assert_eq!(Payload::decode(&want).unwrap(), p);
    // Merged two-scale codebook {-1, -0.25, 0, 0.25, 1}: idx 0 → -1, 4 → 1,
    // 2 → 0 — the same fixture PROTOCOL.md §4.5 walks through.
    assert_eq!(Payload::decode(&want).unwrap().dequantize(), vec![-1.0, 1.0, 0.0]);
}

#[test]
fn frame_kind_peeks_the_header() {
    let uniform = Payload::Uniform { alpha: 1.0, s: 3, idx: vec![0, 1] }.encode(2);
    assert_eq!(wire::frame_kind(&uniform), Some(1));
    assert_ne!(wire::frame_kind(&uniform), Some(wire::KIND_SPARSE));
    assert_eq!(wire::frame_kind(&[0x54]), None, "short frames have no kind");
}

// ---------------------------------------------------------------------------
// Server aggregation: sharded == serial, bit for bit, for every scheme ×
// bit width × shard count — the determinism contract behind the parallel
// stage-4 server path (disjoint layer-group shards, fixed client order)
// ---------------------------------------------------------------------------

/// Serial vs sharded aggregation over real codec frames, including
/// stale-decayed weights. The reference is the pre-sharding two-pass loop
/// (decode into a dense scratch, then weighted accumulate) written out
/// verbatim, so this pins BOTH the fused decode-accumulate kernel and the
/// shard fan-out to the historical server bits.
#[test]
fn sharded_aggregation_is_bit_identical_to_serial() {
    prop::check(5, |rng| {
        // Random layer-group geometry: 2-4 groups, uneven sizes.
        let n_groups = 2 + rng.below(3) as usize;
        let groups: Vec<GroupRange> = {
            let mut start = 0usize;
            (0..n_groups)
                .map(|i| {
                    let len = 120 + rng.below(500) as usize;
                    let g = GroupRange { group: format!("g{i}"), start, end: start + len };
                    start = g.end;
                    g
                })
                .collect()
        };
        let d_total = groups.last().unwrap().end;
        let n_clients = 3 + rng.below(3) as usize;
        // Client weights with stale decay on the tail clients, normalized —
        // exactly the coordinator's w_i = weight_i * decay^s / Σw shape.
        let raw: Vec<f64> = (0..n_clients)
            .map(|ci| {
                let staleness = if ci >= n_clients - 2 { (ci % 3) as i32 } else { 0 };
                (0.5 + rng.f64()) * 0.5f64.powi(staleness)
            })
            .collect();
        let w_total: f64 = raw.iter().sum();
        let ws: Vec<f32> = raw.iter().map(|w| (w / w_total) as f32).collect();

        for scheme in Scheme::all() {
            for bits in 1..=8u32 {
                if scheme == Scheme::Tbqsgd && bits < 2 {
                    continue; // BiScaled needs s >= 3 intervals
                }
                // Per-client frames: every (client, group) its own codec
                // state and RNG stream, like the real federation.
                let frames: Vec<Vec<(usize, Vec<u8>)>> = (0..n_clients)
                    .map(|ci| {
                        groups
                            .iter()
                            .enumerate()
                            .map(|(gi, g)| {
                                // Exactly group-sized heavy-tailed draws —
                                // frame length must equal the group range.
                                let grads: Vec<f32> = (0..g.end - g.start)
                                    .map(|_| (rng.student_t(3.0) * 0.01) as f32)
                                    .collect();
                                let mut c = make_compressor(&QuantConfig {
                                    scheme,
                                    bits,
                                    ..Default::default()
                                });
                                c.refit(&grads);
                                let mut r = Rng::new(0xA6 + ci as u64 * 977 + gi as u64);
                                (gi, c.compress(&grads, &mut r))
                            })
                            .collect()
                    })
                    .collect();
                let items: Vec<WeightedContribution<'_>> = frames
                    .iter()
                    .zip(&ws)
                    .map(|(f, &w)| WeightedContribution {
                        data: ContributionData::Frames(f.as_slice()),
                        w,
                    })
                    .collect();

                // Historical reference: two-pass scratch loop.
                let mut want = vec![0.0f32; d_total];
                let mut scratch = Vec::new();
                for (f, &w) in frames.iter().zip(&ws) {
                    for (gi, frame) in f {
                        let g = &groups[*gi];
                        wire::decode_dequantize_into(frame, &mut scratch)
                            .map_err(|e| format!("{scheme:?} b{bits}: {e}"))?;
                        if scratch.len() != g.end - g.start {
                            return Err(format!("{scheme:?} b{bits}: bad frame length"));
                        }
                        for (a, &d) in want[g.start..g.end].iter_mut().zip(&scratch) {
                            *a += w * d;
                        }
                    }
                }

                let mut fused = vec![0.5f32; d_total]; // dirty on purpose
                accumulate_serial(&groups, &items, &mut fused)
                    .map_err(|e| format!("{scheme:?} b{bits} serial: {e}"))?;
                if !bits_eq(&fused, &want) {
                    return Err(format!(
                        "{scheme:?} b{bits}: fused serial != two-pass reference"
                    ));
                }
                for shards in [1usize, 2, 7] {
                    let mut agg = vec![-1.0f32; d_total]; // dirty on purpose
                    accumulate_sharded(&groups, &items, &mut agg, shards)
                        .map_err(|e| format!("{scheme:?} b{bits} x{shards}: {e}"))?;
                    if !bits_eq(&agg, &want) {
                        return Err(format!(
                            "{scheme:?} b{bits}: {shards}-shard aggregate != serial bits"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().map(|x| x.to_bits()).eq(b.iter().map(|x| x.to_bits()))
}

// ---------------------------------------------------------------------------
// SIMD dispatch: the detected kernel table is bit-identical to scalar
// ---------------------------------------------------------------------------

/// The dispatch contract of `quant::simd` (invariant 8 in
/// docs/DETERMINISM.md): at every bit width the wire can carry (1..=16) and
/// ragged lengths around the SIMD block size — including lengths below one
/// block, NaN and signed-zero inputs — the detected table produces
/// byte-identical packed streams off identical RNG draws, bit-identical
/// accumulates (including the partial-write + `Err(first_bad_index)` path
/// for corrupt frames) and bit-identical `max_abs`.
#[test]
fn simd_matches_scalar() {
    let sc = tqsgd::quant::simd::scalar_kernels();
    let dt = tqsgd::quant::simd::detected_kernels();
    for bits in 1..=16u32 {
        prop::check(6, |rng| {
            // Length buckets: below one 8-lane block / one block + ragged
            // tail / a few hundred elements.
            let n = match rng.below(3) {
                0 => rng.below(8) as usize,
                1 => 8 + rng.below(9) as usize,
                _ => rng.below(400) as usize,
            };
            let mut g: Vec<f32> =
                (0..n).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
            if n > 0 {
                g[rng.below(n as u64) as usize] = -0.0;
                if rng.below(2) == 1 {
                    g[rng.below(n as u64) as usize] = f32::NAN;
                }
            }
            let alpha = (0.01 + rng.f64() * 0.2) as f32;
            let s = (1u32 << bits) - 1;
            let seed = rng.below(1u64 << 32);

            // Uniform quantize+pack: same RNG stream, same appended bytes
            // (non-empty prefix pins the append-to-frame semantics).
            let (mut a, mut b) = (vec![0x5Au8], vec![0x5Au8]);
            let (mut r1, mut r2) = (Rng::new(seed), Rng::new(seed));
            (sc.quantize_uniform_pack_into)(&g, &mut r1, alpha, s, bits, &mut a);
            (dt.quantize_uniform_pack_into)(&g, &mut r2, alpha, s, bits, &mut b);
            prop::assert_prop(
                a == b,
                format!("uniform b{bits} n{n}: dispatched bytes != scalar"),
            )?;

            // Codebook quantize+pack: small codebooks take the SIMD lane
            // path, > 32 interior levels the delegation path — both must
            // match scalar.
            let max_len = (1usize << bits).min(40);
            let cb_len = 2 + rng.below((max_len - 1) as u64) as usize;
            let mut cb: Vec<f32> =
                (0..cb_len).map(|_| (rng.f64() * 0.4 - 0.2) as f32).collect();
            cb.sort_by(f32::total_cmp);
            for i in 1..cb.len() {
                if cb[i] <= cb[i - 1] {
                    cb[i] = cb[i - 1] + 1e-3;
                }
            }
            let (mut a, mut b) = (Vec::new(), Vec::new());
            let (mut r1, mut r2) = (Rng::new(seed ^ 1), Rng::new(seed ^ 1));
            (sc.quantize_codebook_pack_into)(&g, &mut r1, &cb, bits, &mut a);
            (dt.quantize_codebook_pack_into)(&g, &mut r2, &cb, bits, &mut b);
            prop::assert_prop(
                a == b,
                format!("codebook b{bits} len{cb_len} n{n}: dispatched bytes != scalar"),
            )?;

            // Accumulate (bits 1..=8: one LUT byte per index): bit-identical
            // sums into a dirty accumulator, and identical partial-write +
            // Err on an injected out-of-range index.
            if bits <= 8 {
                let n_levels = cb.len();
                let mut wlut = [0.0f32; 256];
                for (w, &c) in wlut.iter_mut().zip(&cb) {
                    *w = 0.3 * c;
                }
                let mut idx: Vec<u32> =
                    (0..n).map(|_| rng.below(n_levels as u64) as u32).collect();
                let packed = bitpack::pack(&idx, bits);
                let mut acc_a: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
                let mut acc_b = acc_a.clone();
                let ra = (sc.accumulate_packed_wlut)(&packed, bits, n_levels, &wlut, &mut acc_a);
                let rb = (dt.accumulate_packed_wlut)(&packed, bits, n_levels, &wlut, &mut acc_b);
                prop::assert_prop(
                    ra == rb && bits_eq(&acc_a, &acc_b),
                    format!("accumulate b{bits} n{n}: dispatched != scalar"),
                )?;
                if n > 0 && n_levels < (1usize << bits) {
                    idx[rng.below(n as u64) as usize] = (1u32 << bits) - 1;
                    let packed = bitpack::pack(&idx, bits);
                    let mut acc_a: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
                    let mut acc_b = acc_a.clone();
                    let ra =
                        (sc.accumulate_packed_wlut)(&packed, bits, n_levels, &wlut, &mut acc_a);
                    let rb =
                        (dt.accumulate_packed_wlut)(&packed, bits, n_levels, &wlut, &mut acc_b);
                    prop::assert_prop(
                        ra.is_err() && ra == rb && bits_eq(&acc_a, &acc_b),
                        format!(
                            "accumulate b{bits} n{n}: corrupt-frame Err/partial-write \
                             dispatched != scalar"
                        ),
                    )?;
                }
            }

            // max_abs: bit-identical (covers NaN skip and -0.0 → +0.0).
            prop::assert_prop(
                (sc.max_abs)(&g).to_bits() == (dt.max_abs)(&g).to_bits(),
                format!("max_abs n{n}: dispatched != scalar"),
            )
        });
    }
}

// ---------------------------------------------------------------------------
// Error feedback: (transmitted + residual) conserves the true gradient
// ---------------------------------------------------------------------------

/// Heavy-tailed gradient draw shared by the EF test.
fn heavy(rng: &mut Rng) -> f32 {
    rng.power_law_gradient(0.01, 4.0, 0.2) as f32
}

#[test]
fn error_feedback_conserves_gradient_mass_over_50_rounds() {
    let mut rng = Rng::new(0x5EED);
    let mut ef = ErrorFeedback::new(make_compressor(&QuantConfig {
        scheme: Scheme::Tqsgd,
        bits: 3,
        ..Default::default()
    }));
    let fit: Vec<f32> = (0..30_000).map(|_| heavy(&mut rng)).collect();
    ef.refit(&fit);

    let d = 512usize;
    let mut sum_g = vec![0.0f64; d];
    let mut sum_dec = vec![0.0f64; d];
    let mut max_abs_g = 0.0f64;
    for _ in 0..50 {
        let g: Vec<f32> = (0..d).map(|_| heavy(&mut rng)).collect();
        let bytes = ef.compress_with_feedback(&g, &mut rng);
        let dec = Payload::decode(&bytes).unwrap().dequantize();
        assert_eq!(dec.len(), d);
        for i in 0..d {
            sum_g[i] += g[i] as f64;
            sum_dec[i] += dec[i] as f64;
            max_abs_g = max_abs_g.max((g[i] as f64).abs());
        }
    }
    // Invariant: residual == Σ g − Σ decoded, elementwise, to f32 rounding
    // accumulated over 50 rounds.
    let residual = ef.residual();
    assert_eq!(residual.len(), d);
    let tol = 50.0 * 1e-5 * max_abs_g.max(1.0);
    for i in 0..d {
        let want = sum_g[i] - sum_dec[i];
        let got = residual[i] as f64;
        assert!(
            (got - want).abs() <= tol,
            "elem {i}: residual {got} vs Σg−Σdec {want} (tol {tol})"
        );
    }
}
