//! Bit-budget scheduler and multi-scale codec properties (see
//! `docs/DETERMINISM.md` invariant 6 and `docs/PROTOCOL.md` §3.3/§4.5):
//!
//! 1. **Disabled is a strict no-op.** `bit_budget = 0` on a preset without
//!    uplink caps constructs no scheduler, and an *unconstrained* budget
//!    (no bound ever binds) is bit-identical to the disabled path — the
//!    budget analogue of cohort invariant 5's K = N degeneracy — across
//!    scenario presets and both pipeline modes.
//! 2. **A feasible budget is respected.** With a binding fleet budget the
//!    per-round uplink goodput never exceeds it, and sits strictly below
//!    the unbudgeted run's.
//! 3. **The plan is pipeline- and transport-invariant.** An engaged
//!    scheduler keeps barrier ≡ streaming bit-identity, and a TCP run
//!    (rates shipped in ROUND_START) matches the in-process barrier run.
//! 4. **Multi-scale stays unbiased at every scheduled rate.** At each
//!    width a real plan assigns, the two-scale codec's round-trip is
//!    unbiased for the truncated gradient with per-element error bounded
//!    by the widest merged-codebook gap.
//! 5. **The kind-4 wire bytes are pinned** (the same fixture as
//!    `quant_props.rs` and PROTOCOL.md §4.5), including the header field
//!    the scheduler's observation channel reads.

use tqsgd::config::{ExperimentConfig, PipelineMode, ScenarioConfig, Scheme};
use tqsgd::coordinator::{run_worker, Coordinator, TcpOptions, TcpServer, WorkerOptions};
use tqsgd::metrics::RunLog;
use tqsgd::quant::wire::{self, Payload};
use tqsgd::quant::{BitBudget, CodecBuilder};
use tqsgd::runtime::{backend_for, Backend};
use tqsgd::util::Rng;

const PRESETS: [&str; 4] = ["clean", "lossy", "stale", "churn"];

fn native() -> Box<dyn Backend> {
    backend_for("native", "unused").unwrap()
}

/// The pipeline_props grid config: small but real.
fn grid_cfg(scheme: Scheme, bits: u32, preset: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = scheme;
    cfg.quant.bits = bits;
    cfg.clients = 4;
    cfg.train_size = 384;
    cfg.test_size = 96;
    cfg.seed = 11;
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    cfg.scenario = ScenarioConfig::preset(preset).unwrap();
    cfg
}

/// Run `rounds` rounds in-process; return (replay digest, final parameters,
/// per-round uplink bytes).
fn run(
    backend: &dyn Backend,
    cfg: &ExperimentConfig,
    rounds: usize,
) -> (String, Vec<f32>, Vec<u64>) {
    let mut coord = Coordinator::new(cfg.clone(), backend).unwrap();
    let mut log = RunLog::default();
    for _ in 0..rounds {
        log.push(coord.step().unwrap());
    }
    let bytes = log.records.iter().map(|r| r.bytes_up).collect();
    (log.replay_digest(), coord.params.clone(), bytes)
}

fn assert_bit_identical(a: &(String, Vec<f32>, Vec<u64>), b: &(String, Vec<f32>, Vec<u64>), label: &str) {
    assert_eq!(a.0, b.0, "{label}: replay digests diverged");
    assert_eq!(a.1.len(), b.1.len(), "{label}: parameter dim diverged");
    for (i, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: param {i} diverged ({x} vs {y})");
    }
}

/// Probe the scheduler's frame-size model for a config: total planned
/// message bytes across `active` at the given fleet budget.
fn planned_total(cfg: &ExperimentConfig, dims: &[usize], active: &[usize]) -> u64 {
    let b = BitBudget::new(cfg, dims.to_vec(), Vec::new());
    let plan = b.plan(0, active);
    active.iter().map(|&c| b.planned_message_bytes(&plan, c).unwrap()).sum()
}

/// A fleet budget halfway between the minimum-width cost and the
/// ceiling cost — feasible by construction, binding by construction.
fn binding_budget(cfg: &ExperimentConfig, dims: &[usize], active: &[usize]) -> u64 {
    let floor = {
        let mut c = cfg.clone();
        c.bit_budget = 1; // infeasible probe: the plan falls back to minima
        planned_total(&c, dims, active)
    };
    let ceil = {
        let mut c = cfg.clone();
        c.bit_budget = 1 << 40; // unconstrained probe: the plan hits the ceiling
        planned_total(&c, dims, active)
    };
    assert!(floor < ceil, "probe budgets must bracket: floor {floor} vs ceiling {ceil}");
    (floor + ceil) / 2
}

/// Layer-group element counts of the configured model.
fn model_dims(backend: &dyn Backend, cfg: &ExperimentConfig) -> Vec<usize> {
    let spec = backend.model(&cfg.model).unwrap();
    spec.groups.iter().map(|g| g.end - g.start).collect()
}

/// Invariant 6, degenerate direction: a budget so large no bound ever binds
/// schedules every codec at the configured ceiling — which must be
/// bit-identical to not constructing the scheduler at all, across presets
/// and both pipelines (error feedback in play).
#[test]
fn unconstrained_budget_is_bit_identical_to_disabled() {
    let backend = native();
    for preset in PRESETS {
        for pipeline in [PipelineMode::Barrier, PipelineMode::Streaming] {
            let mut cfg = grid_cfg(Scheme::Tqsgd, 3, preset);
            cfg.quant.error_feedback = true;
            cfg.pipeline = pipeline;
            let reference = run(backend.as_ref(), &cfg, 3);
            let mut c = cfg.clone();
            c.bit_budget = 1 << 40;
            let got = run(backend.as_ref(), &c, 3);
            let label = format!("tqsgd+ef@{preset} {} unconstrained-budget", pipeline.name());
            assert_bit_identical(&reference, &got, &label);
        }
    }
}

/// Invariant 6, binding direction: with a feasible fleet budget, every
/// round's uplink goodput respects it — and sits strictly below the
/// unbudgeted run (the budget is observable, not decorative).
#[test]
fn feasible_budget_caps_per_round_uplink_bytes() {
    let backend = native();
    let cfg = grid_cfg(Scheme::Tqsgd, 8, "clean");
    let dims = model_dims(backend.as_ref(), &cfg);
    let active: Vec<usize> = (0..cfg.clients).collect();
    let budget = binding_budget(&cfg, &dims, &active);

    let (_, _, free_bytes) = run(backend.as_ref(), &cfg, 3);
    let mut budgeted = cfg;
    budgeted.bit_budget = budget;
    let (_, params, bytes) = run(backend.as_ref(), &budgeted, 3);

    assert!(params.iter().all(|p| p.is_finite()));
    for (r, (&b, &f)) in bytes.iter().zip(&free_bytes).enumerate() {
        assert!(b <= budget, "round {r}: bytes_up {b} exceeds the {budget}-byte budget");
        assert!(b < f, "round {r}: budgeted bytes {b} not below unbudgeted {f}");
        assert!(b > 0, "round {r}: a feasible budget must still ship frames");
    }
}

/// The bandwidth preset's per-client uplink caps engage the scheduler on
/// their own (no fleet budget) and shrink the uplink versus clean.
#[test]
fn bandwidth_preset_caps_shrink_the_uplink() {
    let backend = native();
    let clean = run(backend.as_ref(), &grid_cfg(Scheme::Tqsgd, 8, "clean"), 3);
    let capped = run(backend.as_ref(), &grid_cfg(Scheme::Tqsgd, 8, "bandwidth"), 3);
    for (r, (&c, &f)) in capped.2.iter().zip(&clean.2).enumerate() {
        assert!(c < f, "round {r}: capped bytes {c} not below clean {f}");
        assert!(c > 0, "round {r}: capped clients must still ship frames");
    }
}

/// An engaged scheduler is decided in the shared round prologue, so the
/// barrier/streaming bit-identity contract survives it — with the
/// multi-scale codec carrying the frames (kind 4 through both decode
/// paths) on top of per-client caps AND a binding fleet budget.
#[test]
fn engaged_budget_keeps_pipeline_bit_identity() {
    let backend = native();
    let base = grid_cfg(Scheme::Multiscale, 6, "bandwidth");
    let dims = model_dims(backend.as_ref(), &base);
    let active: Vec<usize> = (0..base.clients).collect();
    let mut cfg = base;
    cfg.bit_budget = binding_budget(&cfg, &dims, &active);

    let mut barrier = cfg.clone();
    barrier.pipeline = PipelineMode::Barrier;
    let a = run(backend.as_ref(), &barrier, 4);
    let mut streaming = cfg;
    streaming.pipeline = PipelineMode::Streaming;
    let b = run(backend.as_ref(), &streaming, 4);
    assert_bit_identical(&a, &b, "multiscale@bandwidth budgeted modes");
}

/// The plan must survive the wire: a TCP run — workers re-targeting their
/// codecs from the ROUND_START rate block (PROTOCOL.md §3.3) — matches the
/// in-process barrier run bit for bit under a binding budget.
#[test]
fn tcp_budget_run_matches_in_process_barrier() {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = Scheme::Multiscale;
    cfg.quant.bits = 6;
    cfg.clients = 3;
    cfg.rounds = 4;
    cfg.train_size = 384;
    cfg.test_size = 96;
    cfg.seed = 11;
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    let backend = native();
    let dims = model_dims(backend.as_ref(), &cfg);
    let active: Vec<usize> = (0..cfg.clients).collect();
    cfg.bit_budget = binding_budget(&cfg, &dims, &active);

    let opts = TcpOptions {
        io_timeout: std::time::Duration::from_secs(30),
        accept_timeout: std::time::Duration::from_secs(30),
    };
    let server = TcpServer::bind("127.0.0.1:0", &cfg, opts).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..cfg.clients)
        .map(|id| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, id, &WorkerOptions::default()))
        })
        .collect();
    let transport = server.accept_workers().unwrap();
    let mut coord =
        Coordinator::with_transport(cfg.clone(), backend.as_ref(), Box::new(transport)).unwrap();
    let log = coord.run_remote(false).unwrap();
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker must exit cleanly");
    }

    let mut ref_cfg = cfg;
    ref_cfg.pipeline = PipelineMode::Barrier;
    let mut ref_coord = Coordinator::new(ref_cfg, backend.as_ref()).unwrap();
    let ref_log = ref_coord.run(false).unwrap();
    assert_eq!(
        log.replay_digest(),
        ref_log.replay_digest(),
        "budgeted TCP digest diverged from in-process barrier"
    );
    for (i, (a, b)) in coord.params.iter().zip(&ref_coord.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i} diverged ({a} vs {b})");
    }
}

/// Multi-scale round-trips at every width a real plan schedules: unbiased
/// for the truncated gradient, per-element error within the widest merged
/// codebook gap — so the budget can move the rate without breaking the
/// unbiased-aggregation contract.
#[test]
fn multiscale_is_unbiased_at_every_scheduled_rate() {
    // Schedule real rates: a mid-sized fleet budget over two uneven groups.
    let mut cfg = ExperimentConfig::default();
    cfg.clients = 2;
    cfg.quant.scheme = Scheme::Multiscale;
    cfg.quant.bits = 8;
    let dims = [600usize, 300];
    cfg.bit_budget = binding_budget(&cfg, &dims, &[0, 1]);
    let b = BitBudget::new(&cfg, dims.to_vec(), Vec::new());
    let plan = b.plan(0, &[0, 1]);
    let mut rates: Vec<u32> = plan.bits.iter().flatten().copied().collect();
    rates.sort_unstable();
    rates.dedup();
    assert!(!rates.is_empty(), "the plan must schedule at least one width");
    assert!(rates.iter().all(|&r| (3..=8).contains(&r)), "scheduled widths {rates:?}");

    for &bits in &rates {
        let mut codec = CodecBuilder::from_quant(&cfg.quant).build_plain();
        let mut rng = Rng::new(0x5EED ^ u64::from(bits));
        let fit: Vec<f32> =
            (0..20_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        codec.refit(&fit);
        codec.set_rate(bits);
        assert_eq!(codec.rate(), bits, "set_rate must land on the scheduled width");

        let n = 48usize;
        let g: Vec<f32> =
            (0..n).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        // Reconstruct the standing merged codebook from a frame's header —
        // the same derivation the decoder uses.
        let probe = codec.compress(&g, &mut Rng::for_stream(0xB06, u64::from(bits), 0, 0));
        let Payload::Multiscale { alpha, beta, s_hi, s_lo, .. } =
            Payload::decode(&probe).unwrap()
        else {
            panic!("multiscale codec must emit kind-4 frames");
        };
        let cb = wire::multiscale_codebook(alpha, beta, s_hi, s_lo);
        let (lo, hi) = (cb[0] as f64, *cb.last().unwrap() as f64);
        let max_gap = cb.windows(2).map(|w| (w[1] - w[0]) as f64).fold(0.0f64, f64::max);
        assert!(max_gap > 0.0, "b{bits}: degenerate codebook");

        let reps = 400u64;
        let mut mean = vec![0.0f64; n];
        for r in 0..reps {
            let mut rr = Rng::for_stream(0xB06, u64::from(bits), r, 1);
            let dec = Payload::decode(&codec.compress(&g, &mut rr)).unwrap().dequantize();
            assert_eq!(dec.len(), n);
            for (i, (&d, m)) in dec.iter().zip(mean.iter_mut()).enumerate() {
                let trunc = (g[i] as f64).clamp(lo, hi);
                assert!(
                    (d as f64 - trunc).abs() <= max_gap + 1e-6,
                    "b{bits} elem {i}: |{d} - {trunc}| above the {max_gap} gap bound"
                );
                *m += d as f64;
            }
        }
        let tol = 4.0 * max_gap / (reps as f64).sqrt();
        for (i, (&gi, &m)) in g.iter().zip(&mean).enumerate() {
            let trunc = (gi as f64).clamp(lo, hi);
            let err = (m / reps as f64 - trunc).abs();
            assert!(err <= tol, "b{bits} elem {i}: bias {err} > tol {tol}");
        }
    }
}

/// The kind-4 golden bytes (restated from `quant_props.rs`, normative copy
/// in PROTOCOL.md §4.5) — plus the header field the scheduler's observation
/// channel reads off every frame it sees.
#[test]
fn golden_multiscale_fixture_feeds_the_observation_channel() {
    let p = Payload::Multiscale { alpha: 1.0, beta: 0.25, s_hi: 2, s_lo: 2, idx: vec![0, 4, 2] };
    let want: Vec<u8> = vec![
        0x54, 0x51, // magic
        0x04, // kind: multiscale
        0x03, // 3 bits per index
        0x03, 0x00, 0x00, 0x00, // d = 3
        0x00, 0x00, 0x80, 0x3F, // alpha = 1.0
        0x00, 0x00, 0x80, 0x3E, // beta = 0.25
        0x02, 0x00, // s_hi = 2
        0x02, 0x00, // s_lo = 2
        0xA0, 0x00, // indices 0,4,2 packed LSB-first
    ];
    let bytes = p.encode(3);
    assert_eq!(bytes, want);
    assert_eq!(Payload::decode(&want).unwrap(), p);
    assert_eq!(Payload::decode(&want).unwrap().dequantize(), vec![-1.0, 1.0, 0.0]);
    // frame_alpha is BitBudget's tail-scale observation: kind 4 carries the
    // truncation threshold at header offset 8.
    assert_eq!(wire::frame_alpha(&bytes), Some(1.0));
}
