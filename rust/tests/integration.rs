//! Integration tests over the full stack: pluggable compute backend +
//! coordinator + codecs + trainer.
//!
//! The default suite runs entirely on the pure-Rust [`NativeBackend`] — no
//! Python, JAX or AOT artifacts required — so `cargo test -q` is green from
//! a clean checkout. The PJRT↔rust parity tests live at the bottom behind
//! the `pjrt` cargo feature and are `#[ignore]`d: they additionally need
//! `make artifacts` output and real xla-rs bindings linked in place of the
//! in-tree stub.

use tqsgd::config::{ExperimentConfig, PipelineMode, ScenarioConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::quant::kernels::{quantize_codebook_slice, quantize_uniform_slice};
use tqsgd::runtime::{backend_for, Backend};
use tqsgd::train::{Sweep, Trainer};
use tqsgd::util::Rng;

fn native() -> Box<dyn Backend> {
    backend_for("native", "unused").unwrap()
}

fn small_cfg(model: &str, scheme: Scheme) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = model.into();
    cfg.backend = "native".into();
    cfg.quant.scheme = scheme;
    cfg.quant.bits = 3;
    cfg.clients = 4;
    cfg.rounds = 6;
    cfg.eval_every = 3;
    cfg.train_size = 512;
    cfg.test_size = 256;
    cfg
}

// ---------------------------------------------------------------------------
// Backend surface
// ---------------------------------------------------------------------------

#[test]
fn native_backend_lists_models_and_runs_mlp_grad() {
    let backend = native();
    let models = backend.models();
    for want in ["mlp", "mlp_tiny", "cnn", "tfm_small"] {
        assert!(models.iter().any(|m| m == want), "missing model {want}: {models:?}");
    }
    let spec = backend.model("mlp").unwrap();
    spec.validate().unwrap();
    let params = backend.init_params("mlp").unwrap();
    assert_eq!(params.len(), spec.param_count);
    let b = spec.train_batch;
    let x = vec![0.5f32; b * spec.input_dim];
    let y: Vec<f32> = (0..b).map(|i| (i % 10) as f32).collect();
    let out = backend.grad("mlp", &params, &x, &y).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0, "loss {}", out.loss);
    assert_eq!(out.grads.len(), spec.param_count);
    let gnorm: f64 = out.grads.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();
    assert!(gnorm > 0.0 && gnorm.is_finite());
}

#[test]
fn backend_rejects_bad_shapes() {
    let backend = native();
    let params = backend.init_params("mlp").unwrap();
    // Wrong parameter count.
    assert!(backend.grad("mlp", &params[..10], &[0.0; 784], &[0.0]).is_err());
    // Wrong element count for the batch.
    let bad = vec![0.0f32; 7];
    assert!(backend.grad("mlp", &params, &bad, &[0.0]).is_err());
    // Unknown model name.
    assert!(backend.model("resnet152").is_err());
    // Unknown backend kind.
    assert!(backend_for("cuda", "unused").is_err());
}

#[test]
fn sweep_auto_falls_back_to_native_without_artifacts() {
    let sweep = Sweep::new("definitely_missing_artifacts_dir").unwrap();
    assert_eq!(sweep.backend().name(), "native");
}

// ---------------------------------------------------------------------------
// Backend gradient correctness: finite differences
// ---------------------------------------------------------------------------

/// Central-difference check of `Backend::grad` against its own loss output.
/// `probe` coordinates are checked: the last few (output biases) plus a
/// random spread across the parameter vector.
fn gradcheck(backend: &dyn Backend, model: &str, x: &[f32], y: &[f32], probes: usize) {
    let mut params = backend.init_params(model).unwrap();
    let analytic = backend.grad(model, &params, x, y).unwrap();
    let n = params.len();
    let mut rng = Rng::new(42);
    for t in 0..probes {
        let i = if t < 8 { n - 1 - t } else { rng.below(n as u64) as usize };
        let orig = params[i];
        let h = 1e-3f32;
        let p_plus = orig + h;
        let p_minus = orig - h;
        params[i] = p_plus;
        let lp = backend.grad(model, &params, x, y).unwrap().loss as f64;
        params[i] = p_minus;
        let lm = backend.grad(model, &params, x, y).unwrap().loss as f64;
        params[i] = orig;
        let fd = (lp - lm) / ((p_plus - p_minus) as f64);
        let an = analytic.grads[i] as f64;
        assert!(
            (fd - an).abs() <= 1e-3 + 0.02 * an.abs(),
            "{model} param {i}: finite-diff {fd} vs analytic {an}"
        );
    }
}

#[test]
fn native_mlp_gradient_matches_finite_differences() {
    let backend = native();
    let ds = tqsgd::data::mnist_like(8, 11);
    let idxs: Vec<usize> = (0..4).collect();
    let (x, y) = tqsgd::data::gather_batch(&ds, &idxs);
    gradcheck(backend.as_ref(), "mlp_tiny", &x, &y, 48);
}

#[test]
fn native_lm_gradient_matches_finite_differences() {
    let backend = native();
    let spec = backend.model("tfm_small").unwrap();
    let corpus = tqsgd::data::MarkovCorpus::new(spec.vocab, 9);
    let mut rng = Rng::new(10);
    let mut toks = Vec::new();
    for _ in 0..2 {
        toks.extend(corpus.sample(spec.seq_len + 1, &mut rng));
    }
    gradcheck(backend.as_ref(), "tfm_small", &toks, &[], 48);
}

// ---------------------------------------------------------------------------
// Distributed training on the native path
// ---------------------------------------------------------------------------

#[test]
fn dsgd_training_reduces_loss() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Dsgd);
    cfg.rounds = 25;
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let first = coord.step().unwrap().train_loss;
    let mut last = first;
    for _ in 0..24 {
        last = coord.step().unwrap().train_loss;
    }
    assert!(last < first, "loss should fall: {first} -> {last}");
}

#[test]
fn quantized_training_runs_and_accounts_bytes() {
    let backend = native();
    for scheme in [Scheme::Tqsgd, Scheme::Tnqsgd, Scheme::Tbqsgd, Scheme::Qsgd] {
        let cfg = small_cfg("mlp_tiny", scheme);
        let mut coord = Coordinator::new(cfg.clone(), backend.as_ref()).unwrap();
        let spec = coord.model_spec().clone();
        let rec = coord.step().unwrap();
        // b=3 bits/element + frame overhead; 4 clients, whole model.
        let payload_bits = (spec.param_count * 3) as f64;
        let bytes_min = payload_bits / 8.0 * cfg.clients as f64;
        let bytes_max = bytes_min * 1.1 + 1024.0 * cfg.clients as f64;
        assert!(
            (rec.bytes_up as f64) >= bytes_min && (rec.bytes_up as f64) <= bytes_max,
            "{scheme:?}: bytes_up {} outside [{bytes_min}, {bytes_max}]",
            rec.bytes_up
        );
        assert!(rec.train_loss.is_finite());
    }
}

#[test]
fn training_is_deterministic_given_seed() {
    let backend = native();
    let run = |seed: u64| {
        let mut cfg = small_cfg("mlp_tiny", Scheme::Tnqsgd);
        cfg.seed = seed;
        cfg.rounds = 4;
        let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
        for _ in 0..4 {
            coord.step().unwrap();
        }
        coord.params.clone()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a, b, "same seed must reproduce bit-identically");
    let c = run(8);
    assert_ne!(a, c, "different seed should differ");
}

#[test]
fn agg_shards_setting_does_not_change_training_bits() {
    // The sharded server aggregation is a pure performance knob: the same
    // experiment at shard widths 1 / 2 / 7 (capped by the model's group
    // count) must land on bit-identical parameters.
    let backend = native();
    let run = |shards: usize| {
        let mut cfg = small_cfg("mlp_tiny", Scheme::Tnqsgd);
        cfg.agg_shards = shards;
        cfg.rounds = 3;
        let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
        for _ in 0..3 {
            coord.step().unwrap();
        }
        coord.params.clone()
    };
    let serial = run(1);
    assert_eq!(serial, run(2), "2-shard aggregation changed the training bits");
    assert_eq!(serial, run(7), "7-shard aggregation changed the training bits");
}

#[test]
fn fault_injection_drops_client_and_still_trains() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
    cfg.drop_client = 0;
    let mut coord = Coordinator::new(cfg.clone(), backend.as_ref()).unwrap();
    let rec = coord.step().unwrap();
    // Only 3 of 4 clients' bytes arrive.
    let full = {
        let mut cfg2 = cfg.clone();
        cfg2.drop_client = usize::MAX;
        let mut c2 = Coordinator::new(cfg2, backend.as_ref()).unwrap();
        c2.step().unwrap().bytes_up
    };
    assert!(rec.bytes_up < full, "dropped client must reduce bytes");
    assert!((rec.bytes_up as f64) > 0.6 * full as f64);
}

#[test]
fn error_feedback_path_runs() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
    cfg.quant.error_feedback = true;
    cfg.rounds = 3;
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    for _ in 0..3 {
        let rec = coord.step().unwrap();
        assert!(rec.train_loss.is_finite());
    }
}

#[test]
fn evaluation_reports_sane_accuracy() {
    let backend = native();
    let cfg = small_cfg("mlp_tiny", Scheme::Dsgd);
    let coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let (loss, acc) = coord.evaluate().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let acc = acc.unwrap();
    assert!((0.0..=1.0).contains(&acc));
    // Untrained model ≈ chance.
    assert!(acc < 0.5, "untrained accuracy {acc} should be near 0.1");
}

#[test]
fn lm_coordinator_trains_bigram() {
    let backend = native();
    let mut cfg = small_cfg("tfm_small", Scheme::Tnqsgd);
    cfg.quant.bits = 4;
    cfg.clients = 2;
    cfg.rounds = 3;
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let first = coord.step().unwrap().train_loss;
    assert!(first.is_finite() && first > 3.0, "init NLL ~ ln(64): {first}");
    let (nll, acc) = coord.evaluate().unwrap();
    assert!(nll.is_finite() && nll > 0.0);
    assert!(acc.is_none(), "LM eval reports NLL only");
}

// ---------------------------------------------------------------------------
// ---------------------------------------------------------------------------
// Zero-allocation steady state
// ---------------------------------------------------------------------------

/// After warm-up rounds every frame buffer comes from a client arena:
/// `Coordinator::step` performs zero per-round frame allocations. This is
/// the acceptance gate behind the `compress_into` hot path; the counter is
/// `quant::arena::FrameArena::fresh_allocs` summed over clients. The
/// staleness-histogram working buffer has the analogous scratch invariant:
/// once the deepest staleness a scenario produces has been seen, its
/// capacity (the `hist_reallocs` growth counter) must stop moving too —
/// the record's sized-to-fit histogram copy is log data, outside it.
fn assert_steady_state_zero_frame_allocs(mut cfg: ExperimentConfig, warmup: usize) {
    let label = format!("{} ef={}", cfg.scenario.name, cfg.quant.error_feedback);
    cfg.rounds = warmup + 5;
    let backend = native();
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    for _ in 0..warmup {
        coord.step().unwrap();
    }
    let warm = coord.frame_allocs();
    let warm_hist = coord.hist_reallocs();
    assert!(warm > 0, "{label}: warm-up must have allocated some frames");
    assert!(warm_hist > 0, "{label}: warm-up must have sized the hist scratch");
    for _ in 0..5 {
        coord.step().unwrap();
    }
    assert_eq!(
        coord.frame_allocs(),
        warm,
        "{label}: steady-state rounds must reuse arena frame buffers"
    );
    assert_eq!(
        coord.hist_reallocs(),
        warm_hist,
        "{label}: steady-state rounds must reuse the staleness-hist scratch"
    );
}

#[test]
fn steady_state_rounds_do_not_allocate_frames() {
    // Clean synchronous path, plain codecs.
    assert_steady_state_zero_frame_allocs(small_cfg("mlp_tiny", Scheme::Tqsgd), 2);
    // Error-feedback wrapping (residual + scratch buffers settle round 1).
    let mut ef = small_cfg("mlp_tiny", Scheme::Tqsgd);
    ef.quant.error_feedback = true;
    assert_steady_state_zero_frame_allocs(ef, 2);
    // Bounded staleness: late frames return to their arena one round later,
    // so the pool needs an extra warm-up round to reach its high-water mark.
    // TQSGD keeps every frame the same size whether or not the tail fit
    // succeeded, so uplink times tie and the late-client set is stable.
    let mut stale = small_cfg("mlp_tiny", Scheme::Tqsgd);
    stale.net.bandwidth_bytes_per_sec = 1e6;
    stale.net.latency_sec = 0.01;
    stale.scenario = ScenarioConfig::preset("stale").unwrap();
    assert_steady_state_zero_frame_allocs(stale.clone(), 4);
    // The streaming pipeline obeys the same invariants (its extra
    // contribution buffers have their own counter, asserted in
    // rust/tests/pipeline_props.rs).
    let mut streaming = small_cfg("mlp_tiny", Scheme::Tqsgd);
    streaming.pipeline = PipelineMode::Streaming;
    assert_steady_state_zero_frame_allocs(streaming, 2);
    stale.pipeline = PipelineMode::Streaming;
    assert_steady_state_zero_frame_allocs(stale, 4);
}

// Scenario engine: heterogeneous / faulty rounds, reproducibly
// ---------------------------------------------------------------------------

/// Run a short experiment under `scenario`; returns the deterministic
/// replay digest of its RunLog and the final parameter vector.
fn run_scenario(scenario: ScenarioConfig, rounds: usize) -> (String, Vec<f32>) {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tnqsgd);
    cfg.rounds = rounds;
    cfg.eval_every = rounds;
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    cfg.scenario = scenario;
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let log = coord.run(false).unwrap();
    (log.replay_digest(), coord.params.clone())
}

#[test]
fn streaming_pipeline_matches_barrier_end_to_end() {
    // Acceptance: the streaming round engine is a pure performance knob —
    // same digests, same final parameters, in clean and degraded rounds.
    // (The full scheme × bits × preset grid lives in
    // rust/tests/pipeline_props.rs; this is the end-to-end smoke.)
    for name in ["clean", "lossy", "stale", "churn"] {
        let sc = ScenarioConfig::preset(name).unwrap();
        let run_mode = |pipeline: PipelineMode| {
            let backend = native();
            let mut cfg = small_cfg("mlp_tiny", Scheme::Tnqsgd);
            cfg.rounds = 5;
            cfg.eval_every = 5;
            cfg.net.bandwidth_bytes_per_sec = 1e6;
            cfg.net.latency_sec = 0.01;
            cfg.scenario = sc.clone();
            cfg.pipeline = pipeline;
            let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
            let log = coord.run(false).unwrap();
            (log.replay_digest(), coord.params.clone())
        };
        let (digest_b, params_b) = run_mode(PipelineMode::Barrier);
        let (digest_s, params_s) = run_mode(PipelineMode::Streaming);
        assert_eq!(digest_b, digest_s, "{name}: streaming digest diverged");
        // Bitwise, not f32 ==: a +0.0/−0.0 sign flip must not slip through.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&params_b), bits(&params_s), "{name}: streaming θ diverged");
    }
}

#[test]
fn churn_rounds_never_poison_the_loss_column() {
    // Regression for the `sum / losses.len()` NaN: a round whose active set
    // computes no losses must carry the previous value, and heavy churn
    // must never produce a non-finite loss in either pipeline mode.
    for pipeline in [PipelineMode::Barrier, PipelineMode::Streaming] {
        let backend = native();
        let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
        cfg.clients = 5;
        cfg.rounds = 25;
        cfg.scenario = ScenarioConfig {
            dropout_prob: 0.6,
            rejoin_prob: 0.3,
            ..ScenarioConfig::preset("churn").unwrap()
        };
        cfg.pipeline = pipeline;
        let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
        for round in 0..25 {
            let rec = coord.step().unwrap();
            assert!(
                rec.train_loss.is_finite(),
                "{pipeline:?} round {round}: train_loss {} not finite",
                rec.train_loss
            );
        }
    }
}

#[test]
fn scenario_runs_are_bit_reproducible() {
    // Acceptance: same seed + same scenario config ⇒ identical RunLog
    // (bytes, losses, drop/retransmit counts) across two runs.
    for name in ["clean", "lossy", "stale"] {
        let sc = ScenarioConfig::preset(name).unwrap();
        let (digest_a, params_a) = run_scenario(sc.clone(), 4);
        let (digest_b, params_b) = run_scenario(sc, 4);
        assert_eq!(digest_a, digest_b, "{name}: RunLog digests must match");
        assert_eq!(params_a, params_b, "{name}: final θ must match bit-for-bit");
    }
}

#[test]
fn stale_with_k_equal_n_degenerates_to_synchronous() {
    // Acceptance: K = N bounded staleness IS the synchronous path — final θ
    // (and the whole deterministic log) match the clean run bit-for-bit.
    let clean = ScenarioConfig::preset("clean").unwrap();
    let stale_kn = ScenarioConfig {
        stale_k: 4, // == cfg.clients in small_cfg
        ..ScenarioConfig::preset("stale").unwrap()
    };
    let (digest_clean, params_clean) = run_scenario(clean, 5);
    let (digest_kn, params_kn) = run_scenario(stale_kn, 5);
    assert_eq!(params_clean, params_kn, "final θ must be bit-identical");
    assert_eq!(digest_clean, digest_kn, "whole RunLog must be bit-identical");
}

#[test]
fn stale_k_of_n_delays_frames_and_still_trains() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
    cfg.scenario = ScenarioConfig { stale_k: 2, stale_decay: 0.5, ..Default::default() };
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let r0 = coord.step().unwrap();
    assert_eq!(r0.staleness_hist, vec![2], "round 0: first K=2 of 4 apply fresh");
    assert_eq!(coord.scenario.pending_len(), 2);
    let r1 = coord.step().unwrap();
    assert_eq!(
        r1.staleness_hist,
        vec![2, 2],
        "round 1: two fresh frames plus two late (staleness 1) frames apply"
    );
    assert!(r1.train_loss.is_finite());
}

#[test]
fn lossy_scenario_retransmits_and_accounts_bytes() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
    cfg.rounds = 10;
    cfg.scenario = ScenarioConfig::preset("lossy").unwrap();
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let mut retrans = 0u64;
    for _ in 0..10 {
        let rec = coord.step().unwrap();
        assert!(rec.train_loss.is_finite());
        retrans += rec.retransmitted_bytes;
    }
    assert!(retrans > 0, "20% loss over 40 uplinks must retransmit something");
    assert_eq!(coord.net.total_retransmitted(), retrans);
}

#[test]
fn churn_scenario_drops_and_rejoins_clients() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
    cfg.clients = 6;
    cfg.scenario = ScenarioConfig {
        dropout_prob: 0.4,
        rejoin_prob: 0.5,
        ..ScenarioConfig::preset("churn").unwrap()
    };
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let mut drops = Vec::new();
    for _ in 0..12 {
        let rec = coord.step().unwrap();
        assert!(rec.train_loss.is_finite());
        assert!(rec.dropped_clients < 6, "at least one client always survives");
        drops.push(rec.dropped_clients);
    }
    assert!(drops.iter().any(|&d| d > 0), "dropout must drop someone: {drops:?}");
    assert!(
        drops.iter().min() != drops.iter().max(),
        "churn must vary the federation membership over rounds: {drops:?}"
    );
}

#[test]
fn straggler_scenario_inflates_tail_latency() {
    let run_net_secs = |scenario: ScenarioConfig| -> f64 {
        let backend = native();
        let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
        cfg.rounds = 3;
        cfg.net.bandwidth_bytes_per_sec = 1e6;
        cfg.net.latency_sec = 0.01;
        cfg.scenario = scenario;
        let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
        (0..3).map(|_| coord.step().unwrap().net_secs).sum()
    };
    let clean = run_net_secs(ScenarioConfig::default());
    let straggler = run_net_secs(ScenarioConfig::preset("straggler").unwrap());
    assert!(
        straggler > 4.0 * clean,
        "an 8x straggler must dominate round time: {straggler} vs {clean}"
    );
}

#[test]
fn noniid_scenario_shards_by_dirichlet_and_trains() {
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tnqsgd);
    cfg.scenario = ScenarioConfig::preset("noniid").unwrap();
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let weights: Vec<f64> = coord.clients.iter().map(|c| c.weight).collect();
    let total: f64 = weights.iter().sum();
    assert!((total - 1.0).abs() < 1e-9, "weights partition the data: {total}");
    assert!(
        weights.iter().any(|&w| (w - weights[0]).abs() > 1e-12),
        "Dirichlet(0.3) shards should not be perfectly balanced: {weights:?}"
    );
    let rec = coord.step().unwrap();
    assert!(rec.train_loss.is_finite());
}

#[test]
fn noniid_scenario_rejected_for_lm_task() {
    // LM clients all sample a shared corpus; silently ignoring the skew and
    // logging an "@noniid" run would be a lie, so construction must fail.
    let backend = native();
    let mut cfg = small_cfg("tfm_small", Scheme::Tnqsgd);
    cfg.quant.bits = 4;
    cfg.scenario = ScenarioConfig::preset("noniid").unwrap();
    assert!(Coordinator::new(cfg, backend.as_ref()).is_err());
}

#[test]
fn total_frame_wipeout_skips_round_instead_of_aborting() {
    // Under extreme loss a round can deliver nothing; the server must skip
    // the update and keep going, not kill the run.
    let backend = native();
    let mut cfg = small_cfg("mlp_tiny", Scheme::Tqsgd);
    cfg.clients = 2;
    cfg.scenario = ScenarioConfig {
        loss_prob: 0.95,
        max_retries: 0,
        ..ScenarioConfig::preset("lossy").unwrap()
    };
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let mut wipeouts = 0;
    for _ in 0..5 {
        let rec = coord.step().unwrap();
        assert!(rec.train_loss.is_finite());
        if rec.staleness_hist.is_empty() {
            wipeouts += 1;
            assert!(rec.retransmitted_bytes > 0, "lost attempts still hit the wire");
        }
    }
    assert!(wipeouts > 0, "95% loss on 2 clients must wipe out some round");
}

// ---------------------------------------------------------------------------
// Trainer round trips (uniform TQSGD + non-uniform TNQSGD presets)
// ---------------------------------------------------------------------------

fn trainer_roundtrip(scheme: Scheme) {
    let mut cfg = small_cfg("mlp_tiny", scheme);
    cfg.rounds = 2;
    cfg.eval_every = 1;
    let mut trainer = Trainer::new(cfg).unwrap();
    assert_eq!(trainer.backend().name(), "native");
    let report = trainer.run().unwrap();
    assert_eq!(report.log.records.len(), 2, "trainer must complete both rounds");
    assert!(report.final_train_loss.is_finite());
    assert!(report.final_test_loss.is_finite());
    assert!((0.0..=1.0).contains(&report.final_accuracy));
    assert!(report.total_bytes_up > 0);
    assert!(report.bits_per_param > 0.0);
}

#[test]
fn trainer_completes_on_native_tqsgd() {
    trainer_roundtrip(Scheme::Tqsgd);
}

#[test]
fn trainer_completes_on_native_tnqsgd() {
    trainer_roundtrip(Scheme::Tnqsgd);
}

// ---------------------------------------------------------------------------
// L1 quantizer kernels through the Backend interface (native parity)
// ---------------------------------------------------------------------------

#[test]
fn backend_uniform_kernel_parity_bitexact() {
    let backend = native();
    let q = backend.quant_kernel("quant_uniform_b3").unwrap();
    let mut rng = Rng::new(5);
    let n = 8192;
    let g: Vec<f32> = (0..n).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
    let u: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let alpha = 0.04f32;
    let (deq, idx) = q.run_uniform(&g, &u, alpha).unwrap();
    let mut rust_idx = Vec::new();
    quantize_uniform_slice(&g, &u, alpha, 7, &mut rust_idx);
    assert_eq!(idx, rust_idx, "kernel and rust codec indices must agree exactly");
    for (i, (&d, &k)) in deq.iter().zip(&rust_idx).enumerate() {
        let expect = -alpha + k as f32 * (2.0 * alpha / 7.0);
        assert!((d - expect).abs() < 1e-6, "i={i}: {d} vs {expect}");
    }
}

#[test]
fn backend_codebook_kernel_parity_bitexact() {
    let backend = native();
    let q = backend.quant_kernel("quant_nonuniform_b3").unwrap();
    let mut rng = Rng::new(6);
    let n = 8192;
    let g: Vec<f32> = (0..n).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
    let u: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let m = tqsgd::tail::PowerLawModel::new(4.0, 0.01, 0.1);
    let alpha = tqsgd::solver::optimal_alpha_nonuniform(&m, 7);
    let cb = tqsgd::solver::nonuniform_codebook(&m, alpha, 7);
    let (_deq, idx) = q.run_codebook(&g, &u, &cb).unwrap();
    let mut rust_idx = Vec::new();
    quantize_codebook_slice(&g, &u, &cb, &mut rust_idx);
    assert_eq!(idx, rust_idx, "codebook kernel parity");
}

// ---------------------------------------------------------------------------
// L1 ↔ L3 parity through PJRT: the pallas kernels and the rust codecs are
// the same function. Requires `--features pjrt`, `make artifacts`, and real
// xla-rs bindings in place of the stub — hence #[ignore] by default (run
// with `cargo test --features pjrt -- --ignored`).
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_parity {
    use super::*;
    use tqsgd::runtime::{PjrtBackend, QuantExec, Runtime};

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn pjrt_cfg(model: &str, scheme: Scheme) -> ExperimentConfig {
        let mut cfg = small_cfg(model, scheme);
        cfg.backend = "pjrt".into();
        cfg.artifacts_dir = artifacts_dir();
        cfg
    }

    #[test]
    #[ignore = "requires AOT artifacts and linked PJRT runtime"]
    fn runtime_loads_and_runs_mlp_grad() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let spec = rt.model("mlp").unwrap().clone();
        let exe = rt.load(&spec.grad_entry).unwrap();
        let params = rt.init_params("mlp").unwrap();
        assert_eq!(params.len(), spec.param_count);
        let b = spec.train_batch;
        let x = vec![0.5f32; b * spec.input_dim];
        let y: Vec<f32> = (0..b).map(|i| (i % 10) as f32).collect();
        let out = exe.run(&[&params, &x, &y]).unwrap();
        assert_eq!(out.len(), 2);
        let loss = out[0][0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        assert_eq!(out[1].len(), spec.param_count);
    }

    #[test]
    #[ignore = "requires AOT artifacts and linked PJRT runtime"]
    fn pjrt_training_reduces_loss() {
        let backend = PjrtBackend::open(artifacts_dir()).unwrap();
        let mut cfg = pjrt_cfg("mlp", Scheme::Dsgd);
        cfg.rounds = 25;
        let mut coord = Coordinator::new(cfg, &backend).unwrap();
        let first = coord.step().unwrap().train_loss;
        let mut last = first;
        for _ in 0..24 {
            last = coord.step().unwrap().train_loss;
        }
        assert!(last < first, "loss should fall: {first} -> {last}");
    }

    #[test]
    #[ignore = "requires AOT artifacts and linked PJRT runtime"]
    fn pallas_uniform_parity_bitexact() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let q = QuantExec::new(&rt, "quant_uniform_b3").unwrap();
        let mut rng = Rng::new(5);
        let g: Vec<f32> =
            (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let u: Vec<f32> = (0..q.tile).map(|_| rng.f32()).collect();
        let alpha = 0.04f32;
        let (deq, idx) = q.run_uniform(&g, &u, alpha).unwrap();
        let mut rust_idx = Vec::new();
        quantize_uniform_slice(&g, &u, alpha, 7, &mut rust_idx);
        assert_eq!(idx, rust_idx, "pallas and rust indices must agree exactly");
        for (i, (&d, &k)) in deq.iter().zip(&rust_idx).enumerate() {
            let expect = -alpha + k as f32 * (2.0 * alpha / 7.0);
            assert!((d - expect).abs() < 1e-6, "i={i}: {d} vs {expect}");
        }
    }

    #[test]
    #[ignore = "requires AOT artifacts and linked PJRT runtime"]
    fn pallas_codebook_parity_bitexact() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let q = QuantExec::new(&rt, "quant_nonuniform_b3").unwrap();
        let mut rng = Rng::new(6);
        let g: Vec<f32> =
            (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let u: Vec<f32> = (0..q.tile).map(|_| rng.f32()).collect();
        let m = tqsgd::tail::PowerLawModel::new(4.0, 0.01, 0.1);
        let alpha = tqsgd::solver::optimal_alpha_nonuniform(&m, 7);
        let cb = tqsgd::solver::nonuniform_codebook(&m, alpha, 7);
        let (_deq, idx) = q.run_codebook(&g, &u, &cb).unwrap();
        let mut rust_idx = Vec::new();
        quantize_codebook_slice(&g, &u, &cb, &mut rust_idx);
        let mismatches = idx.iter().zip(&rust_idx).filter(|(a, b)| a != b).count();
        assert_eq!(mismatches, 0, "{mismatches} codebook index mismatches");
    }

    #[test]
    #[ignore = "requires AOT artifacts and linked PJRT runtime"]
    fn pallas_tail_stats_matches_rust() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let q = QuantExec::new(&rt, "tail_stats").unwrap();
        let mut rng = Rng::new(8);
        let g: Vec<f32> =
            (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let g_min = 0.01f32;
        let stats = q.run_stats(&g, g_min).unwrap();
        // Rust-side reference is the native kernel — same contract.
        let native = super::native();
        let want = native.quant_kernel("tail_stats").unwrap().run_stats(&g, g_min).unwrap();
        assert_eq!(stats.len(), want.len());
        for (i, (&a, &b)) in stats.iter().zip(&want).enumerate() {
            let denom = (b.abs()).max(1.0);
            assert!(((a - b) / denom).abs() < 1e-3, "stat {i}: {a} vs {b}");
        }
    }

    #[test]
    #[ignore = "requires AOT artifacts and linked PJRT runtime"]
    fn cnn_gradients_are_heavy_tailed() {
        let backend = PjrtBackend::open(artifacts_dir()).unwrap();
        let mut cfg = pjrt_cfg("cnn", Scheme::Dsgd);
        cfg.rounds = 8;
        cfg.clients = 4;
        let mut coord = Coordinator::new(cfg, &backend).unwrap();
        for _ in 0..8 {
            coord.step().unwrap();
        }
        let spec = coord.model_spec().clone();
        let grads = coord.last_aggregate();
        let fc = spec.groups.iter().find(|g| g.group == "fc").unwrap();
        let xs = &grads[fc.start..fc.end];
        let pl = tqsgd::tail::fit_power_law(xs).expect("fit");
        let ga = tqsgd::tail::fit_gaussian(xs);
        assert!(
            pl.ks < 0.1 && ga.ks > 2.0 * pl.ks,
            "power-law KS {} vs gaussian KS {}",
            pl.ks,
            ga.ks
        );
    }
}
