//! Integration tests over the full stack: PJRT runtime + AOT artifacts +
//! coordinator + codecs. Require `make artifacts` to have run (the Makefile
//! `test` target guarantees it).

use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::quant::kernels::{quantize_codebook_slice, quantize_uniform_slice};
use tqsgd::runtime::{QuantExec, Runtime};
use tqsgd::util::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn small_cfg(model: &str, scheme: Scheme) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = model.into();
    cfg.artifacts_dir = artifacts_dir();
    cfg.quant.scheme = scheme;
    cfg.quant.bits = 3;
    cfg.clients = 4;
    cfg.rounds = 6;
    cfg.eval_every = 3;
    cfg.train_size = 512;
    cfg.test_size = 256;
    cfg
}

#[test]
fn runtime_loads_and_runs_mlp_grad() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let spec = rt.model("mlp").unwrap().clone();
    let exe = rt.load(&spec.grad_entry).unwrap();
    let params = rt.init_params("mlp").unwrap();
    assert_eq!(params.len(), spec.param_count);
    let b = spec.train_batch;
    let x = vec![0.5f32; b * spec.input_dim];
    let y: Vec<f32> = (0..b).map(|i| (i % 10) as f32).collect();
    let out = exe.run(&[&params, &x, &y]).unwrap();
    assert_eq!(out.len(), 2);
    let loss = out[0][0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert_eq!(out[1].len(), spec.param_count);
    let gnorm: f64 = out[1].iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();
    assert!(gnorm > 0.0 && gnorm.is_finite());
}

#[test]
fn runtime_rejects_bad_shapes() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let spec = rt.model("mlp").unwrap().clone();
    let exe = rt.load(&spec.grad_entry).unwrap();
    let params = rt.init_params("mlp").unwrap();
    // Wrong input count.
    assert!(exe.run(&[&params]).is_err());
    // Wrong element count.
    let bad = vec![0.0f32; 7];
    assert!(exe.run(&[&params, &bad, &bad]).is_err());
}

#[test]
fn dsgd_training_reduces_loss() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = small_cfg("mlp", Scheme::Dsgd);
    cfg.rounds = 25;
    let mut coord = Coordinator::new(cfg, &rt).unwrap();
    let first = coord.step().unwrap().train_loss;
    let mut last = first;
    for _ in 0..24 {
        last = coord.step().unwrap().train_loss;
    }
    assert!(last < first, "loss should fall: {first} -> {last}");
}

#[test]
fn quantized_training_runs_and_accounts_bytes() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for scheme in [Scheme::Tqsgd, Scheme::Tnqsgd, Scheme::Tbqsgd, Scheme::Qsgd] {
        let cfg = small_cfg("cnn", scheme);
        let mut coord = Coordinator::new(cfg.clone(), &rt).unwrap();
        let spec = coord.model_spec().clone();
        let rec = coord.step().unwrap();
        // b=3 bits/element + frame overhead; 4 clients, whole model.
        let payload_bits = (spec.param_count * 3) as f64;
        let bytes_min = payload_bits / 8.0 * cfg.clients as f64;
        let bytes_max = bytes_min * 1.1 + 1024.0 * cfg.clients as f64;
        assert!(
            (rec.bytes_up as f64) >= bytes_min && (rec.bytes_up as f64) <= bytes_max,
            "{scheme:?}: bytes_up {} outside [{bytes_min}, {bytes_max}]",
            rec.bytes_up
        );
        assert!(rec.train_loss.is_finite());
    }
}

#[test]
fn training_is_deterministic_given_seed() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let run = |seed: u64| {
        let mut cfg = small_cfg("mlp", Scheme::Tnqsgd);
        cfg.seed = seed;
        cfg.rounds = 4;
        let mut coord = Coordinator::new(cfg, &rt).unwrap();
        for _ in 0..4 {
            coord.step().unwrap();
        }
        coord.params.clone()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a, b, "same seed must reproduce bit-identically");
    let c = run(8);
    assert_ne!(a, c, "different seed should differ");
}

#[test]
fn fault_injection_drops_client_and_still_trains() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = small_cfg("mlp", Scheme::Tqsgd);
    cfg.drop_client = 0;
    let mut coord = Coordinator::new(cfg.clone(), &rt).unwrap();
    let rec = coord.step().unwrap();
    // Only 3 of 4 clients' bytes arrive.
    let full = {
        let mut cfg2 = cfg.clone();
        cfg2.drop_client = usize::MAX;
        let mut c2 = Coordinator::new(cfg2, &rt).unwrap();
        c2.step().unwrap().bytes_up
    };
    assert!(rec.bytes_up < full, "dropped client must reduce bytes");
    assert!((rec.bytes_up as f64) > 0.6 * full as f64);
}

#[test]
fn error_feedback_path_runs() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = small_cfg("mlp", Scheme::Tqsgd);
    cfg.quant.error_feedback = true;
    cfg.rounds = 3;
    let mut coord = Coordinator::new(cfg, &rt).unwrap();
    for _ in 0..3 {
        let rec = coord.step().unwrap();
        assert!(rec.train_loss.is_finite());
    }
}

#[test]
fn evaluation_reports_sane_accuracy() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let cfg = small_cfg("cnn", Scheme::Dsgd);
    let mut coord = Coordinator::new(cfg, &rt).unwrap();
    let (loss, acc) = coord.evaluate().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let acc = acc.unwrap();
    assert!((0.0..=1.0).contains(&acc));
    // Untrained model ≈ chance.
    assert!(acc < 0.5, "untrained accuracy {acc} should be near 0.1");
}

#[test]
fn lm_coordinator_trains_transformer() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = small_cfg("tfm_small", Scheme::Tnqsgd);
    cfg.quant.bits = 4;
    cfg.clients = 2;
    cfg.rounds = 3;
    let mut coord = Coordinator::new(cfg, &rt).unwrap();
    let first = coord.step().unwrap().train_loss;
    assert!(first.is_finite() && first > 3.0, "init NLL ~ ln(64): {first}");
    let (nll, acc) = coord.evaluate().unwrap();
    assert!(nll.is_finite() && nll > 0.0);
    assert!(acc.is_none(), "LM eval reports NLL only");
}

// ---------------------------------------------------------------------------
// L1 ↔ L3 parity through PJRT: the pallas kernels and the rust codecs are
// the same function.
// ---------------------------------------------------------------------------

#[test]
fn pallas_uniform_parity_bitexact() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let q = QuantExec::new(&rt, "quant_uniform_b3").unwrap();
    let mut rng = Rng::new(5);
    let g: Vec<f32> =
        (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
    let u: Vec<f32> = (0..q.tile).map(|_| rng.f32()).collect();
    let alpha = 0.04f32;
    let (deq, idx) = q.run_uniform(&g, &u, alpha).unwrap();
    let mut rust_idx = Vec::new();
    quantize_uniform_slice(&g, &u, alpha, 7, &mut rust_idx);
    assert_eq!(idx, rust_idx, "pallas and rust indices must agree exactly");
    for (i, (&d, &k)) in deq.iter().zip(&rust_idx).enumerate() {
        let expect = -alpha + k as f32 * (2.0 * alpha / 7.0);
        assert!((d - expect).abs() < 1e-6, "i={i}: {d} vs {expect}");
    }
}

#[test]
fn pallas_codebook_parity_bitexact() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let q = QuantExec::new(&rt, "quant_nonuniform_b3").unwrap();
    let mut rng = Rng::new(6);
    let g: Vec<f32> =
        (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
    let u: Vec<f32> = (0..q.tile).map(|_| rng.f32()).collect();
    let m = tqsgd::tail::PowerLawModel::new(4.0, 0.01, 0.1);
    let alpha = tqsgd::solver::optimal_alpha_nonuniform(&m, 7);
    let cb = tqsgd::solver::nonuniform_codebook(&m, alpha, 7);
    let (_deq, idx) = q.run_codebook(&g, &u, &cb).unwrap();
    let mut rust_idx = Vec::new();
    quantize_codebook_slice(&g, &u, &cb, &mut rust_idx);
    let mismatches = idx.iter().zip(&rust_idx).filter(|(a, b)| a != b).count();
    assert_eq!(mismatches, 0, "{mismatches} codebook index mismatches");
}

#[test]
fn pallas_biscaled_parity() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let q = QuantExec::new(&rt, "quant_biscaled_b3").unwrap();
    let mut rng = Rng::new(7);
    let g: Vec<f32> =
        (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
    let u: Vec<f32> = (0..q.tile).map(|_| rng.f32()).collect();
    // The artifact pins s_beta=5, s_alpha=2 (manifest quant.biscaled_b3).
    let (alpha, beta) = (0.05f32, 0.02f32);
    let (deq, idx) = q.run_biscaled(&g, &u, alpha, beta).unwrap();
    // Compare against the rust codebook path with the equivalent codebook.
    let mut cb = Vec::new();
    cb.push(-alpha);
    for i in 0..=5 {
        cb.push(-beta + 2.0 * beta * i as f32 / 5.0);
    }
    cb.push(alpha);
    let mut rust_idx = Vec::new();
    quantize_codebook_slice(&g, &u, &cb, &mut rust_idx);
    let mismatch = idx.iter().zip(&rust_idx).filter(|(a, b)| a != b).count();
    // Boundary FP differences allowed at a tiny rate; values must agree.
    assert!(
        mismatch < q.tile / 1000,
        "biscaled parity: {mismatch}/{} index mismatches",
        q.tile
    );
    for (&d, &k) in deq.iter().zip(&rust_idx) {
        if (d - cb[k as usize]).abs() > 1e-6 {
            // allow the neighbour level at FP boundaries
            let kk = k as usize;
            let near = (kk > 0 && (d - cb[kk - 1]).abs() < 1e-6)
                || (kk + 1 < cb.len() && (d - cb[kk + 1]).abs() < 1e-6);
            assert!(near, "deq {d} not near level {k}");
        }
    }
}

#[test]
fn pallas_tail_stats_matches_rust() {
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let q = QuantExec::new(&rt, "tail_stats").unwrap();
    let mut rng = Rng::new(8);
    let g: Vec<f32> =
        (0..q.tile).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
    let g_min = 0.01f32;
    let stats = q.run_stats(&g, g_min).unwrap();
    // Rust-side reference.
    let mut n = 0f64;
    let mut slog = 0f64;
    let mut sabs = 0f64;
    let mut ssq = 0f64;
    let mut amax = 0f32;
    for &x in &g {
        let a = x.abs();
        if a > g_min {
            n += 1.0;
            slog += (a as f64 / g_min as f64).ln();
        }
        sabs += a as f64;
        ssq += (x as f64) * (x as f64);
        amax = amax.max(a);
    }
    assert_eq!(stats.len(), 5);
    assert!((stats[0] as f64 - n).abs() < 0.5, "n: {} vs {n}", stats[0]);
    assert!((stats[1] as f64 - slog).abs() / slog < 1e-3);
    assert!((stats[2] as f64 - sabs).abs() / sabs < 1e-3);
    assert!((stats[3] as f64 - ssq).abs() / ssq < 1e-2);
    assert!((stats[4] - amax).abs() < 1e-6);
    // MLE from kernel stats recovers gamma ≈ 4.
    let gamma_hat = 1.0 + stats[0] as f64 / stats[1] as f64;
    assert!((gamma_hat - 4.0).abs() < 0.3, "gamma_hat {gamma_hat}");
}

#[test]
fn cnn_gradients_are_heavy_tailed() {
    // The paper's empirical premise (Fig. 1), as a regression test: after a
    // few rounds the fc-group gradient's power-law fit beats Gaussian by a
    // wide KS margin.
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let mut cfg = small_cfg("cnn", Scheme::Dsgd);
    cfg.rounds = 8;
    cfg.clients = 4;
    let mut coord = Coordinator::new(cfg, &rt).unwrap();
    for _ in 0..8 {
        coord.step().unwrap();
    }
    let spec = coord.model_spec().clone();
    let grads = coord.last_aggregate();
    let fc = spec.groups.iter().find(|g| g.group == "fc").unwrap();
    let xs = &grads[fc.start..fc.end];
    let pl = tqsgd::tail::fit_power_law(xs).expect("fit");
    let ga = tqsgd::tail::fit_gaussian(xs);
    assert!(
        pl.ks < 0.1 && ga.ks > 2.0 * pl.ks,
        "power-law KS {} vs gaussian KS {}",
        pl.ks,
        ga.ks
    );
}
