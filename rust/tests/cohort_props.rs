//! Cohort-sampling and two-tier aggregation properties (the million-client
//! round machinery; see `docs/DETERMINISM.md` invariant 5):
//!
//! 1. **K = N degenerates bit-for-bit.** A run with `cohort_k` equal to (or
//!    above) the fleet size produces the exact `replay_digest()` and final
//!    parameter bits of a run with cohort sampling disabled — across every
//!    scenario preset, both pipeline modes, error feedback, and the TCP
//!    transport.
//! 2. **K < N keeps pipeline bit-identity.** An engaged cohort is decided in
//!    the shared round prologue, so barrier and streaming still agree
//!    bit-for-bit.
//! 3. **Cohort draws are uniform.** The seeded per-round draw covers clients
//!    evenly and the cohort mean is an unbiased estimate of the full mean.
//! 4. **Two-tier partial aggregates are unbiased with bounded variance.**
//!    Re-encoding mid-tier partial sums through an unbiased stochastic
//!    quantizer preserves the flat aggregate in expectation, with
//!    per-element noise bounded by the summed per-node quantizer variance.
//! 5. **Resting is not dropping.** With an engaged cohort on a clean
//!    scenario, `dropped_clients` stays 0 (counted against K, not N) and the
//!    parked non-cohort residuals shrink `bytes_per_client`.

use tqsgd::config::{ExperimentConfig, PipelineMode, ScenarioConfig, Scheme};
use tqsgd::coordinator::aggregate::{
    accumulate_sharded, accumulate_two_tier, ContributionData, WeightedContribution,
};
use tqsgd::coordinator::{run_worker, Coordinator, ScenarioEngine, TcpOptions, TcpServer, WorkerOptions};
use tqsgd::metrics::RunLog;
use tqsgd::runtime::{backend_for, Backend, GroupRange};

const PRESETS: [&str; 4] = ["clean", "lossy", "stale", "churn"];

fn native() -> Box<dyn Backend> {
    backend_for("native", "unused").unwrap()
}

/// The pipeline_props grid config: small but real, with simulated arrival
/// times so stale/churn presets have an ordering to cut.
fn grid_cfg(scheme: Scheme, bits: u32, preset: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = scheme;
    cfg.quant.bits = bits;
    cfg.clients = 4;
    cfg.train_size = 384;
    cfg.test_size = 96;
    cfg.seed = 11;
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    cfg.scenario = ScenarioConfig::preset(preset).unwrap();
    cfg
}

/// Run `rounds` rounds in-process; return (replay digest, final parameters).
fn run(backend: &dyn Backend, cfg: &ExperimentConfig, rounds: usize) -> (String, Vec<f32>) {
    let mut coord = Coordinator::new(cfg.clone(), backend).unwrap();
    let mut log = RunLog::default();
    for _ in 0..rounds {
        log.push(coord.step().unwrap());
    }
    (log.replay_digest(), coord.params.clone())
}

fn assert_bit_identical(a: &(String, Vec<f32>), b: &(String, Vec<f32>), label: &str) {
    assert_eq!(a.0, b.0, "{label}: replay digests diverged");
    assert_eq!(a.1.len(), b.1.len(), "{label}: parameter dim diverged");
    for (i, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: param {i} diverged ({x} vs {y})");
    }
}

/// Invariant 5, in-process: cohort_k in {N, > N} must be indistinguishable
/// from cohort_k = 0 — the degenerate path draws nothing and parks nothing.
#[test]
fn full_cohort_is_bit_identical_to_disabled_cohort() {
    let backend = native();
    for preset in PRESETS {
        for pipeline in [PipelineMode::Barrier, PipelineMode::Streaming] {
            let mut cfg = grid_cfg(Scheme::Tnqsgd, 3, preset);
            cfg.pipeline = pipeline;
            let reference = run(backend.as_ref(), &cfg, 3);
            for k in [cfg.clients, cfg.clients + 5] {
                let mut c = cfg.clone();
                c.cohort_k = k;
                let got = run(backend.as_ref(), &c, 3);
                let label = format!("tnqsgd@{preset} {} K={k}", pipeline.name());
                assert_bit_identical(&reference, &got, &label);
            }
        }
    }
}

/// Same degenerate-K invariant with error feedback in play: K >= N must not
/// touch (let alone park) any EF residual.
#[test]
fn full_cohort_parity_holds_with_error_feedback() {
    let backend = native();
    for preset in PRESETS {
        let mut cfg = grid_cfg(Scheme::Tqsgd, 3, preset);
        cfg.quant.error_feedback = true;
        let reference = run(backend.as_ref(), &cfg, 4);
        let mut c = cfg.clone();
        c.cohort_k = c.clients;
        let got = run(backend.as_ref(), &c, 4);
        assert_bit_identical(&reference, &got, &format!("tqsgd+ef@{preset} K=N"));
    }
}

/// An engaged cohort (K < N) is decided in the shared round prologue, so
/// the barrier/streaming bit-identity contract must survive it — including
/// the park/unpark state migration under error feedback.
#[test]
fn engaged_cohort_keeps_pipeline_bit_identity() {
    let backend = native();
    for preset in PRESETS {
        let mut cfg = grid_cfg(Scheme::Tqsgd, 3, preset);
        cfg.quant.error_feedback = true;
        cfg.cohort_k = 2;
        let mut barrier = cfg.clone();
        barrier.pipeline = PipelineMode::Barrier;
        let a = run(backend.as_ref(), &barrier, 4);
        let mut streaming = cfg;
        streaming.pipeline = PipelineMode::Streaming;
        let b = run(backend.as_ref(), &streaming, 4);
        assert_bit_identical(&a, &b, &format!("tqsgd+ef@{preset} K=2 modes"));
    }
}

/// Invariant 5 over real sockets: a TCP run at K = N must match the
/// in-process barrier run with cohort sampling disabled, bit for bit.
#[test]
fn tcp_full_cohort_matches_in_process_disabled_cohort() {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = Scheme::Tnqsgd;
    cfg.quant.bits = 3;
    cfg.clients = 3;
    cfg.rounds = 4;
    cfg.train_size = 384;
    cfg.test_size = 96;
    cfg.seed = 11;
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    cfg.cohort_k = 3; // == clients: engaged in name, degenerate in effect

    let opts = TcpOptions {
        io_timeout: std::time::Duration::from_secs(30),
        accept_timeout: std::time::Duration::from_secs(30),
    };
    let server = TcpServer::bind("127.0.0.1:0", &cfg, opts).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..cfg.clients)
        .map(|id| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, id, &WorkerOptions::default()))
        })
        .collect();
    let transport = server.accept_workers().unwrap();
    let backend = native();
    let mut coord =
        Coordinator::with_transport(cfg.clone(), backend.as_ref(), Box::new(transport)).unwrap();
    let log = coord.run_remote(false).unwrap();
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker must exit cleanly");
    }

    let mut ref_cfg = cfg;
    ref_cfg.cohort_k = 0;
    ref_cfg.pipeline = PipelineMode::Barrier;
    let mut ref_coord = Coordinator::new(ref_cfg, backend.as_ref()).unwrap();
    let ref_log = ref_coord.run(false).unwrap();
    assert_eq!(
        log.replay_digest(),
        ref_log.replay_digest(),
        "tcp K=N digest diverged from in-process cohort-disabled barrier"
    );
    for (i, (a, b)) in coord.params.iter().zip(&ref_coord.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i} diverged ({a} vs {b})");
    }
}

/// The seeded cohort draw: K sorted unique indices per round, per-client
/// selection frequency uniform, and the cohort mean an unbiased estimator
/// of the full-population mean (all deterministic under the fixed seed).
#[test]
fn cohort_draws_are_uniform_and_unbiased() {
    let (n, k, rounds) = (10usize, 3usize, 4000u64);
    let eng = ScenarioEngine::new(ScenarioConfig::default(), n, 42);
    // Fixed "client values" with a heavy spread, so bias would show.
    let v: Vec<f64> = (0..n).map(|i| (i * i) as f64).collect();
    let full_mean = v.iter().sum::<f64>() / n as f64;
    let mut counts = vec![0u64; n];
    let mut mean_of_means = 0.0;
    for r in 0..rounds {
        let cohort = eng.sample_cohort(r, n, k);
        assert_eq!(cohort.len(), k, "round {r}: cohort size");
        assert!(
            cohort.windows(2).all(|w| w[0] < w[1]) && *cohort.last().unwrap() < n,
            "round {r}: cohort must be sorted, unique, in range: {cohort:?}"
        );
        for &i in &cohort {
            counts[i] += 1;
        }
        mean_of_means += cohort.iter().map(|&i| v[i]).sum::<f64>() / k as f64;
    }
    mean_of_means /= rounds as f64;
    assert!(
        (mean_of_means - full_mean).abs() < 0.05 * full_mean,
        "cohort mean {mean_of_means} is a biased estimate of {full_mean}"
    );
    let expect = rounds * k as u64 / n as u64;
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            (c as f64) > 0.85 * expect as f64 && (c as f64) < 1.15 * expect as f64,
            "client {i} drawn {c} times, expected ~{expect}: draw is not uniform"
        );
    }
}

/// Two-tier re-encoded partial sums with an unbiased stochastic quantizer
/// (QSGD): the per-round aggregate is lossy (bits change by design), but
/// its mean over independent rounds converges on the flat aggregate, and
/// the per-element spread stays within the summed per-node quantizer
/// variance envelope.
#[test]
fn two_tier_partial_aggregates_are_unbiased_with_bounded_variance() {
    let dim = 96usize;
    let groups = vec![
        GroupRange { group: "a".into(), start: 0, end: 48 },
        GroupRange { group: "b".into(), start: 48, end: dim },
    ];
    // 9 deterministic dense contributions at uniform normalized weights.
    let n_items = 9usize;
    let dense: Vec<Vec<f32>> = (0..n_items)
        .map(|j| (0..dim).map(|e| ((j * 31 + e) % 17) as f32 * 0.1 - 0.8).collect())
        .collect();
    let items: Vec<WeightedContribution<'_>> = dense
        .iter()
        .map(|d| WeightedContribution {
            data: ContributionData::Dense(&d[..]),
            w: 1.0 / n_items as f32,
        })
        .collect();
    let mut flat = vec![0.0f32; dim];
    accumulate_sharded(&groups, &items, &mut flat, 2).unwrap();

    let mut quant = ExperimentConfig::default().quant;
    quant.scheme = Scheme::Qsgd;
    quant.bits = 4;
    quant.error_feedback = false;

    let rounds = 600u64;
    let mut agg = vec![0.0f32; dim];
    let mut sum = vec![0.0f64; dim];
    let mut sum_sq = vec![0.0f64; dim];
    let mut any_lossy = false;
    for r in 0..rounds {
        let bytes = accumulate_two_tier(&groups, &items, &mut agg, 2, &quant, 7, r).unwrap();
        assert!(bytes > 0, "round {r}: mid-tier re-encode must ship frames");
        for e in 0..dim {
            sum[e] += agg[e] as f64;
            sum_sq[e] += (agg[e] as f64) * (agg[e] as f64);
            if agg[e].to_bits() != flat[e].to_bits() {
                any_lossy = true;
            }
        }
    }
    assert!(any_lossy, "two-tier re-quantization should change bits (it is lossy by design)");
    for e in 0..dim {
        let mean = sum[e] / rounds as f64;
        let var = (sum_sq[e] / rounds as f64 - mean * mean).max(0.0);
        assert!(
            (mean - flat[e] as f64).abs() < 0.01,
            "element {e}: tiered mean {mean} drifted from flat {}",
            flat[e]
        );
        // ceil(sqrt(9)) = 3 nodes, each with per-element stochastic-rounding
        // variance <= (alpha/s)^2/4; partials stay within |0.31|, s = 15 at
        // 4 bits, so the summed envelope is ~1e-3 — 2.5e-3 is generous.
        assert!(var < 2.5e-3, "element {e}: variance {var} above the per-node envelope");
    }
}

/// The single-item degenerate tree takes the flat path exactly: zero tier
/// bytes, bit-identical aggregate.
#[test]
fn two_tier_degenerates_to_flat_for_tiny_fan_in() {
    let dim = 32usize;
    let groups = vec![GroupRange { group: "a".into(), start: 0, end: dim }];
    let dense: Vec<f32> = (0..dim).map(|e| e as f32 * 0.01 - 0.2).collect();
    let items =
        vec![WeightedContribution { data: ContributionData::Dense(&dense[..]), w: 1.0 }];
    let mut flat = vec![0.0f32; dim];
    accumulate_sharded(&groups, &items, &mut flat, 1).unwrap();
    let quant = {
        let mut q = ExperimentConfig::default().quant;
        q.scheme = Scheme::Qsgd;
        q.bits = 4;
        q
    };
    let mut agg = vec![0.0f32; dim];
    let bytes = accumulate_two_tier(&groups, &items, &mut agg, 1, &quant, 7, 0).unwrap();
    assert_eq!(bytes, 0, "a single-node tree must not re-encode anything");
    for (e, (a, b)) in agg.iter().zip(&flat).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "element {e} diverged on the degenerate path");
    }
}

/// Engaged cohort end-to-end: resting clients are not failures
/// (`dropped_clients` counts against K), training stays finite, and parking
/// the non-cohort EF residuals shrinks the per-client memory footprint
/// versus full participation.
#[test]
fn engaged_cohort_rests_clients_without_counting_drops_and_compacts_state() {
    let backend = native();
    let base = {
        let mut cfg = grid_cfg(Scheme::Tqsgd, 3, "clean");
        cfg.quant.error_feedback = true;
        cfg
    };
    let full_bpc = {
        let mut coord = Coordinator::new(base.clone(), backend.as_ref()).unwrap();
        let mut last = 0u64;
        for _ in 0..4 {
            last = coord.step().unwrap().bytes_per_client;
        }
        last
    };
    let mut cfg = base;
    cfg.cohort_k = 2;
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    let mut cohort_bpc = 0u64;
    for _ in 0..4 {
        let rec = coord.step().unwrap();
        assert_eq!(rec.dropped_clients, 0, "resting non-cohort clients are not drops");
        assert!(rec.train_loss.is_finite());
        assert!(rec.bytes_per_client > 0, "memory metric must be recorded");
        cohort_bpc = rec.bytes_per_client;
    }
    assert!(coord.params.iter().all(|p| p.is_finite()));
    assert!(
        cohort_bpc < full_bpc,
        "parked residuals should compact state: cohort {cohort_bpc} vs full {full_bpc}"
    );
}
