//! Streaming-vs-barrier bit-identity for the round pipeline, across the
//! full configuration grid: every compression scheme × every bit width the
//! wire carries (1..=8) × the degraded-round scenario presets, plus shard
//! widths and error feedback.
//!
//! The contract under test (see `coordinator/pipeline.rs` for the
//! argument): `PipelineMode::Streaming` overlaps client encode with server
//! decode, but buffers per-client contributions and applies them in the
//! fixed (origin round, client id) order — so the parameters and the whole
//! deterministic `RunLog::replay_digest()` must match `PipelineMode::Barrier`
//! bit-for-bit, at every worker/shard count.

use tqsgd::config::{ExperimentConfig, PipelineMode, ScenarioConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::metrics::RunLog;
use tqsgd::runtime::{backend_for, Backend};

/// The scenario presets the grid sweeps: the synchronous happy path, lossy
/// uplinks (retransmits + total losses + EF repair), bounded staleness
/// (late frames cross rounds, decayed weights) and membership churn
/// (reweighted survivors, possible empty-loss rounds).
const PRESETS: [&str; 4] = ["clean", "lossy", "stale", "churn"];

fn native() -> Box<dyn Backend> {
    backend_for("native", "unused").unwrap()
}

fn grid_cfg(scheme: Scheme, bits: u32, preset: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = scheme;
    cfg.quant.bits = bits;
    // 4 clients > stale_k = 3, so the stale preset actually defers frames.
    cfg.clients = 4;
    cfg.train_size = 384;
    cfg.test_size = 96;
    cfg.seed = 11;
    // Distinct simulated arrival times so the staleness schedule has a real
    // ordering to cut.
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    cfg.scenario = ScenarioConfig::preset(preset).unwrap();
    cfg
}

/// Run `rounds` rounds; return (replay digest, final parameters).
fn run(backend: &dyn Backend, cfg: &ExperimentConfig, rounds: usize) -> (String, Vec<f32>) {
    let mut coord = Coordinator::new(cfg.clone(), backend).unwrap();
    let mut log = RunLog::default();
    for _ in 0..rounds {
        log.push(coord.step().unwrap());
    }
    (log.replay_digest(), coord.params.clone())
}

fn assert_modes_match(backend: &dyn Backend, cfg: &ExperimentConfig, rounds: usize, label: &str) {
    let mut barrier = cfg.clone();
    barrier.pipeline = PipelineMode::Barrier;
    let (d_barrier, p_barrier) = run(backend, &barrier, rounds);
    let mut streaming = cfg.clone();
    streaming.pipeline = PipelineMode::Streaming;
    let (d_streaming, p_streaming) = run(backend, &streaming, rounds);
    assert_eq!(d_barrier, d_streaming, "{label}: replay digests diverged");
    assert_eq!(p_barrier.len(), p_streaming.len(), "{label}: parameter dim diverged");
    for (i, (a, b)) in p_barrier.iter().zip(&p_streaming).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: param {i} diverged ({a} vs {b})");
    }
}

/// The acceptance grid: every scheme × bits 1..=8 × scenario preset.
/// (TBQSGD needs s >= 3 quantization intervals, so b = 1 is skipped for it,
/// as everywhere else in the suite.)
#[test]
fn streaming_is_bit_identical_to_barrier_for_every_scheme_bits_preset() {
    let backend = native();
    for preset in PRESETS {
        for scheme in Scheme::all() {
            for bits in 1..=8u32 {
                if scheme == Scheme::Tbqsgd && bits < 2 {
                    continue;
                }
                let cfg = grid_cfg(scheme, bits, preset);
                let label = format!("{}@{preset} b{bits}", scheme.name());
                assert_modes_match(backend.as_ref(), &cfg, 3, &label);
            }
        }
    }
}

/// Worker-count sweep: the streaming pipeline must agree with the
/// single-shard barrier reference at every aggregation shard width, in
/// every preset — the shard count is a pure performance knob in both modes.
#[test]
fn streaming_is_bit_identical_at_every_shard_width() {
    let backend = native();
    for preset in PRESETS {
        let reference = {
            let mut cfg = grid_cfg(Scheme::Tnqsgd, 3, preset);
            cfg.agg_shards = 1;
            cfg.pipeline = PipelineMode::Barrier;
            run(backend.as_ref(), &cfg, 3)
        };
        for pipeline in [PipelineMode::Barrier, PipelineMode::Streaming] {
            for shards in [1usize, 2, 7] {
                let mut cfg = grid_cfg(Scheme::Tnqsgd, 3, preset);
                cfg.agg_shards = shards;
                cfg.pipeline = pipeline;
                let got = run(backend.as_ref(), &cfg, 3);
                assert_eq!(
                    reference,
                    got,
                    "tnqsgd@{preset} {} x{shards} != barrier x1",
                    pipeline.name()
                );
            }
        }
    }
}

/// Encode-pool sweep: the barrier pipeline's client-side encode pool
/// (`encode_threads`, the compression-side mirror of `agg_shards`) chunks
/// the active clients in order and per-client codec state is disjoint, so
/// every pool width must reproduce the single-worker run bit-for-bit —
/// digest and parameters — in every preset. Invariant 8 in
/// docs/DETERMINISM.md.
#[test]
fn barrier_encode_pool_is_bit_identical_at_every_width() {
    let backend = native();
    for preset in PRESETS {
        let reference = {
            let mut cfg = grid_cfg(Scheme::Tqsgd, 4, preset);
            cfg.encode_threads = 1;
            cfg.pipeline = PipelineMode::Barrier;
            run(backend.as_ref(), &cfg, 3)
        };
        for threads in [1usize, 2, 7] {
            let mut cfg = grid_cfg(Scheme::Tqsgd, 4, preset);
            cfg.encode_threads = threads;
            cfg.pipeline = PipelineMode::Barrier;
            let got = run(backend.as_ref(), &cfg, 3);
            assert_eq!(
                reference, got,
                "tqsgd@{preset} encode_threads={threads} != single worker"
            );
        }
    }
}

/// Error feedback moves state repair (`restore_lost`) onto the encode
/// workers in streaming mode; the per-client mutation sequence is unchanged
/// so lossy EF runs must stay bit-identical too.
#[test]
fn streaming_is_bit_identical_with_error_feedback() {
    let backend = native();
    for preset in PRESETS {
        let mut cfg = grid_cfg(Scheme::Tqsgd, 3, preset);
        cfg.quant.error_feedback = true;
        let label = format!("tqsgd+ef@{preset}");
        assert_modes_match(backend.as_ref(), &cfg, 4, &label);
    }
}

/// The streaming pipeline's contribution buffers are sized on the first
/// round and reused forever: together with the frame arenas and the
/// staleness-hist scratch, steady-state streaming rounds allocate nothing.
#[test]
fn streaming_pipeline_is_zero_alloc_in_steady_state() {
    let backend = native();
    let mut cfg = grid_cfg(Scheme::Tqsgd, 3, "stale");
    cfg.pipeline = PipelineMode::Streaming;
    let mut coord = Coordinator::new(cfg, backend.as_ref()).unwrap();
    for _ in 0..4 {
        coord.step().unwrap();
    }
    let (frames, hist, contrib) =
        (coord.frame_allocs(), coord.hist_reallocs(), coord.contrib_reallocs());
    assert!(frames > 0, "warm-up must have allocated frames");
    assert!(contrib > 0, "warm-up must have sized the contribution buffers");
    for _ in 0..5 {
        coord.step().unwrap();
    }
    assert_eq!(coord.frame_allocs(), frames, "steady-state frame allocs moved");
    assert_eq!(coord.hist_reallocs(), hist, "steady-state hist scratch regrew");
    assert_eq!(coord.contrib_reallocs(), contrib, "steady-state contrib buffers regrew");
}
