//! Multi-process transport contracts (see `docs/PROTOCOL.md` and
//! `docs/DETERMINISM.md`):
//!
//! 1. every `quant::wire` frame kind survives a real loopback-TCP transit
//!    byte-for-byte, pinned against the same golden fixtures as
//!    `quant_props.rs`;
//! 2. a 3-worker × 5-round run over real sockets produces a
//!    `RunLog::replay_digest()` and final parameters bit-identical to the
//!    in-process barrier pipeline (the tcp == in-process invariant);
//! 3. killing a worker mid-run takes the server's existing drop/reweight
//!    path — the run finishes (no hang), records `dropped_clients`, and
//!    the parameters stay finite;
//! 4. a chaos-killed worker (cooperative kill + REJOIN next round) and
//!    seeded payload corruption (CRC32 + retransmit) are digest-parity
//!    with the in-process barrier model, and leave the learning
//!    trajectory bit-identical to a fault-free run;
//! 5. checkpoint-at-k + resume is bit-identical to the uninterrupted run
//!    (DETERMINISM.md invariant 7) for every scheme × EF setting, plus an
//!    EF + binding-bit-budget combination.
//!
//! Workers here run as threads calling the same [`run_worker`] entrypoint
//! the `tqsgd worker` subcommand uses; the CI smoke job covers the real
//! process-per-worker topology via `tqsgd launch --verify-digest`.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use tqsgd::config::{ExperimentConfig, PipelineMode, ScenarioConfig, Scheme};
use tqsgd::coordinator::{
    run_worker, Coordinator, TcpOptions, TcpServer, WorkerExit, WorkerOptions,
};
use tqsgd::metrics::RunLog;
use tqsgd::quant::wire::Payload;
use tqsgd::runtime::{backend_for, Backend};

fn native() -> Box<dyn Backend> {
    backend_for("native", "unused").unwrap()
}

/// A small but real experiment: the paper's nonuniform scheme at 3 bits so
/// uplinks carry codebook frames, with enough data per client to train.
fn tcp_cfg(clients: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = Scheme::Tnqsgd;
    cfg.quant.bits = 3;
    cfg.clients = clients;
    cfg.rounds = rounds;
    cfg.train_size = 384;
    cfg.test_size = 96;
    cfg.seed = 11;
    cfg.net.bandwidth_bytes_per_sec = 1e6;
    cfg.net.latency_sec = 0.01;
    cfg
}

/// Generous on a healthy loopback, tight enough that a genuine deadlock
/// fails the test instead of hanging the suite.
fn test_opts() -> TcpOptions {
    TcpOptions { io_timeout: Duration::from_secs(30), accept_timeout: Duration::from_secs(30) }
}

/// The golden wire fixtures from `quant_props.rs`, restated so a drift in
/// either copy breaks a test: (payload, exact on-the-wire bytes).
fn golden_frames() -> Vec<(Payload, Vec<u8>)> {
    vec![
        (
            Payload::Raw(vec![1.0, -2.0]),
            vec![
                0x54, 0x51, // magic "TQ"
                0x00, // kind: raw
                0x00, // bits
                0x02, 0x00, 0x00, 0x00, // d = 2
                0x00, 0x00, 0x80, 0x3F, // 1.0f32
                0x00, 0x00, 0x00, 0xC0, // -2.0f32
            ],
        ),
        (
            Payload::Uniform { alpha: 1.0, s: 7, idx: vec![0, 3, 7, 5] },
            vec![
                0x54, 0x51, // magic
                0x01, // kind: uniform
                0x03, // 3 bits per index
                0x04, 0x00, 0x00, 0x00, // d = 4
                0x00, 0x00, 0x80, 0x3F, // alpha = 1.0
                0x07, 0x00, // s = 7
                0xD8, 0x0B, // indices 0,3,7,5 packed LSB-first
            ],
        ),
        (
            Payload::Codebook { levels: vec![-0.5, 0.0, 0.5], idx: vec![2, 0, 1] },
            vec![
                0x54, 0x51, // magic
                0x02, // kind: codebook
                0x02, // 2 bits per index
                0x03, 0x00, 0x00, 0x00, // d = 3
                0x03, 0x00, // 3 levels
                0x00, 0x00, 0x00, 0xBF, // -0.5f32
                0x00, 0x00, 0x00, 0x00, // 0.0f32
                0x00, 0x00, 0x00, 0x3F, // 0.5f32
                0x12, // indices 2,0,1 packed LSB-first
            ],
        ),
        (
            Payload::Sparse { d: 6, pairs: vec![(1, 1.5), (4, -0.25)] },
            vec![
                0x54, 0x51, // magic
                0x03, // kind: sparse
                0x00, // bits
                0x06, 0x00, 0x00, 0x00, // d = 6
                0x02, 0x00, 0x00, 0x00, // k = 2
                0x01, 0x00, 0x00, 0x00, // index 1
                0x04, 0x00, 0x00, 0x00, // index 4
                0x00, 0x00, 0xC0, 0x3F, // 1.5f32
                0x00, 0x00, 0x80, 0xBE, // -0.25f32
            ],
        ),
        (
            Payload::Multiscale { alpha: 1.0, beta: 0.25, s_hi: 2, s_lo: 2, idx: vec![0, 4, 2] },
            vec![
                0x54, 0x51, // magic
                0x04, // kind: multiscale
                0x03, // 3 bits per index
                0x03, 0x00, 0x00, 0x00, // d = 3
                0x00, 0x00, 0x80, 0x3F, // alpha = 1.0
                0x00, 0x00, 0x80, 0x3E, // beta = 0.25
                0x02, 0x00, // s_hi = 2
                0x02, 0x00, // s_lo = 2
                0xA0, 0x00, // indices 0,4,2 packed LSB-first
            ],
        ),
    ]
}

/// Every frame kind, length-prefixed exactly as the transport frames it,
/// across a real TCP socket: the bytes and the decoded payload must both
/// come back unchanged.
#[test]
fn loopback_tcp_roundtrips_every_golden_frame_kind() {
    let fixtures = golden_frames();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sender = {
        let frames: Vec<Vec<u8>> = fixtures.iter().map(|(_, b)| b.clone()).collect();
        thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            for f in &frames {
                s.write_all(&(f.len() as u32).to_le_bytes()).unwrap();
                s.write_all(f).unwrap();
            }
        })
    };
    let (mut conn, _) = listener.accept().unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for (expect, golden) in &fixtures {
        let mut len = [0u8; 4];
        conn.read_exact(&mut len).unwrap();
        let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
        conn.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, golden, "frame bytes changed in TCP transit");
        let decoded = Payload::decode(&buf).expect("frame must decode after transit");
        assert_eq!(&decoded, expect, "decoded payload diverged after transit");
    }
    sender.join().unwrap();
}

/// The tentpole acceptance test: same seed + config, three real workers
/// over TCP vs the in-process barrier pipeline — replay digest and every
/// final parameter bit must match.
#[test]
fn tcp_run_matches_in_process_barrier_bit_for_bit() {
    let cfg = tcp_cfg(3, 5);
    let server = TcpServer::bind("127.0.0.1:0", &cfg, test_opts()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..cfg.clients)
        .map(|id| {
            let addr = addr.clone();
            thread::spawn(move || run_worker(&addr, id, &WorkerOptions::default()))
        })
        .collect();
    let transport = server.accept_workers().unwrap();
    let backend = native();
    let mut coord =
        Coordinator::with_transport(cfg.clone(), backend.as_ref(), Box::new(transport)).unwrap();
    let log = coord.run_remote(false).unwrap();
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker must exit cleanly");
    }

    let mut ref_cfg = cfg;
    ref_cfg.pipeline = PipelineMode::Barrier;
    let mut ref_coord = Coordinator::new(ref_cfg, backend.as_ref()).unwrap();
    let ref_log = ref_coord.run(false).unwrap();
    assert_eq!(
        log.replay_digest(),
        ref_log.replay_digest(),
        "multi-process digest diverged from in-process barrier"
    );
    assert_eq!(coord.params.len(), ref_coord.params.len());
    for (i, (a, b)) in coord.params.iter().zip(&ref_coord.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i} diverged ({a} vs {b})");
    }
}

/// Kill one worker after two rounds: the server must detect the dead
/// socket, finish every remaining round with the survivors (drop path, no
/// hang), record the drop in `dropped_clients`, and keep the parameters
/// finite.
#[test]
fn killed_worker_takes_the_drop_path_without_hanging() {
    let cfg = tcp_cfg(3, 5);
    let opts = TcpOptions { io_timeout: Duration::from_secs(10), ..test_opts() };
    let server = TcpServer::bind("127.0.0.1:0", &cfg, opts).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..cfg.clients)
        .map(|id| {
            let addr = addr.clone();
            // Worker 2 vanishes after two active rounds — no goodbye, just
            // a dead socket, like a SIGKILL mid-run.
            let wopts = WorkerOptions {
                max_rounds: if id == 2 { Some(2) } else { None },
                ..WorkerOptions::default()
            };
            thread::spawn(move || run_worker(&addr, id, &wopts))
        })
        .collect();
    let transport = server.accept_workers().unwrap();
    let backend = native();
    let mut coord =
        Coordinator::with_transport(cfg.clone(), backend.as_ref(), Box::new(transport)).unwrap();
    let log = coord.run_remote(false).unwrap();
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker must exit cleanly");
    }

    assert_eq!(log.records.len(), cfg.rounds, "the run must finish every round");
    assert!(
        log.records.iter().skip(2).all(|r| r.dropped_clients >= 1),
        "a killed worker must surface as dropped_clients from its death round on"
    );
    assert!(log.records.iter().all(|r| r.train_loss.is_finite()));
    assert!(coord.params.iter().all(|p| p.is_finite()), "params must stay finite under the fault");
}

/// The chaos tentpole: the seeded kill round really kills a worker
/// ([`WorkerExit::ChaosKilled`]), the respawned worker rejoins next round
/// via REJOIN + the parked STATE blob, and seeded payload corruption takes
/// the CRC32 → RETRANSMIT path. All of it must be digest-parity with the
/// in-process barrier model of the same config, and — because the kill is
/// cooperative and corruption is always repaired by a clean retransmit —
/// the final parameters must be bit-identical to a fault-free run.
#[test]
fn chaos_killed_worker_rejoins_bit_for_bit() {
    let mut cfg = tcp_cfg(3, 6);
    cfg.quant.estimate_every = 1;
    cfg.quant.error_feedback = true;
    cfg.scenario = ScenarioConfig::preset("chaos").unwrap();
    // Preset corruption is p=0.25; raise it so this seed is effectively
    // guaranteed at least one corrupt frame across 3 clients × 6 rounds.
    cfg.scenario.chaos_corrupt_prob = 0.5;
    let kill_round = cfg.scenario.chaos_kill_round;
    assert!(kill_round + 1 < cfg.rounds, "rejoin round must land inside the run");

    let server = TcpServer::bind("127.0.0.1:0", &cfg, test_opts()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..cfg.clients)
        .map(|id| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut rejoin_from = None;
                loop {
                    let opts = WorkerOptions { rejoin_from, ..WorkerOptions::default() };
                    match run_worker(&addr, id, &opts).expect("worker failed") {
                        WorkerExit::Clean => return,
                        // Chaos killed us: come back as a fresh "process"
                        // carrying only the rejoin round, like the launch
                        // monitor's respawn with --rejoin-from.
                        WorkerExit::ChaosKilled { round } => rejoin_from = Some(round),
                    }
                }
            })
        })
        .collect();
    let transport = server.accept_workers().unwrap();
    let backend = native();
    let mut coord =
        Coordinator::with_transport(cfg.clone(), backend.as_ref(), Box::new(transport)).unwrap();
    let log = coord.run_remote(false).unwrap();
    for w in workers {
        w.join().expect("worker thread panicked");
    }

    assert_eq!(log.records.len(), cfg.rounds, "the kill must not cost a round");
    assert_eq!(
        log.records[kill_round + 1].rejoined_clients, 1,
        "the victim must rejoin exactly one round after its kill"
    );
    assert!(
        log.records.iter().all(|r| r.dropped_clients == 0),
        "a cooperative kill + rejoin must never take the drop path"
    );
    let corrupt: u32 = log.records.iter().map(|r| r.corrupt_frames).sum();
    assert!(corrupt > 0, "seeded corruption must surface in corrupt_frames");
    let retrans: u64 = log.records.iter().map(|r| r.retransmitted_bytes).sum();
    assert!(retrans > 0, "every corrupt frame must be retransmitted");

    // Digest parity with the in-process barrier model of the same chaos.
    let mut ref_cfg = cfg.clone();
    ref_cfg.pipeline = PipelineMode::Barrier;
    let mut ref_coord = Coordinator::new(ref_cfg, backend.as_ref()).unwrap();
    let ref_log = ref_coord.run(false).unwrap();
    assert_eq!(
        log.replay_digest(),
        ref_log.replay_digest(),
        "chaos multi-process digest diverged from the in-process barrier model"
    );
    for (i, (a, b)) in coord.params.iter().zip(&ref_coord.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i} diverged ({a} vs {b})");
    }

    // Faults repaired in-wire must be invisible to learning: same params as
    // a run with the chaos harness off entirely.
    let mut clean_cfg = cfg;
    clean_cfg.pipeline = PipelineMode::Barrier;
    clean_cfg.scenario = ScenarioConfig::default();
    let mut clean = Coordinator::new(clean_cfg, backend.as_ref()).unwrap();
    clean.run(false).unwrap();
    for (i, (a, b)) in coord.params.iter().zip(&clean.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "chaos must not perturb learning (param {i})");
    }
}

/// Invariant 7 in full: run 2 rounds, checkpoint, resume in a fresh
/// coordinator, finish — parameters and `replay_digest()` must match the
/// uninterrupted run bit for bit.
fn assert_checkpoint_roundtrip(
    cfg: &ExperimentConfig,
    backend: &dyn Backend,
    dir: &std::path::Path,
    tag: &str,
) {
    let path = dir.join(format!("{tag}.ckpt"));

    let mut full = Coordinator::new(cfg.clone(), backend).unwrap();
    let mut full_log = RunLog { config_id: cfg.id(), ..Default::default() };
    for _ in 0..cfg.rounds {
        full_log.push(full.step().unwrap());
    }

    let mut head = Coordinator::new(cfg.clone(), backend).unwrap();
    let mut head_log = RunLog { config_id: cfg.id(), ..Default::default() };
    for _ in 0..2 {
        head_log.push(head.step().unwrap());
    }
    head.checkpoint(&head_log, &path).unwrap();
    drop(head); // the interruption: the original process is gone

    let mut tail = Coordinator::resume(&path, backend).unwrap();
    let tail_log = tail.run(false).unwrap();
    assert_eq!(tail_log.records.len(), cfg.rounds, "{tag}: resumed log must cover every round");
    assert_eq!(
        tail_log.replay_digest(),
        full_log.replay_digest(),
        "{tag}: resumed digest diverged from the uninterrupted run"
    );
    for (i, (a, b)) in full.params.iter().zip(&tail.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: param {i} diverged ({a} vs {b})");
    }
    std::fs::remove_file(&path).ok();
}

/// Checkpoint/resume bit-exactness (invariant 7) across the full scheme
/// matrix, with and without error feedback, plus one EF + binding fleet
/// bit-budget combination so the scheduler's observation table is part of
/// the snapshot under test.
#[test]
fn checkpoint_resume_is_bit_exact_for_every_scheme() {
    let backend = native();
    let dir = std::env::temp_dir().join(format!("tqcp-props-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    for scheme in Scheme::all() {
        for ef in [false, true] {
            let mut cfg = tcp_cfg(2, 4);
            cfg.quant.scheme = scheme;
            cfg.quant.estimate_every = 1;
            cfg.quant.error_feedback = ef;
            let tag = format!("{}-ef{}", scheme.name(), u8::from(ef));
            assert_checkpoint_roundtrip(&cfg, backend.as_ref(), &dir, &tag);
        }
    }

    let mut cfg = tcp_cfg(2, 4);
    cfg.quant.scheme = Scheme::Multiscale;
    cfg.quant.estimate_every = 1;
    cfg.quant.error_feedback = true;
    cfg.bit_budget = 6000; // binding at mlp_tiny sizes: the scheduler engages
    assert_checkpoint_roundtrip(&cfg, backend.as_ref(), &dir, "multiscale-ef-budget");
}
