//! CLI substrate: subcommands + `--flag value` / `--flag=value` parsing.
//!
//! Hand-rolled (no clap in the build image). Supports:
//! * positional subcommand as the first free argument,
//! * `--key value`, `--key=value`, boolean `--key`,
//! * typed getters with defaults and error messages,
//! * auto-generated usage text from registered flags.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First free argument, e.g. `train` in `tqsgd train --rounds 5`.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs; bare `--key` stores `"true"`.
    pub flags: BTreeMap<String, String>,
    /// Free arguments after the subcommand (or after a `--` terminator).
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional.
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else if a.starts_with('-') && a.len() > 1 && !a[1..].starts_with(|c: char| c.is_ascii_digit()) {
                bail!("short flags are not supported: {a}");
            } else if out.subcommand.is_none() && out.flags.is_empty() && out.positional.is_empty()
            {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process's own command line (skipping the program name).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--key` was given at all (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// The raw value of `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// `usize` flag with a default; parse failures name the flag.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// `u64` flag with a default; parse failures name the flag.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// `f64` flag with a default; parse failures name the flag.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Boolean flag with a default; accepts `true/false`, `1/0`, `yes/no`.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{key} expects a boolean, got {v:?}"),
        }
    }
}

/// A registered flag, for usage text.
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// One-line description shown in the usage block.
    pub help: &'static str,
    /// Default value rendered in the usage block.
    pub default: &'static str,
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, about: &str, flags: &[FlagSpec]) -> String {
    let mut s = format!("tqsgd {cmd} — {about}\n\nflags:\n");
    for f in flags {
        s.push_str(&format!("  --{:<22} {} (default: {})\n", f.name, f.help, f.default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--model", "cnn", "--bits=3", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("cnn"));
        assert_eq!(a.usize_or("bits", 0).unwrap(), 3);
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn flag_value_can_be_negative_number() {
        let a = parse(&["x", "--lr", "-0.5"]);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = parse(&["x", "--dry-run", "--n", "4"]);
        assert!(a.bool_or("dry-run", false).unwrap());
        assert_eq!(a.usize_or("n", 0).unwrap(), 4);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.usize_or("rounds", 100).unwrap(), 100);
        assert_eq!(a.str_or("model", "mlp"), "mlp");
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
        let b = parse(&["x", "--flag", "maybe"]);
        assert!(b.bool_or("flag", false).is_err());
    }

    #[test]
    fn double_dash_positional() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn usage_renders() {
        let u = usage("train", "train a model", &[FlagSpec { name: "bits", help: "quant bits", default: "3" }]);
        assert!(u.contains("--bits"));
    }
}
