//! Minimal JSON substrate (parser + writer) — no serde in the build image.
//!
//! Supports the full JSON grammar we produce/consume: objects, arrays,
//! numbers (f64), strings with escapes, booleans, null. Used for
//! `artifacts/manifest.json`, the config system and metrics output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object; `BTreeMap` keeps key order deterministic when re-serialized.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number truncated to `usize`, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string contents, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- writer -------------------------------------------------------------

    /// Serialize to compact JSON. Floats print via Rust's shortest-roundtrip
    /// `Display`, so `parse(to_json(v))` recovers bit-identical numbers —
    /// the property the TCP handshake's config exchange relies on.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructor: an object from `(key, value)` pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience constructor: a number.
pub fn num(x: f64) -> Value {
    Value::Num(x)
}

/// Convenience constructor: a string.
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

/// Convenience constructor: an array.
pub fn arr(vs: Vec<Value>) -> Value {
    Value::Arr(vs)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: handle the BMP-only case plus pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?);
                                } else {
                                    bail!("lone surrogate");
                                }
                            } else {
                                out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        out.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" -1.5e3 ").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true,"x\"y"],"n":-3,"o":{"k":"v"}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Value::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("1 2").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        // The actual manifest written by aot.py, if present.
        if let Ok(text) = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/manifest.json"
        )) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("artifacts").is_some());
        }
    }
}
