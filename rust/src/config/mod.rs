//! Typed configuration system over the JSON substrate.
//!
//! Experiments are described by [`ExperimentConfig`]: model + data sizes,
//! distributed topology, optimizer hyper-parameters (the paper's §V values
//! are the defaults), the compression scheme, and the simulated network.
//! Configs round-trip through JSON files and ship with named presets used by
//! the CLI, the examples and every figure bench.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{self, Value};

/// Gradient-compression scheme (the paper's methods + its baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Oracle: uncompressed 32-bit gradients.
    Dsgd,
    /// QSGD: uniform quantization over [-max|g|, max|g|], no truncation.
    Qsgd,
    /// Non-uniform (p^{1/3}) quantization over the full range, no truncation.
    Nqsgd,
    /// Paper: truncated uniform quantization (Thm. 1).
    Tqsgd,
    /// Paper: truncated non-uniform quantization (Thm. 2).
    Tnqsgd,
    /// Paper: truncated BiScaled quantization (Thm. 3 / Appendix D).
    Tbqsgd,
    /// TernGrad baseline (Wen et al. 2017): ternary levels scaled by max|g|.
    Terngrad,
    /// Top-k sparsification baseline.
    Topk,
}

impl Scheme {
    pub fn parse(s: &str) -> Result<Scheme> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dsgd" => Scheme::Dsgd,
            "qsgd" => Scheme::Qsgd,
            "nqsgd" => Scheme::Nqsgd,
            "tqsgd" => Scheme::Tqsgd,
            "tnqsgd" => Scheme::Tnqsgd,
            "tbqsgd" => Scheme::Tbqsgd,
            "terngrad" => Scheme::Terngrad,
            "topk" => Scheme::Topk,
            other => bail!("unknown scheme {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Dsgd => "dsgd",
            Scheme::Qsgd => "qsgd",
            Scheme::Nqsgd => "nqsgd",
            Scheme::Tqsgd => "tqsgd",
            Scheme::Tnqsgd => "tnqsgd",
            Scheme::Tbqsgd => "tbqsgd",
            Scheme::Terngrad => "terngrad",
            Scheme::Topk => "topk",
        }
    }

    /// Does this scheme use the truncated two-stage quantizer?
    pub fn truncated(&self) -> bool {
        matches!(self, Scheme::Tqsgd | Scheme::Tnqsgd | Scheme::Tbqsgd)
    }

    pub fn all() -> [Scheme; 8] {
        [
            Scheme::Dsgd,
            Scheme::Qsgd,
            Scheme::Nqsgd,
            Scheme::Tqsgd,
            Scheme::Tnqsgd,
            Scheme::Tbqsgd,
            Scheme::Terngrad,
            Scheme::Topk,
        ]
    }
}

/// Compression configuration.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub scheme: Scheme,
    /// Bit budget b per element (s = 2^b − 1 levels). Ignored by DSGD and
    /// TernGrad (b = 2 effective).
    pub bits: u32,
    /// Fraction kept by Top-k.
    pub topk_frac: f64,
    /// Re-estimate the tail model every this many rounds (paper re-fits γ
    /// per layer-group from local gradients).
    pub estimate_every: usize,
    /// Optional error-feedback wrapper (extension; off reproduces the paper).
    pub error_feedback: bool,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            scheme: Scheme::Tnqsgd,
            bits: 3,
            topk_frac: 0.01,
            estimate_every: 10,
            error_feedback: false,
        }
    }
}

/// Simulated-network model for the wire between clients and server.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Link bandwidth in bytes/sec used for simulated latency accounting
    /// (0 = infinite / accounting only).
    pub bandwidth_bytes_per_sec: f64,
    /// Fixed per-message latency in seconds (simulated).
    pub latency_sec: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { bandwidth_bytes_per_sec: 0.0, latency_sec: 0.0 }
    }
}

/// A full experiment description (paper §V defaults).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model name from artifacts/manifest.json (mlp | cnn | tfm_small | ...).
    pub model: String,
    /// Number of clients N.
    pub clients: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Server learning rate η.
    pub lr: f64,
    /// Momentum (paper: 0.9).
    pub momentum: f64,
    /// Weight decay (paper: 5e-4).
    pub weight_decay: f64,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    /// Training samples (total, sharded across clients).
    pub train_size: usize,
    /// Held-out test samples.
    pub test_size: usize,
    /// RNG seed for everything.
    pub seed: u64,
    pub quant: QuantConfig,
    pub net: NetConfig,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Compute backend: "auto" (pjrt when built + artifacts exist, else
    /// native), "native" (pure Rust), or "pjrt" (AOT HLO via PJRT).
    pub backend: String,
    /// Fail-injection: drop this client's update every round (usize::MAX =
    /// none) — exercises the coordinator's straggler/fault path.
    pub drop_client: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "cnn".into(),
            clients: 8,
            rounds: 300,
            lr: 0.01,
            momentum: 0.9,
            weight_decay: 5e-4,
            eval_every: 25,
            train_size: 8192,
            test_size: 2048,
            seed: 42,
            quant: QuantConfig::default(),
            net: NetConfig::default(),
            artifacts_dir: "artifacts".into(),
            backend: "auto".into(),
            drop_client: usize::MAX,
        }
    }
}

impl ExperimentConfig {
    /// Named presets. `<model>_<scheme>_b<bits>` plus a few specials.
    pub fn preset(name: &str) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        match name {
            "quickstart" => {
                cfg.model = "mlp".into();
                cfg.rounds = 60;
                cfg.quant.scheme = Scheme::Tnqsgd;
                cfg.quant.bits = 3;
                return Ok(cfg);
            }
            "e2e_transformer" => {
                cfg.model = "tfm_small".into();
                cfg.rounds = 150;
                cfg.clients = 4;
                cfg.lr = 3e-3;
                cfg.momentum = 0.9;
                cfg.weight_decay = 0.0;
                cfg.quant.scheme = Scheme::Tnqsgd;
                cfg.quant.bits = 4;
                cfg.train_size = 4096;
                cfg.test_size = 512;
                cfg.eval_every = 25;
                return Ok(cfg);
            }
            _ => {}
        }
        // Grammar: <model>_<scheme>_b<bits>
        let parts: Vec<&str> = name.split('_').collect();
        if parts.len() == 3 && parts[2].starts_with('b') {
            cfg.model = parts[0].to_string();
            cfg.quant.scheme = Scheme::parse(parts[1])?;
            cfg.quant.bits = parts[2][1..]
                .parse()
                .map_err(|e| anyhow!("bad bits in preset {name:?}: {e}"))?;
            cfg.validate()?;
            return Ok(cfg);
        }
        bail!("unknown preset {name:?}")
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("clients must be >= 1");
        }
        if !(1..=8).contains(&self.quant.bits) {
            bail!("bits must be in 1..=8, got {}", self.quant.bits);
        }
        if self.lr <= 0.0 || !(0.0..1.0).contains(&self.momentum) {
            bail!("bad optimizer hyper-parameters");
        }
        if !(0.0..=1.0).contains(&self.quant.topk_frac) {
            bail!("topk_frac must be in [0, 1]");
        }
        if self.quant.estimate_every == 0 {
            bail!("estimate_every must be >= 1");
        }
        if !matches!(self.backend.as_str(), "auto" | "native" | "pjrt") {
            bail!("backend must be auto | native | pjrt, got {:?}", self.backend);
        }
        Ok(())
    }

    /// Apply CLI flag overrides (`--model`, `--scheme`, `--bits`, ...).
    pub fn apply_args(&mut self, args: &crate::cli::Args) -> Result<()> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(s) = args.get("scheme") {
            self.quant.scheme = Scheme::parse(s)?;
        }
        self.quant.bits = args.usize_or("bits", self.quant.bits as usize)? as u32;
        self.clients = args.usize_or("clients", self.clients)?;
        self.rounds = args.usize_or("rounds", self.rounds)?;
        self.lr = args.f64_or("lr", self.lr)?;
        self.momentum = args.f64_or("momentum", self.momentum)?;
        self.weight_decay = args.f64_or("weight-decay", self.weight_decay)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.train_size = args.usize_or("train-size", self.train_size)?;
        self.test_size = args.usize_or("test-size", self.test_size)?;
        self.quant.estimate_every =
            args.usize_or("estimate-every", self.quant.estimate_every)?;
        self.quant.error_feedback =
            args.bool_or("error-feedback", self.quant.error_feedback)?;
        self.quant.topk_frac = args.f64_or("topk-frac", self.quant.topk_frac)?;
        if let Some(dir) = args.get("artifacts") {
            self.artifacts_dir = dir.to_string();
        }
        if let Some(b) = args.get("backend") {
            self.backend = b.to_string();
        }
        self.drop_client = args.usize_or("drop-client", self.drop_client)?;
        self.validate()
    }

    // -- JSON round trip ----------------------------------------------------

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("clients", json::num(self.clients as f64)),
            ("rounds", json::num(self.rounds as f64)),
            ("lr", json::num(self.lr)),
            ("momentum", json::num(self.momentum)),
            ("weight_decay", json::num(self.weight_decay)),
            ("eval_every", json::num(self.eval_every as f64)),
            ("train_size", json::num(self.train_size as f64)),
            ("test_size", json::num(self.test_size as f64)),
            ("seed", json::num(self.seed as f64)),
            ("artifacts_dir", json::s(&self.artifacts_dir)),
            ("backend", json::s(&self.backend)),
            ("drop_client", json::num(if self.drop_client == usize::MAX {
                -1.0
            } else {
                self.drop_client as f64
            })),
            (
                "quant",
                json::obj(vec![
                    ("scheme", json::s(self.quant.scheme.name())),
                    ("bits", json::num(self.quant.bits as f64)),
                    ("topk_frac", json::num(self.quant.topk_frac)),
                    ("estimate_every", json::num(self.quant.estimate_every as f64)),
                    ("error_feedback", Value::Bool(self.quant.error_feedback)),
                ]),
            ),
            (
                "net",
                json::obj(vec![
                    ("bandwidth_bytes_per_sec", json::num(self.net.bandwidth_bytes_per_sec)),
                    ("latency_sec", json::num(self.net.latency_sec)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        let getf = |key: &str, dflt: f64| v.get(key).and_then(Value::as_f64).unwrap_or(dflt);
        if let Some(m) = v.get("model").and_then(Value::as_str) {
            cfg.model = m.to_string();
        }
        cfg.clients = getf("clients", cfg.clients as f64) as usize;
        cfg.rounds = getf("rounds", cfg.rounds as f64) as usize;
        cfg.lr = getf("lr", cfg.lr);
        cfg.momentum = getf("momentum", cfg.momentum);
        cfg.weight_decay = getf("weight_decay", cfg.weight_decay);
        cfg.eval_every = getf("eval_every", cfg.eval_every as f64) as usize;
        cfg.train_size = getf("train_size", cfg.train_size as f64) as usize;
        cfg.test_size = getf("test_size", cfg.test_size as f64) as usize;
        cfg.seed = getf("seed", cfg.seed as f64) as u64;
        if let Some(dir) = v.get("artifacts_dir").and_then(Value::as_str) {
            cfg.artifacts_dir = dir.to_string();
        }
        if let Some(b) = v.get("backend").and_then(Value::as_str) {
            cfg.backend = b.to_string();
        }
        let dc = getf("drop_client", -1.0);
        cfg.drop_client = if dc < 0.0 { usize::MAX } else { dc as usize };
        if let Some(q) = v.get("quant") {
            if let Some(s) = q.get("scheme").and_then(Value::as_str) {
                cfg.quant.scheme = Scheme::parse(s)?;
            }
            cfg.quant.bits =
                q.get("bits").and_then(Value::as_f64).unwrap_or(cfg.quant.bits as f64) as u32;
            cfg.quant.topk_frac =
                q.get("topk_frac").and_then(Value::as_f64).unwrap_or(cfg.quant.topk_frac);
            cfg.quant.estimate_every = q
                .get("estimate_every")
                .and_then(Value::as_f64)
                .unwrap_or(cfg.quant.estimate_every as f64) as usize;
            cfg.quant.error_feedback = q
                .get("error_feedback")
                .and_then(Value::as_bool)
                .unwrap_or(cfg.quant.error_feedback);
        }
        if let Some(n) = v.get("net") {
            cfg.net.bandwidth_bytes_per_sec = n
                .get("bandwidth_bytes_per_sec")
                .and_then(Value::as_f64)
                .unwrap_or(0.0);
            cfg.net.latency_sec = n.get("latency_sec").and_then(Value::as_f64).unwrap_or(0.0);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&Value::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_json())
            .with_context(|| format!("writing config {path:?}"))
    }

    /// Short human id used in logs: `cnn/tnqsgd/b3/N8`.
    pub fn id(&self) -> String {
        format!(
            "{}/{}/b{}/N{}",
            self.model,
            self.quant.scheme.name(),
            self.quant.bits,
            self.clients
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_roundtrip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::parse(s.name()).unwrap(), s);
        }
        assert!(Scheme::parse("nope").is_err());
    }

    #[test]
    fn preset_grammar() {
        let c = ExperimentConfig::preset("cnn_tnqsgd_b3").unwrap();
        assert_eq!(c.model, "cnn");
        assert_eq!(c.quant.scheme, Scheme::Tnqsgd);
        assert_eq!(c.quant.bits, 3);
        assert!(ExperimentConfig::preset("cnn_martian_b3").is_err());
        assert!(ExperimentConfig::preset("garbage").is_err());
    }

    #[test]
    fn defaults_match_paper_section_v() {
        let c = ExperimentConfig::default();
        assert_eq!(c.clients, 8);
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.momentum, 0.9);
        assert_eq!(c.weight_decay, 5e-4);
    }

    #[test]
    fn backend_validation_and_override() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.backend, "auto");
        c.backend = "native".into();
        c.validate().unwrap();
        c.backend = "tpu9000".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--backend", "native"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.backend, "native");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::preset("mlp_tbqsgd_b4").unwrap();
        c.quant.error_feedback = true;
        c.net.latency_sec = 0.01;
        c.drop_client = 3;
        c.backend = "native".into();
        let j = c.to_json().to_json();
        let c2 = ExperimentConfig::from_json(&Value::parse(&j).unwrap()).unwrap();
        assert_eq!(c2.model, "mlp");
        assert_eq!(c2.quant.scheme, Scheme::Tbqsgd);
        assert_eq!(c2.quant.bits, 4);
        assert!(c2.quant.error_feedback);
        assert_eq!(c2.drop_client, 3);
        assert_eq!(c2.backend, "native");
        assert!((c2.net.latency_sec - 0.01).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = ExperimentConfig::default();
        c.quant.bits = 0;
        assert!(c.validate().is_err());
        c.quant.bits = 3;
        c.clients = 0;
        assert!(c.validate().is_err());
        c.clients = 2;
        c.quant.topk_frac = 2.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn args_override() {
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--scheme", "qsgd", "--bits", "5", "--rounds", "10"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.quant.scheme, Scheme::Qsgd);
        assert_eq!(c.quant.bits, 5);
        assert_eq!(c.rounds, 10);
    }
}
