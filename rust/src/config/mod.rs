//! Typed configuration system over the JSON substrate.
//!
//! Experiments are described by [`ExperimentConfig`]: model + data sizes,
//! distributed topology, optimizer hyper-parameters (the paper's §V values
//! are the defaults), the compression scheme, and the simulated network.
//! Configs round-trip through JSON files and ship with named presets used by
//! the CLI, the examples and every figure bench.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{self, Value};

/// Largest per-element index bit-width the whole stack carries: `bitpack`
/// packs 1..=16-bit indices, the wire decoders fall back off the 256-entry
/// w·LUT above 8 bits, and [`ExperimentConfig::validate`] plus the preset
/// grammar reject anything outside 1..=`MAX_BITS`. This is the single
/// source of truth for the bound — the fused ≤ 8-bit kernels are a fast
/// path, not a format limit.
pub const MAX_BITS: u32 = 16;

/// Gradient-compression scheme (the paper's methods + its baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Oracle: uncompressed 32-bit gradients.
    Dsgd,
    /// QSGD: uniform quantization over [-max|g|, max|g|], no truncation.
    Qsgd,
    /// Non-uniform (p^{1/3}) quantization over the full range, no truncation.
    Nqsgd,
    /// Paper: truncated uniform quantization (Thm. 1).
    Tqsgd,
    /// Paper: truncated non-uniform quantization (Thm. 2).
    Tnqsgd,
    /// Paper: truncated BiScaled quantization (Thm. 3 / Appendix D).
    Tbqsgd,
    /// TernGrad baseline (Wen et al. 2017): ternary levels scaled by max|g|.
    Terngrad,
    /// Top-k sparsification baseline.
    Topk,
    /// Extension: unbiased two-scale quantizer (Vineeth 2021) — a fine grid
    /// on the distribution body merged with a coarse grid out to the
    /// truncation threshold. Rate-adaptive via `Compressor::set_rate`.
    Multiscale,
}

impl Scheme {
    /// Parse a scheme name as written on the CLI (case-insensitive).
    pub fn parse(s: &str) -> Result<Scheme> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dsgd" => Scheme::Dsgd,
            "qsgd" => Scheme::Qsgd,
            "nqsgd" => Scheme::Nqsgd,
            "tqsgd" => Scheme::Tqsgd,
            "tnqsgd" => Scheme::Tnqsgd,
            "tbqsgd" => Scheme::Tbqsgd,
            "terngrad" => Scheme::Terngrad,
            "topk" => Scheme::Topk,
            "multiscale" => Scheme::Multiscale,
            other => bail!("unknown scheme {other:?}"),
        })
    }

    /// Canonical lowercase name (inverse of [`Scheme::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Dsgd => "dsgd",
            Scheme::Qsgd => "qsgd",
            Scheme::Nqsgd => "nqsgd",
            Scheme::Tqsgd => "tqsgd",
            Scheme::Tnqsgd => "tnqsgd",
            Scheme::Tbqsgd => "tbqsgd",
            Scheme::Terngrad => "terngrad",
            Scheme::Topk => "topk",
            Scheme::Multiscale => "multiscale",
        }
    }

    /// Does this scheme use the truncated two-stage quantizer? (Multiscale
    /// also truncates at a fitted α, merged with its body grid.)
    pub fn truncated(&self) -> bool {
        matches!(
            self,
            Scheme::Tqsgd | Scheme::Tnqsgd | Scheme::Tbqsgd | Scheme::Multiscale
        )
    }

    /// Can [`Compressor::set_rate`](crate::quant::Compressor::set_rate)
    /// re-target this scheme's per-element bit-width? False for the codecs
    /// whose rate is intrinsic (DSGD fp32, TernGrad's 2 bits, Top-k's
    /// sparse pairs) — the bit-budget scheduler treats those as fixed cost.
    pub fn rate_adaptive(&self) -> bool {
        !matches!(self, Scheme::Dsgd | Scheme::Terngrad | Scheme::Topk)
    }

    /// Every scheme, in the order the sweeps and test grids iterate.
    pub fn all() -> [Scheme; 9] {
        [
            Scheme::Dsgd,
            Scheme::Qsgd,
            Scheme::Nqsgd,
            Scheme::Tqsgd,
            Scheme::Tnqsgd,
            Scheme::Tbqsgd,
            Scheme::Terngrad,
            Scheme::Topk,
            Scheme::Multiscale,
        ]
    }
}

/// How the coordinator executes one communication round (see
/// `coordinator::pipeline` for the engine and the bit-identity argument).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum PipelineMode {
    /// Strict stage barriers: grad → encode (join all) → uplink →
    /// aggregate. The reference semantics.
    #[default]
    Barrier,
    /// Per-client frame hand-off: finished encodes flow through the
    /// scenario-conditioned network straight into buffered server decode
    /// while slower clients still encode; the weighted apply runs in the
    /// fixed (round, client) order. Bit-identical to `Barrier`.
    Streaming,
}

impl PipelineMode {
    /// Parse a mode name (`barrier` | `streaming`).
    pub fn parse(s: &str) -> Result<PipelineMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "barrier" => PipelineMode::Barrier,
            "streaming" => PipelineMode::Streaming,
            other => bail!("unknown pipeline mode {other:?}; expected barrier | streaming"),
        })
    }

    /// Canonical name (the `--pipeline` / JSON value).
    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::Barrier => "barrier",
            PipelineMode::Streaming => "streaming",
        }
    }
}

/// Compression configuration.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// The gradient-compression scheme.
    pub scheme: Scheme,
    /// Bit budget b per element (s = 2^b − 1 levels). Ignored by DSGD and
    /// TernGrad (b = 2 effective).
    pub bits: u32,
    /// Fraction kept by Top-k.
    pub topk_frac: f64,
    /// Re-estimate the tail model every this many rounds (paper re-fits γ
    /// per layer-group from local gradients).
    pub estimate_every: usize,
    /// Optional error-feedback wrapper (extension; off reproduces the paper).
    pub error_feedback: bool,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            scheme: Scheme::Tnqsgd,
            bits: 3,
            topk_frac: 0.01,
            estimate_every: 10,
            error_feedback: false,
        }
    }
}

/// Deterministic perturbations layered onto each training round by the
/// coordinator's scenario engine: compute stragglers, lossy uplinks with
/// retransmits, client churn, bounded-staleness aggregation, and non-IID
/// sharding. All fields compose freely; the named presets
/// (`clean | straggler | lossy | churn | stale | noniid`) are starting
/// points, not modes. Every perturbation draws from a dedicated per-scenario
/// RNG stream keyed on the experiment seed, so runs are bit-reproducible.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioConfig {
    /// Preset label this config started from (for logs / run ids).
    pub name: String,
    /// Fraction of clients that are compute stragglers (rounded to a count).
    pub straggler_frac: f64,
    /// Uplink-time multiplier applied to straggler clients (>= 1).
    pub straggler_mult: f64,
    /// Per-attempt probability an uplink frame is lost and must be resent.
    pub loss_prob: f64,
    /// Retransmits allowed per frame per round before it counts as lost.
    pub max_retries: u32,
    /// Per-round probability an active client drops out.
    pub dropout_prob: f64,
    /// Per-round probability a dropped client rejoins.
    pub rejoin_prob: f64,
    /// Bounded staleness: the server steps after the first K uplinks of the
    /// round; later frames apply next round with decayed weight. 0 = fully
    /// synchronous (K = N); values above the surviving-client count clamp.
    pub stale_k: usize,
    /// Aggregation-weight decay per round of staleness, in (0, 1].
    pub stale_decay: f64,
    /// Dirichlet concentration for label-skew (non-IID) sharding of the
    /// vision dataset; 0 = IID contiguous shards. Smaller = more skew.
    pub noniid_alpha: f64,
    /// Per-client per-round uplink cap in bytes (0 = uncapped). A binding
    /// cap engages the bit-budget scheduler even without a global
    /// `bit_budget`, throttling that client's codecs so its round message
    /// fits — observable in the `bytes_per_client` column.
    pub uplink_cap_bytes: u64,
    /// Uplink-cap heterogeneity: each client's cap is drawn deterministically
    /// (seeded, dedicated stream role) from
    /// `[uplink_cap_min_frac · cap, cap]`. 1.0 = homogeneous caps.
    pub uplink_cap_min_frac: f64,
    /// Chaos harness: per-round probability an ARRIVED uplink's bytes are
    /// corrupted in flight (the CRC trailer catches it and the transport
    /// retransmits — see PROTOCOL.md §2 and the `corrupt_frames` column).
    /// Drawn per (client, round) from the dedicated `ROLE_CHAOS` stream.
    pub chaos_corrupt_prob: f64,
    /// Chaos harness: how many payload bytes a corruption event flips
    /// (distinct seeded positions, XOR 0xFF). Must be >= 1 when
    /// `chaos_corrupt_prob > 0`.
    pub chaos_corrupt_bytes: usize,
    /// Chaos harness: the round after whose uplink one seeded worker dies
    /// and is respawned (the victim is drawn from `ROLE_CHAOS`; it uploads
    /// its exact state first and REJOINs the next round — see PROTOCOL.md
    /// §3.6/§3.7). 0 = no kill.
    pub chaos_kill_round: usize,
    /// Chaos harness: per-round probability a worker stalls (sleeps) before
    /// its uplink. Wall-clock only — the simulated network clock, and hence
    /// the digest, is unaffected while the stall stays under `io_timeout`.
    pub chaos_stall_prob: f64,
    /// Chaos harness: stall duration in (real) seconds.
    pub chaos_stall_secs: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            name: "clean".into(),
            straggler_frac: 0.0,
            straggler_mult: 1.0,
            loss_prob: 0.0,
            max_retries: 3,
            dropout_prob: 0.0,
            rejoin_prob: 0.0,
            stale_k: 0,
            stale_decay: 1.0,
            noniid_alpha: 0.0,
            uplink_cap_bytes: 0,
            uplink_cap_min_frac: 1.0,
            chaos_corrupt_prob: 0.0,
            chaos_corrupt_bytes: 0,
            chaos_kill_round: 0,
            chaos_stall_prob: 0.0,
            chaos_stall_secs: 0.0,
        }
    }
}

impl ScenarioConfig {
    /// All preset names, in presentation order.
    pub fn preset_names() -> [&'static str; 8] {
        ["clean", "straggler", "lossy", "churn", "stale", "noniid", "bandwidth", "chaos"]
    }

    /// Named scenario presets (see README §Scenarios).
    pub fn preset(name: &str) -> Result<ScenarioConfig> {
        let mut s = ScenarioConfig { name: name.to_string(), ..Default::default() };
        match name {
            "clean" => {}
            "straggler" => {
                s.straggler_frac = 0.25;
                s.straggler_mult = 8.0;
            }
            "lossy" => {
                s.loss_prob = 0.2;
                s.max_retries = 5;
            }
            "churn" => {
                s.dropout_prob = 0.15;
                s.rejoin_prob = 0.5;
            }
            "stale" => {
                s.stale_k = 3;
                s.stale_decay = 0.5;
            }
            "noniid" => {
                s.noniid_alpha = 0.3;
            }
            "bandwidth" => {
                // Heterogeneous per-client uplink caps that bind at the
                // default model sizes, so the bit-budget scheduler's
                // throttling shows up in bytes_up / bytes_per_client.
                s.uplink_cap_bytes = 8192;
                s.uplink_cap_min_frac = 0.5;
            }
            "chaos" => {
                // Seeded transport faults: frequent small corruptions (the
                // CRC trailer + retransmit path), one scheduled worker
                // kill/rejoin after round 3. Stalls stay off by default —
                // they add wall-clock without touching the digest.
                s.chaos_corrupt_prob = 0.25;
                s.chaos_corrupt_bytes = 3;
                s.chaos_kill_round = 3;
            }
            other => bail!(
                "unknown scenario {other:?}; presets: {}",
                Self::preset_names().join(" ")
            ),
        }
        Ok(s)
    }

    /// Is every perturbation switched off (behaviourally identical to the
    /// synchronous happy path)?
    pub fn is_clean(&self) -> bool {
        self.straggler_frac == 0.0
            && self.loss_prob == 0.0
            && self.dropout_prob == 0.0
            && self.rejoin_prob == 0.0
            && self.stale_k == 0
            && self.noniid_alpha == 0.0
            && self.uplink_cap_bytes == 0
            && self.chaos_corrupt_prob == 0.0
            && self.chaos_kill_round == 0
            && self.chaos_stall_prob == 0.0
    }

    /// Validate field ranges.
    pub fn validate(&self) -> Result<()> {
        for (label, p) in [
            ("straggler_frac", self.straggler_frac),
            ("loss_prob", self.loss_prob),
            ("dropout_prob", self.dropout_prob),
            ("rejoin_prob", self.rejoin_prob),
            ("chaos_corrupt_prob", self.chaos_corrupt_prob),
            ("chaos_stall_prob", self.chaos_stall_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("scenario {label} must be in [0, 1], got {p}");
            }
        }
        if self.loss_prob >= 1.0 {
            bail!("scenario loss_prob must be < 1");
        }
        if self.straggler_mult < 1.0 || !self.straggler_mult.is_finite() {
            bail!("scenario straggler_mult must be >= 1, got {}", self.straggler_mult);
        }
        if !(self.stale_decay > 0.0 && self.stale_decay <= 1.0) {
            bail!("scenario stale_decay must be in (0, 1], got {}", self.stale_decay);
        }
        if self.noniid_alpha < 0.0 || !self.noniid_alpha.is_finite() {
            bail!("scenario noniid_alpha must be >= 0, got {}", self.noniid_alpha);
        }
        if !(self.uplink_cap_min_frac > 0.0 && self.uplink_cap_min_frac <= 1.0) {
            bail!(
                "scenario uplink_cap_min_frac must be in (0, 1], got {}",
                self.uplink_cap_min_frac
            );
        }
        if self.chaos_corrupt_prob > 0.0 && self.chaos_corrupt_bytes == 0 {
            bail!("scenario chaos_corrupt_bytes must be >= 1 when chaos_corrupt_prob > 0");
        }
        if self.chaos_stall_secs < 0.0 || !self.chaos_stall_secs.is_finite() {
            bail!(
                "scenario chaos_stall_secs must be a finite nonnegative number, got {}",
                self.chaos_stall_secs
            );
        }
        Ok(())
    }

    /// JSON object for the `scenario` block of a config file.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("straggler_frac", json::num(self.straggler_frac)),
            ("straggler_mult", json::num(self.straggler_mult)),
            ("loss_prob", json::num(self.loss_prob)),
            ("max_retries", json::num(self.max_retries as f64)),
            ("dropout_prob", json::num(self.dropout_prob)),
            ("rejoin_prob", json::num(self.rejoin_prob)),
            ("stale_k", json::num(self.stale_k as f64)),
            ("stale_decay", json::num(self.stale_decay)),
            ("noniid_alpha", json::num(self.noniid_alpha)),
            ("uplink_cap_bytes", json::num(self.uplink_cap_bytes as f64)),
            ("uplink_cap_min_frac", json::num(self.uplink_cap_min_frac)),
            ("chaos_corrupt_prob", json::num(self.chaos_corrupt_prob)),
            ("chaos_corrupt_bytes", json::num(self.chaos_corrupt_bytes as f64)),
            ("chaos_kill_round", json::num(self.chaos_kill_round as f64)),
            ("chaos_stall_prob", json::num(self.chaos_stall_prob)),
            ("chaos_stall_secs", json::num(self.chaos_stall_secs)),
        ])
    }

    /// Parse a `scenario` block (missing fields keep their defaults).
    pub fn from_json(v: &Value) -> Result<ScenarioConfig> {
        let mut s = ScenarioConfig::default();
        if let Some(n) = v.get("name").and_then(Value::as_str) {
            s.name = n.to_string();
        }
        let getf = |key: &str, dflt: f64| v.get(key).and_then(Value::as_f64).unwrap_or(dflt);
        s.straggler_frac = getf("straggler_frac", s.straggler_frac);
        s.straggler_mult = getf("straggler_mult", s.straggler_mult);
        s.loss_prob = getf("loss_prob", s.loss_prob);
        // Counts must fail loudly on negatives rather than saturate to 0
        // (`-3 as usize` would silently mean "synchronous").
        let max_retries = getf("max_retries", s.max_retries as f64);
        let stale_k = getf("stale_k", s.stale_k as f64);
        if max_retries < 0.0 || stale_k < 0.0 {
            bail!("scenario max_retries/stale_k must be >= 0");
        }
        s.max_retries = max_retries as u32;
        s.stale_k = stale_k as usize;
        s.dropout_prob = getf("dropout_prob", s.dropout_prob);
        s.rejoin_prob = getf("rejoin_prob", s.rejoin_prob);
        s.stale_decay = getf("stale_decay", s.stale_decay);
        s.noniid_alpha = getf("noniid_alpha", s.noniid_alpha);
        // Same loud failure for a negative byte cap (`-1 as u64` would mean
        // "cap at 16 EiB", i.e. silently uncapped).
        let cap = getf("uplink_cap_bytes", s.uplink_cap_bytes as f64);
        if cap < 0.0 {
            bail!("scenario uplink_cap_bytes must be >= 0, got {cap}");
        }
        s.uplink_cap_bytes = cap as u64;
        s.uplink_cap_min_frac = getf("uplink_cap_min_frac", s.uplink_cap_min_frac);
        s.chaos_corrupt_prob = getf("chaos_corrupt_prob", s.chaos_corrupt_prob);
        s.chaos_stall_prob = getf("chaos_stall_prob", s.chaos_stall_prob);
        s.chaos_stall_secs = getf("chaos_stall_secs", s.chaos_stall_secs);
        // Chaos counts fail loudly on negatives like the other counts above.
        let corrupt_bytes = getf("chaos_corrupt_bytes", s.chaos_corrupt_bytes as f64);
        let kill_round = getf("chaos_kill_round", s.chaos_kill_round as f64);
        if corrupt_bytes < 0.0 || kill_round < 0.0 {
            bail!("scenario chaos_corrupt_bytes/chaos_kill_round must be >= 0");
        }
        s.chaos_corrupt_bytes = corrupt_bytes as usize;
        s.chaos_kill_round = kill_round as usize;
        s.validate()?;
        Ok(s)
    }
}

/// Simulated-network model for the wire between clients and server.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Link bandwidth in bytes/sec used for simulated latency accounting
    /// (0 = infinite / accounting only).
    pub bandwidth_bytes_per_sec: f64,
    /// Fixed per-message latency in seconds (simulated).
    pub latency_sec: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { bandwidth_bytes_per_sec: 0.0, latency_sec: 0.0 }
    }
}

/// A full experiment description (paper §V defaults).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model name from artifacts/manifest.json (mlp | cnn | tfm_small | ...).
    pub model: String,
    /// Number of clients N.
    pub clients: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Server learning rate η.
    pub lr: f64,
    /// Momentum (paper: 0.9).
    pub momentum: f64,
    /// Weight decay (paper: 5e-4).
    pub weight_decay: f64,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    /// Training samples (total, sharded across clients).
    pub train_size: usize,
    /// Held-out test samples.
    pub test_size: usize,
    /// RNG seed for everything.
    pub seed: u64,
    /// Gradient-compression settings.
    pub quant: QuantConfig,
    /// Simulated-network model (bandwidth + latency).
    pub net: NetConfig,
    /// Round-perturbation scenario (stragglers, loss, churn, staleness,
    /// non-IID sharding). Defaults to the clean synchronous path.
    pub scenario: ScenarioConfig,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Compute backend: "auto" (pjrt when built + artifacts exist, else
    /// native), "native" (pure Rust), or "pjrt" (AOT HLO via PJRT).
    pub backend: String,
    /// Fail-injection: drop this client's update every round (usize::MAX =
    /// none) — exercises the coordinator's straggler/fault path.
    pub drop_client: usize,
    /// Server aggregation fan-out width (layer-group granularity): 0 = auto
    /// (one shard per available core, capped by the model's group count).
    /// A pure performance knob — sharded aggregation is bit-identical to
    /// the serial path at every width.
    pub agg_shards: usize,
    /// Client-side encode pool width for the barrier pipeline (the mirror
    /// of `agg_shards` on the compression side): 0 = auto (one worker per
    /// available core, capped by the client count), 1 = a single encode
    /// worker. A pure performance knob — per-client codec state is
    /// disjoint, so every width is bit-identical (the streaming pipeline
    /// keeps its own worker-per-client channel design).
    pub encode_threads: usize,
    /// Round execution mode: strict stage barriers, or the streaming
    /// pipeline that overlaps client encode with server decode. A pure
    /// performance knob — the two modes are bit-identical.
    pub pipeline: PipelineMode,
    /// Per-round cohort size K: each round only K of the N clients compute
    /// and uplink, drawn from a dedicated seeded RNG stream so the draw
    /// composes with churn/straggler/staleness without shifting their
    /// streams. 0 (or any K >= N) = full participation — that path skips
    /// the draw entirely and is bit-identical to the pre-cohort engine.
    pub cohort_k: usize,
    /// Aggregator-tree depth: 1 = the flat server aggregation; 2 = mid-tier
    /// nodes fuse-decode their cohort slice and re-encode the quantized
    /// partial sum uplink through the configured codec (unbiased, so the
    /// expected aggregate is unchanged — see `coordinator::aggregate`).
    pub agg_tiers: usize,
    /// Per-round total uplink byte budget driving the adaptive bit-rate
    /// scheduler (`quant::budget::BitBudget`): each round the server
    /// allocates per-(client, layer-group) bit-widths — DQ-SGD style,
    /// from the observed truncation thresholds — so the fleet's summed
    /// frame bytes fit the budget. Named for the bit allocation it drives;
    /// the unit is bytes. 0 = disabled (codecs keep the static
    /// `quant.bits`, bit-identical to the unscheduled engine). Per-client
    /// caps (`scenario.uplink_cap_bytes`) compose with, and also engage,
    /// the scheduler.
    pub bit_budget: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "cnn".into(),
            clients: 8,
            rounds: 300,
            lr: 0.01,
            momentum: 0.9,
            weight_decay: 5e-4,
            eval_every: 25,
            train_size: 8192,
            test_size: 2048,
            seed: 42,
            quant: QuantConfig::default(),
            net: NetConfig::default(),
            scenario: ScenarioConfig::default(),
            artifacts_dir: "artifacts".into(),
            backend: "auto".into(),
            drop_client: usize::MAX,
            agg_shards: 0,
            encode_threads: 0,
            pipeline: PipelineMode::default(),
            cohort_k: 0,
            agg_tiers: 1,
            bit_budget: 0,
        }
    }
}

impl ExperimentConfig {
    /// Named presets. `<model>_<scheme>_b<bits>` plus a few specials.
    pub fn preset(name: &str) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        match name {
            "quickstart" => {
                cfg.model = "mlp".into();
                cfg.rounds = 60;
                cfg.quant.scheme = Scheme::Tnqsgd;
                cfg.quant.bits = 3;
                return Ok(cfg);
            }
            "e2e_transformer" => {
                cfg.model = "tfm_small".into();
                cfg.rounds = 150;
                cfg.clients = 4;
                cfg.lr = 3e-3;
                cfg.momentum = 0.9;
                cfg.weight_decay = 0.0;
                cfg.quant.scheme = Scheme::Tnqsgd;
                cfg.quant.bits = 4;
                cfg.train_size = 4096;
                cfg.test_size = 512;
                cfg.eval_every = 25;
                return Ok(cfg);
            }
            _ => {}
        }
        // Grammar: <model>_<scheme>_b<bits>
        let parts: Vec<&str> = name.split('_').collect();
        if parts.len() == 3 && parts[2].starts_with('b') {
            cfg.model = parts[0].to_string();
            cfg.quant.scheme = Scheme::parse(parts[1])?;
            let bits: u32 = parts[2][1..]
                .parse()
                .map_err(|e| anyhow!("bad bits in preset {name:?}: {e}"))?;
            if !(1..=MAX_BITS).contains(&bits) {
                bail!("preset {name:?}: bits must be in 1..={MAX_BITS}, got {bits}");
            }
            cfg.quant.bits = bits;
            cfg.validate()?;
            return Ok(cfg);
        }
        bail!("unknown preset {name:?}")
    }

    /// Reject configurations the runtime cannot execute (zero clients,
    /// out-of-range bit widths, inconsistent scenario knobs, ...).
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("clients must be >= 1");
        }
        if !(1..=MAX_BITS).contains(&self.quant.bits) {
            bail!("bits must be in 1..={MAX_BITS}, got {}", self.quant.bits);
        }
        if self.lr <= 0.0 || !(0.0..1.0).contains(&self.momentum) {
            bail!("bad optimizer hyper-parameters");
        }
        if !(0.0..=1.0).contains(&self.quant.topk_frac) {
            bail!("topk_frac must be in [0, 1]");
        }
        if self.quant.estimate_every == 0 {
            bail!("estimate_every must be >= 1");
        }
        if !matches!(self.backend.as_str(), "auto" | "native" | "pjrt") {
            bail!("backend must be auto | native | pjrt, got {:?}", self.backend);
        }
        if self.agg_tiers == 0 || self.agg_tiers > 2 {
            bail!("agg_tiers must be 1 (flat) or 2 (mid-tier re-encode), got {}", self.agg_tiers);
        }
        self.scenario.validate()?;
        Ok(())
    }

    /// Apply CLI flag overrides (`--model`, `--scheme`, `--bits`, ...).
    pub fn apply_args(&mut self, args: &crate::cli::Args) -> Result<()> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(s) = args.get("scheme") {
            self.quant.scheme = Scheme::parse(s)?;
        }
        self.quant.bits = args.usize_or("bits", self.quant.bits as usize)? as u32;
        self.clients = args.usize_or("clients", self.clients)?;
        self.rounds = args.usize_or("rounds", self.rounds)?;
        self.lr = args.f64_or("lr", self.lr)?;
        self.momentum = args.f64_or("momentum", self.momentum)?;
        self.weight_decay = args.f64_or("weight-decay", self.weight_decay)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.train_size = args.usize_or("train-size", self.train_size)?;
        self.test_size = args.usize_or("test-size", self.test_size)?;
        self.quant.estimate_every =
            args.usize_or("estimate-every", self.quant.estimate_every)?;
        self.quant.error_feedback =
            args.bool_or("error-feedback", self.quant.error_feedback)?;
        self.quant.topk_frac = args.f64_or("topk-frac", self.quant.topk_frac)?;
        if let Some(dir) = args.get("artifacts") {
            self.artifacts_dir = dir.to_string();
        }
        if let Some(b) = args.get("backend") {
            self.backend = b.to_string();
        }
        self.drop_client = args.usize_or("drop-client", self.drop_client)?;
        self.agg_shards = args.usize_or("agg-shards", self.agg_shards)?;
        self.encode_threads = args.usize_or("encode-threads", self.encode_threads)?;
        if let Some(p) = args.get("pipeline") {
            self.pipeline = PipelineMode::parse(p)?;
        }
        self.cohort_k = args.usize_or("cohort-k", self.cohort_k)?;
        self.agg_tiers = args.usize_or("agg-tiers", self.agg_tiers)?;
        self.bit_budget = args.u64_or("bit-budget", self.bit_budget)?;
        // Scenario: `--scenario <preset>` selects a base, then freeform
        // flags override individual fields on top of it.
        if let Some(name) = args.get("scenario") {
            self.scenario = ScenarioConfig::preset(name)?;
        }
        let sc = &mut self.scenario;
        sc.straggler_frac = args.f64_or("straggler-frac", sc.straggler_frac)?;
        sc.straggler_mult = args.f64_or("straggler-mult", sc.straggler_mult)?;
        sc.loss_prob = args.f64_or("loss-prob", sc.loss_prob)?;
        sc.max_retries = args.usize_or("max-retries", sc.max_retries as usize)? as u32;
        sc.dropout_prob = args.f64_or("dropout-prob", sc.dropout_prob)?;
        sc.rejoin_prob = args.f64_or("rejoin-prob", sc.rejoin_prob)?;
        sc.stale_k = args.usize_or("stale-k", sc.stale_k)?;
        sc.stale_decay = args.f64_or("stale-decay", sc.stale_decay)?;
        sc.noniid_alpha = args.f64_or("noniid-alpha", sc.noniid_alpha)?;
        sc.uplink_cap_bytes = args.u64_or("uplink-cap", sc.uplink_cap_bytes)?;
        sc.uplink_cap_min_frac = args.f64_or("uplink-cap-frac", sc.uplink_cap_min_frac)?;
        sc.chaos_corrupt_prob = args.f64_or("chaos-corrupt-prob", sc.chaos_corrupt_prob)?;
        sc.chaos_corrupt_bytes =
            args.usize_or("chaos-corrupt-bytes", sc.chaos_corrupt_bytes)?;
        sc.chaos_kill_round = args.usize_or("chaos-kill-round", sc.chaos_kill_round)?;
        sc.chaos_stall_prob = args.f64_or("chaos-stall-prob", sc.chaos_stall_prob)?;
        sc.chaos_stall_secs = args.f64_or("chaos-stall-secs", sc.chaos_stall_secs)?;
        self.validate()
    }

    // -- JSON round trip ----------------------------------------------------

    /// Serialize to the JSON document [`ExperimentConfig::from_json`]
    /// accepts. Float fields survive the round trip bit-exactly (see
    /// [`crate::json`]), which the TCP handshake relies on for
    /// cross-process determinism.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("clients", json::num(self.clients as f64)),
            ("rounds", json::num(self.rounds as f64)),
            ("lr", json::num(self.lr)),
            ("momentum", json::num(self.momentum)),
            ("weight_decay", json::num(self.weight_decay)),
            ("eval_every", json::num(self.eval_every as f64)),
            ("train_size", json::num(self.train_size as f64)),
            ("test_size", json::num(self.test_size as f64)),
            ("seed", json::num(self.seed as f64)),
            ("artifacts_dir", json::s(&self.artifacts_dir)),
            ("backend", json::s(&self.backend)),
            ("drop_client", json::num(if self.drop_client == usize::MAX {
                -1.0
            } else {
                self.drop_client as f64
            })),
            ("agg_shards", json::num(self.agg_shards as f64)),
            ("encode_threads", json::num(self.encode_threads as f64)),
            ("pipeline", json::s(self.pipeline.name())),
            ("cohort_k", json::num(self.cohort_k as f64)),
            ("agg_tiers", json::num(self.agg_tiers as f64)),
            ("bit_budget", json::num(self.bit_budget as f64)),
            (
                "quant",
                json::obj(vec![
                    ("scheme", json::s(self.quant.scheme.name())),
                    ("bits", json::num(self.quant.bits as f64)),
                    ("topk_frac", json::num(self.quant.topk_frac)),
                    ("estimate_every", json::num(self.quant.estimate_every as f64)),
                    ("error_feedback", Value::Bool(self.quant.error_feedback)),
                ]),
            ),
            (
                "net",
                json::obj(vec![
                    ("bandwidth_bytes_per_sec", json::num(self.net.bandwidth_bytes_per_sec)),
                    ("latency_sec", json::num(self.net.latency_sec)),
                ]),
            ),
            ("scenario", self.scenario.to_json()),
        ])
    }

    /// Build a validated config from JSON; absent fields keep defaults.
    pub fn from_json(v: &Value) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        let getf = |key: &str, dflt: f64| v.get(key).and_then(Value::as_f64).unwrap_or(dflt);
        if let Some(m) = v.get("model").and_then(Value::as_str) {
            cfg.model = m.to_string();
        }
        cfg.clients = getf("clients", cfg.clients as f64) as usize;
        cfg.rounds = getf("rounds", cfg.rounds as f64) as usize;
        cfg.lr = getf("lr", cfg.lr);
        cfg.momentum = getf("momentum", cfg.momentum);
        cfg.weight_decay = getf("weight_decay", cfg.weight_decay);
        cfg.eval_every = getf("eval_every", cfg.eval_every as f64) as usize;
        cfg.train_size = getf("train_size", cfg.train_size as f64) as usize;
        cfg.test_size = getf("test_size", cfg.test_size as f64) as usize;
        cfg.seed = getf("seed", cfg.seed as f64) as u64;
        if let Some(dir) = v.get("artifacts_dir").and_then(Value::as_str) {
            cfg.artifacts_dir = dir.to_string();
        }
        if let Some(b) = v.get("backend").and_then(Value::as_str) {
            cfg.backend = b.to_string();
        }
        let dc = getf("drop_client", -1.0);
        cfg.drop_client = if dc < 0.0 { usize::MAX } else { dc as usize };
        // Negative values saturate to 0 = auto (float → usize casts clamp).
        cfg.agg_shards = getf("agg_shards", cfg.agg_shards as f64) as usize;
        // Older configs without the field stay on auto encode-pool width.
        cfg.encode_threads = getf("encode_threads", cfg.encode_threads as f64) as usize;
        // Older configs without the field stay on the barrier reference.
        if let Some(p) = v.get("pipeline").and_then(Value::as_str) {
            cfg.pipeline = PipelineMode::parse(p)?;
        }
        // Older configs without the fields run full-participation and flat
        // aggregation (cohort_k <= 0 saturates to 0 = everyone).
        cfg.cohort_k = getf("cohort_k", cfg.cohort_k as f64).max(0.0) as usize;
        cfg.agg_tiers = getf("agg_tiers", cfg.agg_tiers as f64).max(0.0) as usize;
        // Older configs without the field run unscheduled (budget disabled);
        // a negative budget fails loudly like the scenario counts above.
        let budget = getf("bit_budget", cfg.bit_budget as f64);
        if budget < 0.0 {
            bail!("bit_budget must be >= 0, got {budget}");
        }
        cfg.bit_budget = budget as u64;
        if let Some(q) = v.get("quant") {
            if let Some(s) = q.get("scheme").and_then(Value::as_str) {
                cfg.quant.scheme = Scheme::parse(s)?;
            }
            cfg.quant.bits =
                q.get("bits").and_then(Value::as_f64).unwrap_or(cfg.quant.bits as f64) as u32;
            cfg.quant.topk_frac =
                q.get("topk_frac").and_then(Value::as_f64).unwrap_or(cfg.quant.topk_frac);
            cfg.quant.estimate_every = q
                .get("estimate_every")
                .and_then(Value::as_f64)
                .unwrap_or(cfg.quant.estimate_every as f64) as usize;
            cfg.quant.error_feedback = q
                .get("error_feedback")
                .and_then(Value::as_bool)
                .unwrap_or(cfg.quant.error_feedback);
        }
        if let Some(n) = v.get("net") {
            cfg.net.bandwidth_bytes_per_sec = n
                .get("bandwidth_bytes_per_sec")
                .and_then(Value::as_f64)
                .unwrap_or(0.0);
            cfg.net.latency_sec = n.get("latency_sec").and_then(Value::as_f64).unwrap_or(0.0);
        }
        if let Some(sc) = v.get("scenario") {
            cfg.scenario = ScenarioConfig::from_json(sc)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load a config from a JSON file.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&Value::parse(&text)?)
    }

    /// Write the config as JSON to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_json())
            .with_context(|| format!("writing config {path:?}"))
    }

    /// Short human id used in logs: `cnn/tnqsgd/b3/N8`, with an `@scenario`
    /// suffix whenever the run is perturbed.
    pub fn id(&self) -> String {
        let base = format!(
            "{}/{}/b{}/N{}",
            self.model,
            self.quant.scheme.name(),
            self.quant.bits,
            self.clients
        );
        if self.scenario.is_clean() {
            base
        } else {
            format!("{base}@{}", self.scenario.name)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_roundtrip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::parse(s.name()).unwrap(), s);
        }
        assert!(Scheme::parse("nope").is_err());
    }

    #[test]
    fn preset_grammar() {
        let c = ExperimentConfig::preset("cnn_tnqsgd_b3").unwrap();
        assert_eq!(c.model, "cnn");
        assert_eq!(c.quant.scheme, Scheme::Tnqsgd);
        assert_eq!(c.quant.bits, 3);
        assert!(ExperimentConfig::preset("cnn_martian_b3").is_err());
        assert!(ExperimentConfig::preset("garbage").is_err());
    }

    #[test]
    fn defaults_match_paper_section_v() {
        let c = ExperimentConfig::default();
        assert_eq!(c.clients, 8);
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.momentum, 0.9);
        assert_eq!(c.weight_decay, 5e-4);
    }

    #[test]
    fn backend_validation_and_override() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.backend, "auto");
        c.backend = "native".into();
        c.validate().unwrap();
        c.backend = "tpu9000".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--backend", "native"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.backend, "native");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::preset("mlp_tbqsgd_b4").unwrap();
        c.quant.error_feedback = true;
        c.net.latency_sec = 0.01;
        c.drop_client = 3;
        c.backend = "native".into();
        c.agg_shards = 4;
        c.encode_threads = 3;
        c.pipeline = PipelineMode::Streaming;
        c.cohort_k = 3;
        c.agg_tiers = 2;
        c.bit_budget = 65536;
        let j = c.to_json().to_json();
        let c2 = ExperimentConfig::from_json(&Value::parse(&j).unwrap()).unwrap();
        assert_eq!(c2.model, "mlp");
        assert_eq!(c2.quant.scheme, Scheme::Tbqsgd);
        assert_eq!(c2.quant.bits, 4);
        assert!(c2.quant.error_feedback);
        assert_eq!(c2.drop_client, 3);
        assert_eq!(c2.backend, "native");
        assert_eq!(c2.agg_shards, 4);
        assert_eq!(c2.encode_threads, 3);
        assert_eq!(c2.pipeline, PipelineMode::Streaming);
        assert_eq!(c2.cohort_k, 3);
        assert_eq!(c2.agg_tiers, 2);
        assert_eq!(c2.bit_budget, 65536);
        assert!((c2.net.latency_sec - 0.01).abs() < 1e-12);
        // Older configs without the fields default to auto / barrier /
        // full participation / flat aggregation / unscheduled.
        let legacy = ExperimentConfig::from_json(&Value::parse("{}").unwrap()).unwrap();
        assert_eq!(legacy.agg_shards, 0);
        assert_eq!(legacy.encode_threads, 0);
        assert_eq!(legacy.pipeline, PipelineMode::Barrier);
        assert_eq!(legacy.cohort_k, 0);
        assert_eq!(legacy.agg_tiers, 1);
        assert_eq!(legacy.bit_budget, 0);
        assert_eq!(legacy.scenario.uplink_cap_bytes, 0);
        // Negative budgets / caps fail loudly instead of wrapping to huge.
        for j in [
            r#"{"bit_budget": -1}"#,
            r#"{"scenario": {"uplink_cap_bytes": -4096}}"#,
        ] {
            let v = Value::parse(j).unwrap();
            assert!(ExperimentConfig::from_json(&v).is_err(), "{j} must be rejected");
        }
    }

    #[test]
    fn cohort_and_tier_flags_parse_and_validate() {
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--cohort-k", "5", "--agg-tiers", "2"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.cohort_k, 5);
        assert_eq!(c.agg_tiers, 2);
        // Scale knobs must not change the run id (same experiment family).
        assert_eq!(c.id(), ExperimentConfig::default().id());
        c.agg_tiers = 0;
        assert!(c.validate().is_err(), "agg_tiers = 0 must be rejected");
        c.agg_tiers = 3;
        assert!(c.validate().is_err(), "agg_tiers > 2 must be rejected");
    }

    #[test]
    fn pipeline_mode_parse_name_and_cli_flag() {
        for m in [PipelineMode::Barrier, PipelineMode::Streaming] {
            assert_eq!(PipelineMode::parse(m.name()).unwrap(), m);
        }
        assert_eq!(PipelineMode::parse("STREAMING").unwrap(), PipelineMode::Streaming);
        assert!(PipelineMode::parse("overlapped").is_err());
        assert_eq!(PipelineMode::default(), PipelineMode::Barrier);
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--pipeline", "streaming"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.pipeline, PipelineMode::Streaming);
        // The mode is a pure performance knob: the run id must not change.
        assert_eq!(c.id(), ExperimentConfig::default().id());
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = ExperimentConfig::default();
        c.quant.bits = 0;
        assert!(c.validate().is_err());
        c.quant.bits = 3;
        c.clients = 0;
        assert!(c.validate().is_err());
        c.clients = 2;
        c.quant.topk_frac = 2.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bits_bound_is_max_bits_everywhere() {
        // The validate bound, the preset grammar, and bitpack all agree on
        // MAX_BITS: 9..=16-bit configs are legal (they take the staged
        // non-LUT decode path), 17 is not.
        let mut c = ExperimentConfig::default();
        for bits in 1..=MAX_BITS {
            c.quant.bits = bits;
            c.validate().unwrap();
        }
        c.quant.bits = MAX_BITS + 1;
        assert!(c.validate().is_err());
        assert_eq!(ExperimentConfig::preset("cnn_qsgd_b12").unwrap().quant.bits, 12);
        assert!(ExperimentConfig::preset("cnn_qsgd_b17").is_err());
        assert!(ExperimentConfig::preset("cnn_qsgd_b0").is_err());
    }

    #[test]
    fn scenario_presets_parse_and_validate() {
        for name in ScenarioConfig::preset_names() {
            let s = ScenarioConfig::preset(name).unwrap();
            assert_eq!(s.name, name);
            s.validate().unwrap();
        }
        assert!(ScenarioConfig::preset("mars-attack").is_err());
        assert!(ScenarioConfig::preset("clean").unwrap().is_clean());
        assert!(!ScenarioConfig::preset("lossy").unwrap().is_clean());
        // A binding uplink cap is a perturbation: it engages the scheduler.
        let bw = ScenarioConfig::preset("bandwidth").unwrap();
        assert!(!bw.is_clean());
        assert_eq!(bw.uplink_cap_bytes, 8192);
        assert_eq!(bw.uplink_cap_min_frac, 0.5);
    }

    #[test]
    fn scenario_validation_rejects_nonsense() {
        let s = ScenarioConfig { loss_prob: 1.5, ..Default::default() };
        assert!(s.validate().is_err());
        let s = ScenarioConfig { straggler_mult: 0.5, ..Default::default() };
        assert!(s.validate().is_err());
        let s = ScenarioConfig { stale_decay: 0.0, ..Default::default() };
        assert!(s.validate().is_err());
        let s = ScenarioConfig { uplink_cap_min_frac: 0.0, ..Default::default() };
        assert!(s.validate().is_err());
        let s = ScenarioConfig { uplink_cap_min_frac: 1.5, ..Default::default() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn chaos_preset_and_validation() {
        let s = ScenarioConfig::preset("chaos").unwrap();
        assert!(!s.is_clean());
        assert_eq!(s.chaos_corrupt_prob, 0.25);
        assert_eq!(s.chaos_corrupt_bytes, 3);
        assert_eq!(s.chaos_kill_round, 3);
        assert_eq!(s.chaos_stall_prob, 0.0, "stalls stay off by default");
        s.validate().unwrap();
        assert!(ScenarioConfig::preset_names().contains(&"chaos"));
        // Corruption without a byte count is a config error, not a silent
        // no-op; probabilities stay in [0, 1]; stall seconds stay finite.
        let bad = ScenarioConfig { chaos_corrupt_prob: 0.5, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ScenarioConfig {
            chaos_corrupt_prob: 1.5,
            chaos_corrupt_bytes: 1,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = ScenarioConfig { chaos_stall_prob: -0.1, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ScenarioConfig { chaos_stall_secs: f64::NAN, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn chaos_json_and_cli_roundtrip() {
        let scenario = ScenarioConfig {
            chaos_stall_prob: 0.1,
            chaos_stall_secs: 0.05,
            ..ScenarioConfig::preset("chaos").unwrap()
        };
        let c = ExperimentConfig { scenario, ..Default::default() };
        let j = c.to_json().to_json();
        let c2 = ExperimentConfig::from_json(&Value::parse(&j).unwrap()).unwrap();
        assert_eq!(c2.scenario, c.scenario, "chaos fields survive the JSON roundtrip");
        for j in [
            r#"{"scenario": {"chaos_corrupt_bytes": -2}}"#,
            r#"{"scenario": {"chaos_kill_round": -1}}"#,
        ] {
            let v = Value::parse(j).unwrap();
            assert!(ExperimentConfig::from_json(&v).is_err(), "{j} must not saturate to 0");
        }
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--scenario", "chaos", "--chaos-kill-round", "5", "--chaos-corrupt-bytes", "1"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.scenario.chaos_kill_round, 5, "freeform flag overrides the preset");
        assert_eq!(c.scenario.chaos_corrupt_bytes, 1);
        assert_eq!(c.scenario.chaos_corrupt_prob, 0.25, "preset value survives overrides");
    }

    #[test]
    fn scenario_json_roundtrip() {
        let scenario = ScenarioConfig {
            stale_k: 5,
            noniid_alpha: 0.25,
            uplink_cap_bytes: 4096,
            uplink_cap_min_frac: 0.75,
            ..ScenarioConfig::preset("lossy").unwrap()
        };
        let c = ExperimentConfig { scenario, ..Default::default() };
        let j = c.to_json().to_json();
        let c2 = ExperimentConfig::from_json(&Value::parse(&j).unwrap()).unwrap();
        assert_eq!(c2.scenario, c.scenario);
    }

    #[test]
    fn scenario_json_rejects_negative_counts() {
        for j in [
            r#"{"scenario": {"stale_k": -3}}"#,
            r#"{"scenario": {"max_retries": -1}}"#,
        ] {
            let v = Value::parse(j).unwrap();
            assert!(ExperimentConfig::from_json(&v).is_err(), "{j} must not saturate to 0");
        }
    }

    #[test]
    fn scenario_cli_flags() {
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--scenario", "stale", "--stale-k", "2", "--loss-prob", "0.1"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.scenario.name, "stale");
        assert_eq!(c.scenario.stale_k, 2, "freeform flag overrides the preset");
        assert_eq!(c.scenario.loss_prob, 0.1, "fields compose across presets");
        assert!(c.id().ends_with("@stale"), "{}", c.id());
    }

    #[test]
    fn args_override() {
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            ["x", "--scheme", "qsgd", "--bits", "5", "--rounds", "10"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.quant.scheme, Scheme::Qsgd);
        assert_eq!(c.quant.bits, 5);
        assert_eq!(c.rounds, 10);
    }

    #[test]
    fn budget_cli_flags() {
        let mut c = ExperimentConfig::default();
        let args = crate::cli::Args::parse(
            [
                "x",
                "--bit-budget",
                "32768",
                "--scenario",
                "bandwidth",
                "--uplink-cap",
                "2048",
                "--uplink-cap-frac",
                "0.8",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.bit_budget, 32768);
        assert_eq!(c.scenario.uplink_cap_bytes, 2048, "flag overrides the preset");
        assert_eq!(c.scenario.uplink_cap_min_frac, 0.8);
        assert!(c.id().ends_with("@bandwidth"), "{}", c.id());
    }
}
