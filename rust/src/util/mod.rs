//! Small shared substrates: deterministic PRNG, numerical math, and the
//! vendored CRC32 behind the transport's wire-integrity trailer.

pub mod crc32;
pub mod math;
pub mod rng;

pub use rng::Rng;

/// Round `n` up to a multiple of `m`.
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance of a slice (0 for empty).
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn mean_variance() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }
}
