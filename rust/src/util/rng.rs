//! Deterministic PRNG substrate (no external crates).
//!
//! Xoshiro256** seeded through SplitMix64. Every stochastic component in the
//! system — batch sampling, stochastic rounding, synthetic data — derives its
//! stream from `(experiment seed, role, index, round)` so runs are exactly
//! reproducible and each (client, round) pair gets an independent stream.

/// SplitMix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Hash a tuple of u64s into one seed (order-sensitive).
pub fn hash_seed(parts: &[u64]) -> u64 {
    let mut s = 0x243F6A8885A308D3u64; // pi digits
    for &p in parts {
        s ^= p.wrapping_mul(0x9E3779B97F4A7C15);
        splitmix64(&mut s);
    }
    splitmix64(&mut s)
}

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box-Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Stream for a (seed, client, round) triple — see module docs.
    pub fn for_stream(seed: u64, role: u64, index: u64, round: u64) -> Self {
        Rng::new(hash_seed(&[seed, role, index, round]))
    }

    /// Snapshot the generator's full state (xoshiro words + the cached
    /// Box-Muller spare) for checkpointing. [`Rng::from_state`] restores a
    /// generator whose future output is bit-identical to this one's.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Self {
        Rng { s, spare_normal }
    }

    /// Next raw 64-bit output (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1) with 24 bits — matches what we feed the kernels.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Two independent uniform f32s from ONE `next_u64` draw (top and middle
    /// 24-bit lanes). The packed codec hot path uses this to halve RNG cost;
    /// the first lane equals what `f32()` would have returned for the same
    /// state, the second comes from otherwise-discarded bits.
    #[inline]
    pub fn f32_pair(&mut self) -> (f32, f32) {
        let w = self.next_u64();
        const SCALE: f32 = 1.0 / (1u64 << 24) as f32;
        ((w >> 40) as f32 * SCALE, ((w >> 16) & 0xFF_FFFF) as f32 * SCALE)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free-enough for our uses; exact via widening.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Gamma(shape k >= 0.01, scale 1) via Marsaglia–Tsang.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^{1/k}
            let g = self.gamma(k + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Student-t with `df` degrees of freedom — our synthetic heavy-tailed
    /// gradient model (tail index gamma = df + 1 in the paper's notation).
    pub fn student_t(&mut self, df: f64) -> f64 {
        let z = self.normal();
        let chi2 = 2.0 * self.gamma(df / 2.0);
        z / (chi2 / df).sqrt()
    }

    /// Pareto / pure power-law tail draw: density ∝ x^{-gamma} on [x_min, ∞).
    /// Inverse CDF: x = x_min * u^{-1/(gamma-1)}.
    pub fn pareto(&mut self, x_min: f64, gamma: f64) -> f64 {
        let u = (1.0 - self.f64()).max(1e-300);
        x_min * u.powf(-1.0 / (gamma - 1.0))
    }

    /// Symmetric power-law-tailed sample used throughout the benches: with
    /// probability `rho` draw ±Pareto(g_min, gamma), else uniform in
    /// (-g_min, g_min) — exactly the paper's tail model (Eq. 10) with a flat
    /// body below the cutoff.
    pub fn power_law_gradient(&mut self, g_min: f64, gamma: f64, rho: f64) -> f64 {
        let sign = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        if self.f64() < rho {
            sign * self.pareto(g_min, gamma)
        } else {
            sign * self.f64() * g_min
        }
    }

    /// Fill a buffer with f32 uniforms in [0,1).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn stream_separation() {
        let a = Rng::for_stream(7, 1, 0, 0).next_u64_once();
        let b = Rng::for_stream(7, 1, 0, 1).next_u64_once();
        let c = Rng::for_stream(7, 1, 1, 0).next_u64_once();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_mean_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_pair_lanes_valid_and_first_matches_f32() {
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        for _ in 0..10_000 {
            let (x, y) = a.f32_pair();
            assert!((0.0..1.0).contains(&x) && (0.0..1.0).contains(&y));
            assert_eq!(x, b.f32(), "first lane must match the f32() stream");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "{m}");
        assert!((v - 1.0).abs() < 0.03, "{v}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(6);
        for &k in &[0.5, 1.5, 4.0] {
            let n = 100_000;
            let m = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((m - k).abs() < 0.08 * k.max(1.0), "k={k} m={m}");
        }
    }

    #[test]
    fn pareto_tail_index_recoverable() {
        // MLE of gamma over Pareto draws should recover the true gamma.
        let mut r = Rng::new(7);
        let (x_min, gamma) = (0.01, 4.0);
        let n = 200_000;
        let sum_log: f64 = (0..n)
            .map(|_| (r.pareto(x_min, gamma) / x_min).ln())
            .sum();
        let est = 1.0 + n as f64 / sum_log;
        assert!((est - gamma).abs() < 0.05, "{est}");
    }

    #[test]
    fn student_t_heavy_tail() {
        // t(3) kurtosis is infinite; just check symmetry + spread sanity.
        let mut r = Rng::new(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.student_t(3.0)).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        assert!(m.abs() < 0.05, "{m}");
        let frac_big = xs.iter().filter(|x| x.abs() > 5.0).count() as f64 / n as f64;
        assert!(frac_big > 0.001, "t(3) should have a real tail: {frac_big}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    impl Rng {
        fn next_u64_once(mut self) -> u64 {
            self.next_u64()
        }
    }
}
