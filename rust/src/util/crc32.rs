//! Vendored zero-dependency CRC32 (IEEE 802.3, reflected, poly 0xEDB88320).
//!
//! Every transport message carries a 4-byte CRC32 trailer over its payload
//! (PROTOCOL.md §2), so a flipped bit on a live socket is detected as
//! *corruption* — a retransmittable condition — instead of being
//! misdiagnosed as a dead peer. The table-driven one-byte-at-a-time form
//! below is the classic public-domain construction: 256-entry table built
//! at first use, byte-reflected, initial value `0xFFFF_FFFF`, final XOR
//! `0xFFFF_FFFF`. It matches zlib's `crc32()` bit for bit (check value:
//! `crc32(b"123456789") == 0xCBF4_3926`).

/// The 256-entry lookup table for the reflected IEEE polynomial, built once.
fn table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC32 of `bytes` (IEEE, reflected — the zlib/PNG/Ethernet checksum).
pub fn crc32(bytes: &[u8]) -> u32 {
    update(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

/// Fold `bytes` into a running (pre-final-XOR) CRC state. Start from
/// `0xFFFF_FFFF`, finish by XORing with `0xFFFF_FFFF` — [`crc32`] does both
/// for the one-shot case; streaming writers (the checkpoint encoder) keep
/// the raw state across chunks.
pub fn update(state: u32, bytes: &[u8]) -> u32 {
    let t = table();
    let mut c = state;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_standard_check_value() {
        // The universal CRC32/IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn streaming_update_equals_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let one = crc32(data);
        let mut state = 0xFFFF_FFFF;
        for chunk in data.chunks(7) {
            state = update(state, chunk);
        }
        assert_eq!(state ^ 0xFFFF_FFFF, one);
    }

    #[test]
    fn detects_any_single_flipped_byte() {
        let clean = b"payload under test: 0123456789abcdef".to_vec();
        let want = crc32(&clean);
        for i in 0..clean.len() {
            let mut bad = clean.clone();
            bad[i] ^= 0xFF;
            assert_ne!(crc32(&bad), want, "flip at byte {i} must change the CRC");
        }
    }
}
