//! Numerical math substrate: quadrature, special functions, root finding.
//!
//! Everything the solver/theory layers need — adaptive Simpson quadrature for
//! the `∫ p(g)/λ(g)²` style integrals of Lemma 2, `erf` for the Gaussian CDF
//! (Fig. 1 fits / KS tests), and a guarded fixed-point iterator for the
//! alternating-iteration thresholds of Eqs. (12)/(19)/(33).

/// Adaptive Simpson quadrature of `f` on [a, b] to absolute tolerance `eps`.
pub fn integrate<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, eps: f64) -> f64 {
    if a == b {
        return 0.0;
    }
    let c = 0.5 * (a + b);
    let (fa, fb, fc) = (f(a), f(b), f(c));
    let whole = simpson(a, b, fa, fc, fb);
    adaptive(f, a, b, fa, fb, fc, whole, eps, 50)
}

#[inline]
fn simpson(a: f64, b: f64, fa: f64, fc: f64, fb: f64) -> f64 {
    (b - a) / 6.0 * (fa + 4.0 * fc + fb)
}

#[allow(clippy::too_many_arguments)]
fn adaptive<F: Fn(f64) -> f64>(
    f: &F, a: f64, b: f64, fa: f64, fb: f64, fc: f64, whole: f64, eps: f64, depth: u32,
) -> f64 {
    let c = 0.5 * (a + b);
    let d = 0.5 * (a + c);
    let e = 0.5 * (c + b);
    let (fd, fe) = (f(d), f(e));
    let left = simpson(a, c, fa, fd, fc);
    let right = simpson(c, b, fc, fe, fb);
    if depth == 0 || (left + right - whole).abs() <= 15.0 * eps {
        return left + right + (left + right - whole) / 15.0;
    }
    adaptive(f, a, c, fa, fc, fd, left, eps / 2.0, depth - 1)
        + adaptive(f, c, b, fc, fb, fe, right, eps / 2.0, depth - 1)
}

/// Error function (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn normal_cdf(x: f64, mu: f64, sigma: f64) -> f64 {
    0.5 * (1.0 + erf((x - mu) / (sigma * std::f64::consts::SQRT_2)))
}

/// Laplace CDF with location `mu`, scale `b`.
pub fn laplace_cdf(x: f64, mu: f64, b: f64) -> f64 {
    if x < mu {
        0.5 * ((x - mu) / b).exp()
    } else {
        1.0 - 0.5 * (-(x - mu) / b).exp()
    }
}

/// Damped fixed-point iteration `x <- (1-w) x + w f(x)` with relative
/// convergence tolerance; returns the final iterate (guarded against NaN by
/// keeping the last finite value).
pub fn fixed_point<F: Fn(f64) -> f64>(f: F, x0: f64, damping: f64, tol: f64, max_iter: usize) -> f64 {
    let mut x = x0;
    for _ in 0..max_iter {
        let fx = f(x);
        if !fx.is_finite() {
            return x;
        }
        let next = (1.0 - damping) * x + damping * fx;
        if (next - x).abs() <= tol * x.abs().max(1e-300) {
            return next;
        }
        x = next;
    }
    x
}

/// Golden-section minimization of a unimodal `f` on [a, b].
pub fn golden_min<F: Fn(f64) -> f64>(f: F, mut a: f64, mut b: f64, tol: f64) -> f64 {
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    let mut c = b - phi * (b - a);
    let mut d = a + phi * (b - a);
    let (mut fc, mut fd) = (f(c), f(d));
    while (b - a).abs() > tol {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - phi * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + phi * (b - a);
            fd = f(d);
        }
    }
    0.5 * (a + b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrate_polynomial() {
        // ∫_0^1 3x² = 1
        let v = integrate(&|x| 3.0 * x * x, 0.0, 1.0, 1e-10);
        assert!((v - 1.0).abs() < 1e-9, "{v}");
    }

    #[test]
    fn integrate_power_law_tail() {
        // ∫_1^∞ x^-4 dx = 1/3; truncate at large B.
        let v = integrate(&|x| x.powi(-4), 1.0, 1e4, 1e-12);
        assert!((v - 1.0 / 3.0).abs() < 1e-6, "{v}");
    }

    #[test]
    fn erf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779).abs() < 1e-6);
    }

    #[test]
    fn cdfs_monotone_and_bounded() {
        let mut last_n = 0.0;
        let mut last_l = 0.0;
        for i in 0..100 {
            let x = -5.0 + i as f64 * 0.1;
            let n = normal_cdf(x, 0.0, 1.0);
            let l = laplace_cdf(x, 0.0, 1.0);
            assert!((0.0..=1.0).contains(&n) && (0.0..=1.0).contains(&l));
            assert!(n >= last_n && l >= last_l);
            last_n = n;
            last_l = l;
        }
    }

    #[test]
    fn fixed_point_sqrt2() {
        // x = f(x) = (x + 2/x)/2 converges to sqrt(2).
        let r = fixed_point(|x| 0.5 * (x + 2.0 / x), 1.0, 1.0, 1e-12, 100);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-10);
    }

    #[test]
    fn golden_min_parabola() {
        let x = golden_min(|x| (x - 0.3).powi(2), -1.0, 1.0, 1e-8);
        assert!((x - 0.3).abs() < 1e-6);
    }
}
