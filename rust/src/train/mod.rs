//! High-level training API: config in, report out.
//!
//! [`Trainer`] owns the compute [`Backend`] + coordinator for one
//! experiment; [`run_experiment`] is the one-call entry the CLI, examples
//! and figure benches use. Sweeps (Fig. 4) reuse a single backend across
//! configs via [`Sweep`], so PJRT artifacts compile once (and the native
//! backend's model zoo is shared).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::Coordinator;
use crate::metrics::RunLog;
use crate::runtime::{backend_for, make_backend, Backend};

/// Result of one experiment.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Per-round records for the whole run.
    pub log: RunLog,
    /// Test accuracy at the final evaluation (0 when never evaluated).
    pub final_accuracy: f64,
    /// Best test accuracy seen during the run.
    pub best_accuracy: f64,
    /// Training loss of the last round.
    pub final_train_loss: f64,
    /// Test loss (or LM NLL) at the final evaluation.
    pub final_test_loss: f64,
    /// Total client→server bytes across the run.
    pub total_bytes_up: u64,
    /// Mean bits shipped per parameter per round per client.
    pub bits_per_param: f64,
}

impl TrainReport {
    fn from_log(log: RunLog, param_count: usize, clients: usize) -> TrainReport {
        let rounds = log.records.len().max(1);
        let bits = log.total_bytes_up() as f64 * 8.0
            / (rounds * param_count * clients) as f64;
        TrainReport {
            final_accuracy: log.final_accuracy().unwrap_or(0.0),
            best_accuracy: log.best_accuracy().unwrap_or(0.0),
            final_train_loss: log.final_train_loss().unwrap_or(f64::NAN),
            final_test_loss: log
                .records
                .iter()
                .rev()
                .find_map(|r| r.test_loss)
                .unwrap_or(f64::NAN),
            total_bytes_up: log.total_bytes_up(),
            bits_per_param: bits,
            log,
        }
    }
}

/// One-experiment trainer.
pub struct Trainer {
    backend: Box<dyn Backend>,
    cfg: ExperimentConfig,
    ckpt: Option<(std::path::PathBuf, usize)>,
}

impl Trainer {
    /// Build the backend the config asks for (`cfg.backend`) and prepare to
    /// train.
    pub fn new(cfg: ExperimentConfig) -> Result<Trainer> {
        let backend = make_backend(&cfg)?;
        Ok(Trainer { backend, cfg, ckpt: None })
    }

    /// The compute backend this trainer selected.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Save a resumable checkpoint to `path` every `every` rounds during the
    /// next run (in-process pipelines only; see `docs/CHECKPOINT.md`).
    pub fn checkpoint_to(&mut self, path: std::path::PathBuf, every: usize) {
        self.ckpt = Some((path, every));
    }

    /// Run the experiment quietly.
    pub fn run(&mut self) -> Result<TrainReport> {
        self.run_verbose(false)
    }

    /// Run the experiment, optionally logging evals to stdout.
    pub fn run_verbose(&mut self, verbose: bool) -> Result<TrainReport> {
        let mut coord = Coordinator::new(self.cfg.clone(), self.backend.as_ref())?;
        if let Some((path, every)) = self.ckpt.clone() {
            coord.checkpoint_to(path, every);
        }
        let params = coord.params.len();
        let clients = self.cfg.clients;
        let log = coord.run(verbose)?;
        Ok(TrainReport::from_log(log, params, clients))
    }
}

/// Run one experiment end to end (convenience).
pub fn run_experiment(cfg: ExperimentConfig, verbose: bool) -> Result<TrainReport> {
    Trainer::new(cfg.clone())?.run_verbose(verbose)
}

/// Multi-config sweep sharing one backend (one PJRT compile per artifact).
pub struct Sweep {
    backend: Box<dyn Backend>,
}

impl Sweep {
    /// Auto-select a backend for an artifacts directory: PJRT when built in
    /// and `manifest.json` exists, the native backend otherwise.
    pub fn new(artifacts_dir: &str) -> Result<Sweep> {
        Ok(Sweep { backend: backend_for("auto", artifacts_dir)? })
    }

    /// Sweep over an explicit backend.
    pub fn with_backend(backend: Box<dyn Backend>) -> Sweep {
        Sweep { backend }
    }

    /// The shared compute backend.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Run one configuration on the shared backend.
    pub fn run(&self, cfg: ExperimentConfig, verbose: bool) -> Result<TrainReport> {
        let mut coord = Coordinator::new(cfg.clone(), self.backend.as_ref())?;
        let params = coord.params.len();
        let log = coord.run(verbose)?;
        Ok(TrainReport::from_log(log, params, cfg.clients))
    }
}
