//! High-level training API: config in, report out.
//!
//! [`Trainer`] owns the PJRT runtime + coordinator for one experiment;
//! [`run_experiment`] is the one-call entry the CLI, examples and figure
//! benches use. Sweeps (Fig. 4) reuse a single `Runtime` across configs via
//! [`Sweep`], so each artifact compiles once.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::Coordinator;
use crate::metrics::RunLog;
use crate::runtime::Runtime;

/// Result of one experiment.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub log: RunLog,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_train_loss: f64,
    pub final_test_loss: f64,
    pub total_bytes_up: u64,
    /// Mean bits shipped per parameter per round per client.
    pub bits_per_param: f64,
}

impl TrainReport {
    fn from_log(log: RunLog, param_count: usize, clients: usize) -> TrainReport {
        let rounds = log.records.len().max(1);
        let bits = log.total_bytes_up() as f64 * 8.0
            / (rounds * param_count * clients) as f64;
        TrainReport {
            final_accuracy: log.final_accuracy().unwrap_or(0.0),
            best_accuracy: log.best_accuracy().unwrap_or(0.0),
            final_train_loss: log.final_train_loss().unwrap_or(f64::NAN),
            final_test_loss: log
                .records
                .iter()
                .rev()
                .find_map(|r| r.test_loss)
                .unwrap_or(f64::NAN),
            total_bytes_up: log.total_bytes_up(),
            bits_per_param: bits,
            log,
        }
    }
}

/// One-experiment trainer.
pub struct Trainer {
    rt: Runtime,
    cfg: ExperimentConfig,
}

impl Trainer {
    pub fn new(cfg: ExperimentConfig) -> Result<Trainer> {
        let rt = Runtime::open(&cfg.artifacts_dir)?;
        Ok(Trainer { rt, cfg })
    }

    pub fn run(&mut self) -> Result<TrainReport> {
        self.run_verbose(false)
    }

    pub fn run_verbose(&mut self, verbose: bool) -> Result<TrainReport> {
        let mut coord = Coordinator::new(self.cfg.clone(), &self.rt)?;
        let params = coord.params.len();
        let clients = self.cfg.clients;
        let log = coord.run(verbose)?;
        Ok(TrainReport::from_log(log, params, clients))
    }
}

/// Run one experiment end to end (convenience).
pub fn run_experiment(cfg: ExperimentConfig, verbose: bool) -> Result<TrainReport> {
    Trainer::new(cfg.clone())?.run_verbose(verbose)
}

/// Multi-config sweep sharing one runtime (one compile per artifact).
pub struct Sweep {
    rt: Runtime,
}

impl Sweep {
    pub fn new(artifacts_dir: &str) -> Result<Sweep> {
        Ok(Sweep { rt: Runtime::open(artifacts_dir)? })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn run(&self, cfg: ExperimentConfig, verbose: bool) -> Result<TrainReport> {
        let mut coord = Coordinator::new(cfg.clone(), &self.rt)?;
        let params = coord.params.len();
        let log = coord.run(verbose)?;
        Ok(TrainReport::from_log(log, params, cfg.clients))
    }
}
