//! Scenario engine: deterministic, seeded perturbations composed onto each
//! training round.
//!
//! The happy-path loop of Algorithm 1 assumes N well-behaved synchronous
//! clients; production federations have none of that. This module turns one
//! [`ScenarioConfig`] into per-round decisions:
//!
//! * **churn** — clients drop out / rejoin round to round (the server
//!   reweights surviving frames),
//! * **stragglers** — a fixed subset of clients uploads slower by a
//!   multiplier (tail latency visible via `SimNet`'s per-client times),
//! * **packet loss** — each uplink frame needs a geometric number of
//!   attempts; past `max_retries` the frame is lost for the round,
//! * **bounded staleness** — the server steps after the first K arrivals;
//!   late frames apply next round with weight decayed by `stale_decay`.
//!
//! Every decision draws from its own `Rng::for_stream(seed, ROLE, client,
//! round)` stream, so (a) runs are bit-reproducible and (b) toggling one
//! perturbation never shifts another's draws. With `stale_k >= N` the
//! schedule degenerates to the synchronous path *bit-for-bit*: the apply
//! set, weights (decay^0 = 1 exactly) and f32 aggregation order all match
//! the clean run — asserted by the integration suite.

use crate::config::ScenarioConfig;
use crate::util::Rng;

use super::network::{LinkCondition, Message};

/// Stream roles (see `util::rng` docs): one per perturbation kind so the
/// draws are independent.
const ROLE_STRAGGLER: u64 = 0x5C_E1;
const ROLE_CHURN: u64 = 0x5C_E2;
const ROLE_LOSS: u64 = 0x5C_E3;
const ROLE_COHORT: u64 = 0x5C_E4;
const ROLE_BW: u64 = 0x5C_E5;
/// Chaos-harness faults (corruption, stalls, the kill target). Sub-streams
/// are separated by high bits of the index so per-client decision draws
/// (index = client) never collide with the corrupt-position draws
/// (client | 1 << 32), stall draws (client | 2 << 32) or the fleet-wide
/// kill-target draw (3 << 32).
const ROLE_CHAOS: u64 = 0x5C_E6;

/// Does the chaos harness corrupt this client's uplink payload this round?
/// Seeded and stateless, so the worker (which flips the bytes) and the
/// server (which models the waste for in-process parity) agree exactly.
pub fn chaos_corrupts(cfg: &ScenarioConfig, seed: u64, client: usize, round: u64) -> bool {
    cfg.chaos_corrupt_prob > 0.0
        && Rng::for_stream(seed, ROLE_CHAOS, client as u64, round).f64() < cfg.chaos_corrupt_prob
}

/// The distinct payload byte positions a corrupting worker flips
/// (`chaos_corrupt_bytes` of them, each XOR 0xFF). Drawn from a dedicated
/// sub-stream so adding corruption never shifts the corrupt-or-not draw.
pub fn chaos_corrupt_positions(
    cfg: &ScenarioConfig,
    seed: u64,
    client: usize,
    round: u64,
    payload_len: usize,
) -> Vec<usize> {
    let want = cfg.chaos_corrupt_bytes.min(payload_len);
    let mut rng = Rng::for_stream(seed, ROLE_CHAOS, client as u64 | (1 << 32), round);
    let mut positions = Vec::with_capacity(want);
    while positions.len() < want {
        let p = rng.below(payload_len as u64) as usize;
        if !positions.contains(&p) {
            positions.push(p);
        }
    }
    positions
}

/// Does the chaos harness stall this client before its uplink this round
/// (a real `sleep(chaos_stall_secs)` on the worker, absorbed by the
/// server's read deadline)?
pub fn chaos_stalls(cfg: &ScenarioConfig, seed: u64, client: usize, round: u64) -> bool {
    cfg.chaos_stall_prob > 0.0
        && Rng::for_stream(seed, ROLE_CHAOS, client as u64 | (2 << 32), round).f64()
            < cfg.chaos_stall_prob
}

/// The worker the chaos harness kills after round `chaos_kill_round`'s
/// uplink, or `None` when no kill is scheduled. One fleet-wide draw keyed
/// on the kill round, so every process (victim, server, orchestrator)
/// derives the same victim from the shared config + seed.
pub fn chaos_kill_target(cfg: &ScenarioConfig, seed: u64, n: usize) -> Option<usize> {
    if cfg.chaos_kill_round == 0 || n == 0 {
        return None;
    }
    let mut rng = Rng::for_stream(seed, ROLE_CHAOS, 3 << 32, cfg.chaos_kill_round as u64);
    Some(rng.below(n as u64) as usize)
}

/// A frame held back by the bounded-staleness scheduler.
#[derive(Clone, Debug)]
struct LateFrame {
    msg: Message,
    /// Rounds the frame has been delayed so far (>= 1 once pending).
    staleness: u32,
}

/// Per-run scenario state: churn membership, straggler assignment and the
/// late-frame queue.
pub struct ScenarioEngine {
    cfg: ScenarioConfig,
    seed: u64,
    /// Churn state per client (true = participating).
    active: Vec<bool>,
    /// Fixed straggler assignment per client.
    slow: Vec<bool>,
    /// Fixed per-client uplink cap in bytes (empty when the scenario sets
    /// no caps; 0 entries would mean "uncapped", but the draw below always
    /// yields positive caps).
    uplink_caps: Vec<u64>,
    pending: Vec<LateFrame>,
}

impl ScenarioEngine {
    /// Build the engine for `n` clients. The straggler subset is chosen by a
    /// dedicated seeded shuffle, so it is stable for a (seed, n) pair. Any
    /// `straggler_frac > 0` designates at least one straggler: on small
    /// fleets `round()` would otherwise yield zero and silently turn the
    /// scenario into `clean` (e.g. n = 3, frac = 0.1 rounds to 0).
    pub fn new(cfg: ScenarioConfig, n: usize, seed: u64) -> Self {
        assert!(n >= 1);
        let slow_count = if cfg.straggler_frac > 0.0 {
            ((cfg.straggler_frac * n as f64).round() as usize).max(1).min(n)
        } else {
            0
        };
        let mut order: Vec<usize> = (0..n).collect();
        Rng::for_stream(seed, ROLE_STRAGGLER, 0, 0).shuffle(&mut order);
        let mut slow = vec![false; n];
        for &i in &order[..slow_count] {
            slow[i] = true;
        }
        // Heterogeneous uplink caps: each client's cap is a seeded draw in
        // [min_frac, 1] of the configured ceiling, fixed for the run (real
        // fleets have stable per-device bandwidth, not per-round jitter).
        // cap == 0 performs NO draws, keeping invariant 6's strict no-op.
        let uplink_caps = if cfg.uplink_cap_bytes > 0 {
            (0..n)
                .map(|i| {
                    let u = Rng::for_stream(seed, ROLE_BW, i as u64, 0).f64();
                    let frac = cfg.uplink_cap_min_frac + (1.0 - cfg.uplink_cap_min_frac) * u;
                    ((cfg.uplink_cap_bytes as f64 * frac) as u64).max(1)
                })
                .collect()
        } else {
            Vec::new()
        };
        ScenarioEngine {
            cfg,
            seed,
            active: vec![true; n],
            slow,
            uplink_caps,
            pending: Vec::new(),
        }
    }

    /// This client's uplink cap in bytes (0 = uncapped). Fixed per run by
    /// a dedicated seeded stream (`ROLE_BW`), so the bit-budget planner's
    /// per-client constraints are reproducible.
    pub fn uplink_cap(&self, client: usize) -> u64 {
        self.uplink_caps.get(client).copied().unwrap_or(0)
    }

    /// Per-client uplink caps for the whole fleet (empty = no caps).
    pub fn uplink_caps(&self) -> &[u64] {
        &self.uplink_caps
    }

    /// The scenario this engine runs.
    pub fn config(&self) -> &ScenarioConfig {
        &self.cfg
    }

    /// Advance churn state for `round` and return the participating client
    /// ids (ascending). At least one client always participates.
    pub fn begin_round(&mut self, round: u64) -> Vec<usize> {
        let n = self.active.len();
        if self.cfg.dropout_prob > 0.0 || self.cfg.rejoin_prob > 0.0 {
            for (i, a) in self.active.iter_mut().enumerate() {
                let u = Rng::for_stream(self.seed, ROLE_CHURN, i as u64, round).f64();
                if *a {
                    if u < self.cfg.dropout_prob {
                        *a = false;
                    }
                } else if u < self.cfg.rejoin_prob {
                    *a = true;
                }
            }
            if !self.active.iter().any(|&a| a) {
                // Never let the federation go dark: deterministically revive
                // one client.
                self.active[(round as usize) % n] = true;
            }
        }
        (0..n).filter(|&i| self.active[i]).collect()
    }

    /// Clients currently dropped out.
    pub fn dropped_count(&self) -> usize {
        self.active.iter().filter(|&&a| !a).count()
    }

    /// Is this client a designated straggler?
    pub fn is_straggler(&self, client: usize) -> bool {
        self.slow[client]
    }

    /// Wire transmissions a fully-lost frame burned before the client gave
    /// up: the initial attempt plus every retransmit.
    pub fn lost_attempts(&self) -> u32 {
        self.cfg.max_retries + 1
    }

    /// Uplink conditions for `client` this round, or `None` when the frame
    /// is lost even after `max_retries` retransmits.
    pub fn link(&self, client: usize, round: u64) -> Option<LinkCondition> {
        let latency_mult = if self.slow[client] { self.cfg.straggler_mult } else { 1.0 };
        if self.cfg.loss_prob <= 0.0 {
            return Some(LinkCondition { latency_mult, attempts: 1 });
        }
        let mut rng = Rng::for_stream(self.seed, ROLE_LOSS, client as u64, round);
        for attempt in 1..=self.cfg.max_retries + 1 {
            if rng.f64() >= self.cfg.loss_prob {
                return Some(LinkCondition { latency_mult, attempts: attempt });
            }
        }
        None
    }

    /// Bounded-staleness scheduler. Input: this round's delivered messages
    /// with their simulated uplink seconds. The first K arrivals (by time,
    /// ties broken by client id) apply now; the rest join the pending queue
    /// and apply next round with staleness 1. All previously pending frames
    /// are drained into the apply set.
    ///
    /// The returned `(message, staleness)` list is sorted by (origin round,
    /// client id) so the server's f32 aggregation order is deterministic —
    /// and identical to the synchronous order when nothing is late.
    ///
    /// The second return value is the round's communication time: the K-th
    /// arrival's seconds (the server steps then, not when the slowest frame
    /// lands), which equals the plain max when nothing is deferred.
    pub fn schedule(&mut self, arrived: Vec<(Message, f64)>) -> (Vec<(Message, u32)>, f64) {
        let k = if self.cfg.stale_k == 0 {
            arrived.len()
        } else {
            self.cfg.stale_k.min(arrived.len())
        };
        let mut order: Vec<usize> = (0..arrived.len()).collect();
        order.sort_by(|&a, &b| {
            arrived[a]
                .1
                .partial_cmp(&arrived[b].1)
                .expect("uplink times are finite")
                .then(arrived[a].0.client.cmp(&arrived[b].0.client))
        });
        let round_secs = if k > 0 { arrived[order[k - 1]].1 } else { 0.0 };
        let late: Vec<bool> = {
            let mut l = vec![false; arrived.len()];
            for &i in order.iter().skip(k) {
                l[i] = true;
            }
            l
        };
        let mut apply: Vec<(Message, u32)> =
            self.pending.drain(..).map(|lf| (lf.msg, lf.staleness)).collect();
        for (i, (m, _)) in arrived.into_iter().enumerate() {
            if late[i] {
                self.pending.push(LateFrame { msg: m, staleness: 1 });
            } else {
                apply.push((m, 0));
            }
        }
        apply.sort_by(|a, b| a.0.round.cmp(&b.0.round).then(a.0.client.cmp(&b.0.client)));
        (apply, round_secs)
    }

    /// Aggregation-weight multiplier for a frame `staleness` rounds old.
    /// Exactly 1.0 for fresh frames, so the synchronous path is untouched.
    ///
    /// The exponent saturates at `i32::MAX` instead of casting `u32 → i32`
    /// directly: a staleness above 2^31 would wrap negative and turn the
    /// decay into an *amplifier* (`decay^-k > 1`). At any such exponent a
    /// decay < 1 has underflowed to 0 long before the clamp matters, so
    /// saturation is bit-identical for every reachable staleness.
    pub fn stale_weight(&self, staleness: u32) -> f64 {
        self.cfg.stale_decay.powi(staleness.min(i32::MAX as u32) as i32)
    }

    /// Frames currently waiting in the late queue.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Snapshot the engine's mutable state for a checkpoint: the churn
    /// membership mask and the late-frame queue as `(message, staleness)`
    /// pairs. Everything else (straggler assignment, uplink caps) is a pure
    /// function of `(cfg, n, seed)` and is rebuilt on resume.
    pub fn export_state(&self) -> (Vec<bool>, Vec<(Message, u32)>) {
        (
            self.active.clone(),
            self.pending.iter().map(|lf| (lf.msg.clone(), lf.staleness)).collect(),
        )
    }

    /// Restore a snapshot taken by [`ScenarioEngine::export_state`].
    /// Panics if the churn mask's length does not match this fleet.
    pub fn restore_state(&mut self, active: Vec<bool>, pending: Vec<(Message, u32)>) {
        assert_eq!(active.len(), self.active.len(), "churn mask size mismatch");
        self.active = active;
        self.pending = pending
            .into_iter()
            .map(|(msg, staleness)| LateFrame { msg, staleness })
            .collect();
    }

    /// Seeded per-round cohort draw: a sorted K-subset of `0..n` chosen by
    /// a Fisher–Yates shuffle on a dedicated stream (`ROLE_COHORT`), so the
    /// draw composes with churn/straggler/loss without shifting their
    /// streams. The cohort is drawn over *all* N clients (independent of
    /// churn state); callers intersect it with the churn-active set.
    ///
    /// `k == 0` or `k >= n` means full participation and performs **no
    /// draws at all** — the K=N degenerate path is bit-identical to the
    /// pre-cohort engine by construction.
    pub fn sample_cohort(&self, round: u64, n: usize, k: usize) -> Vec<usize> {
        if k == 0 || k >= n {
            return (0..n).collect();
        }
        let mut order: Vec<usize> = (0..n).collect();
        Rng::for_stream(self.seed, ROLE_COHORT, 0, round).shuffle(&mut order);
        let mut cohort = order[..k].to_vec();
        cohort.sort_unstable();
        cohort
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(client: usize, round: usize) -> Message {
        Message { client, round, frames: vec![(0, vec![0u8; 8])], loss: 0.0 }
    }

    #[test]
    fn clean_engine_is_inert() {
        let mut e = ScenarioEngine::new(ScenarioConfig::default(), 4, 1);
        for round in 0..5 {
            assert_eq!(e.begin_round(round), vec![0, 1, 2, 3]);
            for c in 0..4 {
                let l = e.link(c, round).unwrap();
                assert_eq!(l.attempts, 1);
                assert_eq!(l.latency_mult, 1.0);
            }
        }
        assert_eq!(e.dropped_count(), 0);
    }

    #[test]
    fn straggler_assignment_is_sized_and_deterministic() {
        let cfg = ScenarioConfig::preset("straggler").unwrap();
        let a = ScenarioEngine::new(cfg.clone(), 8, 7);
        let b = ScenarioEngine::new(cfg, 8, 7);
        let slow_a: Vec<bool> = (0..8).map(|i| a.is_straggler(i)).collect();
        let slow_b: Vec<bool> = (0..8).map(|i| b.is_straggler(i)).collect();
        assert_eq!(slow_a, slow_b);
        assert_eq!(slow_a.iter().filter(|&&s| s).count(), 2, "25% of 8");
        let slow = slow_a.iter().position(|&s| s).unwrap();
        assert_eq!(a.link(slow, 0).unwrap().latency_mult, 8.0);
    }

    #[test]
    fn churn_keeps_at_least_one_client() {
        let cfg = ScenarioConfig {
            dropout_prob: 1.0, // everyone tries to leave every round
            rejoin_prob: 0.0,
            ..ScenarioConfig::preset("churn").unwrap()
        };
        let mut e = ScenarioEngine::new(cfg, 4, 3);
        for round in 0..10 {
            let active = e.begin_round(round);
            assert!(!active.is_empty(), "round {round} went dark");
        }
    }

    #[test]
    fn churn_is_deterministic_and_actually_churns() {
        let cfg = ScenarioConfig::preset("churn").unwrap();
        let mut a = ScenarioEngine::new(cfg.clone(), 8, 5);
        let mut b = ScenarioEngine::new(cfg, 8, 5);
        let mut ever_dropped = false;
        for round in 0..30 {
            let xa = a.begin_round(round);
            assert_eq!(xa, b.begin_round(round));
            ever_dropped |= xa.len() < 8;
        }
        assert!(ever_dropped, "dropout_prob=0.15 over 30 rounds must drop someone");
    }

    #[test]
    fn loss_draws_are_per_round_streams() {
        let cfg = ScenarioConfig::preset("lossy").unwrap();
        let e = ScenarioEngine::new(cfg, 4, 9);
        // Deterministic: same (client, round) twice gives the same answer.
        let first = e.link(0, 3).map(|l| l.attempts);
        let again = e.link(0, 3).map(|l| l.attempts);
        assert_eq!(first, again);
        // With loss 0.2 and 5 retries, retransmits must occur somewhere over
        // many draws, and most frames still get through.
        let mut retransmitted = 0usize;
        let mut delivered = 0usize;
        for round in 0..200 {
            for c in 0..4 {
                if let Some(l) = e.link(c, round) {
                    delivered += 1;
                    if l.attempts > 1 {
                        retransmitted += 1;
                    }
                }
            }
        }
        assert!(retransmitted > 50, "~20% of 800 frames should need retries");
        assert!(delivered > 780, "loss^6 wipeouts should be vanishingly rare");
    }

    #[test]
    fn schedule_k_of_n_delays_slowest_and_applies_next_round() {
        let cfg = ScenarioConfig { stale_k: 2, stale_decay: 0.5, ..Default::default() };
        let mut e = ScenarioEngine::new(cfg, 3, 1);
        let arrived = vec![(msg(0, 0), 0.1), (msg(1, 0), 0.9), (msg(2, 0), 0.2)];
        let (apply, secs) = e.schedule(arrived);
        let ids: Vec<usize> = apply.iter().map(|(m, _)| m.client).collect();
        assert_eq!(ids, vec![0, 2], "client 1 (slowest) is late");
        assert_eq!(secs, 0.2, "server steps at the K-th arrival, not the slowest");
        assert_eq!(e.pending_len(), 1);
        // Next round: the late frame applies first (older round), staleness 1.
        let (apply2, _) = e.schedule(vec![(msg(0, 1), 0.1), (msg(1, 1), 0.2), (msg(2, 1), 0.3)]);
        assert_eq!(apply2[0].0.client, 1);
        assert_eq!(apply2[0].0.round, 0);
        assert_eq!(apply2[0].1, 1);
        assert_eq!(e.stale_weight(apply2[0].1), 0.5);
        assert_eq!(e.stale_weight(0), 1.0);
    }

    #[test]
    fn small_fleet_nonzero_frac_selects_at_least_one_straggler() {
        // Regression: n = 3, frac = 0.1 rounds to 0 stragglers, silently
        // degrading the scenario to `clean`. The engine must clamp to 1.
        let cfg = ScenarioConfig {
            straggler_frac: 0.1,
            straggler_mult: 4.0,
            ..Default::default()
        };
        let e = ScenarioEngine::new(cfg.clone(), 3, 11);
        let slow: Vec<usize> = (0..3).filter(|&i| e.is_straggler(i)).collect();
        assert_eq!(slow.len(), 1, "straggler_frac > 0 must select >= 1 straggler");
        // The assignment is digest-relevant (straggler_mult scales net_secs,
        // which replay_digest folds in) — pin that it is seed-stable.
        let e2 = ScenarioEngine::new(cfg, 3, 11);
        let slow2: Vec<usize> = (0..3).filter(|&i| e2.is_straggler(i)).collect();
        assert_eq!(slow, slow2);
        assert_eq!(e.link(slow[0], 0).unwrap().latency_mult, 4.0);
        // frac = 0 still means zero stragglers (the clean path is untouched).
        let clean = ScenarioEngine::new(ScenarioConfig::default(), 3, 11);
        assert!((0..3).all(|i| !clean.is_straggler(i)));
    }

    #[test]
    fn stale_weight_saturates_at_extreme_staleness() {
        let cfg = ScenarioConfig { stale_decay: 0.5, ..Default::default() };
        let e = ScenarioEngine::new(cfg, 2, 1);
        // Existing semantics are untouched at reachable staleness.
        assert_eq!(e.stale_weight(0), 1.0);
        assert_eq!(e.stale_weight(1), 0.5);
        assert_eq!(e.stale_weight(10), 0.5f64.powi(10));
        // Extreme staleness: a naive `as i32` cast would wrap negative and
        // return 2^k > 1; the saturated form underflows to 0 instead.
        for s in [i32::MAX as u32, i32::MAX as u32 + 1, u32::MAX] {
            let w = e.stale_weight(s);
            assert!(
                w >= 0.0 && w <= f64::MIN_POSITIVE,
                "stale_weight({s}) = {w} must underflow toward 0, never amplify"
            );
        }
        // decay = 1.0 (the synchronous default) stays exactly 1 everywhere.
        let sync = ScenarioEngine::new(ScenarioConfig::default(), 2, 1);
        assert_eq!(sync.stale_weight(u32::MAX), 1.0);
    }

    #[test]
    fn cohort_draw_is_seeded_sorted_and_composes() {
        let cfg = ScenarioConfig::preset("churn").unwrap();
        let e = ScenarioEngine::new(cfg.clone(), 8, 5);
        // K = 0 and K >= N are full participation with no draws.
        assert_eq!(e.sample_cohort(0, 8, 0), (0..8).collect::<Vec<_>>());
        assert_eq!(e.sample_cohort(0, 8, 8), (0..8).collect::<Vec<_>>());
        assert_eq!(e.sample_cohort(0, 8, 99), (0..8).collect::<Vec<_>>());
        // K < N: sorted K-subset, deterministic per (seed, round).
        let c = e.sample_cohort(3, 8, 3);
        assert_eq!(c.len(), 3);
        assert!(c.windows(2).all(|w| w[0] < w[1]), "cohort must be sorted: {c:?}");
        assert!(c.iter().all(|&i| i < 8));
        assert_eq!(c, e.sample_cohort(3, 8, 3), "same (seed, round) → same cohort");
        // Different rounds vary the draw (over 16 rounds at K=3 of N=8 a
        // constant cohort is astronomically unlikely).
        let varies = (0..16).any(|r| e.sample_cohort(r, 8, 3) != c);
        assert!(varies, "cohort must be redrawn per round");
        // Composability: the cohort draw must not perturb the churn stream —
        // an engine that never samples cohorts sees identical churn.
        let mut with = ScenarioEngine::new(cfg.clone(), 8, 5);
        let mut without = ScenarioEngine::new(cfg, 8, 5);
        for round in 0..12 {
            let _ = with.sample_cohort(round, 8, 3);
            assert_eq!(with.begin_round(round), without.begin_round(round));
        }
    }

    #[test]
    fn uplink_caps_are_seeded_bounded_and_off_by_default() {
        let cfg = ScenarioConfig::preset("bandwidth").unwrap();
        let a = ScenarioEngine::new(cfg.clone(), 8, 5);
        let b = ScenarioEngine::new(cfg.clone(), 8, 5);
        for c in 0..8 {
            let cap = a.uplink_cap(c);
            assert_eq!(cap, b.uplink_cap(c), "caps must be seed-stable");
            let lo = (cfg.uplink_cap_bytes as f64 * cfg.uplink_cap_min_frac) as u64;
            assert!(
                cap >= lo && cap <= cfg.uplink_cap_bytes,
                "client {c}: cap {cap} outside [{lo}, {}]",
                cfg.uplink_cap_bytes
            );
        }
        assert!(
            (0..8).any(|c| a.uplink_cap(c) < cfg.uplink_cap_bytes),
            "min_frac < 1 should produce heterogeneous caps"
        );
        // The default scenario draws nothing and reports uncapped.
        let clean = ScenarioEngine::new(ScenarioConfig::default(), 8, 5);
        assert!(clean.uplink_caps().is_empty());
        assert_eq!(clean.uplink_cap(3), 0);
    }

    #[test]
    fn chaos_draws_are_seeded_and_off_by_default() {
        let clean = ScenarioConfig::default();
        for c in 0..4 {
            assert!(!chaos_corrupts(&clean, 7, c, 0));
            assert!(!chaos_stalls(&clean, 7, c, 0));
        }
        assert_eq!(chaos_kill_target(&clean, 7, 4), None, "kill_round 0 = no kill");

        let chaos = ScenarioConfig::preset("chaos").unwrap();
        // Kill target: deterministic, in range, keyed on the kill round.
        let victim = chaos_kill_target(&chaos, 7, 4).unwrap();
        assert!(victim < 4);
        assert_eq!(Some(victim), chaos_kill_target(&chaos, 7, 4));
        // Corruption decision: deterministic per (client, round), and at
        // prob 0.25 both outcomes occur over 4 clients x 50 rounds.
        let mut hits = 0usize;
        for round in 0..50 {
            for c in 0..4 {
                let a = chaos_corrupts(&chaos, 7, c, round);
                assert_eq!(a, chaos_corrupts(&chaos, 7, c, round));
                hits += a as usize;
            }
        }
        assert!(hits > 10 && hits < 190, "corrupt_prob 0.25 should hit ~50/200: {hits}");
        // Positions: exactly `chaos_corrupt_bytes` distinct in-bounds
        // indices, identical on redraw (worker and test harness agree).
        let pos = chaos_corrupt_positions(&chaos, 7, 1, 3, 64);
        assert_eq!(pos.len(), chaos.chaos_corrupt_bytes);
        assert!(pos.iter().all(|&p| p < 64));
        let mut uniq = pos.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), pos.len(), "positions must be distinct: {pos:?}");
        assert_eq!(pos, chaos_corrupt_positions(&chaos, 7, 1, 3, 64));
        // A tiny payload clamps to its length instead of spinning forever.
        assert_eq!(chaos_corrupt_positions(&chaos, 7, 1, 3, 2).len(), 2);
    }

    #[test]
    fn scenario_state_export_restore_roundtrips() {
        let cfg = ScenarioConfig { stale_k: 1, stale_decay: 0.5, ..Default::default() };
        let mut e = ScenarioEngine::new(cfg.clone(), 3, 1);
        let (apply, _) = e.schedule(vec![(msg(0, 0), 0.1), (msg(1, 0), 0.9), (msg(2, 0), 0.5)]);
        assert_eq!(apply.len(), 1);
        assert_eq!(e.pending_len(), 2);
        let (active, pending) = e.export_state();
        let mut fresh = ScenarioEngine::new(cfg, 3, 1);
        assert_eq!(fresh.pending_len(), 0);
        fresh.restore_state(active, pending);
        assert_eq!(fresh.pending_len(), 2);
        // The restored queue drains exactly like the original's would.
        let (a1, s1) = e.schedule(vec![(msg(0, 1), 0.2)]);
        let (a2, s2) = fresh.schedule(vec![(msg(0, 1), 0.2)]);
        assert_eq!(s1, s2);
        assert_eq!(a1.len(), a2.len());
        for (x, y) in a1.iter().zip(&a2) {
            assert_eq!(x.0.client, y.0.client);
            assert_eq!(x.0.round, y.0.round);
            assert_eq!(x.1, y.1);
        }
    }

    #[test]
    fn schedule_with_k_geq_n_is_synchronous() {
        for stale_k in [0usize, 3, 99] {
            let cfg = ScenarioConfig { stale_k, ..Default::default() };
            let mut e = ScenarioEngine::new(cfg, 3, 1);
            let (apply, secs) =
                e.schedule(vec![(msg(2, 0), 0.3), (msg(0, 0), 0.5), (msg(1, 0), 0.1)]);
            let ids: Vec<usize> = apply.iter().map(|(m, _)| m.client).collect();
            assert_eq!(ids, vec![0, 1, 2], "client order, all staleness 0");
            assert_eq!(secs, 0.5, "synchronous round time is the slowest arrival");
            assert!(apply.iter().all(|(_, s)| *s == 0));
            assert_eq!(e.pending_len(), 0);
        }
    }
}
