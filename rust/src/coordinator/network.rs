//! Simulated network between clients and server.
//!
//! The coordinator exchanges REAL bytes (wire frames); this module accounts
//! for them and models transfer time under a bandwidth/latency model.  The
//! paper's communication budget is bits-per-element-per-round; the benches
//! read `bytes_up` directly from here.

use crate::config::NetConfig;

/// Per-round message with its payload bytes.
#[derive(Clone, Debug)]
pub struct Message {
    pub client: usize,
    pub round: usize,
    /// (group index, frame bytes) per quantization group.
    pub frames: Vec<(usize, Vec<u8>)>,
    /// Client-side training loss this round (scalar metadata).
    pub loss: f32,
}

impl Message {
    /// Total bytes on the wire: fixed header + per-frame length prefix +
    /// frame payloads.
    pub fn wire_bytes(&self) -> u64 {
        let header = 16u64; // client, round, loss, frame count
        header
            + self
                .frames
                .iter()
                .map(|(_, f)| 4 + f.len() as u64)
                .sum::<u64>()
    }
}

/// Accounting + latency model for one round of uplinks.
pub struct SimNet {
    cfg: NetConfig,
    pub total_bytes_up: u64,
}

impl SimNet {
    pub fn new(cfg: NetConfig) -> Self {
        SimNet { cfg, total_bytes_up: 0 }
    }

    /// Register a round's uplink messages; returns the simulated wall-clock
    /// seconds the round spends in communication. Clients upload in
    /// parallel, so round time = max over clients (latency + bytes / bw).
    pub fn round_uplink(&mut self, msgs: &[Message]) -> (u64, f64) {
        let mut round_bytes = 0u64;
        let mut slowest = 0.0f64;
        for m in msgs {
            let b = m.wire_bytes();
            round_bytes += b;
            let t = self.cfg.latency_sec
                + if self.cfg.bandwidth_bytes_per_sec > 0.0 {
                    b as f64 / self.cfg.bandwidth_bytes_per_sec
                } else {
                    0.0
                };
            slowest = slowest.max(t);
        }
        self.total_bytes_up += round_bytes;
        (round_bytes, slowest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(bytes: usize) -> Message {
        Message { client: 0, round: 0, frames: vec![(0, vec![0u8; bytes])], loss: 0.0 }
    }

    #[test]
    fn wire_bytes_counts_everything() {
        let m = msg(100);
        assert_eq!(m.wire_bytes(), 16 + 4 + 100);
    }

    #[test]
    fn accounting_accumulates() {
        let mut net = SimNet::new(NetConfig::default());
        let (b, t) = net.round_uplink(&[msg(100), msg(50)]);
        assert_eq!(b, (16 + 4 + 100) + (16 + 4 + 50));
        assert_eq!(t, 0.0);
        net.round_uplink(&[msg(10)]);
        assert_eq!(net.total_bytes_up, b + 16 + 4 + 10);
    }

    #[test]
    fn latency_model_takes_slowest() {
        let mut net = SimNet::new(NetConfig {
            bandwidth_bytes_per_sec: 1000.0,
            latency_sec: 0.01,
        });
        let (_, t) = net.round_uplink(&[msg(1000), msg(10)]);
        // slowest message: (16 + 4 + 1000) bytes at 1000 B/s + 10ms latency.
        assert!((t - (0.01 + 1020.0 / 1000.0)).abs() < 1e-9);
    }
}
