//! The network seam between clients and server: the [`Transport`] trait,
//! plus the simulated in-process implementation, [`SimNet`].
//!
//! The coordinator exchanges REAL bytes (wire frames); this module accounts
//! for them and models transfer time under a bandwidth/latency model.  The
//! paper's communication budget is bits-per-element-per-round; the benches
//! read `bytes_up` directly from here.
//!
//! Scenario support: each uplink can carry a [`LinkCondition`] — a latency
//! multiplier (compute/network straggler) and an attempt count (packet loss
//! with retransmits). The per-round [`UplinkReport`] surfaces *per-client*
//! communication time (not just the max), so straggler scenarios can report
//! tail latency, plus the bytes burned on retransmissions.
//!
//! [`Transport`] abstracts how a round's parameter broadcast and gradient
//! uplinks move: [`SimNet`] keeps everything in-process (clients are threads
//! in the coordinator), while `coordinator::transport::TcpTransport` drives
//! real worker processes over TCP sockets (see `docs/PROTOCOL.md`). Both
//! share the SimNet accounting model, so a clean multi-process run's
//! `replay_digest()` is bit-identical to the in-process pipelines.

use anyhow::{bail, Result};

use crate::config::NetConfig;
use crate::quant::RatePlan;

/// Per-round message with its payload bytes.
#[derive(Clone, Debug)]
pub struct Message {
    /// Originating client id in `0..N`.
    pub client: usize,
    /// Communication round the frames were encoded in.
    pub round: usize,
    /// (group index, frame bytes) per quantization group.
    pub frames: Vec<(usize, Vec<u8>)>,
    /// Client-side training loss this round (scalar metadata).
    pub loss: f32,
}

impl Message {
    /// Total bytes on the wire: fixed header + per-frame length prefix +
    /// frame payloads.
    pub fn wire_bytes(&self) -> u64 {
        let header = 16u64; // client, round, loss, frame count
        header
            + self
                .frames
                .iter()
                .map(|(_, f)| 4 + f.len() as u64)
                .sum::<u64>()
    }

    /// Wire bytes burned by this message when it never arrives: every one
    /// of its `attempts` transmissions hit the wire and was wasted. The one
    /// formula behind [`SimNet::account_lost`] and the round pipelines'
    /// worker-side loss accounting.
    pub fn lost_wire_bytes(&self, attempts: u32) -> u64 {
        self.wire_bytes() * attempts as u64
    }

    /// Byte length of the multi-process UPLINK payload carrying this
    /// message (PROTOCOL.md §3.4, Arrived outcome): type + round + client +
    /// loss + outcome + frame count, then a `(group, len)` prefix per
    /// frame. This is what a corrupted uplink wastes on the wire, so both
    /// the TCP transport and the in-process chaos model charge exactly this
    /// many bytes per corrupt transmission — keeping `replay_digest()`
    /// bit-identical across transports under seeded corruption.
    pub fn remote_uplink_payload_bytes(&self) -> u64 {
        18 + self
            .frames
            .iter()
            .map(|(_, f)| 8 + f.len() as u64)
            .sum::<u64>()
    }
}

/// Per-uplink transmission conditions injected by the scenario engine.
#[derive(Clone, Copy, Debug)]
pub struct LinkCondition {
    /// Multiplier on this client's transfer time (stragglers > 1).
    pub latency_mult: f64,
    /// Transmissions needed for delivery (1 = first try; n > 1 means n − 1
    /// lost attempts were re-sent and accounted as retransmitted bytes).
    pub attempts: u32,
}

impl Default for LinkCondition {
    fn default() -> Self {
        LinkCondition { latency_mult: 1.0, attempts: 1 }
    }
}

/// What one round of uplinks cost.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UplinkReport {
    /// Goodput: bytes of frames that arrived (excludes retransmissions).
    pub bytes: u64,
    /// Extra bytes burned re-sending lost attempts.
    pub retransmitted_bytes: u64,
    /// Simulated wall-clock seconds for the round (slowest client).
    pub secs: f64,
    /// Per-client simulated seconds, in message order: (client id, secs).
    pub per_client: Vec<(usize, f64)>,
}

/// One client's contribution to a round as seen by a remote transport:
/// the uplink decisions (packet loss, fault-injected drop) run on the
/// worker, and the server receives only their outcome.
#[derive(Clone, Debug)]
pub struct RemoteUplink {
    /// Originating client id.
    pub client: usize,
    /// The client's local training loss this round (reported for every
    /// outcome — the in-process pipelines compute losses before routing, so
    /// the round's loss mean includes lost and skipped clients too).
    pub loss: f32,
    /// What happened to the client's frames on the way up.
    pub outcome: UplinkOutcome,
}

/// Fate of one remote client's frames for a round (mirrors the in-process
/// pipeline's routing outcomes).
#[derive(Clone, Debug)]
pub enum UplinkOutcome {
    /// The frames survived the uplink: `(group index, frame bytes)` pairs.
    Arrived(Vec<(usize, Vec<u8>)>),
    /// Lost after every retransmit; `wasted` wire bytes were burned.
    Lost {
        /// Wire bytes burned across all failed attempts.
        wasted: u64,
    },
    /// Fault-injected drop (`drop_client`): nothing was sent.
    Skipped,
}

/// How a round's bytes move between the server and its clients.
///
/// Two implementations exist:
///
/// * [`SimNet`] — the in-process simulation. Clients are threads inside the
///   coordinator, so `begin_round`/`collect_round` are inert and only the
///   accounting methods do work.
/// * `coordinator::transport::TcpTransport` — real worker processes on TCP
///   sockets exchanging the `quant::wire` frames as length-prefixed
///   payloads (`docs/PROTOCOL.md` is the normative spec).
///
/// Every implementation routes its byte/latency accounting through the
/// [`SimNet`] model, keeping `RunLog::replay_digest()` comparable — and on
/// clean scenarios bit-identical — across transports.
pub trait Transport: Send {
    /// Short transport label for logs (`"sim"` | `"tcp"`).
    fn name(&self) -> &'static str;

    /// Which clients this transport can still reach, or `None` when
    /// reachability is not a transport concern (the in-process simulation).
    /// A remote transport reports a dead socket here so the coordinator's
    /// churn mask excludes the client instead of hanging on its uplink.
    fn reachable(&self) -> Option<Vec<bool>> {
        None
    }

    /// Broadcast the round's parameters to the reachable clients, with the
    /// participation mask (`active_set[i]` = client `i` computes this
    /// round) and, when the bit-budget scheduler is active, each client's
    /// per-layer-group bit assignment for the round (`rates` is `None`
    /// whenever the scheduler is off — the wire then carries an empty rate
    /// block, see PROTOCOL.md §3.3). In-process transports have nothing to
    /// send: the coordinator applies the plan to its own `Client`s.
    fn begin_round(
        &mut self,
        round: usize,
        active_set: &[bool],
        params: &[f32],
        rates: Option<&RatePlan>,
    ) -> Result<()>;

    /// Collect one uplink outcome from every reachable active client.
    /// Clients whose connection dies mid-round are silently excluded (they
    /// count toward `dropped_clients`, exactly like churned clients).
    fn collect_round(&mut self, round: usize, active_set: &[bool]) -> Result<Vec<RemoteUplink>>;

    /// Re-admit workers that restarted after a seeded chaos kill. Called at
    /// the top of each round, *before* [`Transport::reachable`], so a
    /// rejoined worker participates in the very round it returns. Returns
    /// how many workers rejoined. In-process transports have no sockets to
    /// re-accept, so the default is a no-op.
    fn poll_rejoins(&mut self, _round: usize) -> Result<u32> {
        Ok(0)
    }

    /// Drain this round's fault counters: `(rejoined workers, corrupt
    /// frames detected, wire bytes wasted by corrupt transmissions)`. The
    /// coordinator folds the waste into its lost-byte accounting and the
    /// counts into the round record, then the counters reset. Transports
    /// without real sockets report zeros.
    fn take_round_faults(&mut self) -> (u32, u32, u64) {
        (0, 0, 0)
    }

    /// Register a round's delivered messages under per-client link
    /// conditions (see [`SimNet::round_uplink_conditioned`]).
    fn round_uplink_conditioned(
        &mut self,
        msgs: &[Message],
        conds: &[LinkCondition],
    ) -> UplinkReport;

    /// Account wasted wire bytes from frames that never arrived (see
    /// [`SimNet::account_lost_bytes`]).
    fn account_lost_bytes(&mut self, wasted: u64);

    /// Cumulative client→server wire bytes (goodput + retransmits + waste).
    fn total_bytes_up(&self) -> u64;

    /// Cumulative retransmitted/wasted bytes across the run.
    fn total_retransmitted(&self) -> u64;

    /// Restore the cumulative byte counters from a checkpoint (resume
    /// path). Transports that don't support checkpointing ignore the call.
    fn restore_totals(&mut self, _bytes_up: u64, _retransmitted: u64) {}

    /// Tear the transport down (remote transports tell workers to exit).
    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Accounting + latency model for one round of uplinks.
pub struct SimNet {
    cfg: NetConfig,
    /// Cumulative client→server wire bytes across the run.
    pub total_bytes_up: u64,
    /// Cumulative retransmitted bytes across the run.
    pub total_retransmitted: u64,
}

impl SimNet {
    /// A fresh accounting model with zeroed totals.
    pub fn new(cfg: NetConfig) -> Self {
        SimNet { cfg, total_bytes_up: 0, total_retransmitted: 0 }
    }

    /// Seconds for ONE transmission attempt of `bytes` on a clean link:
    /// `latency + bytes / bandwidth`. Clients upload in parallel, so the
    /// round's communication time is the max of this over clients.
    pub fn attempt_secs(&self, bytes: u64) -> f64 {
        self.cfg.latency_sec
            + if self.cfg.bandwidth_bytes_per_sec > 0.0 {
                bytes as f64 / self.cfg.bandwidth_bytes_per_sec
            } else {
                0.0
            }
    }

    /// Register a round's uplinks under ideal conditions (no stragglers, no
    /// loss). Equivalent to [`Self::round_uplink_conditioned`] with default
    /// [`LinkCondition`]s.
    pub fn round_uplink(&mut self, msgs: &[Message]) -> UplinkReport {
        self.round_uplink_conditioned(msgs, &vec![LinkCondition::default(); msgs.len()])
    }

    /// Register a round's uplink messages under per-client conditions.
    /// `conds` must be parallel to `msgs`. Each client's time is
    /// `attempts * latency_mult * (latency + bytes / bw)`; the round spends
    /// the max over clients in communication (parallel uplinks).
    pub fn round_uplink_conditioned(
        &mut self,
        msgs: &[Message],
        conds: &[LinkCondition],
    ) -> UplinkReport {
        assert_eq!(msgs.len(), conds.len(), "one LinkCondition per message");
        let mut rep = UplinkReport::default();
        for (m, c) in msgs.iter().zip(conds) {
            let b = m.wire_bytes();
            let resent = b * (c.attempts.max(1) as u64 - 1);
            let t = c.attempts.max(1) as f64 * c.latency_mult * self.attempt_secs(b);
            rep.bytes += b;
            rep.retransmitted_bytes += resent;
            rep.secs = rep.secs.max(t);
            rep.per_client.push((m.client, t));
        }
        self.total_bytes_up += rep.bytes + rep.retransmitted_bytes;
        self.total_retransmitted += rep.retransmitted_bytes;
        rep
    }

    /// Account a frame that never arrived: all `attempts` transmissions hit
    /// the wire and were wasted. Returns the wasted bytes so the caller can
    /// fold them into the round's retransmission column.
    pub fn account_lost(&mut self, msg: &Message, attempts: u32) -> u64 {
        let wasted = msg.lost_wire_bytes(attempts);
        self.account_lost_bytes(wasted);
        wasted
    }

    /// Account already-summed wasted bytes from lost frames (the streaming
    /// pipeline computes `wire_bytes * attempts` on the encode workers and
    /// hands the totals over; u64 addition is order-independent, so this is
    /// byte-identical to per-message [`Self::account_lost`] calls).
    pub fn account_lost_bytes(&mut self, wasted: u64) {
        self.total_bytes_up += wasted;
        self.total_retransmitted += wasted;
    }
}

impl Transport for SimNet {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn begin_round(
        &mut self,
        _round: usize,
        _active_set: &[bool],
        _params: &[f32],
        _rates: Option<&RatePlan>,
    ) -> Result<()> {
        // In-process clients read the parameter vector directly (and the
        // coordinator applies rate plans to its own clients).
        Ok(())
    }

    fn collect_round(&mut self, _round: usize, _active_set: &[bool]) -> Result<Vec<RemoteUplink>> {
        bail!("SimNet has no remote workers; use the barrier/streaming pipelines")
    }

    fn round_uplink_conditioned(
        &mut self,
        msgs: &[Message],
        conds: &[LinkCondition],
    ) -> UplinkReport {
        SimNet::round_uplink_conditioned(self, msgs, conds)
    }

    fn account_lost_bytes(&mut self, wasted: u64) {
        SimNet::account_lost_bytes(self, wasted);
    }

    fn total_bytes_up(&self) -> u64 {
        self.total_bytes_up
    }

    fn total_retransmitted(&self) -> u64 {
        self.total_retransmitted
    }

    fn restore_totals(&mut self, bytes_up: u64, retransmitted: u64) {
        self.total_bytes_up = bytes_up;
        self.total_retransmitted = retransmitted;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(client: usize, bytes: usize) -> Message {
        Message { client, round: 0, frames: vec![(0, vec![0u8; bytes])], loss: 0.0 }
    }

    #[test]
    fn wire_bytes_counts_everything() {
        let m = msg(0, 100);
        assert_eq!(m.wire_bytes(), 16 + 4 + 100);
    }

    #[test]
    fn accounting_accumulates() {
        let mut net = SimNet::new(NetConfig::default());
        let rep = net.round_uplink(&[msg(0, 100), msg(1, 50)]);
        assert_eq!(rep.bytes, (16 + 4 + 100) + (16 + 4 + 50));
        assert_eq!(rep.secs, 0.0);
        assert_eq!(rep.retransmitted_bytes, 0);
        net.round_uplink(&[msg(0, 10)]);
        assert_eq!(net.total_bytes_up, rep.bytes + 16 + 4 + 10);
        assert_eq!(net.total_retransmitted, 0);
    }

    #[test]
    fn latency_model_takes_slowest_and_reports_per_client() {
        // Pin the parallel-uplink formula: t_i = latency + bytes_i / bw,
        // round time = max_i t_i.
        let mut net = SimNet::new(NetConfig {
            bandwidth_bytes_per_sec: 1000.0,
            latency_sec: 0.01,
        });
        let rep = net.round_uplink(&[msg(3, 1000), msg(7, 10)]);
        let t_big = 0.01 + 1020.0 / 1000.0;
        let t_small = 0.01 + 30.0 / 1000.0;
        assert!((rep.secs - t_big).abs() < 1e-9);
        assert_eq!(rep.per_client.len(), 2);
        assert_eq!(rep.per_client[0].0, 3);
        assert!((rep.per_client[0].1 - t_big).abs() < 1e-9);
        assert_eq!(rep.per_client[1].0, 7);
        assert!(
            (rep.per_client[1].1 - t_small).abs() < 1e-9,
            "per-client time must be the client's own, not the max"
        );
    }

    #[test]
    fn conditions_scale_time_and_account_retransmits() {
        let mut net = SimNet::new(NetConfig {
            bandwidth_bytes_per_sec: 1000.0,
            latency_sec: 0.01,
        });
        let conds = [
            LinkCondition { latency_mult: 4.0, attempts: 1 }, // straggler
            LinkCondition { latency_mult: 1.0, attempts: 3 }, // two lost attempts
        ];
        let rep = net.round_uplink_conditioned(&[msg(0, 100), msg(1, 100)], &conds);
        let one = 0.01 + 120.0 / 1000.0;
        assert!((rep.per_client[0].1 - 4.0 * one).abs() < 1e-9);
        assert!((rep.per_client[1].1 - 3.0 * one).abs() < 1e-9);
        assert_eq!(rep.bytes, 2 * 120);
        assert_eq!(rep.retransmitted_bytes, 2 * 120, "two re-sent copies of one frame");
        assert_eq!(net.total_bytes_up, 4 * 120, "wire total includes retransmits");
        assert_eq!(net.total_retransmitted, 240);
    }

    #[test]
    fn lost_frames_account_every_attempt() {
        let mut net = SimNet::new(NetConfig::default());
        let wasted = net.account_lost(&msg(0, 100), 4);
        assert_eq!(wasted, 4 * (16 + 4 + 100));
        assert_eq!(net.total_bytes_up, wasted);
        assert_eq!(net.total_retransmitted, wasted);
    }
}
