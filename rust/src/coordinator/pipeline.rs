//! The round engine: Algorithm 1 as an explicit stage pipeline.
//!
//! One communication round is six typed stages:
//!
//! ```text
//! Compute → Encode → Uplink → Schedule → Accumulate → Apply
//! ```
//!
//! and the engine runs them in one of two modes ([`PipelineMode`], config
//! field `pipeline` / CLI `--pipeline`):
//!
//! * **Barrier** — the historical strict-barrier loop: every stage waits for
//!   the whole previous stage (all encoders join before the first frame is
//!   decoded). Simple, and the reference semantics.
//! * **Streaming** — per-client frame hand-off: the moment one client's
//!   encode worker finishes, its [`Message`] flows through the
//!   scenario-conditioned network checks and straight into a fused
//!   decode on the driver thread ([`wire::decode_dequantize_accumulate_into`]
//!   at weight 1.0, into that client's reused dense contribution buffer) —
//!   while slower clients are still encoding. Encode and server decode
//!   overlap within the round instead of serializing behind the slowest
//!   encoder.
//!
//! **Why streaming is bit-identical to barrier.** Everything
//! order-sensitive is deferred to fixed-order passes over buffered state:
//!
//! 1. per-client state (codec refit, EF residual repair, arena recycling)
//!    mutates only on that client's own worker, in the same per-client
//!    sequence as the barrier path;
//! 2. network accounting sums are commutative integer adds, and the
//!    delivered set is re-sorted to ascending client id before the
//!    scheduler runs, so `schedule`'s inputs — and therefore the apply set,
//!    the staleness bookkeeping and `net_secs` — match the barrier path
//!    exactly;
//! 3. the weighted accumulate runs AFTER the apply set and the normalized
//!    weights are known, walking the fixed (origin round, client id) apply
//!    order per layer group — the same order the barrier path uses. A
//!    fresh frame's buffered contribution holds exactly its dense
//!    reconstruction (decoding at weight 1.0 is exact), so its
//!    `agg[e] += w * d[e]` apply issues per element the same single `w * d`
//!    product and add as the fused barrier kernel (see [`aggregate`]).
//!
//! Hence the two modes produce bit-identical parameters and
//! [`RunLog::replay_digest`](crate::metrics::RunLog::replay_digest)s at
//! every worker/shard count — property-tested across schemes × bits ×
//! scenario presets in `rust/tests/pipeline_props.rs`.
//!
//! The overlap is measurable: [`RoundRecord`] carries a per-stage
//! wall-clock breakdown (`compute_secs`, `encode_secs`, `agg_secs` — in
//! streaming mode `encode_secs` covers the overlapped encode+decode window
//! and `agg_secs` the residual weighted apply), and `benches/perf_round.rs`
//! gates end-to-end round throughput in CI.

use anyhow::{anyhow, bail, Result};

pub use crate::config::PipelineMode;
use crate::metrics::{RoundRecord, Timer};
use crate::quant::wire;
use crate::runtime::GroupRange;

use super::aggregate::{self, ContributionData, WeightedContribution};
use super::network::{LinkCondition, Message, UplinkOutcome};
use super::Coordinator;

/// Outcome of one message's uplink decisions.
pub(crate) enum Produced {
    /// The message survived the uplink.
    Arrived(Message, LinkCondition),
    /// Lost after every retransmit: the EF residual is already repaired and
    /// the frames recycled; `wasted` wire bytes burned.
    Lost { wasted: u64 },
    /// Fault-injected drop (`drop_client`): frames recycled.
    Skipped,
}

/// The per-message uplink decisions — `drop_client` fault, packet loss with
/// EF residual repair, frame recycling — shared verbatim by the barrier
/// driver loop, the streaming encode workers AND the remote TCP worker
/// (`transport::run_worker`), so the three paths cannot drift apart.
/// Touches only this client's own state.
pub(crate) fn route_message(
    c: &mut super::Client,
    msg: Message,
    scenario: &super::ScenarioEngine,
    drop_client: usize,
    round: u64,
) -> Produced {
    if msg.client == drop_client {
        c.recycle(msg);
        return Produced::Skipped;
    }
    match scenario.link(msg.client, round) {
        Some(cond) => Produced::Arrived(msg, cond),
        // Fully lost: every attempt still burned wire bytes, and an EF
        // client keeps the undelivered mass in its residual.
        None => {
            let wasted = msg.lost_wire_bytes(scenario.lost_attempts());
            c.restore_lost(&msg);
            c.recycle(msg);
            Produced::Lost { wasted }
        }
    }
}

/// The round prologue shared verbatim by both modes (any drift here would
/// break the modes' bit-identity contract): overall round timer, churn
/// decisions, and the compute stage with its clock.
struct RoundStart {
    timer: Timer,
    /// Participation mask over all clients (churn ∧ cohort decisions).
    active_set: Vec<bool>,
    /// Number of participating clients (the encode fan-out width).
    active_len: usize,
    /// How many uplinks the server expects this round: the cohort size K
    /// when cohort sampling is engaged, N otherwise — the baseline
    /// `dropped_clients` is counted against.
    expected: usize,
    grads: Vec<Vec<f32>>,
    losses: Vec<f32>,
    compute_secs: f64,
}

/// The cohort stage: intersect the round's cohort draw into the
/// participation mask and (in-process modes) migrate EF residual state —
/// cohort members unpark, everyone else parks as a compact quantized frame.
///
/// **Degenerate case is a strict no-op.** When `cohort_k == 0` or
/// `cohort_k >= N` the function returns `N` without touching the mask,
/// drawing from any RNG stream, or parking anything — so full-participation
/// runs are bit-identical to a build without cohort sampling at all
/// (invariant 5 in docs/DETERMINISM.md, pinned by
/// `rust/tests/cohort_props.rs`).
///
/// `migrate_state` is false on the remote path: there the per-client codec
/// state lives in the worker processes, and non-cohort workers simply sit
/// the round out.
fn cohort_stage(
    coord: &mut Coordinator<'_>,
    round: u64,
    active_set: &mut [bool],
    migrate_state: bool,
) -> Result<usize> {
    let n = coord.clients.len();
    let k = coord.cfg.cohort_k;
    if k == 0 || k >= n {
        return Ok(n);
    }
    let cohort = coord.scenario.sample_cohort(round, n, k);
    let mut in_cohort = vec![false; n];
    for &i in &cohort {
        in_cohort[i] = true;
    }
    for (i, a) in active_set.iter_mut().enumerate() {
        *a = *a && in_cohort[i];
    }
    // Cohort ∧ churn may be empty; mirror the churn engine's never-go-dark
    // rule by reviving one deterministic cohort member.
    if !active_set.iter().any(|&a| a) {
        active_set[cohort[round as usize % cohort.len()]] = true;
    }
    if migrate_state {
        let seed = coord.cfg.seed;
        for (i, c) in coord.clients.iter_mut().enumerate() {
            if in_cohort[i] {
                c.unpark_residuals()?;
            } else {
                c.park_residuals(seed, round);
            }
        }
    }
    Ok(k)
}

/// The bit-budget stage shared by the in-process modes: compute this
/// round's rate plan from the latest tail observations (if the scheduler is
/// engaged) and re-target the active clients' codecs at the scheduled
/// widths — `set_rate` re-derives thresholds from each codec's standing
/// fit, no refit. Strict no-op when the scheduler is off (`budget: None`):
/// no plan, no draws, no codec touches (DETERMINISM.md invariant 6).
fn apply_rate_plan(coord: &mut Coordinator<'_>, round: u64, active_set: &[bool]) {
    let Some(budget) = &coord.budget else { return };
    let active: Vec<usize> =
        active_set.iter().enumerate().filter_map(|(i, &a)| a.then_some(i)).collect();
    let plan = budget.plan(round, &active);
    for (i, bits) in plan.clients.iter().zip(&plan.bits) {
        coord.clients[*i].set_rates(bits);
    }
}

/// In-process model of the chaos harness's wire faults, so the three
/// pipeline modes keep their bit-identity contract with the TCP transport:
///
/// * **Corruption** — on the wire, a chaos worker flips bytes of its first
///   uplink transmission; the server's CRC32 trailer rejects it and a clean
///   retransmit follows. Digest-visible cost: one extra copy of the uplink
///   payload ([`Message::remote_uplink_payload_bytes`]) burned per corrupt
///   frame, charged to lost-byte accounting exactly like a scenario loss.
///   The in-process modes have no wire, so they charge the same bytes from
///   the same seeded draw ([`super::scenario::chaos_corrupts`]).
/// * **Kill + rejoin** — cooperative: the victim uploads, ships its state,
///   dies, and rejoins next round with that state restored, so training is
///   bit-identical to an uninterrupted run. In-process it is a pure
///   bookkeeping entry: `rejoined = 1` on the round after the kill.
///
/// Returns `(rejoined, corrupt_frames, corrupt_wasted_bytes)` — all zero
/// whenever the chaos knobs are off, so non-chaos runs take no draws.
fn model_chaos_faults(
    coord: &Coordinator<'_>,
    round: u64,
    delivered: &[Message],
) -> (u32, u32, u64) {
    let sc = &coord.cfg.scenario;
    if sc.chaos_corrupt_prob == 0.0 && sc.chaos_kill_round == 0 {
        return (0, 0, 0);
    }
    let seed = coord.cfg.seed;
    let mut corrupt = 0u32;
    let mut wasted = 0u64;
    for m in delivered {
        if super::scenario::chaos_corrupts(sc, seed, m.client, round) {
            corrupt += 1;
            wasted += m.remote_uplink_payload_bytes();
        }
    }
    let rejoined = u32::from(
        sc.chaos_kill_round > 0
            && round as usize == sc.chaos_kill_round + 1
            && super::scenario::chaos_kill_target(sc, seed, coord.clients.len()).is_some(),
    );
    (rejoined, corrupt, wasted)
}

fn begin_round_stage(coord: &mut Coordinator<'_>) -> Result<RoundStart> {
    let timer = Timer::start();
    let round = coord.round;
    // Scenario: churn decides who participates this round.
    let churn_active = coord.scenario.begin_round(round as u64);
    let mut active_set = vec![false; coord.clients.len()];
    for &i in &churn_active {
        active_set[i] = true;
    }
    // Cohort sampling narrows participation further (no-op at K = 0 / K ≥ N)
    // and migrates EF residual state in/out of parked form.
    let expected = cohort_stage(coord, round as u64, &mut active_set, true)?;
    let active: Vec<usize> =
        active_set.iter().enumerate().filter_map(|(i, &a)| a.then_some(i)).collect();
    // Compute: local gradients for participating clients (backend on this
    // thread; PJRT/XLA parallelizes inside, the native path is cheap scalar
    // math).
    let t = Timer::start();
    let (grads, losses) = compute_stage(coord, &active)?;
    Ok(RoundStart {
        timer,
        active_set,
        active_len: active.len(),
        expected,
        grads,
        losses,
        compute_secs: t.secs(),
    })
}

/// One strict-barrier round (the historical `Coordinator::step` body, with
/// the per-stage clock added).
pub(crate) fn step_barrier(coord: &mut Coordinator<'_>) -> Result<RoundRecord> {
    let start = begin_round_stage(coord)?;
    let round = coord.round;
    apply_rate_plan(coord, round as u64, &start.active_set);

    // Encode: per-client compression over a pool of `encode_threads` scoped
    // workers, each owning a contiguous chunk of active clients. Strict
    // barrier — the round proceeds only once every encoder has joined.
    // Chunks preserve client order and per-client codec state is disjoint,
    // so the message vector (and every digest) is identical at any width.
    let t = Timer::start();
    let refit_now = round % coord.cfg.quant.estimate_every == 0;
    let seed = coord.cfg.seed;
    let pool = coord.encode_threads.max(1);
    let msgs: Vec<Message> = {
        let groups: &[GroupRange] = &coord.groups;
        let mut work: Vec<(&mut super::Client, &[f32], f32)> =
            Vec::with_capacity(start.active_len);
        let mut k = 0usize;
        for (i, c) in coord.clients.iter_mut().enumerate() {
            if !start.active_set[i] {
                continue;
            }
            work.push((c, &start.grads[k], start.losses[k]));
            k += 1;
        }
        let chunk = work.len().div_ceil(pool).max(1);
        std::thread::scope(|scope| {
            let handles: Vec<_> = work
                .chunks_mut(chunk)
                .map(|ch| {
                    scope.spawn(move || {
                        ch.iter_mut()
                            .map(|(c, g, loss)| {
                                c.compress(g, groups, round, seed, refit_now, *loss)
                            })
                            .collect::<Vec<Message>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("codec thread"))
                .collect()
        })
    };
    let encode_secs = t.secs();

    // Uplink through the simulated network: the same per-message routing
    // the streaming workers run, here on the driver after the barrier.
    let mut delivered: Vec<Message> = Vec::with_capacity(msgs.len());
    let mut conds: Vec<LinkCondition> = Vec::with_capacity(msgs.len());
    let mut lost_bytes = 0u64;
    let drop_client = coord.cfg.drop_client;
    for m in msgs {
        let ci = m.client;
        let c = &mut coord.clients[ci];
        match route_message(c, m, &coord.scenario, drop_client, round as u64) {
            Produced::Arrived(m, cond) => {
                delivered.push(m);
                conds.push(cond);
            }
            Produced::Lost { wasted } => {
                coord.net.account_lost_bytes(wasted);
                lost_bytes += wasted;
            }
            Produced::Skipped => {}
        }
    }
    // Chaos harness: charge corrupt first-transmissions and record rejoins
    // exactly as the TCP transport reports them, keeping digests aligned.
    let (rejoined, corrupt, corrupt_wasted) = model_chaos_faults(coord, round as u64, &delivered);
    coord.net.account_lost_bytes(corrupt_wasted);
    lost_bytes += corrupt_wasted;
    finish_round(
        coord,
        start.timer,
        start.expected,
        delivered,
        conds,
        lost_bytes,
        rejoined,
        corrupt,
        &start.losses,
        start.compute_secs,
        encode_secs,
        None,
    )
}

/// One streaming round: encode workers hand each finished message straight
/// to the driver, which decodes it into the client's contribution buffer
/// while the remaining encoders are still running.
pub(crate) fn step_streaming(coord: &mut Coordinator<'_>) -> Result<RoundRecord> {
    let start = begin_round_stage(coord)?;
    let round = coord.round;
    apply_rate_plan(coord, round as u64, &start.active_set);

    // Lazily size the per-client contribution buffers (one full-dimension
    // f32 buffer per client, reused across rounds — the decode-side
    // analogue of the frame arena; `contrib_reallocs` must go flat after
    // warm-up, asserted next to the frame-alloc invariant).
    let dim = coord.params.len();
    if coord.contrib.len() < coord.clients.len() {
        coord.contrib.resize_with(coord.clients.len(), Vec::new);
    }

    // Encode → Uplink → (overlapped) decode. Each worker encodes its
    // client, runs the per-client uplink decisions itself (drop_client,
    // packet loss — per-client state stays on the client's own thread,
    // exactly the barrier sequence) and hands survivors to the driver,
    // which decodes them on arrival.
    let t = Timer::start();
    let refit_now = round % coord.cfg.quant.estimate_every == 0;
    let seed = coord.cfg.seed;
    let drop_client = coord.cfg.drop_client;
    let mut arrived: Vec<(Message, LinkCondition)> = Vec::with_capacity(start.active_len);
    let mut dense_ok = vec![false; coord.clients.len()];
    let mut lost_bytes = 0u64;
    let mut decode_err: Option<anyhow::Error> = None;
    {
        let groups: &[GroupRange] = &coord.groups;
        let scenario = &coord.scenario;
        let clients = &mut coord.clients;
        let contrib = &mut coord.contrib;
        let contrib_reallocs = &mut coord.contrib_reallocs;
        std::thread::scope(|scope| {
            let (tx, rx) = std::sync::mpsc::channel::<Produced>();
            let mut expected = 0usize;
            let mut k = 0usize;
            for (i, c) in clients.iter_mut().enumerate() {
                if !start.active_set[i] {
                    continue;
                }
                let g = &start.grads[k];
                let loss = start.losses[k];
                k += 1;
                let tx = tx.clone();
                expected += 1;
                scope.spawn(move || {
                    let msg = c.compress(g, groups, round, seed, refit_now, loss);
                    let prod = route_message(c, msg, scenario, drop_client, round as u64);
                    tx.send(prod).expect("pipeline hand-off");
                });
            }
            drop(tx);
            // Driver: decode each arrival the moment it lands — this is the
            // overlap with the encoders still running above. The decode is
            // speculative: a frame the staleness scheduler later defers is
            // decoded again (fused, with its real weight) when it applies —
            // wasted work only in stale scenarios, and hidden inside the
            // overlap window. A decode error is remembered (not returned)
            // so the channel drains and every worker joins cleanly.
            for _ in 0..expected {
                match rx.recv().expect("pipeline hand-off") {
                    Produced::Arrived(msg, cond) => {
                        if decode_err.is_none() {
                            match decode_contribution(groups, &msg, contrib, contrib_reallocs, dim)
                            {
                                Ok(densified) => dense_ok[msg.client] = densified,
                                Err(e) => decode_err = Some(e),
                            }
                        }
                        arrived.push((msg, cond));
                    }
                    Produced::Lost { wasted } => lost_bytes += wasted,
                    Produced::Skipped => {}
                }
            }
        });
    }
    if let Some(e) = decode_err {
        return Err(e);
    }
    let encode_secs = t.secs();

    // Deterministic bookkeeping: re-sort arrivals to ascending client id —
    // exactly the barrier path's message order (completion order above is
    // timing-dependent and must not leak into any recorded quantity).
    arrived.sort_by_key(|(m, _)| m.client);
    let mut delivered: Vec<Message> = Vec::with_capacity(arrived.len());
    let mut conds: Vec<LinkCondition> = Vec::with_capacity(arrived.len());
    for (m, c) in arrived {
        delivered.push(m);
        conds.push(c);
    }
    // Chaos harness: same seeded fault model as the barrier path, applied
    // after the re-sort so the draws see the deterministic delivered set.
    let (rejoined, corrupt, corrupt_wasted) = model_chaos_faults(coord, round as u64, &delivered);
    coord.net.account_lost_bytes(lost_bytes + corrupt_wasted);
    let lost_bytes = lost_bytes + corrupt_wasted;
    finish_round(
        coord,
        start.timer,
        start.expected,
        delivered,
        conds,
        lost_bytes,
        rejoined,
        corrupt,
        &start.losses,
        start.compute_secs,
        encode_secs,
        Some((round, &dense_ok[..])),
    )
}

/// One round against remote workers on the coordinator's [`Transport`]
/// (`super::transport::TcpTransport` behind `tqsgd serve`/`launch`):
/// broadcast the parameters, let every worker run Compute → Encode and its
/// own per-client uplink routing ([`route_message`], the code the
/// in-process modes run), collect the outcomes, then hand the delivered set
/// to the shared [`finish_round`] epilogue.
///
/// **Why tcp == in-process (barrier) bit-for-bit on clean scenarios.**
///
/// 1. the churn draws come first, from the same seeded stream in the same
///    order as [`begin_round_stage`];
/// 2. the worker rebuilds its `Client` via `coordinator::build_fleet` from
///    the handshake config and receives the server's exact parameter bits,
///    so its gradients, codec refits and frame bytes equal the in-process
///    encode output;
/// 3. outcomes are re-sorted to ascending client id — the barrier message
///    order — before losses and accounting fold in, and the per-client
///    [`LinkCondition`] is redrawn server-side from the same stateless
///    per-(client, round) stream the worker used, rather than shipped;
/// 4. [`finish_round`] is the same code, and the simulated-time accounting
///    runs on the transport's embedded [`SimNet`] model — `net_secs` stays
///    simulated time, not socket wall-clock, by design.
///
/// A worker whose socket dies is simply absent from the collected outcomes:
/// it counts toward `dropped_clients` and is masked out of later rounds via
/// `Transport::reachable`, which is exactly the churn drop/reweight path.
/// Pinned by `rust/tests/transport_props.rs` and the CI transport smoke.
pub(crate) fn step_remote(coord: &mut Coordinator<'_>) -> Result<RoundRecord> {
    let timer = Timer::start();
    let round = coord.round;
    let n = coord.clients.len();
    // Scenario churn first — same draws, same order as the local prologue.
    let active = coord.scenario.begin_round(round as u64);
    // Fault tolerance: re-admit chaos-killed workers whose respawns are
    // waiting in the listen backlog BEFORE reachability is computed, so a
    // rejoined worker participates this very round (no dropped-round gap).
    coord.net.poll_rejoins(round)?;
    let reachable = coord.net.reachable().unwrap_or_else(|| vec![true; n]);
    let mut active_set = vec![false; n];
    for &i in &active {
        if reachable[i] {
            active_set[i] = true;
        }
    }
    if !active_set.iter().any(|&a| a) {
        bail!("no reachable active workers; every connection is dead");
    }
    // Cohort sampling narrows the broadcast set exactly as in-process; the
    // per-client codec state lives in the worker processes, so no residual
    // parking happens here — non-cohort workers just sit the round out.
    let expected = cohort_stage(coord, round as u64, &mut active_set, false)?;
    // Bit-budget scheduler: the plan rides the ROUND_START broadcast so the
    // workers re-target their codecs exactly as the in-process modes do
    // (`None` → an empty rate block on the wire, PROTOCOL.md §3.3).
    let rates = coord.budget.as_ref().map(|b| {
        let active: Vec<usize> =
            active_set.iter().enumerate().filter_map(|(i, &a)| a.then_some(i)).collect();
        b.plan(round as u64, &active)
    });
    let t = Timer::start();
    coord.net.begin_round(round, &active_set, &coord.params, rates.as_ref())?;
    let mut ups = coord.net.collect_round(round, &active_set)?;
    let exchange_secs = t.secs();
    // Ascending client id — the barrier path's deterministic message order
    // (collection order is connection-dependent and must not leak).
    ups.sort_by_key(|u| u.client);
    let mut delivered: Vec<Message> = Vec::with_capacity(ups.len());
    let mut conds: Vec<LinkCondition> = Vec::with_capacity(ups.len());
    let mut losses: Vec<f32> = Vec::with_capacity(ups.len());
    let mut lost_bytes = 0u64;
    for u in ups {
        losses.push(u.loss);
        match u.outcome {
            UplinkOutcome::Arrived(frames) => {
                // The worker drew Some(..) from the same stateless
                // per-(client, round) stream; redraw it here instead of
                // shipping floats over the wire.
                let cond = coord.scenario.link(u.client, round as u64).ok_or_else(|| {
                    anyhow!(
                        "client {}: frames arrived but the loss scenario says lost \
                         (worker/server seed or config drift?)",
                        u.client
                    )
                })?;
                delivered.push(Message { client: u.client, round, frames, loss: u.loss });
                conds.push(cond);
            }
            UplinkOutcome::Lost { wasted } => {
                coord.net.account_lost_bytes(wasted);
                lost_bytes += wasted;
            }
            UplinkOutcome::Skipped => {}
        }
    }
    // Fault counters the transport accumulated during the exchange: CRC
    // failures already cost a retransmit on the wire; fold the wasted bytes
    // into the same lost-byte accounting the in-process chaos model charges,
    // so digests stay aligned across transports.
    let (rejoined, corrupt, corrupt_wasted) = coord.net.take_round_faults();
    coord.net.account_lost_bytes(corrupt_wasted);
    lost_bytes += corrupt_wasted;
    // compute/encode happened on the workers; the exchange window is the
    // closest local analogue of the overlapped encode+uplink stage.
    finish_round(
        coord,
        timer,
        expected,
        delivered,
        conds,
        lost_bytes,
        rejoined,
        corrupt,
        &losses,
        0.0,
        exchange_secs,
        None,
    )
}

/// Stages shared verbatim by both modes once the delivered set is known (in
/// ascending client order): network accounting, the bounded-staleness
/// schedule, the staleness histogram, the weighted apply + optimizer step,
/// frame recycling, and the round record. `expected` is how many uplinks
/// this round asked for — the cohort size K when sampling is engaged, N
/// otherwise — so `dropped_clients` counts real failures (churn, dead
/// sockets, drop faults), never clients the cohort deliberately rested.
/// `dense` is the streaming mode's `(round, per-client buffered?)` marker
/// for contributions decoded during the overlap; `None` in barrier mode.
#[allow(clippy::too_many_arguments)]
fn finish_round(
    coord: &mut Coordinator<'_>,
    timer: Timer,
    expected: usize,
    delivered: Vec<Message>,
    conds: Vec<LinkCondition>,
    lost_bytes: u64,
    rejoined: u32,
    corrupt: u32,
    losses: &[f32],
    compute_secs: f64,
    encode_secs: f64,
    dense: Option<(usize, &[bool])>,
) -> Result<RoundRecord> {
    let round = coord.round;
    let dropped_clients = expected.saturating_sub(delivered.len());
    // Bit-budget observation: harvest the truncation threshold each frame
    // already carries (keyed by the frame's origin round, newest-wins), so
    // the next plan sees current tail scale. Only when the scheduler is
    // engaged — the disabled path must not touch budget state at all.
    if let Some(budget) = &mut coord.budget {
        for m in &delivered {
            budget.observe(m.client, m.round, &m.frames);
        }
    }
    let report = coord.net.round_uplink_conditioned(&delivered, &conds);

    // Bounded-staleness schedule: which frames apply now vs next round
    // (with decayed weight). The server steps at the K-th arrival, so that
    // — not the slowest client — is the round's communication time.
    let arrivals: Vec<(Message, f64)> = delivered
        .into_iter()
        .zip(report.per_client.iter().map(|&(_, t)| t))
        .collect();
    let (apply, net_secs) = coord.scenario.schedule(arrivals);
    // An empty apply set under packet loss is a transient wipeout: skip the
    // update (θ unchanged) and keep training. Without loss in play it is
    // structural (drop_client killed the whole federation) — fail.
    if apply.is_empty() && coord.cfg.scenario.loss_prob == 0.0 {
        return Err(anyhow!("all clients dropped; nothing to aggregate"));
    }
    let staleness_hist =
        build_staleness_hist(&mut coord.staleness_scratch, &mut coord.hist_reallocs, &apply);

    // Accumulate + Apply: decode + weighted aggregate + optimizer step,
    // sharded by layer-group ranges in the fixed (round, client) order.
    let t = Timer::start();
    weighted_apply(coord, &apply, dense)?;
    let agg_secs = t.secs();
    // Aggregation is done with these frames: hand the buffers back to their
    // owners' arenas so next round's encode allocates nothing.
    for (m, _) in apply {
        let ci = m.client;
        coord.clients[ci].recycle(m);
    }

    let train_loss = round_train_loss(losses, coord.last_train_loss);
    coord.last_train_loss = train_loss;
    coord.round += 1;
    Ok(RoundRecord {
        round,
        train_loss,
        bytes_up: report.bytes,
        test_loss: None,
        test_accuracy: None,
        secs: timer.secs(),
        net_secs,
        compute_secs,
        encode_secs,
        agg_secs,
        dropped_clients,
        retransmitted_bytes: report.retransmitted_bytes + lost_bytes,
        rejoined_clients: rejoined,
        corrupt_frames: corrupt,
        staleness_hist,
        bytes_per_client: coord.bytes_per_client(),
    })
}

/// Compute stage: local gradients + losses for the participating clients,
/// on the driver thread (backends may be single-threaded).
fn compute_stage(
    coord: &mut Coordinator<'_>,
    active: &[usize],
) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
    let round = coord.round;
    let train_batch = coord.spec.train_batch;
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(active.len());
    let mut losses: Vec<f32> = Vec::with_capacity(active.len());
    for &ci in active {
        let c = &mut coord.clients[ci];
        let (x, y) = c.next_batch(train_batch, coord.cfg.seed, round as u64);
        let out = coord.backend.grad(&coord.cfg.model, &coord.params, &x, &y)?;
        losses.push(out.loss);
        grads.push(out.grads);
    }
    Ok((grads, losses))
}

/// Decode one arrived message into its client's dense contribution buffer:
/// the fused kernel at weight 1.0 writes exactly the frame's reconstruction
/// (`1.0 * d == d`), so the later `+= w * d` apply is bit-identical to
/// fused-decoding with `w` directly. Returns whether the message was
/// densified: sparse (Top-k) frames are left for the fused scatter at apply
/// time (`Ok(false)`) — densifying them would turn their O(nnz) server work
/// into an O(dim) fill + walk.
fn decode_contribution(
    groups: &[GroupRange],
    msg: &Message,
    contrib: &mut [Vec<f32>],
    reallocs: &mut u64,
    dim: usize,
) -> Result<bool> {
    if msg.frames.iter().any(|(_, f)| wire::frame_kind(f) == Some(wire::KIND_SPARSE)) {
        return Ok(false);
    }
    let buf = &mut contrib[msg.client];
    if buf.len() != dim {
        if dim > buf.capacity() {
            *reallocs += 1;
        }
        buf.resize(dim, 0.0);
    }
    buf.fill(0.0);
    for (gi, frame) in &msg.frames {
        let g = groups
            .get(*gi)
            .ok_or_else(|| anyhow!("frame references unknown group {gi}"))?;
        if g.end > buf.len() || g.start > g.end {
            bail!("group {gi} range {}..{} outside contribution buffer", g.start, g.end);
        }
        wire::decode_dequantize_accumulate_into(frame, 1.0, &mut buf[g.start..g.end])?;
    }
    Ok(true)
}

/// The weighted accumulate + optimizer step over the apply set, in the
/// fixed (origin round, client id) order `schedule` returns. Messages the
/// streaming pipeline densified during the overlap (`dense` marks the round
/// and the clients) read their buffered contributions; everything else —
/// barrier mode, late/stale frames, sparse frames — decodes through the
/// fused kernel here. Late frames count with weight
/// `w_i * decay^staleness`; for the synchronous case every staleness is 0
/// and `decay^0 = 1` exactly, so this reduces bit-for-bit to the plain
/// weighted mean.
fn weighted_apply(
    coord: &mut Coordinator<'_>,
    apply: &[(Message, u32)],
    dense: Option<(usize, &[bool])>,
) -> Result<()> {
    if apply.is_empty() {
        return Ok(());
    }
    let clients = &coord.clients;
    let scenario = &coord.scenario;
    let contrib = &coord.contrib;
    let w_total: f64 = apply
        .iter()
        .map(|(m, s)| clients[m.client].weight * scenario.stale_weight(*s))
        .sum();
    let items: Vec<WeightedContribution<'_>> = apply
        .iter()
        .map(|(m, s)| {
            let w = ((clients[m.client].weight * scenario.stale_weight(*s)) / w_total) as f32;
            let data = match dense {
                Some((r, ok)) if m.round == r && ok[m.client] => {
                    ContributionData::Dense(&contrib[m.client][..])
                }
                _ => ContributionData::Frames(&m.frames),
            };
            WeightedContribution { data, w }
        })
        .collect();
    if coord.cfg.agg_tiers >= 2 {
        // Two-tier aggregator tree: mid-tier nodes shard-accumulate their
        // slice of the apply order, then re-encode the partial sum through
        // the experiment's codec before the root folds it in. Changes bits
        // by design (opt-in lossy interior hop); tier traffic is tracked
        // separately from client uplink bytes.
        let tier_bytes = aggregate::accumulate_two_tier(
            &coord.groups,
            &items,
            &mut coord.agg,
            coord.agg_shards,
            &coord.cfg.quant,
            coord.cfg.seed,
            coord.round as u64,
        )?;
        coord.tier_bytes += tier_bytes;
    } else {
        aggregate::accumulate_sharded(&coord.groups, &items, &mut coord.agg, coord.agg_shards)?;
    }
    drop(items);
    let agg = std::mem::take(&mut coord.agg);
    coord.opt.step(&mut coord.params, &agg);
    coord.agg = agg;
    Ok(())
}

/// Staleness histogram into the reused scratch (capacity survives rounds;
/// the returned copy is sized-to-fit log data for the round record).
fn build_staleness_hist(
    scratch: &mut Vec<u32>,
    reallocs: &mut u64,
    apply: &[(Message, u32)],
) -> Vec<u32> {
    scratch.clear();
    for &(_, s) in apply {
        let s = s as usize;
        if scratch.len() <= s {
            if s + 1 > scratch.capacity() {
                *reallocs += 1;
            }
            scratch.resize(s + 1, 0);
        }
        scratch[s] += 1;
    }
    scratch.clone()
}

/// Mean client training loss for the round's record. The empty branch is
/// defensive: `ScenarioEngine::begin_round` currently revives one client
/// whenever churn would empty the federation, but if that invariant ever
/// changes (or a new scenario skips compute), the mean must carry the
/// previous round's value rather than poison the column with `0/0` NaN.
pub(crate) fn round_train_loss(losses: &[f32], prev: f64) -> f64 {
    if losses.is_empty() {
        return prev;
    }
    losses.iter().map(|&l| l as f64).sum::<f64>() / losses.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_train_loss_is_the_mean() {
        assert_eq!(round_train_loss(&[1.0, 2.0, 3.0], 9.9), 2.0);
        assert_eq!(round_train_loss(&[4.0], 9.9), 4.0);
    }

    #[test]
    fn round_train_loss_carries_previous_value_for_an_empty_round() {
        // The defensive `sum / len` NaN guard: a round that computes no
        // losses must not poison the loss column.
        let carried = round_train_loss(&[], 1.25);
        assert_eq!(carried, 1.25);
        assert!(round_train_loss(&[], 0.0).is_finite());
    }

    #[test]
    fn staleness_hist_builds_in_place_and_counts_growth() {
        let mut scratch = Vec::new();
        let mut reallocs = 0u64;
        let msg = |client: usize| Message {
            client,
            round: 0,
            frames: vec![(0, vec![0u8; 4])],
            loss: 0.0,
        };
        let apply = vec![(msg(0), 0u32), (msg(1), 2u32), (msg(2), 0u32)];
        let hist = build_staleness_hist(&mut scratch, &mut reallocs, &apply);
        assert_eq!(hist, vec![2, 0, 1]);
        assert!(reallocs >= 1, "first build must size the scratch");
        let before = reallocs;
        let hist2 = build_staleness_hist(&mut scratch, &mut reallocs, &apply);
        assert_eq!(hist2, hist);
        assert_eq!(reallocs, before, "warm scratch must not regrow");
    }
}
