//! Real multi-process transport: the coordinator and its clients as
//! separate OS processes exchanging `quant::wire` frames over TCP.
//!
//! Every message on the socket is a **length-prefixed payload**: a `u32`
//! little-endian byte count, then that many payload bytes, whose first byte
//! is the message type. Five message types exist — HELLO, WELCOME,
//! ROUND_START, UPLINK, SHUTDOWN — and `docs/PROTOCOL.md` is the normative
//! byte-level spec (including the five wire-frame kinds an UPLINK carries).
//!
//! Roles:
//!
//! * **server** ([`TcpServer`] → [`TcpTransport`]) — binds, accepts one
//!   connection per client, and drives rounds through
//!   `Coordinator::run_remote`. The handshake WELCOME carries the full
//!   `ExperimentConfig` as JSON, so every process derives identical data
//!   shards, codec state and RNG streams from one config + seed.
//! * **worker** ([`run_worker`]) — connects, rebuilds its `Client` via
//!   `coordinator::build_fleet`, then loops: receive parameters, compute
//!   the local gradient, encode frames, run the same per-client uplink
//!   routing the in-process pipelines use, and send the outcome back.
//! * **orchestrator** (`tqsgd launch`) — spawns N local worker processes,
//!   runs the server in-process, and tears everything down with
//!   [`teardown_workers`]'s kill deadline.
//!
//! **Determinism.** The transport moves real bytes but keeps the
//! *simulated* network clock: all byte/latency accounting runs through the
//! embedded [`SimNet`] model, so `RunLog::replay_digest()` (which folds in
//! `net_secs` as simulated seconds) is bit-identical between a clean
//! multi-process run and the in-process barrier pipeline — see
//! `pipeline::step_remote` for the argument and `docs/DETERMINISM.md` for
//! the invariant table.
//!
//! **Fault injection on real connections.** A killed worker or dead socket
//! surfaces as a read/write error or EOF; the server marks the connection
//! dead, finishes the round with the survivors (the scenario engine's
//! drop/reweight path), and masks the client out of later rounds via
//! [`Transport::reachable`]. Read deadlines ([`TcpOptions::io_timeout`])
//! bound how long a hung worker can stall a round, so a kill never hangs
//! the run.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::json::Value;
use crate::quant::RatePlan;
use crate::runtime::make_backend;

use super::network::{
    LinkCondition, Message, RemoteUplink, SimNet, Transport, UplinkOutcome, UplinkReport,
};
use super::pipeline::{self, Produced};
use super::ScenarioEngine;

/// Protocol version carried by HELLO/WELCOME. Both sides must match
/// exactly; bump it whenever a message layout or wire-frame kind changes
/// (see `docs/PROTOCOL.md` §Versioning). Version 2 added the ROUND_START
/// rate block and the multiscale wire-frame kind (4).
pub const PROTO_VERSION: u16 = 2;

// Message type bytes (first payload byte).
const MSG_HELLO: u8 = 0x01;
const MSG_WELCOME: u8 = 0x02;
const MSG_ROUND_START: u8 = 0x03;
const MSG_UPLINK: u8 = 0x04;
const MSG_SHUTDOWN: u8 = 0x05;

// UPLINK outcome bytes (mirror `pipeline::Produced`).
const OUTCOME_ARRIVED: u8 = 0;
const OUTCOME_LOST: u8 = 1;
const OUTCOME_SKIPPED: u8 = 2;

/// Upper bound on one length-prefixed payload; a larger prefix is treated
/// as protocol corruption rather than an allocation request.
const MAX_MSG_LEN: u32 = 256 * 1024 * 1024;

// -- framing ----------------------------------------------------------------

/// Checked `usize → u32` conversion against the protocol frame bound, for
/// every length/count a writer serializes. A plain `as u32` cast would
/// silently truncate past `u32::MAX` and desync the stream; bounding at
/// [`MAX_MSG_LEN`] mirrors the read-side check so an oversized payload is
/// rejected **before** it hits the wire, not by the confused peer.
fn checked_wire_len(n: usize, what: &str) -> Result<u32> {
    if n as u64 > MAX_MSG_LEN as u64 {
        bail!("{what} length {n} exceeds the {MAX_MSG_LEN}-byte protocol bound");
    }
    Ok(n as u32)
}

/// Write one length-prefixed payload.
fn write_msg<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    w.write_all(&checked_wire_len(payload.len(), "payload")?.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed payload.
fn read_msg<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len);
    if n > MAX_MSG_LEN {
        bail!("message length {n} exceeds the {MAX_MSG_LEN}-byte protocol bound");
    }
    let mut buf = vec![0u8; n as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Bounds-checked little-endian payload reader (the transport analogue of
/// `quant::wire`'s internal reader).
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow!("truncated transport message"))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.b[self.i..];
        self.i = self.b.len();
        s
    }
}

// -- server -----------------------------------------------------------------

/// Socket tuning for the server side of the transport.
#[derive(Clone, Copy, Debug)]
pub struct TcpOptions {
    /// Per-read deadline on worker sockets: bounds how long a hung or
    /// killed worker can stall a round before it is declared dead.
    pub io_timeout: Duration,
    /// How long [`TcpServer::accept_workers`] waits for all N workers to
    /// connect and complete the handshake.
    pub accept_timeout: Duration,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            io_timeout: Duration::from_secs(30),
            accept_timeout: Duration::from_secs(60),
        }
    }
}

/// A bound listener waiting for its worker fleet: the step between "pick a
/// port" and "all N workers handshaked" — split so an orchestrator can
/// learn the ephemeral port before spawning workers at it.
pub struct TcpServer {
    listener: TcpListener,
    cfg: ExperimentConfig,
    opts: TcpOptions,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) for an
    /// experiment expecting `cfg.clients` workers.
    pub fn bind(addr: &str, cfg: &ExperimentConfig, opts: TcpOptions) -> Result<TcpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator on {addr}"))?;
        Ok(TcpServer { listener, cfg: cfg.clone(), opts })
    }

    /// The bound socket address (the port workers must connect to).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and handshake all `cfg.clients` workers, or fail once
    /// [`TcpOptions::accept_timeout`] elapses — a deadlocked handshake
    /// fails fast instead of hanging the run.
    pub fn accept_workers(self) -> Result<TcpTransport> {
        let n = self.cfg.clients;
        let cfg_json = self.cfg.to_json().to_json();
        let deadline = Instant::now() + self.opts.accept_timeout;
        self.listener.set_nonblocking(true)?;
        let mut conns: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        let mut connected = 0usize;
        while connected < n {
            match self.listener.accept() {
                Ok((mut stream, peer)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(Some(self.opts.io_timeout))?;
                    let id = handshake_worker(&mut stream, n, &cfg_json)
                        .with_context(|| format!("handshaking worker at {peer}"))?;
                    if conns[id].is_some() {
                        bail!("two workers claimed client id {id}");
                    }
                    conns[id] = Some(stream);
                    connected += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out waiting for workers: {connected}/{n} connected \
                             within {:?}",
                            self.opts.accept_timeout
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(TcpTransport { sim: SimNet::new(self.cfg.net), conns })
    }
}

/// Server side of one worker handshake: read HELLO, validate, send WELCOME
/// with the experiment config. Returns the worker's client id.
fn handshake_worker(stream: &mut TcpStream, n: usize, cfg_json: &str) -> Result<usize> {
    let msg = read_msg(stream)?;
    let mut c = Cur::new(&msg);
    let t = c.u8()?;
    if t != MSG_HELLO {
        bail!("expected HELLO (0x01), got message type {t:#04x}");
    }
    let version = c.u16()?;
    if version != PROTO_VERSION {
        bail!("protocol version mismatch: worker speaks {version}, server {PROTO_VERSION}");
    }
    let id = c.u32()? as usize;
    if id >= n {
        bail!("client id {id} out of range for {n} clients");
    }
    let mut welcome = Vec::with_capacity(7 + cfg_json.len());
    welcome.push(MSG_WELCOME);
    welcome.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    welcome.extend_from_slice(&(id as u32).to_le_bytes());
    welcome.extend_from_slice(cfg_json.as_bytes());
    write_msg(stream, &welcome)?;
    Ok(id)
}

/// The multi-process [`Transport`]: one TCP connection per worker plus the
/// embedded [`SimNet`] accounting model (real bytes, simulated clock — the
/// digest's `net_secs` stays the bandwidth/latency model, by design).
pub struct TcpTransport {
    sim: SimNet,
    /// One slot per client; `None` once the connection is declared dead.
    conns: Vec<Option<TcpStream>>,
}

impl TcpTransport {
    /// Clients whose connection is still alive.
    pub fn alive(&self) -> usize {
        self.conns.iter().filter(|c| c.is_some()).count()
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn reachable(&self) -> Option<Vec<bool>> {
        Some(self.conns.iter().map(|c| c.is_some()).collect())
    }

    /// Send ROUND_START to every live worker — actives get the parameter
    /// vector plus their bit-budget plan row (empty when the scheduler is
    /// off), churned-out workers an empty keep-alive (so their read clock
    /// keeps ticking). A failed write marks the connection dead; the round
    /// proceeds with the survivors.
    fn begin_round(
        &mut self,
        round: usize,
        active_set: &[bool],
        params: &[f32],
        rates: Option<&RatePlan>,
    ) -> Result<()> {
        for (i, slot) in self.conns.iter_mut().enumerate() {
            let Some(stream) = slot else { continue };
            let active = active_set.get(i).copied().unwrap_or(false);
            let bits: &[u32] = if active {
                rates.and_then(|plan| plan.rates_for(i)).unwrap_or(&[])
            } else {
                &[]
            };
            let body = if active { 14 + 4 * params.len() + bits.len() } else { 14 };
            let mut p = Vec::with_capacity(body);
            p.push(MSG_ROUND_START);
            p.extend_from_slice(&(round as u32).to_le_bytes());
            p.push(active as u8);
            if active {
                // Checked: a model with > MAX_MSG_LEN parameters must fail
                // loudly here, not truncate the count and desync the worker.
                p.extend_from_slice(&checked_wire_len(params.len(), "params")?.to_le_bytes());
                for x in params {
                    p.extend_from_slice(&x.to_le_bytes());
                }
            } else {
                p.extend_from_slice(&0u32.to_le_bytes());
            }
            // Rate block (PROTOCOL.md §3.3): this worker's plan row, one
            // byte per layer group. Empty when the scheduler is off, the
            // worker is inactive, or the plan has no row for the client —
            // the worker then keeps its standing codec widths.
            p.extend_from_slice(&checked_wire_len(bits.len(), "rates")?.to_le_bytes());
            for &b in bits {
                p.push(b.min(u8::MAX as u32) as u8);
            }
            if write_msg(stream, &p).is_err() {
                *slot = None;
            }
        }
        Ok(())
    }

    /// Read one UPLINK from every live active worker, in ascending client
    /// id. Sequential reads cannot deadlock — every worker computes and
    /// writes independently, and replies buffer in the sockets until read.
    /// Any read error (EOF from a killed worker, a blown
    /// [`TcpOptions::io_timeout`], a malformed payload) declares that
    /// connection dead and excludes the client from the round.
    fn collect_round(&mut self, round: usize, active_set: &[bool]) -> Result<Vec<RemoteUplink>> {
        let mut ups = Vec::new();
        for i in 0..self.conns.len() {
            if !active_set.get(i).copied().unwrap_or(false) {
                continue;
            }
            let Some(stream) = self.conns[i].as_mut() else { continue };
            match read_uplink(stream, round, i) {
                Ok(u) => ups.push(u),
                Err(_) => self.conns[i] = None,
            }
        }
        Ok(ups)
    }

    fn round_uplink_conditioned(
        &mut self,
        msgs: &[Message],
        conds: &[LinkCondition],
    ) -> UplinkReport {
        self.sim.round_uplink_conditioned(msgs, conds)
    }

    fn account_lost_bytes(&mut self, wasted: u64) {
        self.sim.account_lost_bytes(wasted);
    }

    fn total_bytes_up(&self) -> u64 {
        self.sim.total_bytes_up
    }

    fn total_retransmitted(&self) -> u64 {
        self.sim.total_retransmitted
    }

    /// Send SHUTDOWN to every live worker and close the connections. Write
    /// errors are ignored — the goal is teardown, not delivery.
    fn shutdown(&mut self) -> Result<()> {
        for slot in &mut self.conns {
            if let Some(stream) = slot {
                let _ = write_msg(stream, &[MSG_SHUTDOWN]);
            }
            *slot = None;
        }
        Ok(())
    }
}

/// Parse one UPLINK payload from `client`, validating the round/client echo.
fn read_uplink(stream: &mut TcpStream, round: usize, client: usize) -> Result<RemoteUplink> {
    let msg = read_msg(stream)?;
    let mut c = Cur::new(&msg);
    let t = c.u8()?;
    if t != MSG_UPLINK {
        bail!("expected UPLINK (0x04), got message type {t:#04x}");
    }
    let r = c.u32()? as usize;
    let cl = c.u32()? as usize;
    if r != round || cl != client {
        bail!("uplink out of sync: got (round {r}, client {cl}), expected ({round}, {client})");
    }
    let loss = c.f32()?;
    let outcome = match c.u8()? {
        OUTCOME_ARRIVED => {
            let nf = c.u32()? as usize;
            let mut frames = Vec::with_capacity(nf.min(1024));
            for _ in 0..nf {
                let gi = c.u32()? as usize;
                let len = c.u32()? as usize;
                frames.push((gi, c.take(len)?.to_vec()));
            }
            UplinkOutcome::Arrived(frames)
        }
        OUTCOME_LOST => UplinkOutcome::Lost { wasted: c.u64()? },
        OUTCOME_SKIPPED => UplinkOutcome::Skipped,
        other => bail!("unknown uplink outcome {other}"),
    };
    Ok(RemoteUplink { client, loss, outcome })
}

// -- worker -----------------------------------------------------------------

/// Socket and lifecycle tuning for a worker process.
#[derive(Clone, Copy, Debug)]
pub struct WorkerOptions {
    /// How long to keep retrying the initial connect (covers the window
    /// where the orchestrator spawned the worker before the server bound).
    pub connect_timeout: Duration,
    /// Per-read deadline: bounds how long the worker waits for the next
    /// ROUND_START/SHUTDOWN (covers the server's aggregate + eval window).
    pub io_timeout: Duration,
    /// Fault-injection hook: exit abruptly (dropping the socket, no
    /// goodbye) after participating in this many active rounds — how the
    /// tests and `--max-rounds` simulate a killed worker.
    pub max_rounds: Option<usize>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            connect_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(120),
            max_rounds: None,
        }
    }
}

/// Retry `TcpStream::connect` until it succeeds or `timeout` elapses.
fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("connecting to coordinator at {addr}: {e}"));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Run one worker process (or thread): connect to the coordinator at
/// `addr`, handshake as `client_id`, rebuild this client's exact
/// in-process state from the config the server sends, then serve rounds
/// until SHUTDOWN.
///
/// Per active round the worker runs the same three client-side stages as
/// the in-process pipelines — batch + gradient, per-group encode
/// (`Client::compress`), and the per-client uplink routing
/// (`pipeline::route_message`: `drop_client` fault, seeded packet loss
/// with EF residual repair) — and reports the outcome. The server redraws
/// the link condition from the same seeded stream, which is what makes the
/// clean-scenario digest bit-identical to the in-process barrier run.
pub fn run_worker(addr: &str, client_id: usize, opts: &WorkerOptions) -> Result<()> {
    let mut stream = connect_with_retry(addr, opts.connect_timeout)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(opts.io_timeout))?;

    // HELLO → WELCOME: version + id check, then the experiment config.
    let mut hello = Vec::with_capacity(7);
    hello.push(MSG_HELLO);
    hello.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    hello.extend_from_slice(&(client_id as u32).to_le_bytes());
    write_msg(&mut stream, &hello)?;
    let msg = read_msg(&mut stream).context("waiting for WELCOME")?;
    let mut c = Cur::new(&msg);
    let t = c.u8()?;
    if t != MSG_WELCOME {
        bail!("expected WELCOME (0x02), got message type {t:#04x}");
    }
    let version = c.u16()?;
    if version != PROTO_VERSION {
        bail!("protocol version mismatch: server speaks {version}, worker {PROTO_VERSION}");
    }
    let echoed = c.u32()? as usize;
    if echoed != client_id {
        bail!("server welcomed client {echoed}, expected {client_id}");
    }
    let cfg_text = std::str::from_utf8(c.rest()).context("WELCOME config is not UTF-8")?;
    let cfg = ExperimentConfig::from_json(&Value::parse(cfg_text)?)?;
    if client_id >= cfg.clients {
        bail!("client id {client_id} out of range for {} clients", cfg.clients);
    }

    // Rebuild this client exactly as the in-process coordinator would:
    // same fleet construction, same scenario engine, same spec. Everything
    // downstream is a pure function of (cfg, params, round), so the frames
    // this worker sends are bit-identical to the in-process encode.
    let backend = make_backend(&cfg)?;
    let spec = backend.model(&cfg.model)?;
    spec.validate()?;
    let mut me = super::build_fleet(&cfg, &spec)?.clients.swap_remove(client_id);
    let scenario = ScenarioEngine::new(cfg.scenario.clone(), cfg.clients, cfg.seed);
    let groups = spec.groups.clone();

    let mut params: Vec<f32> = Vec::new();
    let mut active_rounds = 0usize;
    loop {
        let msg = read_msg(&mut stream).context("waiting for ROUND_START")?;
        let mut c = Cur::new(&msg);
        match c.u8()? {
            MSG_SHUTDOWN => return Ok(()),
            MSG_ROUND_START => {
                let round = c.u32()? as usize;
                let active = c.u8()? != 0;
                let count = c.u32()? as usize;
                if !active {
                    // Keep-alive for a churned-out round: nothing to do (the
                    // trailing rate block is dropped with the payload).
                    continue;
                }
                let bytes = c.take(
                    count
                        .checked_mul(4)
                        .ok_or_else(|| anyhow!("parameter count overflow"))?,
                )?;
                params.clear();
                params.reserve(count);
                params.extend(
                    bytes
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().expect("4 bytes"))),
                );
                // Rate block: re-target the codecs at the scheduled widths
                // before encoding, exactly as the in-process pipelines do.
                // Empty block → the scheduler is off; keep standing widths.
                let nrates = c.u32()? as usize;
                let rate_bytes = c.take(nrates)?;
                if !rate_bytes.is_empty() {
                    let bits: Vec<u32> = rate_bytes.iter().map(|&b| b as u32).collect();
                    me.set_rates(&bits);
                }

                // Compute → Encode → per-client uplink routing: the same
                // stages, through the same code, as the in-process round.
                let (x, y) = me.next_batch(spec.train_batch, cfg.seed, round as u64);
                let out = backend.grad(&cfg.model, &params, &x, &y)?;
                let refit_now = round % cfg.quant.estimate_every == 0;
                let m = me.compress(&out.grads, &groups, round, cfg.seed, refit_now, out.loss);
                let produced =
                    pipeline::route_message(&mut me, m, &scenario, cfg.drop_client, round as u64);

                let mut payload = Vec::with_capacity(14);
                payload.push(MSG_UPLINK);
                payload.extend_from_slice(&(round as u32).to_le_bytes());
                payload.extend_from_slice(&(client_id as u32).to_le_bytes());
                payload.extend_from_slice(&out.loss.to_le_bytes());
                match produced {
                    Produced::Arrived(m, _cond) => {
                        payload.push(OUTCOME_ARRIVED);
                        payload
                            .extend_from_slice(&checked_wire_len(m.frames.len(), "frame count")?.to_le_bytes());
                        for (gi, frame) in &m.frames {
                            payload.extend_from_slice(&checked_wire_len(*gi, "group index")?.to_le_bytes());
                            payload.extend_from_slice(&checked_wire_len(frame.len(), "frame")?.to_le_bytes());
                            payload.extend_from_slice(frame);
                        }
                        me.recycle(m);
                    }
                    Produced::Lost { wasted } => {
                        payload.push(OUTCOME_LOST);
                        payload.extend_from_slice(&wasted.to_le_bytes());
                    }
                    Produced::Skipped => payload.push(OUTCOME_SKIPPED),
                }
                write_msg(&mut stream, &payload)?;

                active_rounds += 1;
                if opts.max_rounds.is_some_and(|max| active_rounds >= max) {
                    // Simulated kill: vanish without a goodbye. The server
                    // must detect the dead socket and take the drop path.
                    return Ok(());
                }
            }
            t => bail!("unexpected message type {t:#04x} mid-run"),
        }
    }
}

// -- orchestrator -----------------------------------------------------------

/// Wait for spawned worker processes to exit, killing any that outlive
/// `timeout`. Collects every failure (nonzero exit, forced kill) into one
/// error so a partial teardown is never silent.
pub fn teardown_workers(children: &mut [std::process::Child], timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    let mut failures = Vec::new();
    for (i, ch) in children.iter_mut().enumerate() {
        loop {
            match ch.try_wait() {
                Ok(Some(status)) => {
                    if !status.success() {
                        failures.push(format!("worker {i} exited with {status}"));
                    }
                    break;
                }
                Ok(None) => {
                    if Instant::now() >= deadline {
                        let _ = ch.kill();
                        let _ = ch.wait();
                        failures.push(format!("worker {i} outlived the teardown deadline; killed"));
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    failures.push(format!("worker {i}: {e}"));
                    break;
                }
            }
        }
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(anyhow!(failures.join("; ")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framing_roundtrip() {
        let mut buf: Vec<u8> = Vec::new();
        write_msg(&mut buf, b"hello").unwrap();
        write_msg(&mut buf, b"").unwrap();
        assert_eq!(&buf[..4], &5u32.to_le_bytes());
        let mut r = &buf[..];
        assert_eq!(read_msg(&mut r).unwrap(), b"hello");
        assert_eq!(read_msg(&mut r).unwrap(), b"");
        assert!(read_msg(&mut r).is_err(), "stream exhausted");
    }

    #[test]
    fn framing_rejects_oversized_prefix() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = &buf[..];
        assert!(read_msg(&mut r).is_err());
    }

    #[test]
    fn write_side_length_check_mirrors_read_bound() {
        // In-bounds conversions pass through unchanged.
        assert_eq!(checked_wire_len(0, "x").unwrap(), 0);
        assert_eq!(checked_wire_len(MAX_MSG_LEN as usize, "x").unwrap(), MAX_MSG_LEN);
        // One past the protocol bound must bail — and so must the sizes a
        // bare `as u32` cast would have *silently truncated* (u32::MAX + 1
        // wraps to 0, desyncing the peer's length-prefixed reader).
        for n in [MAX_MSG_LEN as usize + 1, u32::MAX as usize, u32::MAX as usize + 1] {
            let err = checked_wire_len(n, "payload").unwrap_err().to_string();
            assert!(err.contains("protocol bound"), "n = {n}: {err}");
        }
        // write_msg routes every payload length through the same gate (the
        // check fires before any byte is written), so in-bounds writes are
        // untouched; the oversized branch is pinned above via the helper
        // rather than by materializing a > 256 MiB buffer in a unit test.
        let mut buf = Vec::new();
        write_msg(&mut buf, &[0u8; 1]).unwrap();
        assert_eq!(&buf[..4], &1u32.to_le_bytes());
    }

    #[test]
    fn cursor_reads_little_endian_and_bounds_checks() {
        let mut b = Vec::new();
        b.push(7u8);
        b.extend_from_slice(&0x0102u16.to_le_bytes());
        b.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        b.extend_from_slice(&1.5f32.to_le_bytes());
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        b.extend_from_slice(b"tail");
        let mut c = Cur::new(&b);
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u16().unwrap(), 0x0102);
        assert_eq!(c.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(c.f32().unwrap(), 1.5);
        assert_eq!(c.u64().unwrap(), u64::MAX);
        assert_eq!(c.rest(), b"tail");
        assert!(c.u8().is_err(), "exhausted cursor must not read");
    }

    #[test]
    fn handshake_rejects_bad_version_and_range() {
        // A HELLO speaking a future protocol version must be refused.
        let mut hello = Vec::new();
        hello.push(MSG_HELLO);
        hello.extend_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        hello.extend_from_slice(&0u32.to_le_bytes());
        let mut c = Cur::new(&hello);
        assert_eq!(c.u8().unwrap(), MSG_HELLO);
        assert_ne!(c.u16().unwrap(), PROTO_VERSION);
    }
}
