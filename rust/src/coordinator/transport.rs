//! Real multi-process transport: the coordinator and its clients as
//! separate OS processes exchanging `quant::wire` frames over TCP.
//!
//! Every message on the socket is a **length-prefixed, checksummed
//! payload**: a `u32` little-endian byte count, that many payload bytes
//! (whose first byte is the message type), then a 4-byte CRC32 trailer
//! over the payload (`util::crc32`). Eight message types exist — HELLO,
//! WELCOME, ROUND_START, UPLINK, SHUTDOWN, REJOIN, STATE, RETRANSMIT —
//! and `docs/PROTOCOL.md` is the normative byte-level spec (including the
//! five wire-frame kinds an UPLINK carries).
//!
//! Roles:
//!
//! * **server** ([`TcpServer`] → [`TcpTransport`]) — binds, accepts one
//!   connection per client, and drives rounds through
//!   `Coordinator::run_remote`. The handshake WELCOME carries the full
//!   `ExperimentConfig` as JSON, so every process derives identical data
//!   shards, codec state and RNG streams from one config + seed. The
//!   listener stays open for the life of the run so a killed worker can
//!   come back (REJOIN).
//! * **worker** ([`run_worker`]) — connects, rebuilds its `Client` via
//!   `coordinator::build_fleet`, then loops: receive parameters, compute
//!   the local gradient, encode frames, run the same per-client uplink
//!   routing the in-process pipelines use, and send the outcome back.
//! * **orchestrator** (`tqsgd launch`) — spawns N local worker processes,
//!   runs the server in-process, and tears everything down with
//!   [`teardown_workers`]'s kill deadline.
//!
//! **Determinism.** The transport moves real bytes but keeps the
//! *simulated* network clock: all byte/latency accounting runs through the
//! embedded [`SimNet`] model, so `RunLog::replay_digest()` (which folds in
//! `net_secs` as simulated seconds) is bit-identical between a clean
//! multi-process run and the in-process barrier pipeline — see
//! `pipeline::step_remote` for the argument and `docs/DETERMINISM.md` for
//! the invariant table.
//!
//! **Fault injection on real connections.** Read failures are classified
//! by the [`ReadError`] taxonomy. EOF from a killed worker or a blown
//! [`TcpOptions::io_timeout`] means the peer is *gone*: the server marks
//! the connection dead, finishes the round with the survivors (the
//! scenario engine's drop/reweight path), and masks the client out of
//! later rounds via [`Transport::reachable`]. A CRC32 trailer mismatch is
//! [`ReadError::Corrupt`] — the bytes arrived but failed integrity — and
//! takes the RETRANSMIT path instead: the server charges the wasted
//! bytes, asks the worker to re-send, and the round proceeds without
//! losing the client. The seeded chaos harness (`scenario::chaos_*`)
//! drives both paths deterministically: byte corruption on UPLINK
//! payloads, real pre-uplink stalls, and a *cooperative* kill where the
//! victim uploads its mutable state (STATE) after its scheduled round and
//! the respawned process re-admits via REJOIN one round later with
//! bit-identical state.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::data::SamplerState;
use crate::json::Value;
use crate::quant::wire;
use crate::quant::RatePlan;
use crate::runtime::make_backend;
use crate::util::crc32::crc32;
use crate::util::Rng;

use super::network::{
    LinkCondition, Message, RemoteUplink, SimNet, Transport, UplinkOutcome, UplinkReport,
};
use super::pipeline::{self, Produced};
use super::scenario::{chaos_corrupt_positions, chaos_corrupts, chaos_kill_target, chaos_stalls};
use super::ScenarioEngine;

/// Protocol version carried by HELLO/WELCOME/REJOIN. Both sides must match
/// exactly; bump it whenever a message layout or wire-frame kind changes
/// (see `docs/PROTOCOL.md` §Versioning). Version 2 added the ROUND_START
/// rate block and the multiscale wire-frame kind (4); version 3 added the
/// CRC32 trailer on every message plus the REJOIN/STATE/RETRANSMIT
/// fault-tolerance messages.
pub const PROTO_VERSION: u16 = 3;

// Message type bytes (first payload byte).
const MSG_HELLO: u8 = 0x01;
const MSG_WELCOME: u8 = 0x02;
const MSG_ROUND_START: u8 = 0x03;
const MSG_UPLINK: u8 = 0x04;
const MSG_SHUTDOWN: u8 = 0x05;
const MSG_REJOIN: u8 = 0x06;
const MSG_STATE: u8 = 0x07;
const MSG_RETRANSMIT: u8 = 0x08;

// UPLINK outcome bytes (mirror `pipeline::Produced`).
const OUTCOME_ARRIVED: u8 = 0;
const OUTCOME_LOST: u8 = 1;
const OUTCOME_SKIPPED: u8 = 2;

/// Upper bound on one length-prefixed payload; a larger prefix is treated
/// as protocol corruption rather than an allocation request.
const MAX_MSG_LEN: u32 = 256 * 1024 * 1024;

/// RETRANSMIT requests the server sends for one uplink before declaring
/// the connection hopeless. Bounds the corrupt-retry loop so a peer that
/// keeps failing integrity (or a desynced stream) can never hang a round.
const MAX_RETRANSMITS: u32 = 3;

// -- framing ----------------------------------------------------------------

/// Why a transport read failed. The taxonomy is the point: [`ReadError::Eof`]
/// and [`ReadError::TimedOut`] mean the peer is *gone* (the connection is
/// declared dead, the drop path), while [`ReadError::Corrupt`] means bytes
/// arrived but failed integrity — a retransmittable condition that must NOT
/// kill the connection (PROTOCOL.md §5).
#[derive(Debug)]
pub enum ReadError {
    /// The peer closed or reset the connection: no more bytes will come.
    Eof,
    /// The read deadline elapsed with the connection still open — a hung
    /// or stalled peer.
    TimedOut,
    /// Bytes arrived but do not form a valid message: CRC32 trailer
    /// mismatch, an oversized length prefix, or a payload that fails
    /// validation.
    Corrupt {
        /// What failed to validate.
        what: String,
        /// Payload bytes read (and thus wasted on the wire) before the
        /// failure was detected.
        wasted: u64,
    },
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Eof => write!(f, "connection closed by peer"),
            ReadError::TimedOut => write!(f, "read deadline elapsed"),
            ReadError::Corrupt { what, wasted } => {
                write!(f, "corrupt message: {what} ({wasted} payload bytes wasted)")
            }
        }
    }
}

impl std::error::Error for ReadError {}

impl From<std::io::Error> for ReadError {
    /// Classify an I/O failure: a blown deadline keeps the connection
    /// (TimedOut); everything else — EOF, reset, broken pipe — means the
    /// peer is gone.
    fn from(e: std::io::Error) -> Self {
        use std::io::ErrorKind;
        match e.kind() {
            ErrorKind::WouldBlock | ErrorKind::TimedOut => ReadError::TimedOut,
            _ => ReadError::Eof,
        }
    }
}

/// Checked `usize → u32` conversion against the protocol frame bound, for
/// every length/count a writer serializes. A plain `as u32` cast would
/// silently truncate past `u32::MAX` and desync the stream; bounding at
/// [`MAX_MSG_LEN`] mirrors the read-side check so an oversized payload is
/// rejected **before** it hits the wire, not by the confused peer.
fn checked_wire_len(n: usize, what: &str) -> Result<u32> {
    if n as u64 > MAX_MSG_LEN as u64 {
        bail!("{what} length {n} exceeds the {MAX_MSG_LEN}-byte protocol bound");
    }
    Ok(n as u32)
}

/// Write one length-prefixed payload with its CRC32 trailer.
fn write_msg<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    w.write_all(&checked_wire_len(payload.len(), "payload")?.to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed payload and verify its CRC32 trailer,
/// classifying failures into the [`ReadError`] taxonomy. A trailer
/// mismatch leaves the stream in sync (exactly one framed message was
/// consumed), which is what makes the retransmit path possible.
fn read_msg<R: Read>(r: &mut R) -> std::result::Result<Vec<u8>, ReadError> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len);
    if n > MAX_MSG_LEN {
        return Err(ReadError::Corrupt {
            what: format!("length prefix {n} exceeds the {MAX_MSG_LEN}-byte protocol bound"),
            wasted: 0,
        });
    }
    let mut buf = vec![0u8; n as usize];
    r.read_exact(&mut buf)?;
    let mut trailer = [0u8; 4];
    r.read_exact(&mut trailer)?;
    if u32::from_le_bytes(trailer) != crc32(&buf) {
        return Err(ReadError::Corrupt {
            what: "CRC32 trailer mismatch".into(),
            wasted: n as u64,
        });
    }
    Ok(buf)
}

/// Bounds-checked little-endian payload reader (the transport analogue of
/// `quant::wire`'s internal reader).
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow!("truncated transport message"))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.b[self.i..];
        self.i = self.b.len();
        s
    }
}

// -- worker state (STATE message) -------------------------------------------

/// Deserialized STATE payload (PROTOCOL.md §3.7): the mutable state a
/// chaos-killed worker uploads before exiting, and a rejoining worker
/// restores — batch-sampler position plus per-group EF residuals as
/// lossless Raw wire frames. Everything else a worker holds is a pure
/// function of `(config, params, round)` and is rebuilt from the WELCOME
/// config (codec *fit* state is the one exception, which is why the
/// rejoin parity invariant is scoped to `estimate_every = 1`; see
/// `docs/DETERMINISM.md` §invariant 7).
struct WorkerState {
    client: usize,
    sampler: SamplerState,
    residuals: Vec<Option<Vec<f32>>>,
}

/// Serialize a worker's mutable state into a STATE payload.
fn encode_state(
    client: usize,
    round: usize,
    sampler: &SamplerState,
    residuals: &[Option<Vec<f32>>],
) -> Result<Vec<u8>> {
    let mut p = Vec::new();
    p.push(MSG_STATE);
    p.extend_from_slice(&(client as u32).to_le_bytes());
    p.extend_from_slice(&(round as u32).to_le_bytes());
    p.extend_from_slice(&checked_wire_len(sampler.order.len(), "sampler order")?.to_le_bytes());
    for &ix in &sampler.order {
        p.extend_from_slice(&checked_wire_len(ix, "sample index")?.to_le_bytes());
    }
    p.extend_from_slice(&checked_wire_len(sampler.cursor, "sampler cursor")?.to_le_bytes());
    for w in sampler.rng {
        p.extend_from_slice(&w.to_le_bytes());
    }
    match sampler.rng_spare {
        Some(x) => {
            p.push(1);
            p.extend_from_slice(&x.to_le_bytes());
        }
        None => p.push(0),
    }
    p.extend_from_slice(&checked_wire_len(residuals.len(), "group count")?.to_le_bytes());
    let mut frame = Vec::new();
    for r in residuals {
        match r {
            Some(res) => {
                // Lossless Raw wire frame (kind 0): the rejoined client's
                // residual must be bit-identical, so the lossy EF park()
                // path is NOT used here.
                wire::encode_raw_into(res, &mut frame);
                p.push(1);
                let len = checked_wire_len(frame.len(), "residual frame")?;
                p.extend_from_slice(&len.to_le_bytes());
                p.extend_from_slice(&frame);
            }
            None => p.push(0),
        }
    }
    Ok(p)
}

/// Parse a STATE payload back into worker state.
fn parse_state(msg: &[u8]) -> Result<WorkerState> {
    let mut c = Cur::new(msg);
    let t = c.u8()?;
    if t != MSG_STATE {
        bail!("expected STATE (0x07), got message type {t:#04x}");
    }
    let client = c.u32()? as usize;
    let _round = c.u32()? as usize;
    let n = c.u32()? as usize;
    let mut order = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        order.push(c.u32()? as usize);
    }
    let cursor = c.u32()? as usize;
    let mut rng = [0u64; 4];
    for w in &mut rng {
        *w = c.u64()?;
    }
    let rng_spare = match c.u8()? {
        0 => None,
        1 => Some(f64::from_bits(c.u64()?)),
        other => bail!("bad sampler spare flag {other}"),
    };
    let ngroups = c.u32()? as usize;
    let mut residuals = Vec::with_capacity(ngroups.min(1024));
    for _ in 0..ngroups {
        residuals.push(match c.u8()? {
            0 => None,
            1 => {
                let len = c.u32()? as usize;
                let mut out = Vec::new();
                wire::decode_dequantize_into(c.take(len)?, &mut out)?;
                Some(out)
            }
            other => bail!("bad residual flag {other}"),
        });
    }
    Ok(WorkerState {
        client,
        sampler: SamplerState { order, cursor, rng, rng_spare },
        residuals,
    })
}

// -- server -----------------------------------------------------------------

/// Socket tuning for the server side of the transport.
#[derive(Clone, Copy, Debug)]
pub struct TcpOptions {
    /// Per-read deadline on worker sockets: bounds how long a hung or
    /// killed worker can stall a round before it is declared dead.
    pub io_timeout: Duration,
    /// How long [`TcpServer::accept_workers`] waits for all N workers to
    /// connect and complete the handshake — and how long
    /// [`Transport::poll_rejoins`] waits for a respawned worker to come
    /// back after a scheduled chaos kill.
    pub accept_timeout: Duration,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            io_timeout: Duration::from_secs(30),
            accept_timeout: Duration::from_secs(60),
        }
    }
}

/// A bound listener waiting for its worker fleet: the step between "pick a
/// port" and "all N workers handshaked" — split so an orchestrator can
/// learn the ephemeral port before spawning workers at it.
pub struct TcpServer {
    listener: TcpListener,
    cfg: ExperimentConfig,
    opts: TcpOptions,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) for an
    /// experiment expecting `cfg.clients` workers.
    pub fn bind(addr: &str, cfg: &ExperimentConfig, opts: TcpOptions) -> Result<TcpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator on {addr}"))?;
        Ok(TcpServer { listener, cfg: cfg.clone(), opts })
    }

    /// The bound socket address (the port workers must connect to).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and handshake all `cfg.clients` workers, or fail once
    /// [`TcpOptions::accept_timeout`] elapses — a deadlocked handshake
    /// fails fast instead of hanging the run. The listener is kept open in
    /// the returned transport so chaos-killed workers can REJOIN.
    pub fn accept_workers(self) -> Result<TcpTransport> {
        let n = self.cfg.clients;
        let cfg_json = self.cfg.to_json().to_json();
        let deadline = Instant::now() + self.opts.accept_timeout;
        self.listener.set_nonblocking(true)?;
        let mut conns: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        let mut connected = 0usize;
        while connected < n {
            match self.listener.accept() {
                Ok((mut stream, peer)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(Some(self.opts.io_timeout))?;
                    let id = handshake_worker(&mut stream, n, &cfg_json)
                        .with_context(|| format!("handshaking worker at {peer}"))?;
                    if conns[id].is_some() {
                        bail!("two workers claimed client id {id}");
                    }
                    conns[id] = Some(stream);
                    connected += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out waiting for workers: {connected}/{n} connected \
                             within {:?}",
                            self.opts.accept_timeout
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(TcpTransport {
            sim: SimNet::new(self.cfg.net),
            conns,
            listener: self.listener,
            cfg: self.cfg,
            cfg_json,
            opts: self.opts,
            parked_state: (0..n).map(|_| None).collect(),
            round_rejoined: 0,
            round_corrupt: 0,
            round_corrupt_wasted: 0,
        })
    }
}

/// Server side of one worker handshake: read HELLO, validate, send WELCOME
/// with the experiment config. Returns the worker's client id.
fn handshake_worker(stream: &mut TcpStream, n: usize, cfg_json: &str) -> Result<usize> {
    let msg = read_msg(stream)?;
    let mut c = Cur::new(&msg);
    let t = c.u8()?;
    if t != MSG_HELLO {
        bail!("expected HELLO (0x01), got message type {t:#04x}");
    }
    let version = c.u16()?;
    if version != PROTO_VERSION {
        bail!("protocol version mismatch: worker speaks {version}, server {PROTO_VERSION}");
    }
    let id = c.u32()? as usize;
    if id >= n {
        bail!("client id {id} out of range for {n} clients");
    }
    write_welcome(stream, id, cfg_json)?;
    Ok(id)
}

/// Send the WELCOME message (version + echoed id + config JSON) — shared by
/// the initial handshake and the REJOIN handshake.
fn write_welcome(stream: &mut TcpStream, id: usize, cfg_json: &str) -> Result<()> {
    let mut welcome = Vec::with_capacity(7 + cfg_json.len());
    welcome.push(MSG_WELCOME);
    welcome.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    welcome.extend_from_slice(&(id as u32).to_le_bytes());
    welcome.extend_from_slice(cfg_json.as_bytes());
    write_msg(stream, &welcome)
}

/// The multi-process [`Transport`]: one TCP connection per worker plus the
/// embedded [`SimNet`] accounting model (real bytes, simulated clock — the
/// digest's `net_secs` stays the bandwidth/latency model, by design). The
/// listener stays open so chaos-killed workers can REJOIN, and each
/// killed worker's STATE upload is parked verbatim until it does.
pub struct TcpTransport {
    sim: SimNet,
    /// One slot per client; `None` once the connection is declared dead.
    conns: Vec<Option<TcpStream>>,
    /// The (still-open) listener REJOIN connections arrive on.
    listener: TcpListener,
    cfg: ExperimentConfig,
    /// The WELCOME config JSON, pre-rendered once.
    cfg_json: String,
    opts: TcpOptions,
    /// Verbatim STATE payloads from cooperatively killed workers, shipped
    /// back on REJOIN.
    parked_state: Vec<Option<Vec<u8>>>,
    /// Workers re-admitted this round (drained by `take_round_faults`).
    round_rejoined: u32,
    /// Corrupt messages detected this round (drained by `take_round_faults`).
    round_corrupt: u32,
    /// Wire bytes wasted by corrupt transmissions this round.
    round_corrupt_wasted: u64,
}

impl TcpTransport {
    /// Clients whose connection is still alive.
    pub fn alive(&self) -> usize {
        self.conns.iter().filter(|c| c.is_some()).count()
    }

    /// REJOIN handshake on a fresh connection: validate the claim, send
    /// WELCOME + the parked STATE blob, and hand back the client id.
    fn handshake_rejoin(&mut self, stream: &mut TcpStream) -> Result<usize> {
        let msg = read_msg(stream)?;
        let mut c = Cur::new(&msg);
        let t = c.u8()?;
        if t != MSG_REJOIN {
            bail!("expected REJOIN (0x06), got message type {t:#04x}");
        }
        let version = c.u16()?;
        if version != PROTO_VERSION {
            bail!("protocol version mismatch: rejoiner speaks {version}, server {PROTO_VERSION}");
        }
        let id = c.u32()? as usize;
        let last_round = c.u32()? as usize;
        if id >= self.conns.len() {
            bail!("rejoin from client id {id}, fleet has {}", self.conns.len());
        }
        if self.conns[id].is_some() {
            bail!("client {id} claims to rejoin but its connection is alive");
        }
        let Some(blob) = self.parked_state[id].take() else {
            bail!("client {id} has no parked state to rejoin with");
        };
        if last_round != self.cfg.scenario.chaos_kill_round {
            bail!(
                "client {id} rejoins from round {last_round}, state was parked at round {}",
                self.cfg.scenario.chaos_kill_round
            );
        }
        write_welcome(stream, id, &self.cfg_json)?;
        write_msg(stream, &blob)?;
        Ok(id)
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn reachable(&self) -> Option<Vec<bool>> {
        Some(self.conns.iter().map(|c| c.is_some()).collect())
    }

    /// Re-admit the chaos-killed worker at the start of the round after its
    /// scheduled kill. The server *knows the schedule* (it is a pure
    /// function of config + seed), so this is a block-accept bounded by
    /// [`TcpOptions::accept_timeout`], not a poll: the rejoined worker is
    /// back before `reachable()` is consulted, which is what keeps the
    /// kill → rejoin cycle invisible to the round structure (and hence the
    /// digest). If the respawn never arrives the round degrades to the
    /// drop path instead of failing.
    fn poll_rejoins(&mut self, round: usize) -> Result<u32> {
        let sc = &self.cfg.scenario;
        if sc.chaos_kill_round == 0 || round != sc.chaos_kill_round + 1 {
            return Ok(0);
        }
        let mut remaining: Vec<usize> = (0..self.conns.len())
            .filter(|&i| self.parked_state[i].is_some() && self.conns[i].is_none())
            .collect();
        if remaining.is_empty() {
            return Ok(0);
        }
        let deadline = Instant::now() + self.opts.accept_timeout;
        let mut rejoined = 0u32;
        while !remaining.is_empty() {
            match self.listener.accept() {
                Ok((mut stream, peer)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(Some(self.opts.io_timeout))?;
                    let id = self
                        .handshake_rejoin(&mut stream)
                        .with_context(|| format!("rejoin handshake with {peer}"))?;
                    if !remaining.contains(&id) {
                        bail!("unexpected rejoin from client {id}");
                    }
                    remaining.retain(|&x| x != id);
                    self.conns[id] = Some(stream);
                    rejoined += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        // The respawn never came back: proceed without it
                        // (the drop path), exactly like a non-cooperative
                        // kill.
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        self.round_rejoined += rejoined;
        Ok(rejoined)
    }

    fn take_round_faults(&mut self) -> (u32, u32, u64) {
        let out = (self.round_rejoined, self.round_corrupt, self.round_corrupt_wasted);
        self.round_rejoined = 0;
        self.round_corrupt = 0;
        self.round_corrupt_wasted = 0;
        out
    }

    /// Send ROUND_START to every live worker — actives get the parameter
    /// vector plus their bit-budget plan row (empty when the scheduler is
    /// off), churned-out workers an empty keep-alive (so their read clock
    /// keeps ticking). A failed write marks the connection dead; the round
    /// proceeds with the survivors.
    fn begin_round(
        &mut self,
        round: usize,
        active_set: &[bool],
        params: &[f32],
        rates: Option<&RatePlan>,
    ) -> Result<()> {
        for (i, slot) in self.conns.iter_mut().enumerate() {
            let Some(stream) = slot else { continue };
            let active = active_set.get(i).copied().unwrap_or(false);
            let bits: &[u32] = if active {
                rates.and_then(|plan| plan.rates_for(i)).unwrap_or(&[])
            } else {
                &[]
            };
            let body = if active { 14 + 4 * params.len() + bits.len() } else { 14 };
            let mut p = Vec::with_capacity(body);
            p.push(MSG_ROUND_START);
            p.extend_from_slice(&(round as u32).to_le_bytes());
            p.push(active as u8);
            if active {
                // Checked: a model with > MAX_MSG_LEN parameters must fail
                // loudly here, not truncate the count and desync the worker.
                p.extend_from_slice(&checked_wire_len(params.len(), "params")?.to_le_bytes());
                for x in params {
                    p.extend_from_slice(&x.to_le_bytes());
                }
            } else {
                p.extend_from_slice(&0u32.to_le_bytes());
            }
            // Rate block (PROTOCOL.md §3.3): this worker's plan row, one
            // byte per layer group. Empty when the scheduler is off, the
            // worker is inactive, or the plan has no row for the client —
            // the worker then keeps its standing codec widths.
            p.extend_from_slice(&checked_wire_len(bits.len(), "rates")?.to_le_bytes());
            for &b in bits {
                p.push(b.min(u8::MAX as u32) as u8);
            }
            if write_msg(stream, &p).is_err() {
                *slot = None;
            }
        }
        Ok(())
    }

    /// Read one UPLINK from every live active worker, in ascending client
    /// id. Sequential reads cannot deadlock — every worker computes and
    /// writes independently, and replies buffer in the sockets until read.
    /// EOF (a killed worker) or a blown [`TcpOptions::io_timeout`] declares
    /// the connection dead and excludes the client from the round; a
    /// [`ReadError::Corrupt`] instead charges the wasted bytes and takes
    /// the RETRANSMIT path (bounded by [`MAX_RETRANSMITS`]), so corruption
    /// alone never costs a client its round. After round
    /// `chaos_kill_round`'s uplinks the seeded victim's STATE upload is
    /// read and parked for the REJOIN one round later.
    fn collect_round(&mut self, round: usize, active_set: &[bool]) -> Result<Vec<RemoteUplink>> {
        let mut ups = Vec::new();
        for i in 0..self.conns.len() {
            if !active_set.get(i).copied().unwrap_or(false) {
                continue;
            }
            let mut retries = 0u32;
            loop {
                let Some(stream) = self.conns[i].as_mut() else { break };
                match read_uplink(stream, round, i) {
                    Ok(u) => {
                        ups.push(u);
                        break;
                    }
                    Err(ReadError::Corrupt { wasted, .. }) => {
                        self.round_corrupt += 1;
                        self.round_corrupt_wasted += wasted;
                        retries += 1;
                        if retries > MAX_RETRANSMITS
                            || write_msg(stream, &[MSG_RETRANSMIT]).is_err()
                        {
                            self.conns[i] = None;
                            break;
                        }
                    }
                    Err(_) => {
                        self.conns[i] = None;
                        break;
                    }
                }
            }
        }
        // Cooperative chaos kill: after round `chaos_kill_round`'s uplinks
        // the seeded victim uploads its mutable state and vanishes. Park
        // the STATE payload verbatim for the REJOIN handshake one round
        // later. A victim that died without the upload degrades to the
        // ordinary drop path.
        let sc = &self.cfg.scenario;
        if sc.chaos_kill_round > 0 && round == sc.chaos_kill_round {
            if let Some(v) = chaos_kill_target(sc, self.cfg.seed, self.conns.len()) {
                if let Some(stream) = self.conns[v].as_mut() {
                    if let Ok(msg) = read_msg(stream) {
                        if msg.first() == Some(&MSG_STATE) {
                            self.parked_state[v] = Some(msg);
                        }
                    }
                    self.conns[v] = None;
                }
            }
        }
        Ok(ups)
    }

    fn round_uplink_conditioned(
        &mut self,
        msgs: &[Message],
        conds: &[LinkCondition],
    ) -> UplinkReport {
        self.sim.round_uplink_conditioned(msgs, conds)
    }

    fn account_lost_bytes(&mut self, wasted: u64) {
        self.sim.account_lost_bytes(wasted);
    }

    fn total_bytes_up(&self) -> u64 {
        self.sim.total_bytes_up
    }

    fn total_retransmitted(&self) -> u64 {
        self.sim.total_retransmitted
    }

    /// Send SHUTDOWN to every live worker and close the connections. Write
    /// errors are ignored — the goal is teardown, not delivery.
    fn shutdown(&mut self) -> Result<()> {
        for slot in &mut self.conns {
            if let Some(stream) = slot {
                let _ = write_msg(stream, &[MSG_SHUTDOWN]);
            }
            *slot = None;
        }
        Ok(())
    }
}

/// Read and parse one UPLINK payload from `client`. A payload that passed
/// framing but fails validation (wrong type, mis-echoed round/client,
/// truncated frame list) is *corruption*, never a dead peer.
fn read_uplink(
    stream: &mut TcpStream,
    round: usize,
    client: usize,
) -> std::result::Result<RemoteUplink, ReadError> {
    let msg = read_msg(stream)?;
    parse_uplink(&msg, round, client).map_err(|e| ReadError::Corrupt {
        what: e.to_string(),
        wasted: msg.len() as u64,
    })
}

/// Parse one UPLINK payload from `client`, validating the round/client echo.
fn parse_uplink(msg: &[u8], round: usize, client: usize) -> Result<RemoteUplink> {
    let mut c = Cur::new(msg);
    let t = c.u8()?;
    if t != MSG_UPLINK {
        bail!("expected UPLINK (0x04), got message type {t:#04x}");
    }
    let r = c.u32()? as usize;
    let cl = c.u32()? as usize;
    if r != round || cl != client {
        bail!("uplink out of sync: got (round {r}, client {cl}), expected ({round}, {client})");
    }
    let loss = c.f32()?;
    let outcome = match c.u8()? {
        OUTCOME_ARRIVED => {
            let nf = c.u32()? as usize;
            let mut frames = Vec::with_capacity(nf.min(1024));
            for _ in 0..nf {
                let gi = c.u32()? as usize;
                let len = c.u32()? as usize;
                frames.push((gi, c.take(len)?.to_vec()));
            }
            UplinkOutcome::Arrived(frames)
        }
        OUTCOME_LOST => UplinkOutcome::Lost { wasted: c.u64()? },
        OUTCOME_SKIPPED => UplinkOutcome::Skipped,
        other => bail!("unknown uplink outcome {other}"),
    };
    Ok(RemoteUplink { client, loss, outcome })
}

// -- worker -----------------------------------------------------------------

/// Socket and lifecycle tuning for a worker process.
#[derive(Clone, Copy, Debug)]
pub struct WorkerOptions {
    /// How long to keep retrying the initial connect (covers the window
    /// where the orchestrator spawned the worker before the server bound).
    pub connect_timeout: Duration,
    /// Per-read deadline: bounds how long the worker waits for the next
    /// ROUND_START/SHUTDOWN (covers the server's aggregate + eval window).
    pub io_timeout: Duration,
    /// Fault-injection hook: exit abruptly (dropping the socket, no
    /// goodbye) after participating in this many active rounds — how the
    /// tests and `--max-rounds` simulate a NON-cooperative kill (the
    /// degraded drop path, unlike the chaos harness's cooperative kill).
    pub max_rounds: Option<usize>,
    /// `Some(r)` when this process replaces a chaos-killed worker whose
    /// last completed round was `r`: the handshake becomes REJOIN and the
    /// worker restores its sampler + EF residual state from the server's
    /// parked STATE blob before serving rounds.
    pub rejoin_from: Option<usize>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            connect_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(120),
            max_rounds: None,
            rejoin_from: None,
        }
    }
}

/// How [`run_worker`] ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerExit {
    /// The server sent SHUTDOWN, or `max_rounds` elapsed: a normal exit.
    Clean,
    /// The chaos harness killed this worker after `round`'s uplink (its
    /// state is parked on the server). The process should be respawned
    /// with `--rejoin-from <round>`; `tqsgd worker` signals this with
    /// exit code 17 so the `launch` monitor knows to respawn rather than
    /// report a crash.
    ChaosKilled {
        /// The last round this worker completed before dying.
        round: usize,
    },
}

/// Stream role for connect/rejoin backoff jitter. Worker-side wall-clock
/// only — never touches a digest-relevant stream.
const ROLE_BACKOFF: u64 = 0xBAC0;

/// Seeded exponential backoff with jitter: attempt `k` (0-based) waits
/// `min(cap, base * 2^k)` scaled into `[0.5, 1.0)` of itself by a draw
/// from a dedicated per-seed stream. Deterministic in `(seed, attempt)`,
/// so a fleet of reconnecting workers de-synchronizes reproducibly
/// instead of stampeding the listener in lockstep.
fn backoff_delay(seed: u64, attempt: u32, base: Duration, cap: Duration) -> Duration {
    let envelope = base
        .checked_mul(1u32 << attempt.min(16))
        .map_or(cap, |d| d.min(cap));
    let u = Rng::for_stream(seed, ROLE_BACKOFF, attempt as u64, 0).f64();
    envelope.mul_f64(0.5 + 0.5 * u)
}

/// Retry `TcpStream::connect` until it succeeds or `timeout` elapses,
/// sleeping [`backoff_delay`] (base 10 ms, cap 500 ms) between attempts.
/// Shared by the initial connect and the post-kill rejoin; `seed` is the
/// worker's client id so each worker jitters differently.
fn connect_with_retry(addr: &str, timeout: Duration, seed: u64) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("connecting to coordinator at {addr}: {e}"));
                }
                std::thread::sleep(backoff_delay(
                    seed,
                    attempt,
                    Duration::from_millis(10),
                    Duration::from_millis(500),
                ));
                attempt += 1;
            }
        }
    }
}

/// Run one worker process (or thread): connect to the coordinator at
/// `addr`, handshake as `client_id` (HELLO, or REJOIN when
/// [`WorkerOptions::rejoin_from`] is set), rebuild this client's exact
/// in-process state from the config the server sends (plus the parked
/// STATE blob on rejoin), then serve rounds until SHUTDOWN.
///
/// Per active round the worker runs the same three client-side stages as
/// the in-process pipelines — batch + gradient, per-group encode
/// (`Client::compress`), and the per-client uplink routing
/// (`pipeline::route_message`: `drop_client` fault, seeded packet loss
/// with EF residual repair) — and reports the outcome. The server redraws
/// the link condition from the same seeded stream, which is what makes the
/// clean-scenario digest bit-identical to the in-process barrier run.
///
/// The seeded chaos harness adds three worker-side faults: payload
/// corruption (the first transmission goes out with flipped bytes under
/// the clean CRC, and the clean payload is re-sent on RETRANSMIT), real
/// pre-uplink stalls, and the cooperative kill (upload STATE after the
/// scheduled round, then exit with [`WorkerExit::ChaosKilled`]).
pub fn run_worker(addr: &str, client_id: usize, opts: &WorkerOptions) -> Result<WorkerExit> {
    let mut stream = connect_with_retry(addr, opts.connect_timeout, client_id as u64)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(opts.io_timeout))?;

    // HELLO (or REJOIN) → WELCOME: version + id check, then the config.
    let mut hello = Vec::with_capacity(11);
    if let Some(last_round) = opts.rejoin_from {
        hello.push(MSG_REJOIN);
        hello.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        hello.extend_from_slice(&(client_id as u32).to_le_bytes());
        hello.extend_from_slice(&checked_wire_len(last_round, "rejoin round")?.to_le_bytes());
    } else {
        hello.push(MSG_HELLO);
        hello.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        hello.extend_from_slice(&(client_id as u32).to_le_bytes());
    }
    write_msg(&mut stream, &hello)?;
    let msg = read_msg(&mut stream).context("waiting for WELCOME")?;
    let mut c = Cur::new(&msg);
    let t = c.u8()?;
    if t != MSG_WELCOME {
        bail!("expected WELCOME (0x02), got message type {t:#04x}");
    }
    let version = c.u16()?;
    if version != PROTO_VERSION {
        bail!("protocol version mismatch: server speaks {version}, worker {PROTO_VERSION}");
    }
    let echoed = c.u32()? as usize;
    if echoed != client_id {
        bail!("server welcomed client {echoed}, expected {client_id}");
    }
    let cfg_text = std::str::from_utf8(c.rest()).context("WELCOME config is not UTF-8")?;
    let cfg = ExperimentConfig::from_json(&Value::parse(cfg_text)?)?;
    if client_id >= cfg.clients {
        bail!("client id {client_id} out of range for {} clients", cfg.clients);
    }

    // Rebuild this client exactly as the in-process coordinator would:
    // same fleet construction, same scenario engine, same spec. Everything
    // downstream is a pure function of (cfg, params, round), so the frames
    // this worker sends are bit-identical to the in-process encode.
    let backend = make_backend(&cfg)?;
    let spec = backend.model(&cfg.model)?;
    spec.validate()?;
    let mut me = super::build_fleet(&cfg, &spec)?.clients.swap_remove(client_id);
    let scenario = ScenarioEngine::new(cfg.scenario.clone(), cfg.clients, cfg.seed);
    let groups = spec.groups.clone();
    let sc = cfg.scenario.clone();

    // Rejoin: restore the mutable state the killed predecessor uploaded —
    // sampler position and EF residuals. Codec fit state is rebuilt by the
    // next refit, which is why rejoin parity is scoped to
    // `estimate_every = 1` (docs/DETERMINISM.md §invariant 7).
    if opts.rejoin_from.is_some() {
        let msg = read_msg(&mut stream).context("waiting for STATE after REJOIN")?;
        let st = parse_state(&msg)?;
        if st.client != client_id {
            bail!("STATE is for client {}, expected {client_id}", st.client);
        }
        me.restore_sampler(st.sampler);
        me.import_residuals(&st.residuals);
    }

    // The cooperative kill schedule is a pure function of config + seed,
    // so the victim knows it is the victim. A respawned (rejoined) worker
    // never re-dies: its rounds start past the kill round anyway, but the
    // guard keeps that explicit.
    let kill_me = opts.rejoin_from.is_none()
        && sc.chaos_kill_round > 0
        && chaos_kill_target(&sc, cfg.seed, cfg.clients) == Some(client_id);

    let mut params: Vec<f32> = Vec::new();
    let mut active_rounds = 0usize;
    // The last clean UPLINK payload, kept for RETRANSMIT.
    let mut last_uplink: Vec<u8> = Vec::new();
    loop {
        let msg = read_msg(&mut stream).context("waiting for ROUND_START")?;
        let mut c = Cur::new(&msg);
        match c.u8()? {
            MSG_SHUTDOWN => return Ok(WorkerExit::Clean),
            MSG_RETRANSMIT => {
                // The server read our uplink as corrupt (the chaos
                // harness's flipped bytes, or a genuinely bad link):
                // re-send the saved clean payload.
                if last_uplink.is_empty() {
                    bail!("RETRANSMIT with no uplink outstanding");
                }
                write_msg(&mut stream, &last_uplink)?;
            }
            MSG_ROUND_START => {
                let round = c.u32()? as usize;
                let active = c.u8()? != 0;
                let count = c.u32()? as usize;
                let dying = kill_me && round == sc.chaos_kill_round;
                if !active {
                    // Keep-alive for a churned-out round: nothing to do (the
                    // trailing rate block is dropped with the payload).
                    if dying {
                        let state = encode_state(
                            client_id,
                            round,
                            &me.sampler_state(),
                            &me.export_residuals(),
                        )?;
                        write_msg(&mut stream, &state)?;
                        return Ok(WorkerExit::ChaosKilled { round });
                    }
                    continue;
                }
                let bytes = c.take(
                    count
                        .checked_mul(4)
                        .ok_or_else(|| anyhow!("parameter count overflow"))?,
                )?;
                params.clear();
                params.reserve(count);
                params.extend(
                    bytes
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().expect("4 bytes"))),
                );
                // Rate block: re-target the codecs at the scheduled widths
                // before encoding, exactly as the in-process pipelines do.
                // Empty block → the scheduler is off; keep standing widths.
                let nrates = c.u32()? as usize;
                let rate_bytes = c.take(nrates)?;
                if !rate_bytes.is_empty() {
                    let bits: Vec<u32> = rate_bytes.iter().map(|&b| b as u32).collect();
                    me.set_rates(&bits);
                }

                // Compute → Encode → per-client uplink routing: the same
                // stages, through the same code, as the in-process round.
                let (x, y) = me.next_batch(spec.train_batch, cfg.seed, round as u64);
                let out = backend.grad(&cfg.model, &params, &x, &y)?;
                let refit_now = round % cfg.quant.estimate_every == 0;
                let m = me.compress(&out.grads, &groups, round, cfg.seed, refit_now, out.loss);
                let produced =
                    pipeline::route_message(&mut me, m, &scenario, cfg.drop_client, round as u64);

                let mut payload = Vec::with_capacity(14);
                payload.push(MSG_UPLINK);
                payload.extend_from_slice(&(round as u32).to_le_bytes());
                payload.extend_from_slice(&(client_id as u32).to_le_bytes());
                payload.extend_from_slice(&out.loss.to_le_bytes());
                let mut arrived = false;
                match produced {
                    Produced::Arrived(m, _cond) => {
                        arrived = true;
                        payload.push(OUTCOME_ARRIVED);
                        let count = checked_wire_len(m.frames.len(), "frame count")?;
                        payload.extend_from_slice(&count.to_le_bytes());
                        for (gi, frame) in &m.frames {
                            let gi = checked_wire_len(*gi, "group index")?;
                            let len = checked_wire_len(frame.len(), "frame")?;
                            payload.extend_from_slice(&gi.to_le_bytes());
                            payload.extend_from_slice(&len.to_le_bytes());
                            payload.extend_from_slice(frame);
                        }
                        me.recycle(m);
                    }
                    Produced::Lost { wasted } => {
                        payload.push(OUTCOME_LOST);
                        payload.extend_from_slice(&wasted.to_le_bytes());
                    }
                    Produced::Skipped => payload.push(OUTCOME_SKIPPED),
                }

                // Chaos stall: a real wall-clock sleep before the uplink,
                // absorbed by the server's read deadline (never simulated
                // time, so the digest is untouched).
                if chaos_stalls(&sc, cfg.seed, client_id, round as u64) {
                    std::thread::sleep(Duration::from_secs_f64(sc.chaos_stall_secs));
                }

                // Chaos corruption (delivered frames only, matching the
                // in-process model): the first transmission carries
                // `chaos_corrupt_bytes` flipped payload bytes under the
                // CLEAN payload's CRC — a guaranteed trailer mismatch at
                // the server, which answers RETRANSMIT.
                let corrupt_this =
                    arrived && chaos_corrupts(&sc, cfg.seed, client_id, round as u64);
                if corrupt_this {
                    let mut bad = payload.clone();
                    for p in
                        chaos_corrupt_positions(&sc, cfg.seed, client_id, round as u64, bad.len())
                    {
                        bad[p] ^= 0xFF;
                    }
                    stream.write_all(&checked_wire_len(bad.len(), "payload")?.to_le_bytes())?;
                    stream.write_all(&bad)?;
                    stream.write_all(&crc32(&payload).to_le_bytes())?;
                    stream.flush()?;
                } else {
                    write_msg(&mut stream, &payload)?;
                }
                last_uplink = payload;

                if dying {
                    if corrupt_this {
                        // The server deterministically answers corruption
                        // with RETRANSMIT; serve it before dying so the
                        // round's aggregate still includes this client.
                        let msg = read_msg(&mut stream)
                            .context("waiting for RETRANSMIT before chaos kill")?;
                        if msg.first() != Some(&MSG_RETRANSMIT) {
                            bail!("expected RETRANSMIT before chaos kill");
                        }
                        write_msg(&mut stream, &last_uplink)?;
                    }
                    let state = encode_state(
                        client_id,
                        round,
                        &me.sampler_state(),
                        &me.export_residuals(),
                    )?;
                    write_msg(&mut stream, &state)?;
                    return Ok(WorkerExit::ChaosKilled { round });
                }

                active_rounds += 1;
                if opts.max_rounds.is_some_and(|max| active_rounds >= max) {
                    // Simulated kill: vanish without a goodbye. The server
                    // must detect the dead socket and take the drop path.
                    return Ok(WorkerExit::Clean);
                }
            }
            t => bail!("unexpected message type {t:#04x} mid-run"),
        }
    }
}

// -- orchestrator -----------------------------------------------------------

/// Wait for spawned worker processes to exit, killing any that outlive
/// `timeout`. Collects every failure (nonzero exit, forced kill) into one
/// error so a partial teardown is never silent.
pub fn teardown_workers(children: &mut [std::process::Child], timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    let mut failures = Vec::new();
    for (i, ch) in children.iter_mut().enumerate() {
        loop {
            match ch.try_wait() {
                Ok(Some(status)) => {
                    if !status.success() {
                        failures.push(format!("worker {i} exited with {status}"));
                    }
                    break;
                }
                Ok(None) => {
                    if Instant::now() >= deadline {
                        let _ = ch.kill();
                        let _ = ch.wait();
                        failures.push(format!("worker {i} outlived the teardown deadline; killed"));
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    failures.push(format!("worker {i}: {e}"));
                    break;
                }
            }
        }
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(anyhow!(failures.join("; ")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framing_roundtrip() {
        let mut buf: Vec<u8> = Vec::new();
        write_msg(&mut buf, b"hello").unwrap();
        write_msg(&mut buf, b"").unwrap();
        assert_eq!(&buf[..4], &5u32.to_le_bytes());
        // Trailer: CRC32 of the payload sits right after it.
        assert_eq!(&buf[9..13], &crc32(b"hello").to_le_bytes());
        let mut r = &buf[..];
        assert_eq!(read_msg(&mut r).unwrap(), b"hello");
        assert_eq!(read_msg(&mut r).unwrap(), b"");
        assert!(
            matches!(read_msg(&mut r), Err(ReadError::Eof)),
            "an exhausted stream is EOF, not corruption"
        );
    }

    #[test]
    fn framing_rejects_oversized_prefix_as_corrupt() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = &buf[..];
        match read_msg(&mut r) {
            Err(ReadError::Corrupt { wasted, .. }) => assert_eq!(wasted, 0),
            other => panic!("oversized prefix must be Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn crc_trailer_flags_flipped_payload_byte_as_corrupt() {
        let mut buf: Vec<u8> = Vec::new();
        write_msg(&mut buf, b"hello").unwrap();
        buf[4 + 2] ^= 0xFF; // flip one payload byte, keep the trailer
        let mut r = &buf[..];
        match read_msg(&mut r) {
            Err(ReadError::Corrupt { wasted, what }) => {
                assert_eq!(wasted, 5, "wasted = payload bytes consumed");
                assert!(what.contains("CRC32"), "{what}");
            }
            other => panic!("flipped byte must be Corrupt, got {other:?}"),
        }
        // The stream stayed in sync: nothing is left after the bad frame.
        assert!(r.is_empty());
    }

    #[test]
    fn read_error_taxonomy_separates_eof_timeout_corrupt() {
        // EOF: the reader has no bytes at all.
        let mut empty: &[u8] = &[];
        assert!(matches!(read_msg(&mut empty), Err(ReadError::Eof)));

        // TimedOut: the io layer reports a blown deadline.
        struct Stall;
        impl Read for Stall {
            fn read(&mut self, _b: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::from(std::io::ErrorKind::WouldBlock))
            }
        }
        assert!(matches!(read_msg(&mut Stall), Err(ReadError::TimedOut)));
        struct Timeout;
        impl Read for Timeout {
            fn read(&mut self, _b: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::from(std::io::ErrorKind::TimedOut))
            }
        }
        assert!(matches!(read_msg(&mut Timeout), Err(ReadError::TimedOut)));

        // Corrupt: framed bytes that fail integrity (see the CRC test);
        // a truncated payload mid-frame is EOF — the peer died mid-write.
        let mut buf: Vec<u8> = Vec::new();
        write_msg(&mut buf, b"hello").unwrap();
        let mut truncated = &buf[..6];
        assert!(matches!(read_msg(&mut truncated), Err(ReadError::Eof)));
    }

    #[test]
    fn parse_failure_is_corrupt_not_eof() {
        // A framed payload that is not a valid UPLINK must classify as
        // Corrupt (retransmittable), never as a dead peer.
        let bogus = [MSG_UPLINK, 9, 9, 9]; // truncated round echo
        let err = parse_uplink(&bogus, 0, 0).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn backoff_is_seeded_capped_and_jittered() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(500);
        for attempt in 0..8 {
            let a = backoff_delay(42, attempt, base, cap);
            let b = backoff_delay(42, attempt, base, cap);
            assert_eq!(a, b, "same (seed, attempt) must give the same delay");
            let envelope = base.checked_mul(1 << attempt).map_or(cap, |d| d.min(cap));
            assert!(a >= envelope.mul_f64(0.5), "attempt {attempt}: {a:?} under half envelope");
            assert!(a < envelope, "attempt {attempt}: {a:?} exceeds the envelope {envelope:?}");
        }
        // The cap bounds the envelope at large attempt counts.
        assert!(backoff_delay(42, 30, base, cap) < cap);
        // Different seeds de-synchronize (somewhere in the first attempts).
        let differs =
            (0..4).any(|k| backoff_delay(1, k, base, cap) != backoff_delay(2, k, base, cap));
        assert!(differs, "jitter must depend on the seed");
    }

    #[test]
    fn state_payload_roundtrips_bit_exactly() {
        let sampler = SamplerState {
            order: vec![3, 1, 4, 1, 5, 9, 2, 6],
            cursor: 5,
            rng: [1, u64::MAX, 0xDEAD_BEEF, 42],
            rng_spare: Some(-1.25),
        };
        let residuals = vec![
            Some(vec![0.5f32, -2.0, 3.25]),
            None,
            Some(vec![f32::MIN_POSITIVE, -0.0, 1e30]),
        ];
        let blob = encode_state(7, 3, &sampler, &residuals).unwrap();
        assert_eq!(blob[0], MSG_STATE);
        let st = parse_state(&blob).unwrap();
        assert_eq!(st.client, 7);
        assert_eq!(st.sampler, sampler);
        assert_eq!(st.residuals.len(), 3);
        assert_eq!(st.residuals[0].as_deref(), Some(&[0.5f32, -2.0, 3.25][..]));
        assert!(st.residuals[1].is_none());
        // Raw frames are lossless: bit-exact f32 round-trip, -0.0 included.
        let r2 = st.residuals[2].as_ref().unwrap();
        for (a, b) in r2.iter().zip(residuals[2].as_ref().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn framing_rejects_oversized_prefix() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = &buf[..];
        assert!(read_msg(&mut r).is_err());
    }

    #[test]
    fn write_side_length_check_mirrors_read_bound() {
        // In-bounds conversions pass through unchanged.
        assert_eq!(checked_wire_len(0, "x").unwrap(), 0);
        assert_eq!(checked_wire_len(MAX_MSG_LEN as usize, "x").unwrap(), MAX_MSG_LEN);
        // One past the protocol bound must bail — and so must the sizes a
        // bare `as u32` cast would have *silently truncated* (u32::MAX + 1
        // wraps to 0, desyncing the peer's length-prefixed reader).
        for n in [MAX_MSG_LEN as usize + 1, u32::MAX as usize, u32::MAX as usize + 1] {
            let err = checked_wire_len(n, "payload").unwrap_err().to_string();
            assert!(err.contains("protocol bound"), "n = {n}: {err}");
        }
        // write_msg routes every payload length through the same gate (the
        // check fires before any byte is written), so in-bounds writes are
        // untouched; the oversized branch is pinned above via the helper
        // rather than by materializing a > 256 MiB buffer in a unit test.
        let mut buf = Vec::new();
        write_msg(&mut buf, &[0u8; 1]).unwrap();
        assert_eq!(&buf[..4], &1u32.to_le_bytes());
    }

    #[test]
    fn cursor_reads_little_endian_and_bounds_checks() {
        let mut b = Vec::new();
        b.push(7u8);
        b.extend_from_slice(&0x0102u16.to_le_bytes());
        b.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        b.extend_from_slice(&1.5f32.to_le_bytes());
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        b.extend_from_slice(b"tail");
        let mut c = Cur::new(&b);
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u16().unwrap(), 0x0102);
        assert_eq!(c.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(c.f32().unwrap(), 1.5);
        assert_eq!(c.u64().unwrap(), u64::MAX);
        assert_eq!(c.rest(), b"tail");
        assert!(c.u8().is_err(), "exhausted cursor must not read");
    }

    #[test]
    fn handshake_rejects_bad_version_and_range() {
        // A HELLO speaking a future protocol version must be refused.
        let mut hello = Vec::new();
        hello.push(MSG_HELLO);
        hello.extend_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        hello.extend_from_slice(&0u32.to_le_bytes());
        let mut c = Cur::new(&hello);
        assert_eq!(c.u8().unwrap(), MSG_HELLO);
        assert_ne!(c.u16().unwrap(), PROTO_VERSION);
    }
}
