//! One logical client: its data shard, per-layer-group codec state and the
//! recycled frame arena — split out of `coordinator/mod.rs` so the round
//! pipeline (`coordinator/pipeline.rs`) and the coordinator construction
//! code share one definition.
//!
//! Everything here runs on the codec worker threads spawned by the round
//! pipeline: `Client::compress` is pure rust (no backend), writes into
//! arena-recycled buffers, and owns all per-client mutable state, so the
//! per-client fan-out needs no locks.

use crate::config::ExperimentConfig;
use crate::data::{gather_batch, BatchSampler, Dataset, MarkovCorpus};
use crate::quant::{CodecBuilder, FrameArena};
use crate::runtime::GroupRange;
use crate::util::Rng;

use super::network::Message;

pub(crate) use crate::quant::GroupCodec;

/// The task a client trains on.
pub enum TaskData {
    /// Image classification over a contiguous shard of the dataset.
    Vision {
        /// This client's shard.
        shard: Dataset,
    },
    /// Language modelling over a shared Markov corpus.
    Lm {
        /// Token source.
        corpus: MarkovCorpus,
        /// Context length per sample.
        seq_len: usize,
    },
}

/// One logical client.
pub struct Client {
    /// Client index in `0..N`.
    pub id: usize,
    pub(crate) data: TaskData,
    pub(crate) sampler: BatchSampler,
    pub(crate) codecs: Vec<GroupCodec>,
    /// Recycled frame buffers: survives across rounds, one arena per client
    /// so the codec worker threads never share a pool.
    pub(crate) arena: FrameArena,
    /// Fraction of the global data this client holds (aggregation weight).
    pub weight: f64,
}

impl Client {
    /// Produce this round's training batch as flat input buffers.
    pub(crate) fn next_batch(
        &mut self,
        train_batch: usize,
        seed: u64,
        round: u64,
    ) -> (Vec<f32>, Vec<f32>) {
        match &self.data {
            TaskData::Vision { shard } => {
                let idxs = self.sampler.next_batch(train_batch);
                gather_batch(shard, &idxs)
            }
            TaskData::Lm { corpus, seq_len } => {
                let mut rng = Rng::for_stream(seed, 0x70C5, self.id as u64, round);
                let mut toks = Vec::with_capacity(train_batch * (seq_len + 1));
                for _ in 0..train_batch {
                    toks.extend(corpus.sample(seq_len + 1, &mut rng));
                }
                (toks, Vec::new())
            }
        }
    }

    /// Compress a gradient per layer group into a message (runs on a worker
    /// thread; pure rust). Frame buffers come from this client's arena, so
    /// in steady state the encode path performs zero heap allocation.
    pub(crate) fn compress(
        &mut self,
        grads: &[f32],
        groups: &[GroupRange],
        round: usize,
        seed: u64,
        refit_now: bool,
        loss: f32,
    ) -> Message {
        let mut frames = Vec::with_capacity(groups.len());
        for (gi, g) in groups.iter().enumerate() {
            let slice = &grads[g.start..g.end];
            if refit_now {
                self.codecs[gi].refit(slice);
            }
            let mut rng = Rng::for_stream(seed, 0x9A7E, (self.id * 1031 + gi) as u64, round as u64);
            let mut buf = self.arena.take();
            self.codecs[gi].encode(slice, &mut rng, &mut buf);
            frames.push((gi, buf));
        }
        Message { client: self.id, round, frames, loss }
    }

    /// Recycle a consumed message's frame buffers back into the arena.
    pub(crate) fn recycle(&mut self, msg: Message) {
        for (_, frame) in msg.frames {
            self.arena.put(frame);
        }
    }

    /// Re-fold an undeliverable message into this client's error-feedback
    /// residuals so its gradient mass survives to the next round.
    pub(crate) fn restore_lost(&mut self, msg: &Message) {
        for (gi, frame) in &msg.frames {
            self.codecs[*gi].restore_lost(frame);
        }
    }

    /// Fresh frame-buffer allocations in this client's arena since
    /// construction (see [`FrameArena::fresh_allocs`]).
    pub fn frame_allocs(&self) -> u64 {
        self.arena.fresh_allocs()
    }

    /// Apply a [`RatePlan`](crate::quant::RatePlan) row: re-target each
    /// layer group's codec at the scheduled width (see
    /// [`Compressor::set_rate`](crate::quant::Compressor::set_rate) — the
    /// standing fit is reused, no refit). Extra entries are ignored,
    /// missing ones leave the codec unchanged.
    pub(crate) fn set_rates(&mut self, bits: &[u32]) {
        for (codec, &b) in self.codecs.iter_mut().zip(bits) {
            codec.set_rate(b);
        }
    }

    /// Park every EF residual as a quantized frame (arena-recycled buffers,
    /// dedicated RNG stream per group) — called on clients left outside the
    /// round cohort. No-op for plain codecs or already-parked state.
    pub(crate) fn park_residuals(&mut self, seed: u64, round: u64) {
        for (gi, codec) in self.codecs.iter_mut().enumerate() {
            if let GroupCodec::Ef(ef) = codec {
                if ef.is_parked() {
                    continue;
                }
                let mut rng =
                    Rng::for_stream(seed, 0x9A7F, (self.id * 1031 + gi) as u64, round);
                let buf = self.arena.take();
                if let Some(unused) = ef.park(&mut rng, buf) {
                    self.arena.put(unused);
                }
            }
        }
    }

    /// Restore any parked EF residuals to dense form — called on cohort
    /// members before they compute/encode. Frame buffers go back to the
    /// arena.
    pub(crate) fn unpark_residuals(&mut self) -> anyhow::Result<()> {
        for codec in &mut self.codecs {
            if let GroupCodec::Ef(ef) = codec {
                if let Some(frame) = ef.unpark()? {
                    self.arena.put(frame);
                }
            }
        }
        Ok(())
    }

    /// Snapshot the batch sampler's complete state (epoch order, cursor,
    /// reshuffle RNG) — serialized into worker STATE messages and
    /// coordinator checkpoints so a restored client draws the exact batch
    /// sequence an uninterrupted one would.
    pub fn sampler_state(&self) -> crate::data::SamplerState {
        self.sampler.export_state()
    }

    /// Restore a [`Self::sampler_state`] snapshot (rejoin/resume path).
    pub fn restore_sampler(&mut self, st: crate::data::SamplerState) {
        self.sampler.restore_state(st);
    }

    /// Export each layer group's EF residual as a dense vector (`None` for
    /// plain codecs). Lossless by design — this is the rejoin/checkpoint
    /// hand-off; the lossy [`Self::park_residuals`] path is only for
    /// dormant cohort members.
    pub fn export_residuals(&self) -> Vec<Option<Vec<f32>>> {
        self.codecs.iter().map(|c| c.ef().map(|ef| ef.residual().to_vec())).collect()
    }

    /// Restore residuals exported by [`Self::export_residuals`]. Entries
    /// match layer groups positionally; `None` and surplus entries leave
    /// the codec untouched.
    pub fn import_residuals(&mut self, residuals: &[Option<Vec<f32>>]) {
        for (codec, r) in self.codecs.iter_mut().zip(residuals) {
            if let (Some(ef), Some(r)) = (codec.ef_mut(), r) {
                ef.set_residual(r.clone());
            }
        }
    }

    /// Resident bytes of this client's mutable per-round state: codec
    /// state (EF residuals, dense or parked) plus pooled arena buffers —
    /// the per-client term of the `bytes_per_client` metric. Model
    /// parameters are shared server state and excluded.
    pub fn state_bytes(&self) -> usize {
        self.codecs.iter().map(GroupCodec::state_bytes).sum::<usize>()
            + self.arena.pooled_bytes()
    }

    /// One-line description of each layer group's codec state.
    pub fn describe_codecs(&self) -> Vec<String> {
        self.codecs.iter().map(|c| c.describe()).collect()
    }
}

/// One codec per layer group, EF-wrapped when the experiment asks for it.
pub(crate) fn make_codecs(cfg: &ExperimentConfig, groups: &[GroupRange]) -> Vec<GroupCodec> {
    CodecBuilder::from_quant(&cfg.quant).build_many(groups.len())
}
