//! Versioned binary checkpoint/resume for in-process training runs.
//!
//! A checkpoint is a **pure observer** of the coordinator: taking one never
//! mutates training state, and `checkpoint-at-k` followed by `resume` is
//! bit-identical — parameters and `replay_digest()` — to the uninterrupted
//! run (DETERMINISM.md invariant 7, pinned by
//! `rust/tests/transport_props.rs`). To make that hold, the snapshot
//! captures *every* piece of mutable training state:
//!
//! * server: parameter vector, optimizer velocity, completed-round counter,
//!   loss carry, two-tier aggregate byte counter, cumulative network byte
//!   totals;
//! * per client: the full batch-sampler state (epoch order, cursor,
//!   reshuffle RNG words) and each layer group's EF residual — dense
//!   residuals as lossless `Raw` wire frames, parked residuals as their
//!   quantized frame **verbatim** (re-parking would be a second lossy hop);
//! * scenario engine: the churn membership mask and the bounded-staleness
//!   late-frame queue;
//! * bit-budget scheduler: the `(round, α²)` observation table;
//! * the run log so far, field-exact (floats as raw bits), so the resumed
//!   run's digest covers the pre-checkpoint rounds unchanged.
//!
//! Codec *fit* state (tail-model parameters) is deliberately absent: the
//! invariant is scoped to `estimate_every == 1`, where every round refits
//! from that round's gradients before encoding, so the fit is re-derived —
//! [`resume`] warns when a config falls outside that scope.
//!
//! **Wire format** (version 1, all integers little-endian): magic `TQCP`,
//! version, config JSON, the state blocks above, and a CRC32 trailer over
//! everything before it — the same integrity check the transport's message
//! framing uses, so a truncated or bit-flipped checkpoint fails loudly
//! instead of resuming silently wrong. Files are written to `<path>.tmp`
//! and atomically renamed, so a crash mid-write never clobbers the last
//! good snapshot. Checkpointing is in-process only: a remote round's
//! client state lives in worker processes the server cannot observe.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::metrics::{RoundRecord, RunLog};
use crate::quant::wire;
use crate::runtime::Backend;
use crate::util::crc32;
use crate::util::json::Value;

use super::Coordinator;

/// File magic: "TQCP".
const MAGIC: &[u8; 4] = b"TQCP";
/// Current checkpoint format version.
const VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// Little-endian buffer writer/reader
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u32(buf, b.len() as u32);
    buf.extend_from_slice(b);
}

/// Bounds-checked reader over the checkpoint body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("checkpoint truncated at byte {}", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }
}

// ---------------------------------------------------------------------------
// Save
// ---------------------------------------------------------------------------

/// Serialize the coordinator's complete mutable training state plus the run
/// log so far, and atomically write it to `path`. Pure observer — the
/// coordinator is untouched. Fails on remote transports: worker-side client
/// state is not observable from the server.
pub fn save(coord: &Coordinator<'_>, log: &RunLog, path: &Path) -> Result<()> {
    if coord.net.name() != "sim" {
        bail!(
            "checkpointing is in-process only: client state lives in remote \
             worker processes on the '{}' transport",
            coord.net.name()
        );
    }
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, VERSION);
    put_bytes(&mut buf, coord.cfg.to_json().to_json().as_bytes());
    put_u64(&mut buf, coord.round as u64);
    put_f64(&mut buf, coord.last_train_loss);
    put_u64(&mut buf, coord.tier_bytes);
    put_u64(&mut buf, coord.net.total_bytes_up());
    put_u64(&mut buf, coord.net.total_retransmitted());

    let mut frame = Vec::new();
    wire::encode_raw_into(&coord.params, &mut frame);
    put_bytes(&mut buf, &frame);
    wire::encode_raw_into(coord.opt.velocity(), &mut frame);
    put_bytes(&mut buf, &frame);

    put_u32(&mut buf, coord.clients.len() as u32);
    for c in &coord.clients {
        let st = c.sampler_state();
        put_u32(&mut buf, st.order.len() as u32);
        for &i in &st.order {
            put_u32(&mut buf, i as u32);
        }
        put_u32(&mut buf, st.cursor as u32);
        for w in st.rng {
            put_u64(&mut buf, w);
        }
        match st.rng_spare {
            Some(s) => {
                buf.push(1);
                put_f64(&mut buf, s);
            }
            None => buf.push(0),
        }
        put_u32(&mut buf, c.codecs.len() as u32);
        for codec in &c.codecs {
            match codec.ef() {
                Some(ef) if ef.is_parked() => {
                    buf.push(2);
                    put_bytes(&mut buf, ef.parked_frame().expect("parked EF has a frame"));
                }
                Some(ef) if !ef.residual().is_empty() => {
                    buf.push(1);
                    wire::encode_raw_into(ef.residual(), &mut frame);
                    put_bytes(&mut buf, &frame);
                }
                _ => buf.push(0),
            }
        }
    }

    let (active, pending) = coord.scenario.export_state();
    put_u32(&mut buf, active.len() as u32);
    buf.extend(active.iter().map(|&a| a as u8));
    put_u32(&mut buf, pending.len() as u32);
    for (msg, staleness) in &pending {
        put_u32(&mut buf, *staleness);
        put_u32(&mut buf, msg.client as u32);
        put_u64(&mut buf, msg.round as u64);
        put_f32(&mut buf, msg.loss);
        put_u32(&mut buf, msg.frames.len() as u32);
        for (gi, f) in &msg.frames {
            put_u32(&mut buf, *gi as u32);
            put_bytes(&mut buf, f);
        }
    }

    match &coord.budget {
        Some(b) => {
            buf.push(1);
            let obs = b.export_obs();
            put_u32(&mut buf, obs.len() as u32);
            for row in &obs {
                put_u32(&mut buf, row.len() as u32);
                for slot in row {
                    match slot {
                        Some((round, v)) => {
                            buf.push(1);
                            put_u64(&mut buf, *round as u64);
                            put_f64(&mut buf, *v);
                        }
                        None => buf.push(0),
                    }
                }
            }
        }
        None => buf.push(0),
    }

    put_u32(&mut buf, log.records.len() as u32);
    for r in &log.records {
        put_record(&mut buf, r);
    }

    let crc = crc32::crc32(&buf);
    put_u32(&mut buf, crc);

    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &buf)
        .with_context(|| format!("writing checkpoint to {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming checkpoint into {}", path.display()))?;
    Ok(())
}

fn put_record(buf: &mut Vec<u8>, r: &RoundRecord) {
    put_u32(buf, r.round as u32);
    put_f64(buf, r.train_loss);
    put_u64(buf, r.bytes_up);
    for opt in [r.test_loss, r.test_accuracy] {
        match opt {
            Some(v) => {
                buf.push(1);
                put_f64(buf, v);
            }
            None => buf.push(0),
        }
    }
    for v in [r.secs, r.net_secs, r.compute_secs, r.encode_secs, r.agg_secs] {
        put_f64(buf, v);
    }
    put_u32(buf, r.dropped_clients as u32);
    put_u64(buf, r.retransmitted_bytes);
    put_u32(buf, r.rejoined_clients);
    put_u32(buf, r.corrupt_frames);
    put_u32(buf, r.staleness_hist.len() as u32);
    for &h in &r.staleness_hist {
        put_u32(buf, h);
    }
    put_u64(buf, r.bytes_per_client);
}

// ---------------------------------------------------------------------------
// Resume
// ---------------------------------------------------------------------------

/// Verify the CRC32 trailer, magic and version, and parse the embedded
/// config; returns a reader positioned at the first state block.
fn open_body(data: &[u8]) -> Result<(Reader<'_>, ExperimentConfig)> {
    if data.len() < MAGIC.len() + 8 {
        bail!("checkpoint too short ({} bytes)", data.len());
    }
    let (body, trailer) = data.split_at(data.len() - 4);
    let stored = u32::from_le_bytes(trailer.try_into().unwrap());
    let actual = crc32::crc32(body);
    if stored != actual {
        bail!("checkpoint CRC mismatch (stored {stored:08x}, computed {actual:08x})");
    }
    let mut r = Reader { buf: body, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("not a tqsgd checkpoint (bad magic)");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version} (this build reads {VERSION})");
    }
    let cfg_text = std::str::from_utf8(r.bytes()?).context("checkpoint config is not UTF-8")?;
    let cfg = ExperimentConfig::from_json(&Value::parse(cfg_text)?)?;
    Ok((r, cfg))
}

/// Read just the experiment config out of a checkpoint (after verifying its
/// CRC32 trailer, magic and version) — e.g. to select a compute backend
/// before calling [`resume`].
pub fn load_config(path: &Path) -> Result<ExperimentConfig> {
    let data = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    Ok(open_body(&data)?.1)
}

/// Load a checkpoint and rebuild a coordinator positioned to continue the
/// run: the returned records are the pre-checkpoint rounds, to be prepended
/// to the continued run's log. Verifies the CRC32 trailer, magic and
/// version before touching any field.
pub fn resume<'b>(
    path: &Path,
    backend: &'b dyn Backend,
) -> Result<(Coordinator<'b>, Vec<RoundRecord>)> {
    let data = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let (mut r, cfg) = open_body(&data)?;
    if cfg.quant.estimate_every != 1 {
        eprintln!(
            "warning: checkpoint config has estimate_every = {}; codec tail \
             fits are re-derived on resume, so bit-exact resume (invariant 7) \
             is only guaranteed at estimate_every = 1",
            cfg.quant.estimate_every
        );
    }
    let round = r.u64()? as usize;
    let last_train_loss = r.f64()?;
    let tier_bytes = r.u64()?;
    let bytes_up = r.u64()?;
    let retransmitted = r.u64()?;

    let mut coord = Coordinator::new(cfg, backend)?;
    wire::decode_dequantize_into(r.bytes()?, &mut coord.params)
        .context("checkpoint parameter frame")?;
    let mut velocity = Vec::new();
    wire::decode_dequantize_into(r.bytes()?, &mut velocity)
        .context("checkpoint velocity frame")?;
    coord.opt.set_velocity(velocity);
    coord.round = round;
    coord.last_train_loss = last_train_loss;
    coord.tier_bytes = tier_bytes;
    coord.net.restore_totals(bytes_up, retransmitted);

    let n = r.u32()? as usize;
    if n != coord.clients.len() {
        bail!("checkpoint has {n} clients, config builds {}", coord.clients.len());
    }
    let mut residual = Vec::new();
    for c in &mut coord.clients {
        let order_len = r.u32()? as usize;
        let mut order = Vec::with_capacity(order_len);
        for _ in 0..order_len {
            order.push(r.u32()? as usize);
        }
        let cursor = r.u32()? as usize;
        let mut rng = [0u64; 4];
        for w in &mut rng {
            *w = r.u64()?;
        }
        let rng_spare = match r.u8()? {
            0 => None,
            _ => Some(r.f64()?),
        };
        c.restore_sampler(crate::data::SamplerState { order, cursor, rng, rng_spare });
        let n_codecs = r.u32()? as usize;
        if n_codecs != c.codecs.len() {
            bail!(
                "checkpoint has {n_codecs} codecs for client {}, expected {}",
                c.id,
                c.codecs.len()
            );
        }
        for codec in &mut c.codecs {
            match r.u8()? {
                0 => {}
                1 => {
                    wire::decode_dequantize_into(r.bytes()?, &mut residual)
                        .context("checkpoint EF residual frame")?;
                    codec
                        .ef_mut()
                        .ok_or_else(|| anyhow!("checkpoint EF residual for a plain codec"))?
                        .set_residual(residual.clone());
                }
                2 => {
                    let frame = r.bytes()?.to_vec();
                    codec
                        .ef_mut()
                        .ok_or_else(|| anyhow!("checkpoint parked frame for a plain codec"))?
                        .set_parked_frame(frame);
                }
                k => bail!("unknown EF state tag {k}"),
            }
        }
    }

    let mask_len = r.u32()? as usize;
    let active: Vec<bool> = r.take(mask_len)?.iter().map(|&b| b != 0).collect();
    let pending_len = r.u32()? as usize;
    let mut pending = Vec::with_capacity(pending_len);
    for _ in 0..pending_len {
        let staleness = r.u32()?;
        let client = r.u32()? as usize;
        let msg_round = r.u64()? as usize;
        let loss = r.f32()?;
        let n_frames = r.u32()? as usize;
        let mut frames = Vec::with_capacity(n_frames);
        for _ in 0..n_frames {
            let gi = r.u32()? as usize;
            frames.push((gi, r.bytes()?.to_vec()));
        }
        pending.push((super::Message { client, round: msg_round, frames, loss }, staleness));
    }
    coord.scenario.restore_state(active, pending);

    if r.u8()? == 1 {
        let rows = r.u32()? as usize;
        let mut obs = Vec::with_capacity(rows);
        for _ in 0..rows {
            let slots = r.u32()? as usize;
            let mut row = Vec::with_capacity(slots);
            for _ in 0..slots {
                row.push(match r.u8()? {
                    0 => None,
                    _ => Some((r.u64()? as usize, r.f64()?)),
                });
            }
            obs.push(row);
        }
        coord
            .budget
            .as_mut()
            .ok_or_else(|| anyhow!("checkpoint has budget observations but the scheduler is off"))?
            .import_obs(obs);
    }

    let n_records = r.u32()? as usize;
    let mut records = Vec::with_capacity(n_records);
    for _ in 0..n_records {
        records.push(read_record(&mut r)?);
    }
    if r.pos != r.buf.len() {
        bail!("{} trailing bytes after checkpoint body", r.buf.len() - r.pos);
    }
    Ok((coord, records))
}

fn read_record(r: &mut Reader<'_>) -> Result<RoundRecord> {
    let round = r.u32()? as usize;
    let train_loss = r.f64()?;
    let bytes_up = r.u64()?;
    let mut opts = [None, None];
    for o in &mut opts {
        *o = match r.u8()? {
            0 => None,
            _ => Some(r.f64()?),
        };
    }
    let [test_loss, test_accuracy] = opts;
    let secs = r.f64()?;
    let net_secs = r.f64()?;
    let compute_secs = r.f64()?;
    let encode_secs = r.f64()?;
    let agg_secs = r.f64()?;
    let dropped_clients = r.u32()? as usize;
    let retransmitted_bytes = r.u64()?;
    let rejoined_clients = r.u32()?;
    let corrupt_frames = r.u32()?;
    let hist_len = r.u32()? as usize;
    let mut staleness_hist = Vec::with_capacity(hist_len);
    for _ in 0..hist_len {
        staleness_hist.push(r.u32()?);
    }
    let bytes_per_client = r.u64()?;
    Ok(RoundRecord {
        round,
        train_loss,
        bytes_up,
        test_loss,
        test_accuracy,
        secs,
        net_secs,
        compute_secs,
        encode_secs,
        agg_secs,
        dropped_clients,
        retransmitted_bytes,
        rejoined_clients,
        corrupt_frames,
        staleness_hist,
        bytes_per_client,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeBackend;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            clients: 2,
            rounds: 4,
            train_size: 64,
            test_size: 32,
            quant: crate::config::QuantConfig {
                estimate_every: 1,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn checkpoint_roundtrips_bit_exactly() {
        let backend = NativeBackend::new();
        let dir = std::env::temp_dir().join(format!("tqcp-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ckpt");

        let cfg = tiny_cfg();
        let mut coord = Coordinator::new(cfg.clone(), &backend).unwrap();
        let mut log = RunLog { config_id: cfg.id(), ..Default::default() };
        for _ in 0..2 {
            log.push(coord.step().unwrap());
        }
        save(&coord, &log, &path).unwrap();

        let (mut resumed, records) = resume(&path, &backend).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(resumed.round, 2);
        assert_eq!(resumed.params, coord.params, "parameters must restore bit-exactly");
        assert_eq!(resumed.opt.velocity(), coord.opt.velocity());

        // Continue both and compare digests: invariant 7 in miniature.
        let mut log_b = RunLog { config_id: cfg.id(), ..Default::default() };
        log_b.records = records;
        for _ in 0..2 {
            let a = coord.step().unwrap();
            let b = resumed.step().unwrap();
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.bytes_up, b.bytes_up);
            log.push(a);
            log_b.push(b);
        }
        assert_eq!(log.replay_digest(), log_b.replay_digest());
        assert_eq!(coord.params, resumed.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_checkpoint_is_rejected() {
        let backend = NativeBackend::new();
        let dir = std::env::temp_dir().join(format!("tqcp-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.ckpt");

        let cfg = tiny_cfg();
        let mut coord = Coordinator::new(cfg.clone(), &backend).unwrap();
        let mut log = RunLog { config_id: cfg.id(), ..Default::default() };
        log.push(coord.step().unwrap());
        save(&coord, &log, &path).unwrap();

        let mut data = std::fs::read(&path).unwrap();
        let mid = data.len() / 2;
        data[mid] ^= 0xFF;
        std::fs::write(&path, &data).unwrap();
        let err = resume(&path, &backend).unwrap_err().to_string();
        assert!(err.contains("CRC"), "flipped byte must fail the CRC check: {err}");

        // Truncation must fail loudly too.
        std::fs::write(&path, &data[..8]).unwrap();
        assert!(resume(&path, &backend).is_err());
        std::fs::remove_file(&path).ok();
    }
}
