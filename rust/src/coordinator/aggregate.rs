//! Server-side aggregation: the weighted-apply hot path of the round
//! pipeline (decode → dequantize → weighted accumulate), parallel and
//! single-pass.
//!
//! The aggregate buffer is **sharded by layer-group ranges**: every model's
//! groups tile the flat parameter vector ([`ModelSpec::validate`] enforces
//! it), so each shard can own a disjoint `&mut` slice of the buffer and the
//! fan-out needs no locks, no atomics and no unsafe — just
//! [`std::thread::scope`], mirroring the client-side codec fan-out.
//!
//! Two kinds of contribution flow through the same machinery
//! ([`ContributionData`]):
//!
//! * **Frames** — wire frames decoded at apply time through the fused
//!   kernel ([`wire::decode_dequantize_accumulate_into`]), the barrier
//!   pipeline's path (and the streaming pipeline's path for late/stale
//!   frames);
//! * **Dense** — a full-dimension, already-decoded contribution buffer
//!   (`d_i`, decoded at weight 1.0 while other clients were still
//!   encoding), the streaming pipeline's path for fresh frames. The apply
//!   is then `agg[e] += w * d_i[e]` per owned group slice.
//!
//! **Determinism argument.** Floating-point addition is not associative, so
//! "parallel" usually means "different bits". Here it does not:
//!
//! 1. every aggregate element belongs to exactly one layer group, and every
//!    group is owned by exactly one shard — no element is written by two
//!    threads;
//! 2. within its groups, a shard walks the applied contributions in the
//!    **fixed apply order** (origin round, then client id — the order
//!    `ScenarioEngine::schedule` already sorts by), so each element receives
//!    its `+= w_i * d_i` contributions in exactly the serial sequence;
//! 3. the fused kernel ([`wire::decode_dequantize_accumulate_into`])
//!    performs per element exactly the f32 operations of the old two-pass
//!    path (dequantize, one `w * d` product, one add) — and a Dense
//!    contribution holds exactly the dequantized `d` values (decoding at
//!    weight 1.0 is exact: `1.0 * d == d`), so its `+= w * d` apply issues
//!    the same product and add.
//!
//! Hence [`accumulate_sharded`] is bit-identical to [`accumulate_serial`]
//! for EVERY shard count — property-tested across schemes × bits × shard
//! counts in `rust/tests/quant_props.rs`, and across barrier vs streaming
//! pipelines in `rust/tests/pipeline_props.rs` — and the shard count is a
//! pure performance knob (config `agg_shards`, 0 = one per available core).
//!
//! [`ModelSpec::validate`]: crate::runtime::ModelSpec::validate

use std::cmp::Reverse;

use anyhow::{anyhow, bail, Result};

use crate::config::QuantConfig;
use crate::quant::{wire, CodecBuilder};
use crate::runtime::GroupRange;
use crate::util::Rng;

/// RNG stream role for the mid-tier partial-sum re-encode draws: dedicated
/// so tier quantization composes with every other seeded stream (client
/// compress, scenario, parking) without shifting their draws.
const ROLE_TIER: u64 = 0x7E1A;

/// Where one applied contribution's per-element values come from.
pub enum ContributionData<'a> {
    /// Wire frames, decoded through the fused kernel at apply time.
    Frames(&'a [(usize, Vec<u8>)]),
    /// A dense, already-decoded contribution spanning the FULL parameter
    /// vector (the streaming pipeline's per-client buffer); the accumulate
    /// reads the owned group slices out of it.
    Dense(&'a [f32]),
}

/// One applied contribution in the fixed apply order, with its normalized
/// aggregation weight.
pub struct WeightedContribution<'a> {
    /// The contribution's element source.
    pub data: ContributionData<'a>,
    /// Normalized weight applied to every element.
    pub w: f32,
}

/// Deterministically assign layer groups to `shards` workers, balancing by
/// element count (longest-processing-time greedy: biggest group first onto
/// the least-loaded shard, ties by lowest index). Returns one ascending
/// group-index list per shard; trailing shards are empty when there are
/// fewer groups than shards. The plan depends only on `(groups, shards)`,
/// never on the frames, so a run's shard layout is reproducible.
pub fn plan_shards(groups: &[GroupRange], shards: usize) -> Vec<Vec<usize>> {
    let shards = shards.max(1);
    let mut order: Vec<usize> = (0..groups.len()).collect();
    order.sort_by_key(|&gi| (Reverse(groups[gi].end - groups[gi].start), gi));
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); shards];
    let mut load = vec![0usize; shards];
    for gi in order {
        let s = (0..shards).min_by_key(|&s| (load[s], s)).expect("shards >= 1");
        load[s] += groups[gi].end - groups[gi].start;
        plan[s].push(gi);
    }
    for p in &mut plan {
        p.sort_unstable();
    }
    plan
}

/// Accumulate one contribution's values for group `gi` into the group's
/// aggregate slice: the fused decode-accumulate walk for frames, a
/// `+= w * d` pass over the group slice for dense contributions. Both issue
/// per element exactly one `w * d` product and one add, in element order —
/// the bit-identity contract the pipelines rely on.
fn accumulate_group(
    item: &WeightedContribution<'_>,
    gi: usize,
    g: &GroupRange,
    acc: &mut [f32],
) -> Result<()> {
    match &item.data {
        ContributionData::Frames(frames) => {
            for (fgi, frame) in *frames {
                if *fgi == gi {
                    wire::decode_dequantize_accumulate_into(frame, item.w, acc)?;
                }
            }
        }
        ContributionData::Dense(d) => {
            for (a, &v) in acc.iter_mut().zip(&d[g.start..g.end]) {
                *a += item.w * v;
            }
        }
    }
    Ok(())
}

/// Zero `agg` and accumulate every contribution into it on the calling
/// thread — groups outer, contributions inner in the fixed apply order.
/// This is the single-shard reference the sharded path must reproduce
/// bit-for-bit; per element the contribution sequence equals the historical
/// uplinks-outer loop, since each element sees only its own group's
/// contributions, in apply order either way.
pub fn accumulate_serial(
    groups: &[GroupRange],
    items: &[WeightedContribution<'_>],
    agg: &mut [f32],
) -> Result<()> {
    check_items(groups, items, agg.len())?;
    agg.fill(0.0);
    for (gi, g) in groups.iter().enumerate() {
        if g.end > agg.len() || g.start > g.end {
            bail!("group {gi} range {}..{} outside aggregate buffer", g.start, g.end);
        }
        for item in items {
            accumulate_group(item, gi, g, &mut agg[g.start..g.end])?;
        }
    }
    Ok(())
}

/// Reject malformed input up front so serial and sharded paths fail alike:
/// a frame tagged with a group no shard owns would otherwise be silently
/// skipped (no `*fgi == gi` match ever fires), and a dense contribution
/// must span the whole aggregate buffer.
fn check_items(
    groups: &[GroupRange],
    items: &[WeightedContribution<'_>],
    total: usize,
) -> Result<()> {
    for item in items {
        match &item.data {
            ContributionData::Frames(frames) => {
                for (gi, _) in *frames {
                    if *gi >= groups.len() {
                        bail!("frame references unknown group {gi}");
                    }
                }
            }
            ContributionData::Dense(d) => {
                if d.len() != total {
                    bail!(
                        "dense contribution length {} != aggregate buffer {total}",
                        d.len()
                    );
                }
            }
        }
    }
    Ok(())
}

/// Sharded accumulate: split `agg` into per-group slices, assign groups to
/// at most `shards` workers ([`plan_shards`]) and fan the per-shard work
/// over [`std::thread::scope`]. Bit-identical to [`accumulate_serial`] for
/// every shard count (see the module docs for the argument); `shards <= 1`
/// short-circuits to the serial path with no thread spawn.
///
/// `groups` must be ascending and non-overlapping (the coordinator's always
/// tile the parameter vector); a frame for a group the apply order never
/// references is simply never decoded, and a frame whose length disagrees
/// with its group range fails the round exactly like the serial path.
pub fn accumulate_sharded(
    groups: &[GroupRange],
    items: &[WeightedContribution<'_>],
    agg: &mut [f32],
    shards: usize,
) -> Result<()> {
    let shards = shards.clamp(1, groups.len().max(1));
    if shards <= 1 {
        return accumulate_serial(groups, items, agg);
    }
    check_items(groups, items, agg.len())?;
    // Zero everything up front (gaps between groups — none in practice —
    // stay zero, exactly like the serial path), then carve the buffer into
    // disjoint per-group &mut slices.
    agg.fill(0.0);
    let total = agg.len();
    let mut rest: &mut [f32] = agg;
    let mut pos = 0usize;
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(groups.len());
    for (gi, g) in groups.iter().enumerate() {
        if g.start < pos || g.end < g.start || g.end > total {
            bail!(
                "group {gi} range {}..{} is not ascending/disjoint within {total}",
                g.start,
                g.end
            );
        }
        let (_gap, tail) = rest.split_at_mut(g.start - pos);
        let (mine, tail) = tail.split_at_mut(g.end - g.start);
        slices.push(mine);
        rest = tail;
        pos = g.end;
    }

    let plan = plan_shards(groups, shards);
    let mut owner = vec![0usize; groups.len()];
    for (si, p) in plan.iter().enumerate() {
        for &gi in p {
            owner[gi] = si;
        }
    }
    let mut shard_work: Vec<Vec<(usize, &mut [f32])>> =
        plan.iter().map(|p| Vec::with_capacity(p.len())).collect();
    for (gi, slice) in slices.into_iter().enumerate() {
        shard_work[owner[gi]].push((gi, slice));
    }

    let results: Vec<Result<()>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shard_work.len());
        for work in shard_work {
            if work.is_empty() {
                continue;
            }
            handles.push(scope.spawn(move || -> Result<()> {
                for (gi, acc) in work {
                    // Fixed apply order per group: the serial contribution
                    // sequence for every element this shard owns.
                    let g = &groups[gi];
                    for item in items {
                        accumulate_group(item, gi, g, &mut acc[..])?;
                    }
                }
                Ok(())
            }));
        }
        handles.into_iter().map(|h| h.join().expect("aggregation shard thread")).collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

/// Two-tier aggregator tree: the million-client round's server side.
///
/// The contributions are split into `ceil(sqrt(n))` contiguous chunks of
/// the fixed apply order. Each mid-tier node runs the existing fused
/// decode-accumulate shards ([`accumulate_sharded`]) over its chunk, then
/// **re-encodes the partial sum uplink** through the configured
/// [`Compressor`](crate::quant::Compressor) — one wire frame per layer
/// group, refit onto the partial sum's own scale, compressed with a
/// dedicated seeded stream per `(node, group, round)`. The top tier fuses
/// those partial-sum frames into `agg` at weight 1.0.
///
/// **Unbiasedness.** The input weights are already normalized over the
/// full apply set, so the exact chunk partials sum to the flat aggregate.
/// With an unbiased quantizer (stochastic rounding — QSGD and the paper's
/// truncated family inside the truncation range), `E[Q(p_j)] = p_j`, and
/// the tiers being independent draws gives `E[Σ_j Q(p_j)] = Σ_j p_j`: the
/// expected aggregate is the flat one, with per-element variance the sum
/// of the per-node quantizer variances — the claim pinned by the property
/// suite (`rust/tests/cohort_props.rs`). The tree changes the aggregate's
/// exact bits (re-quantization + a different f32 association), which is
/// why `agg_tiers = 2` is an explicit opt-in, not a default.
///
/// Returns the total re-encoded partial-sum bytes (the tree's interior
/// uplink traffic). These are *not* folded into the round's `bytes_up` —
/// that column is client uplink traffic, and the digest pins it.
pub fn accumulate_two_tier(
    groups: &[GroupRange],
    items: &[WeightedContribution<'_>],
    agg: &mut [f32],
    shards: usize,
    quant: &QuantConfig,
    seed: u64,
    round: u64,
) -> Result<u64> {
    let n = items.len();
    let nodes = (n as f64).sqrt().ceil() as usize;
    if n <= 1 || nodes <= 1 {
        // A single mid-tier node would re-quantize the whole aggregate for
        // no fan-in reduction; degrade to the flat path.
        accumulate_sharded(groups, items, agg, shards)?;
        return Ok(0);
    }
    check_items(groups, items, agg.len())?;
    agg.fill(0.0);
    let mut partial = vec![0.0f32; agg.len()];
    let mut frame: Vec<u8> = Vec::new();
    let mut tier_bytes = 0u64;
    // Mid-tier codecs come from the same builder as the client fleet's, but
    // always bare: partial sums are transient, so error feedback across
    // rounds would be meaningless here.
    let builder = CodecBuilder::from_quant(quant).error_feedback(false);
    // Contiguous chunks of the apply order, sizes as equal as possible
    // (the first `n % nodes` chunks take one extra item) — a deterministic
    // partition, so the tree is replayable like everything else.
    let (base, extra) = (n / nodes, n % nodes);
    let mut start = 0usize;
    for node in 0..nodes {
        let len = base + usize::from(node < extra);
        let chunk = &items[start..start + len];
        start += len;
        if chunk.is_empty() {
            continue;
        }
        accumulate_sharded(groups, chunk, &mut partial, shards)?;
        for (gi, g) in groups.iter().enumerate() {
            let slice = &partial[g.start..g.end];
            let mut codec = builder.build_plain();
            codec.refit(slice);
            let mut rng =
                Rng::for_stream(seed, ROLE_TIER, (node * 1031 + gi) as u64, round);
            frame.clear();
            codec.compress_into(slice, &mut rng, &mut frame);
            tier_bytes += frame.len() as u64;
            wire::decode_dequantize_accumulate_into(&frame, 1.0, &mut agg[g.start..g.end])?;
        }
    }
    Ok(tier_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn groups_of(sizes: &[usize]) -> Vec<GroupRange> {
        let mut start = 0usize;
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                let g = GroupRange { group: format!("g{i}"), start, end: start + n };
                start += n;
                g
            })
            .collect()
    }

    #[test]
    fn plan_is_deterministic_balanced_and_complete() {
        let groups = groups_of(&[100, 700, 300, 200, 50]);
        for shards in [1usize, 2, 3, 7] {
            let plan = plan_shards(&groups, shards);
            assert_eq!(plan.len(), shards);
            assert_eq!(plan, plan_shards(&groups, shards), "plan must be deterministic");
            let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "every group exactly once");
        }
        // LPT: with 2 shards the 700 group sits alone against 100+300+200+50.
        let plan = plan_shards(&groups, 2);
        let load = |p: &[usize]| -> usize {
            p.iter().map(|&gi| groups[gi].end - groups[gi].start).sum()
        };
        let (a, b) = (load(&plan[0]), load(&plan[1]));
        assert_eq!(a.max(b), 700, "{plan:?}");
    }

    #[test]
    fn serial_aggregate_matches_two_pass_reference() {
        use crate::quant::wire::Payload;
        let groups = groups_of(&[40, 25]);
        let mut rng = crate::util::Rng::new(9);
        let mk = |rng: &mut crate::util::Rng, d: usize| -> Vec<u8> {
            Payload::Raw((0..d).map(|_| rng.f32() - 0.5).collect()).encode(0)
        };
        let frames_a = vec![(0usize, mk(&mut rng, 40)), (1usize, mk(&mut rng, 25))];
        let frames_b = vec![(0usize, mk(&mut rng, 40)), (1usize, mk(&mut rng, 25))];
        let ups = [(&frames_a, 0.75f32), (&frames_b, 0.25f32)];
        // Reference: the old scratch-buffer loop, uplinks outer.
        let mut want = vec![0.0f32; 65];
        let mut scratch = Vec::new();
        for (frames, w) in &ups {
            for (gi, frame) in frames.iter() {
                let g = &groups[*gi];
                wire::decode_dequantize_into(frame, &mut scratch).unwrap();
                for (a, &d) in want[g.start..g.end].iter_mut().zip(&scratch) {
                    *a += w * d;
                }
            }
        }
        let items: Vec<WeightedContribution<'_>> = ups
            .iter()
            .map(|(f, w)| WeightedContribution {
                data: ContributionData::Frames(f.as_slice()),
                w: *w,
            })
            .collect();
        let mut got = vec![7.0f32; 65]; // dirty: aggregate must zero first
        accumulate_serial(&groups, &items, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn dense_contributions_match_frames_bitwise() {
        use crate::quant::wire::Payload;
        let groups = groups_of(&[33, 47]);
        let mut rng = crate::util::Rng::new(11);
        let d_total = 80usize;
        // Two clients' dense gradients + their raw wire frames.
        let dense: Vec<Vec<f32>> = (0..2)
            .map(|_| (0..d_total).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let frames: Vec<Vec<(usize, Vec<u8>)>> = dense
            .iter()
            .map(|d| {
                groups
                    .iter()
                    .enumerate()
                    .map(|(gi, g)| (gi, Payload::Raw(d[g.start..g.end].to_vec()).encode(0)))
                    .collect()
            })
            .collect();
        let ws = [0.75f32, 0.25f32];
        let frame_items: Vec<WeightedContribution<'_>> = frames
            .iter()
            .zip(ws)
            .map(|(f, w)| WeightedContribution { data: ContributionData::Frames(f), w })
            .collect();
        let dense_items: Vec<WeightedContribution<'_>> = dense
            .iter()
            .zip(ws)
            .map(|(d, w)| WeightedContribution { data: ContributionData::Dense(d), w })
            .collect();
        // Mixed: first client by frames, second dense — the streaming
        // pipeline's stale + fresh mix.
        let mixed_items = vec![
            WeightedContribution { data: ContributionData::Frames(&frames[0]), w: ws[0] },
            WeightedContribution { data: ContributionData::Dense(&dense[1]), w: ws[1] },
        ];
        let mut want = vec![0.0f32; d_total];
        accumulate_serial(&groups, &frame_items, &mut want).unwrap();
        for items in [&dense_items, &mixed_items] {
            let mut got = vec![3.0f32; d_total]; // dirty on purpose
            accumulate_serial(&groups, items, &mut got).unwrap();
            assert_eq!(got, want, "serial dense/mixed must match frames bitwise");
            for shards in [2usize, 7] {
                let mut got = vec![-1.0f32; d_total];
                accumulate_sharded(&groups, items, &mut got, shards).unwrap();
                assert_eq!(got, want, "{shards}-shard dense/mixed must match bitwise");
            }
        }
    }

    #[test]
    fn two_tier_with_lossless_codec_matches_flat_within_rounding() {
        use crate::config::{QuantConfig, Scheme};
        let groups = groups_of(&[50, 30]);
        let mut rng = crate::util::Rng::new(21);
        let dense: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..80).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let items: Vec<WeightedContribution<'_>> = dense
            .iter()
            .map(|d| WeightedContribution { data: ContributionData::Dense(d), w: 1.0 / 9.0 })
            .collect();
        let mut flat = vec![0.0f32; 80];
        accumulate_serial(&groups, &items, &mut flat).unwrap();
        // DSGD mid-tier frames are raw f32 (lossless), so the only tree
        // effect left is the f32 association of the top-tier adds.
        let q = QuantConfig { scheme: Scheme::Dsgd, ..Default::default() };
        let mut tiered = vec![0.0f32; 80];
        let bytes = accumulate_two_tier(&groups, &items, &mut tiered, 1, &q, 7, 0).unwrap();
        assert!(bytes > 0, "9 items → 3 mid-tier nodes → interior frames");
        for (i, (&a, &b)) in tiered.iter().zip(&flat).enumerate() {
            assert!((a - b).abs() <= 1e-5, "elem {i}: tiered {a} vs flat {b}");
        }
        // A single contribution degrades to the flat path: no tree, 0 bytes.
        let one = &items[..1];
        let mut t1 = vec![1.0f32; 80];
        let mut f1 = vec![0.0f32; 80];
        assert_eq!(accumulate_two_tier(&groups, one, &mut t1, 1, &q, 7, 0).unwrap(), 0);
        accumulate_serial(&groups, one, &mut f1).unwrap();
        assert_eq!(t1, f1, "degenerate tree must be the flat path bit-for-bit");
    }

    #[test]
    fn two_tier_draws_are_seeded_per_round() {
        use crate::config::{QuantConfig, Scheme};
        let groups = groups_of(&[64]);
        let mut rng = crate::util::Rng::new(3);
        let dense: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..64).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let items: Vec<WeightedContribution<'_>> = dense
            .iter()
            .map(|d| WeightedContribution { data: ContributionData::Dense(d), w: 0.25 })
            .collect();
        let q = QuantConfig { scheme: Scheme::Qsgd, bits: 3, ..Default::default() };
        let run = |round: u64| -> Vec<f32> {
            let mut agg = vec![0.0f32; 64];
            accumulate_two_tier(&groups, &items, &mut agg, 1, &q, 42, round).unwrap();
            agg
        };
        let a = run(0);
        assert_eq!(a, run(0), "same (seed, round) → bit-identical tree output");
        assert_ne!(a, run(1), "rounds use independent quantization draws");
    }

    #[test]
    fn dense_length_mismatch_is_rejected_on_both_paths() {
        let groups = groups_of(&[30, 30]);
        let short = vec![0.0f32; 10];
        let items =
            vec![WeightedContribution { data: ContributionData::Dense(&short), w: 1.0 }];
        let mut agg = vec![0.0f32; 60];
        assert!(accumulate_serial(&groups, &items, &mut agg).is_err());
        assert!(accumulate_sharded(&groups, &items, &mut agg, 2).is_err());
    }

    #[test]
    fn sharded_rejects_overlapping_groups_and_bad_frames() {
        let mut groups = groups_of(&[30, 30]);
        groups[1].start = 20; // overlap
        let frames = vec![(0usize, crate::quant::wire::Payload::Raw(vec![0.0; 30]).encode(0))];
        let items =
            vec![WeightedContribution { data: ContributionData::Frames(&frames), w: 1.0 }];
        let mut agg = vec![0.0f32; 60];
        assert!(accumulate_sharded(&groups, &items, &mut agg, 2).is_err());
        // Frame length != group size errors through the shard threads too.
        let groups = groups_of(&[30, 30]);
        let short = vec![(0usize, crate::quant::wire::Payload::Raw(vec![0.0; 10]).encode(0))];
        let items =
            vec![WeightedContribution { data: ContributionData::Frames(&short), w: 1.0 }];
        assert!(accumulate_sharded(&groups, &items, &mut agg, 2).is_err());
        assert!(accumulate_serial(&groups, &items, &mut agg).is_err());
        // A frame referencing a group that does not exist must fail on BOTH
        // paths — never be silently skipped by the shard match.
        let orphan = vec![(5usize, crate::quant::wire::Payload::Raw(vec![0.0; 30]).encode(0))];
        let items =
            vec![WeightedContribution { data: ContributionData::Frames(&orphan), w: 1.0 }];
        assert!(accumulate_sharded(&groups, &items, &mut agg, 2).is_err());
        assert!(accumulate_serial(&groups, &items, &mut agg).is_err());
    }
}
