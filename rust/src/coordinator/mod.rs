//! The distributed DSGD coordinator — Algorithm 1 of the paper as a system.
//!
//! One process hosts the server and N logical clients:
//!
//! ```text
//! round t:
//!   server  --θ_t-->  clients                     (broadcast)
//!   client i: g_i = grad(θ_t, batch_i)            (backend, main thread)
//!             ĝ_i = Q_λs[T_α(g_i)] per layer group (rust codecs, N threads)
//!   clients --frames-->  server                   (simulated network, real bytes)
//!   server: ḡ = Σ w_i dequantize(frame_i);  θ_{t+1} = θ_t − η·step(ḡ)
//! ```
//!
//! The round itself is executed by the [`pipeline`] engine as six typed
//! stages — `Compute → Encode → Uplink → Schedule → Accumulate → Apply` —
//! in one of two modes ([`PipelineMode`], config field `pipeline`):
//! the strict-barrier reference loop, or the streaming pipeline that
//! overlaps client encode with server decode via per-client frame hand-off
//! (bit-identical to the barrier path; see the [`pipeline`] docs for the
//! argument).
//!
//! Compute (model fwd/bwd) goes through the pluggable [`Backend`] — pure
//! Rust by default, PJRT behind the `pjrt` feature. Backends may be
//! single-threaded (PJRT's client is `Rc`-based and not `Send`), so gradient
//! execution stays on the driver thread; the embarrassingly parallel codec
//! work fans out over `std::thread::scope`. Each (client, layer-group) pair
//! owns an independent quantizer state whose tail model is re-fitted every
//! `estimate_every` rounds — exactly the paper's per-layer γ estimation (§V).
//!
//! The server side mirrors the client fan-out: the weighted accumulate runs
//! through [`aggregate`], which shards the aggregate buffer by layer-group
//! ranges across `std::thread::scope` workers and folds the `w * d`
//! accumulate directly into the bitstream walk (fused decode-accumulate
//! kernels, no dense scratch pass). The sharded result is bit-identical to
//! the serial path at every shard count — see the [`aggregate`] module docs
//! for the determinism argument.
//!
//! Degraded-mode rounds (stragglers, lossy uplinks, churn, bounded
//! staleness, non-IID shards) are injected by the [`scenario`] engine from
//! the experiment's `ScenarioConfig`; the clean preset reproduces the
//! synchronous loop above bit-for-bit.
//!
//! The client↔server byte exchange sits behind the [`Transport`] trait
//! ([`network`]): the default [`SimNet`] keeps the N clients in-process as
//! above, while [`transport`] runs them as real worker processes over TCP
//! (`Coordinator::run_remote`, CLI `tqsgd serve | worker | launch`) — with
//! bit-identical `replay_digest()`s on clean scenarios, and real
//! connection faults (killed workers, dead sockets) folding into the same
//! drop/reweight path the scenario engine exercises in-process.

pub mod aggregate;
pub mod checkpoint;
pub mod client;
pub mod network;
pub mod pipeline;
pub mod scenario;
pub mod transport;

pub use client::{Client, TaskData};
pub use network::{
    LinkCondition, Message, RemoteUplink, SimNet, Transport, UplinkOutcome, UplinkReport,
};
pub use pipeline::PipelineMode;
pub use scenario::ScenarioEngine;
pub use transport::{
    run_worker, teardown_workers, ReadError, TcpOptions, TcpServer, TcpTransport, WorkerExit,
    WorkerOptions,
};

use anyhow::{anyhow, Result};

use client::make_codecs;

use crate::config::ExperimentConfig;
use crate::data::{gather_batch, BatchSampler, Dataset, MarkovCorpus};
use crate::metrics::{RoundRecord, RunLog};
use crate::optim::MomentumSgd;
use crate::quant::{BitBudget, FrameArena};
use crate::runtime::{Backend, GroupRange, ModelSpec};
use crate::util::Rng;

/// Server + clients + network for one experiment.
pub struct Coordinator<'b> {
    /// The experiment description this coordinator runs.
    pub cfg: ExperimentConfig,
    pub(crate) backend: &'b dyn Backend,
    pub(crate) spec: ModelSpec,
    /// The logical clients.
    pub clients: Vec<Client>,
    /// The global flat parameter vector (server copy).
    pub params: Vec<f32>,
    pub(crate) opt: MomentumSgd,
    /// The transport the round's bytes move through: the in-process
    /// [`SimNet`] simulation by default, or a remote transport (TCP worker
    /// processes) injected via [`Coordinator::with_transport`]. Either way
    /// it accounts real wire bytes under the SimNet latency model.
    pub net: Box<dyn Transport>,
    /// Scenario engine: per-round churn/straggler/loss/staleness decisions.
    pub scenario: ScenarioEngine,
    pub(crate) groups: Vec<GroupRange>,
    test: Option<Dataset>,
    lm_eval_corpus: Option<MarkovCorpus>,
    /// Number of completed communication rounds.
    pub round: usize,
    /// Scratch: aggregated gradient buffer.
    pub(crate) agg: Vec<f32>,
    /// Server aggregation fan-out width (resolved from config at build:
    /// explicit `agg_shards`, or one per available core, capped by the
    /// number of layer groups). A pure performance knob — the sharded
    /// aggregation is bit-identical at every width.
    pub(crate) agg_shards: usize,
    /// Client-side encode pool width for the barrier pipeline (resolved
    /// from config at build: explicit `encode_threads`, or one per
    /// available core, capped by the client count). The compression-side
    /// mirror of `agg_shards` — a pure performance knob, bit-identical at
    /// every width because per-client codec state is disjoint.
    pub(crate) encode_threads: usize,
    /// Scratch: per-round staleness histogram, built in place each round so
    /// the working buffer never regrows in steady state. The round record
    /// still receives one sized-to-fit copy (it owns its data for the run
    /// log) — the invariant is about the scratch, not the record.
    pub(crate) staleness_scratch: Vec<u32>,
    /// Debug counter: times `staleness_scratch` had to grow. Must go flat
    /// after warm-up (asserted next to the frame-alloc invariant).
    pub(crate) hist_reallocs: u64,
    /// Scratch: per-client dense contribution buffers for the streaming
    /// pipeline (decoded during the encode overlap, read by the weighted
    /// apply). Empty until the first streaming round, then one full-dim
    /// buffer per client, reused forever.
    pub(crate) contrib: Vec<Vec<f32>>,
    /// Debug counter: times a contribution buffer had to grow its capacity.
    /// Flat after the first streaming round (asserted with the invariants
    /// above).
    pub(crate) contrib_reallocs: u64,
    /// Last round's mean training loss — the defensive carry for a round
    /// that computes no losses at all, so the loss column can never turn
    /// `0/0` NaN (unreachable today: churn always revives one client).
    pub(crate) last_train_loss: f64,
    /// Cumulative mid-tier partial-sum bytes re-encoded by the two-tier
    /// aggregator tree (`agg_tiers = 2`); 0 on the flat path. Interior
    /// server-tree traffic — deliberately not folded into `bytes_up`.
    pub(crate) tier_bytes: u64,
    /// Adaptive bit-rate scheduler, engaged only when `bit_budget > 0` or
    /// the scenario sets per-client uplink caps. `None` is the strict
    /// no-op path: no plans, no observations, no RNG draws — bit-identical
    /// to the pre-scheduler engine (DETERMINISM.md invariant 6).
    pub(crate) budget: Option<BitBudget>,
    /// Where periodic checkpoints go (`None` = checkpointing off). Set via
    /// [`Coordinator::checkpoint_to`]; snapshots are taken by the run loop
    /// every `ckpt_every` completed rounds.
    pub(crate) ckpt_path: Option<std::path::PathBuf>,
    /// Checkpoint cadence in completed rounds (0 = off).
    pub(crate) ckpt_every: usize,
    /// Round records restored from a checkpoint, prepended to the next run
    /// loop's log so `replay_digest()` spans the whole training history.
    pub(crate) restored_records: Vec<RoundRecord>,
}

/// The N logical clients of one experiment plus the server-side evaluation
/// data, built deterministically from `(cfg, spec)`.
pub(crate) struct Fleet {
    pub(crate) clients: Vec<Client>,
    pub(crate) test: Option<Dataset>,
    pub(crate) lm_eval_corpus: Option<MarkovCorpus>,
}

/// Build the client fleet for an experiment — shared verbatim by
/// [`Coordinator::new`] and the remote worker (`transport::run_worker`), so
/// every process derives bit-identical shards, samplers, weights and codec
/// state from the same config. Any drift here breaks the tcp==in-process
/// digest parity pinned by `rust/tests/transport_props.rs`.
pub(crate) fn build_fleet(cfg: &ExperimentConfig, spec: &ModelSpec) -> Result<Fleet> {
    let mut clients = Vec::with_capacity(cfg.clients);
    let mut test = None;
    let mut lm_eval_corpus = None;
    if spec.kind == "classifier" {
        let train = crate::data::mnist_like_split(cfg.train_size, cfg.seed, 0);
        test = Some(crate::data::mnist_like_split(cfg.test_size, cfg.seed, 1));
        let total = train.len() as f64;
        // IID contiguous shards, or Dirichlet label-skew under the
        // non-IID scenario.
        let shards: Vec<Dataset> = if cfg.scenario.noniid_alpha > 0.0 {
            crate::data::dirichlet_shards(
                &train,
                cfg.clients,
                cfg.scenario.noniid_alpha,
                cfg.seed,
            )
        } else {
            (0..cfg.clients).map(|i| train.shard(i, cfg.clients)).collect()
        };
        for (i, shard) in shards.into_iter().enumerate() {
            let weight = shard.len() as f64 / total;
            clients.push(Client {
                id: i,
                sampler: BatchSampler::new(shard.len(), cfg.seed, i as u64),
                data: TaskData::Vision { shard },
                codecs: make_codecs(cfg, &spec.groups),
                arena: FrameArena::new(),
                weight,
            });
        }
    } else {
        // LM task: every client samples from the same chain (IID) —
        // label-skew sharding has no meaning here, so reject it rather
        // than silently logging an "@noniid" run that never skewed.
        if cfg.scenario.noniid_alpha > 0.0 {
            return Err(anyhow!(
                "noniid scenario requires a classifier task; \
                 LM clients sample a shared corpus"
            ));
        }
        let alphabet = spec.vocab.min(64).max(2);
        for i in 0..cfg.clients {
            clients.push(Client {
                id: i,
                sampler: BatchSampler::new(1, cfg.seed, i as u64),
                data: TaskData::Lm {
                    corpus: MarkovCorpus::new(alphabet, cfg.seed),
                    seq_len: spec.seq_len,
                },
                codecs: make_codecs(cfg, &spec.groups),
                arena: FrameArena::new(),
                weight: 1.0 / cfg.clients as f64,
            });
        }
        lm_eval_corpus = Some(MarkovCorpus::new(alphabet, cfg.seed));
    }
    Ok(Fleet { clients, test, lm_eval_corpus })
}

impl<'b> Coordinator<'b> {
    /// Build the server, clients and their codecs for one experiment, on the
    /// in-process [`SimNet`] transport.
    pub fn new(cfg: ExperimentConfig, backend: &'b dyn Backend) -> Result<Self> {
        let net = Box::new(SimNet::new(cfg.net));
        Self::with_transport(cfg, backend, net)
    }

    /// Build the server for one experiment over an explicit [`Transport`]
    /// (the TCP server mode injects a `TcpTransport` here). The coordinator
    /// still builds the full in-process client fleet: a remote round uses it
    /// only for weights, while `step()` keeps working for local rounds.
    pub fn with_transport(
        cfg: ExperimentConfig,
        backend: &'b dyn Backend,
        net: Box<dyn Transport>,
    ) -> Result<Self> {
        cfg.validate()?;
        let spec = backend.model(&cfg.model)?;
        spec.validate()?;
        let params = backend.init_params(&cfg.model)?;
        let opt = MomentumSgd::new(params.len(), cfg.lr, cfg.momentum, cfg.weight_decay);
        let Fleet { clients, test, lm_eval_corpus } = build_fleet(&cfg, &spec)?;

        let dim = params.len();
        let agg_shards = if cfg.agg_shards > 0 {
            cfg.agg_shards
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
        .min(spec.groups.len().max(1));
        let encode_threads = if cfg.encode_threads > 0 {
            cfg.encode_threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
        .min(cfg.clients.max(1));
        let scenario = ScenarioEngine::new(cfg.scenario.clone(), cfg.clients, cfg.seed);
        let budget = if cfg.bit_budget > 0 || cfg.scenario.uplink_cap_bytes > 0 {
            let dims = spec.groups.iter().map(|g| g.end - g.start).collect();
            Some(BitBudget::new(&cfg, dims, scenario.uplink_caps().to_vec()))
        } else {
            None
        };
        Ok(Coordinator {
            net,
            scenario,
            groups: spec.groups.clone(),
            spec,
            cfg,
            backend,
            clients,
            params,
            opt,
            test,
            lm_eval_corpus,
            round: 0,
            agg: vec![0.0; dim],
            agg_shards,
            encode_threads,
            staleness_scratch: Vec::new(),
            hist_reallocs: 0,
            contrib: Vec::new(),
            contrib_reallocs: 0,
            last_train_loss: 0.0,
            tier_bytes: 0,
            budget,
            ckpt_path: None,
            ckpt_every: 0,
            restored_records: Vec::new(),
        })
    }

    /// Rebuild a coordinator from a checkpoint written by
    /// [`Coordinator::checkpoint`], positioned at the checkpointed round
    /// with the pre-checkpoint records queued for the next run loop's log.
    /// See [`checkpoint`] for the format and the bit-exactness contract
    /// (DETERMINISM.md invariant 7).
    pub fn resume(path: &std::path::Path, backend: &'b dyn Backend) -> Result<Self> {
        let (mut coord, records) = checkpoint::resume(path, backend)?;
        coord.restored_records = records;
        Ok(coord)
    }

    /// Enable periodic checkpoints: every `every` completed rounds the run
    /// loop snapshots the full training state to `path` (atomic replace).
    /// `every == 0` disables. In-process transports only.
    pub fn checkpoint_to(&mut self, path: std::path::PathBuf, every: usize) {
        self.ckpt_path = Some(path);
        self.ckpt_every = every;
    }

    /// Snapshot the complete mutable training state plus `log` to `path`
    /// (see [`checkpoint::save`]). Pure observer — training is unaffected.
    pub fn checkpoint(&self, log: &RunLog, path: &std::path::Path) -> Result<()> {
        checkpoint::save(self, log, path)
    }

    /// Metadata of the model this experiment trains.
    pub fn model_spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// The compute backend this coordinator runs on.
    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    /// The last round's aggregated (dequantized, weighted-mean) gradient.
    /// Under DSGD this is the exact mean raw gradient — used by `fit-tail`
    /// and the Fig. 1 bench to harvest realistic gradients.
    pub fn last_aggregate(&self) -> &[f32] {
        &self.agg
    }

    /// Total fresh frame-buffer allocations across all client arenas since
    /// construction — the debug counter behind the steady-state
    /// zero-allocation invariant: after warm-up rounds this number must
    /// stop moving (asserted by the integration suite and surfaced by the
    /// `perf_hotpath` bench).
    pub fn frame_allocs(&self) -> u64 {
        self.clients.iter().map(|c| c.frame_allocs()).sum()
    }

    /// Times the reused staleness-histogram scratch had to grow its
    /// capacity: after the deepest staleness a scenario produces has been
    /// seen once, this counter must stop moving (asserted by the
    /// integration suite next to the frame-arena invariant). The record's
    /// own sized-to-fit copy of the histogram is log data, not scratch,
    /// and is deliberately outside this counter.
    pub fn hist_reallocs(&self) -> u64 {
        self.hist_reallocs
    }

    /// Times a streaming contribution buffer had to grow its capacity:
    /// sized on the first streaming round, flat forever after (the
    /// streaming pipeline's piece of the steady-state zero-allocation
    /// invariant). Always 0 in barrier mode.
    pub fn contrib_reallocs(&self) -> u64 {
        self.contrib_reallocs
    }

    /// Resolved server-aggregation shard count (config `agg_shards`, or one
    /// per available core, capped by the layer-group count).
    pub fn agg_shards(&self) -> usize {
        self.agg_shards
    }

    /// Resolved barrier-pipeline encode pool width (config
    /// `encode_threads`, or one per available core, capped by the client
    /// count).
    pub fn encode_threads(&self) -> usize {
        self.encode_threads
    }

    /// Cumulative bytes the two-tier aggregator tree (`agg_tiers = 2`) spent
    /// re-encoding mid-tier partial sums. Interior server traffic: reported
    /// by the scale bench but deliberately not part of `bytes_up` (which
    /// stays "client uplink bytes", the paper's communication metric).
    pub fn tier_uplink_bytes(&self) -> u64 {
        self.tier_bytes
    }

    /// Mean resident bytes of mutable per-client state (EF residuals — dense
    /// or parked as quantized frames — plus pooled arena buffers). The
    /// million-client capacity metric: cohort sampling parks non-cohort
    /// residuals, so this shrinks toward the quantized-frame size as
    /// `cohort_k` drops.
    pub fn bytes_per_client(&self) -> u64 {
        if self.clients.is_empty() {
            return 0;
        }
        let total: u64 = self.clients.iter().map(|c| c.state_bytes() as u64).sum();
        total / self.clients.len() as u64
    }

    /// Execute one communication round through the configured pipeline;
    /// returns the round record. The two modes are bit-identical — see the
    /// [`pipeline`] module docs.
    pub fn step(&mut self) -> Result<RoundRecord> {
        match self.cfg.pipeline {
            PipelineMode::Barrier => pipeline::step_barrier(self),
            PipelineMode::Streaming => pipeline::step_streaming(self),
        }
    }

    /// Execute one communication round against remote workers on the
    /// injected [`Transport`]: broadcast parameters, collect uplink
    /// outcomes, then run the same schedule/aggregate/apply epilogue as the
    /// in-process pipelines. On clean scenarios the resulting
    /// `replay_digest()` is bit-identical to [`Coordinator::step`] under
    /// `PipelineMode::Barrier` — see `coordinator::transport`.
    pub fn step_remote(&mut self) -> Result<RoundRecord> {
        pipeline::step_remote(self)
    }

    /// Run the full experiment against remote workers ([`Self::step_remote`]
    /// every round), then shut the transport down (workers exit cleanly).
    pub fn run_remote(&mut self, verbose: bool) -> Result<RunLog> {
        let log = self.run_rounds(verbose, true);
        // Tear workers down even when a round failed mid-run.
        let shutdown = self.net.shutdown();
        let log = log?;
        shutdown?;
        Ok(log)
    }

    /// Evaluate the current global model on the held-out set.
    /// Classifier: (mean loss, accuracy). LM: (mean token NLL, None).
    pub fn evaluate(&self) -> Result<(f64, Option<f64>)> {
        if let Some(test) = &self.test {
            let b = self.spec.eval_batch;
            let chunks = test.len() / b;
            if chunks == 0 {
                return Err(anyhow!("test set smaller than eval batch {b}"));
            }
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for ch in 0..chunks {
                let idxs: Vec<usize> = (ch * b..(ch + 1) * b).collect();
                let (x, y) = gather_batch(test, &idxs);
                let ev = self.backend.eval(&self.cfg.model, &self.params, &x, &y)?;
                loss_sum += ev.loss_sum;
                correct += ev.count;
            }
            let n = (chunks * b) as f64;
            Ok((loss_sum / n, Some(correct / n)))
        } else if let Some(corpus) = &self.lm_eval_corpus {
            let b = self.spec.train_batch;
            let mut rng = Rng::for_stream(self.cfg.seed, 0xE7A1, 0, 0);
            let mut loss_sum = 0.0;
            let mut count = 0.0;
            for _ in 0..4 {
                let mut toks = Vec::with_capacity(b * (self.spec.seq_len + 1));
                for _ in 0..b {
                    toks.extend(corpus.sample(self.spec.seq_len + 1, &mut rng));
                }
                let ev = self.backend.eval(&self.cfg.model, &self.params, &toks, &[])?;
                loss_sum += ev.loss_sum;
                count += ev.count;
            }
            Ok((loss_sum / count, None))
        } else {
            Err(anyhow!("no evaluation data"))
        }
    }

    /// Run the full experiment, logging every round + periodic evals.
    pub fn run(&mut self, verbose: bool) -> Result<RunLog> {
        self.run_rounds(verbose, false)
    }

    /// The shared run loop: from the current round (0 on a fresh build,
    /// later after [`Coordinator::resume`]) to `cfg.rounds`, through either
    /// the local pipelines or the remote transport, with periodic
    /// evaluations and (when configured) periodic checkpoints.
    fn run_rounds(&mut self, verbose: bool, remote: bool) -> Result<RunLog> {
        if remote && self.ckpt_every > 0 {
            return Err(anyhow!(
                "checkpointing is in-process only: remote workers own the \
                 client state a checkpoint must capture"
            ));
        }
        let mut log = RunLog { config_id: self.cfg.id(), ..Default::default() };
        log.records = std::mem::take(&mut self.restored_records);
        while self.round < self.cfg.rounds {
            let mut rec = if remote { self.step_remote()? } else { self.step()? };
            let last = self.round == self.cfg.rounds;
            if self.round % self.cfg.eval_every == 0 || last {
                let (l, a) = self.evaluate()?;
                rec.test_loss = Some(l);
                rec.test_accuracy = a;
                if verbose {
                    match a {
                        Some(acc) => println!(
                            "[{}] round {:>5} train_loss {:.4} test_loss {:.4} acc {:.4}",
                            log.config_id, rec.round, rec.train_loss, l, acc
                        ),
                        None => println!(
                            "[{}] round {:>5} train_loss {:.4} test_nll {:.4}",
                            log.config_id, rec.round, rec.train_loss, l
                        ),
                    }
                }
            }
            log.push(rec);
            // Snapshot AFTER the record lands so checkpoint-at-k restores
            // to exactly "k rounds completed, k records logged".
            if self.ckpt_every > 0 && self.round % self.ckpt_every == 0 {
                if let Some(path) = self.ckpt_path.clone() {
                    self.checkpoint(&log, &path)?;
                }
            }
        }
        Ok(log)
    }
}
