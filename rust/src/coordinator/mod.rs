//! The distributed DSGD coordinator — Algorithm 1 of the paper as a system.
//!
//! One process hosts the server and N logical clients:
//!
//! ```text
//! round t:
//!   server  --θ_t-->  clients                     (broadcast)
//!   client i: g_i = grad(θ_t, batch_i)            (backend, main thread)
//!             ĝ_i = Q_λs[T_α(g_i)] per layer group (rust codecs, N threads)
//!   clients --frames-->  server                   (simulated network, real bytes)
//!   server: ḡ = Σ w_i dequantize(frame_i);  θ_{t+1} = θ_t − η·step(ḡ)
//! ```
//!
//! Compute (model fwd/bwd) goes through the pluggable [`Backend`] — pure
//! Rust by default, PJRT behind the `pjrt` feature. Backends may be
//! single-threaded (PJRT's client is `Rc`-based and not `Send`), so gradient
//! execution stays on the driver thread; the embarrassingly parallel codec
//! work fans out over `std::thread::scope`. Each (client, layer-group) pair
//! owns an independent quantizer state whose tail model is re-fitted every
//! `estimate_every` rounds — exactly the paper's per-layer γ estimation (§V).
//!
//! The server side mirrors the client fan-out: stage 4 (decode → dequantize
//! → weighted accumulate) runs through [`aggregate`], which shards the
//! aggregate buffer by layer-group ranges across `std::thread::scope`
//! workers and folds the `w * d` accumulate directly into the bitstream
//! walk (fused decode-accumulate kernels, no dense scratch pass). The
//! sharded result is bit-identical to the serial path at every shard count
//! — see the [`aggregate`] module docs for the determinism argument.
//!
//! Degraded-mode rounds (stragglers, lossy uplinks, churn, bounded
//! staleness, non-IID shards) are injected by the [`scenario`] engine from
//! the experiment's `ScenarioConfig`; the clean preset reproduces the
//! synchronous loop above bit-for-bit.

pub mod aggregate;
pub mod network;
pub mod scenario;

pub use network::{LinkCondition, Message, SimNet, UplinkReport};
pub use scenario::ScenarioEngine;

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::data::{gather_batch, BatchSampler, Dataset, MarkovCorpus};
use crate::metrics::{RoundRecord, RunLog, Timer};
use crate::optim::MomentumSgd;
use crate::quant::{make_compressor, Compressor, ErrorFeedback, FrameArena};
use crate::runtime::{Backend, GroupRange, ModelSpec};
use crate::util::Rng;

/// Per-(client, group) compression state: plain codec or EF-wrapped.
enum GroupCodec {
    Plain(Box<dyn Compressor>),
    Ef(ErrorFeedback),
}

impl GroupCodec {
    fn refit(&mut self, grads: &[f32]) {
        match self {
            GroupCodec::Plain(c) => c.refit(grads),
            GroupCodec::Ef(c) => c.refit(grads),
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        match self {
            GroupCodec::Plain(c) => c.compress_into(grads, rng, out),
            GroupCodec::Ef(c) => c.compress_with_feedback_into(grads, rng, out),
        }
    }

    /// The network lost this frame for good: EF codecs fold it back into the
    /// residual (plain codecs have no state to repair).
    fn restore_lost(&mut self, frame: &[u8]) {
        if let GroupCodec::Ef(c) = self {
            c.restore_lost(frame);
        }
    }

    fn describe(&self) -> String {
        match self {
            GroupCodec::Plain(c) => c.describe(),
            GroupCodec::Ef(c) => c.describe(),
        }
    }
}

/// The task a client trains on.
pub enum TaskData {
    /// Image classification over a contiguous shard of the dataset.
    Vision {
        /// This client's shard.
        shard: Dataset,
    },
    /// Language modelling over a shared Markov corpus.
    Lm {
        /// Token source.
        corpus: MarkovCorpus,
        /// Context length per sample.
        seq_len: usize,
    },
}

/// One logical client.
pub struct Client {
    /// Client index in `0..N`.
    pub id: usize,
    data: TaskData,
    sampler: BatchSampler,
    codecs: Vec<GroupCodec>,
    /// Recycled frame buffers: survives across rounds, one arena per client
    /// so the codec worker threads never share a pool.
    arena: FrameArena,
    /// Fraction of the global data this client holds (aggregation weight).
    pub weight: f64,
}

impl Client {
    /// Produce this round's training batch as flat input buffers.
    fn next_batch(&mut self, train_batch: usize, seed: u64, round: u64) -> (Vec<f32>, Vec<f32>) {
        match &self.data {
            TaskData::Vision { shard } => {
                let idxs = self.sampler.next_batch(train_batch);
                gather_batch(shard, &idxs)
            }
            TaskData::Lm { corpus, seq_len } => {
                let mut rng = Rng::for_stream(seed, 0x70C5, self.id as u64, round);
                let mut toks = Vec::with_capacity(train_batch * (seq_len + 1));
                for _ in 0..train_batch {
                    toks.extend(corpus.sample(seq_len + 1, &mut rng));
                }
                (toks, Vec::new())
            }
        }
    }

    /// Compress a gradient per layer group into a message (runs on a worker
    /// thread; pure rust). Frame buffers come from this client's arena, so
    /// in steady state the encode path performs zero heap allocation.
    fn compress(
        &mut self,
        grads: &[f32],
        groups: &[GroupRange],
        round: usize,
        seed: u64,
        refit_now: bool,
        loss: f32,
    ) -> Message {
        let mut frames = Vec::with_capacity(groups.len());
        for (gi, g) in groups.iter().enumerate() {
            let slice = &grads[g.start..g.end];
            if refit_now {
                self.codecs[gi].refit(slice);
            }
            let mut rng = Rng::for_stream(seed, 0x9A7E, (self.id * 1031 + gi) as u64, round as u64);
            let mut buf = self.arena.take();
            self.codecs[gi].compress_into(slice, &mut rng, &mut buf);
            frames.push((gi, buf));
        }
        Message { client: self.id, round, frames, loss }
    }

    /// Recycle a consumed message's frame buffers back into the arena.
    fn recycle(&mut self, msg: Message) {
        for (_, frame) in msg.frames {
            self.arena.put(frame);
        }
    }

    /// Re-fold an undeliverable message into this client's error-feedback
    /// residuals so its gradient mass survives to the next round.
    fn restore_lost(&mut self, msg: &Message) {
        for (gi, frame) in &msg.frames {
            self.codecs[*gi].restore_lost(frame);
        }
    }

    /// One-line description of each layer group's codec state.
    pub fn describe_codecs(&self) -> Vec<String> {
        self.codecs.iter().map(|c| c.describe()).collect()
    }
}

/// Server + clients + network for one experiment.
pub struct Coordinator<'b> {
    /// The experiment description this coordinator runs.
    pub cfg: ExperimentConfig,
    backend: &'b dyn Backend,
    spec: ModelSpec,
    /// The logical clients.
    pub clients: Vec<Client>,
    /// The global flat parameter vector (server copy).
    pub params: Vec<f32>,
    opt: MomentumSgd,
    /// Simulated uplink network (accounts real wire bytes).
    pub net: SimNet,
    /// Scenario engine: per-round churn/straggler/loss/staleness decisions.
    pub scenario: ScenarioEngine,
    groups: Vec<GroupRange>,
    test: Option<Dataset>,
    lm_eval_corpus: Option<MarkovCorpus>,
    /// Number of completed communication rounds.
    pub round: usize,
    /// Scratch: aggregated gradient buffer.
    agg: Vec<f32>,
    /// Server aggregation fan-out width (resolved from config at build:
    /// explicit `agg_shards`, or one per available core, capped by the
    /// number of layer groups). A pure performance knob — the sharded
    /// aggregation is bit-identical at every width.
    agg_shards: usize,
    /// Scratch: per-round staleness histogram, built in place each round so
    /// the working buffer never regrows in steady state. The round record
    /// still receives one sized-to-fit copy (it owns its data for the run
    /// log) — the invariant is about the scratch, not the record.
    staleness_scratch: Vec<u32>,
    /// Debug counter: times `staleness_scratch` had to grow. Must go flat
    /// after warm-up (asserted next to the frame-alloc invariant).
    hist_reallocs: u64,
}

impl<'b> Coordinator<'b> {
    /// Build the server, clients and their codecs for one experiment.
    pub fn new(cfg: ExperimentConfig, backend: &'b dyn Backend) -> Result<Self> {
        cfg.validate()?;
        let spec = backend.model(&cfg.model)?;
        spec.validate()?;
        let params = backend.init_params(&cfg.model)?;
        let opt = MomentumSgd::new(params.len(), cfg.lr, cfg.momentum, cfg.weight_decay);

        let mut clients = Vec::with_capacity(cfg.clients);
        let mut test = None;
        let mut lm_eval_corpus = None;
        if spec.kind == "classifier" {
            let train = crate::data::mnist_like_split(cfg.train_size, cfg.seed, 0);
            test = Some(crate::data::mnist_like_split(cfg.test_size, cfg.seed, 1));
            let total = train.len() as f64;
            // IID contiguous shards, or Dirichlet label-skew under the
            // non-IID scenario.
            let shards: Vec<Dataset> = if cfg.scenario.noniid_alpha > 0.0 {
                crate::data::dirichlet_shards(
                    &train,
                    cfg.clients,
                    cfg.scenario.noniid_alpha,
                    cfg.seed,
                )
            } else {
                (0..cfg.clients).map(|i| train.shard(i, cfg.clients)).collect()
            };
            for (i, shard) in shards.into_iter().enumerate() {
                let weight = shard.len() as f64 / total;
                clients.push(Client {
                    id: i,
                    sampler: BatchSampler::new(shard.len(), cfg.seed, i as u64),
                    data: TaskData::Vision { shard },
                    codecs: make_codecs(&cfg, &spec.groups),
                    arena: FrameArena::new(),
                    weight,
                });
            }
        } else {
            // LM task: every client samples from the same chain (IID) —
            // label-skew sharding has no meaning here, so reject it rather
            // than silently logging an "@noniid" run that never skewed.
            if cfg.scenario.noniid_alpha > 0.0 {
                return Err(anyhow!(
                    "noniid scenario requires a classifier task; \
                     LM clients sample a shared corpus"
                ));
            }
            let alphabet = spec.vocab.min(64).max(2);
            for i in 0..cfg.clients {
                clients.push(Client {
                    id: i,
                    sampler: BatchSampler::new(1, cfg.seed, i as u64),
                    data: TaskData::Lm {
                        corpus: MarkovCorpus::new(alphabet, cfg.seed),
                        seq_len: spec.seq_len,
                    },
                    codecs: make_codecs(&cfg, &spec.groups),
                    arena: FrameArena::new(),
                    weight: 1.0 / cfg.clients as f64,
                });
            }
            lm_eval_corpus = Some(MarkovCorpus::new(alphabet, cfg.seed));
        }

        let dim = params.len();
        let agg_shards = if cfg.agg_shards > 0 {
            cfg.agg_shards
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
        .min(spec.groups.len().max(1));
        Ok(Coordinator {
            net: SimNet::new(cfg.net),
            scenario: ScenarioEngine::new(cfg.scenario.clone(), cfg.clients, cfg.seed),
            groups: spec.groups.clone(),
            spec,
            cfg,
            backend,
            clients,
            params,
            opt,
            test,
            lm_eval_corpus,
            round: 0,
            agg: vec![0.0; dim],
            agg_shards,
            staleness_scratch: Vec::new(),
            hist_reallocs: 0,
        })
    }

    /// Metadata of the model this experiment trains.
    pub fn model_spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// The compute backend this coordinator runs on.
    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    /// The last round's aggregated (dequantized, weighted-mean) gradient.
    /// Under DSGD this is the exact mean raw gradient — used by `fit-tail`
    /// and the Fig. 1 bench to harvest realistic gradients.
    pub fn last_aggregate(&self) -> &[f32] {
        &self.agg
    }

    /// Total fresh frame-buffer allocations across all client arenas since
    /// construction — the debug counter behind the steady-state
    /// zero-allocation invariant: after warm-up rounds this number must
    /// stop moving (asserted by the integration suite and surfaced by the
    /// `perf_hotpath` bench).
    pub fn frame_allocs(&self) -> u64 {
        self.clients.iter().map(|c| c.arena.fresh_allocs()).sum()
    }

    /// Times the reused staleness-histogram scratch had to grow its
    /// capacity: after the deepest staleness a scenario produces has been
    /// seen once, this counter must stop moving (asserted by the
    /// integration suite next to the frame-arena invariant). The record's
    /// own sized-to-fit copy of the histogram is log data, not scratch,
    /// and is deliberately outside this counter.
    pub fn hist_reallocs(&self) -> u64 {
        self.hist_reallocs
    }

    /// Resolved server-aggregation shard count (config `agg_shards`, or one
    /// per available core, capped by the layer-group count).
    pub fn agg_shards(&self) -> usize {
        self.agg_shards
    }

    /// Execute one communication round; returns the round record.
    pub fn step(&mut self) -> Result<RoundRecord> {
        let timer = Timer::start();
        let round = self.round;
        let train_batch = self.spec.train_batch;

        // 0. Scenario: churn decides who participates this round.
        let active = self.scenario.begin_round(round as u64);
        let mut active_set = vec![false; self.clients.len()];
        for &i in &active {
            active_set[i] = true;
        }

        // 1. Local gradients for participating clients (backend on this
        //    thread; PJRT/XLA parallelizes inside, the native path is cheap
        //    scalar math).
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(active.len());
        let mut losses: Vec<f32> = Vec::with_capacity(active.len());
        for &ci in &active {
            let c = &mut self.clients[ci];
            let (x, y) = c.next_batch(train_batch, self.cfg.seed, round as u64);
            let out = self.backend.grad(&self.cfg.model, &self.params, &x, &y)?;
            losses.push(out.loss);
            grads.push(out.grads);
        }

        // 2. Per-client compression, fanned out across threads.
        let refit_now = round % self.cfg.quant.estimate_every == 0;
        let seed = self.cfg.seed;
        let groups = &self.groups;
        let msgs: Vec<Message> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(active.len());
            let mut k = 0usize;
            for (i, c) in self.clients.iter_mut().enumerate() {
                if !active_set[i] {
                    continue;
                }
                let g = &grads[k];
                let loss = losses[k];
                k += 1;
                handles.push(scope.spawn(move || {
                    c.compress(g, groups, round, seed, refit_now, loss)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("codec thread")).collect()
        });

        // 3. Uplink through the simulated network. The legacy `drop_client`
        //    fault kills one client's message outright; the scenario engine
        //    injects packet loss (retransmits, possibly total loss) and
        //    straggler latency multipliers per surviving message.
        let mut delivered: Vec<Message> = Vec::with_capacity(msgs.len());
        let mut conds: Vec<LinkCondition> = Vec::with_capacity(msgs.len());
        let mut lost_bytes = 0u64;
        for m in msgs {
            if m.client == self.cfg.drop_client {
                let ci = m.client;
                self.clients[ci].recycle(m);
                continue;
            }
            match self.scenario.link(m.client, round as u64) {
                Some(cond) => {
                    delivered.push(m);
                    conds.push(cond);
                }
                // Fully lost: every attempt still burned wire bytes, and an
                // EF client keeps the undelivered mass in its residual.
                None => {
                    lost_bytes += self.net.account_lost(&m, self.scenario.lost_attempts());
                    let ci = m.client;
                    self.clients[ci].restore_lost(&m);
                    self.clients[ci].recycle(m);
                }
            }
        }
        let dropped_clients = self.clients.len() - delivered.len();
        let report = self.net.round_uplink_conditioned(&delivered, &conds);

        // 3b. Bounded-staleness schedule: which frames apply now vs next
        //     round (with decayed weight).
        let arrivals: Vec<(Message, f64)> = delivered
            .into_iter()
            .zip(report.per_client.iter().map(|&(_, t)| t))
            .collect();
        // The server steps at the K-th arrival, so that — not the slowest
        // client — is the round's communication time.
        let (apply, net_secs) = self.scenario.schedule(arrivals);
        // An empty apply set under packet loss is a transient wipeout: skip
        // the update (θ unchanged) and keep training. Without loss in play
        // it is structural (drop_client killed the whole federation) — fail.
        if apply.is_empty() && self.cfg.scenario.loss_prob == 0.0 {
            return Err(anyhow!("all clients dropped; nothing to aggregate"));
        }
        // Staleness histogram into the reused scratch (capacity survives
        // rounds; the record below gets a sized-to-fit copy).
        self.staleness_scratch.clear();
        for &(_, s) in &apply {
            let s = s as usize;
            if self.staleness_scratch.len() <= s {
                if s + 1 > self.staleness_scratch.capacity() {
                    self.hist_reallocs += 1;
                }
                self.staleness_scratch.resize(s + 1, 0);
            }
            self.staleness_scratch[s] += 1;
        }
        let staleness_hist = self.staleness_scratch.clone();

        // 4. Server: decode + weighted aggregate + optimizer step, sharded
        //    by layer-group ranges over worker threads with the fused
        //    decode-accumulate kernels (see [`aggregate`]) — bit-identical
        //    to the serial scratch-buffer loop it replaced. Late frames
        //    count with weight w_i * decay^staleness; for the synchronous
        //    case every staleness is 0 and decay^0 = 1 exactly, so this
        //    reduces bit-for-bit to the plain weighted mean.
        if !apply.is_empty() {
            let w_total: f64 = apply
                .iter()
                .map(|(m, s)| self.clients[m.client].weight * self.scenario.stale_weight(*s))
                .sum();
            let uplinks: Vec<aggregate::WeightedUplink<'_>> = apply
                .iter()
                .map(|(m, s)| aggregate::WeightedUplink {
                    frames: &m.frames,
                    w: ((self.clients[m.client].weight * self.scenario.stale_weight(*s))
                        / w_total) as f32,
                })
                .collect();
            aggregate::aggregate_sharded(&self.groups, &uplinks, &mut self.agg, self.agg_shards)?;
            let agg = std::mem::take(&mut self.agg);
            self.opt.step(&mut self.params, &agg);
            self.agg = agg;
        }
        // Aggregation is done with these frames: hand the buffers back to
        // their owners' arenas so next round's encode allocates nothing.
        for (m, _) in apply {
            let ci = m.client;
            self.clients[ci].recycle(m);
        }

        let train_loss =
            losses.iter().map(|&l| l as f64).sum::<f64>() / losses.len() as f64;
        self.round += 1;
        Ok(RoundRecord {
            round,
            train_loss,
            bytes_up: report.bytes,
            test_loss: None,
            test_accuracy: None,
            secs: timer.secs(),
            net_secs,
            dropped_clients,
            retransmitted_bytes: report.retransmitted_bytes + lost_bytes,
            staleness_hist,
        })
    }

    /// Evaluate the current global model on the held-out set.
    /// Classifier: (mean loss, accuracy). LM: (mean token NLL, None).
    pub fn evaluate(&self) -> Result<(f64, Option<f64>)> {
        if let Some(test) = &self.test {
            let b = self.spec.eval_batch;
            let chunks = test.len() / b;
            if chunks == 0 {
                return Err(anyhow!("test set smaller than eval batch {b}"));
            }
            let mut loss_sum = 0.0;
            let mut correct = 0.0;
            for ch in 0..chunks {
                let idxs: Vec<usize> = (ch * b..(ch + 1) * b).collect();
                let (x, y) = gather_batch(test, &idxs);
                let ev = self.backend.eval(&self.cfg.model, &self.params, &x, &y)?;
                loss_sum += ev.loss_sum;
                correct += ev.count;
            }
            let n = (chunks * b) as f64;
            Ok((loss_sum / n, Some(correct / n)))
        } else if let Some(corpus) = &self.lm_eval_corpus {
            let b = self.spec.train_batch;
            let mut rng = Rng::for_stream(self.cfg.seed, 0xE7A1, 0, 0);
            let mut loss_sum = 0.0;
            let mut count = 0.0;
            for _ in 0..4 {
                let mut toks = Vec::with_capacity(b * (self.spec.seq_len + 1));
                for _ in 0..b {
                    toks.extend(corpus.sample(self.spec.seq_len + 1, &mut rng));
                }
                let ev = self.backend.eval(&self.cfg.model, &self.params, &toks, &[])?;
                loss_sum += ev.loss_sum;
                count += ev.count;
            }
            Ok((loss_sum / count, None))
        } else {
            Err(anyhow!("no evaluation data"))
        }
    }

    /// Run the full experiment, logging every round + periodic evals.
    pub fn run(&mut self, verbose: bool) -> Result<RunLog> {
        let mut log = RunLog { config_id: self.cfg.id(), ..Default::default() };
        for _ in 0..self.cfg.rounds {
            let mut rec = self.step()?;
            let last = self.round == self.cfg.rounds;
            if self.round % self.cfg.eval_every == 0 || last {
                let (l, a) = self.evaluate()?;
                rec.test_loss = Some(l);
                rec.test_accuracy = a;
                if verbose {
                    match a {
                        Some(acc) => println!(
                            "[{}] round {:>5} train_loss {:.4} test_loss {:.4} acc {:.4}",
                            log.config_id, rec.round, rec.train_loss, l, acc
                        ),
                        None => println!(
                            "[{}] round {:>5} train_loss {:.4} test_nll {:.4}",
                            log.config_id, rec.round, rec.train_loss, l
                        ),
                    }
                }
            }
            log.push(rec);
        }
        Ok(log)
    }
}

fn make_codecs(cfg: &ExperimentConfig, groups: &[GroupRange]) -> Vec<GroupCodec> {
    groups
        .iter()
        .map(|_| {
            let inner = make_compressor(&cfg.quant);
            if cfg.quant.error_feedback {
                GroupCodec::Ef(ErrorFeedback::new(inner))
            } else {
                GroupCodec::Plain(inner)
            }
        })
        .collect()
}
