//! Synthetic data substrate.
//!
//! The build image has no network, so MNIST is substituted with a
//! deterministic generator (documented in DESIGN.md §Substitutions):
//!
//! * [`mnist_like`] — 10-class 28×28 grayscale images built from
//!   class-specific Gaussian-blob prototypes with per-sample affine jitter
//!   and pixel noise. Learnable by the same MLP/CNN architectures, and —
//!   the property the paper actually needs — training gradients on it are
//!   heavy-tailed (verified by the Fig. 1 bench).
//! * [`MarkovCorpus`] — byte-level token sequences from a seeded Markov
//!   chain, for the transformer LM end-to-end example.
//!
//! Data is sharded across clients by contiguous ranges (the paper's
//! `D^(i)`), with per-client deterministic batch sampling.

use crate::util::Rng;

/// Image side length (MNIST-shaped 28×28 inputs).
pub const IMG_SIDE: usize = 28;
/// Pixels per flattened image.
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
/// Number of label classes.
pub const NUM_CLASSES: usize = 10;

/// A labelled image dataset, images flattened row-major, pixels in [0, 1].
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Flattened images, `len() * IMG_PIXELS` f32s in [0, 1].
    pub images: Vec<f32>,
    /// One label per image, in `0..NUM_CLASSES`.
    pub labels: Vec<u8>,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// The `i`-th flattened image.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    /// Contiguous shard `i` of `n` (sizes differ by at most 1).
    pub fn shard(&self, i: usize, n: usize) -> Dataset {
        assert!(i < n);
        let len = self.len();
        let base = len / n;
        let extra = len % n;
        let start = i * base + i.min(extra);
        let count = base + usize::from(i < extra);
        Dataset {
            images: self.images[start * IMG_PIXELS..(start + count) * IMG_PIXELS].to_vec(),
            labels: self.labels[start..start + count].to_vec(),
        }
    }
}

/// Class prototypes: a SHARED base pattern (common to all classes, so
/// classes overlap and the task is non-trivial) plus a few class-specific
/// Gaussian bumps. Difficulty is controlled by the bump amplitude relative
/// to the base + sample noise.
fn prototype(class: usize, seed: u64) -> Vec<f32> {
    let mut img = vec![0.0f32; IMG_PIXELS];
    let mut add_blobs = |rng: &mut Rng, count: usize, amp_lo: f64, amp_hi: f64| {
        for _ in 0..count {
            let cx = 5.0 + rng.f64() * 18.0;
            let cy = 5.0 + rng.f64() * 18.0;
            let sx = 1.5 + rng.f64() * 3.0;
            let sy = 1.5 + rng.f64() * 3.0;
            let amp = amp_lo + rng.f64() * (amp_hi - amp_lo);
            for y in 0..IMG_SIDE {
                for x in 0..IMG_SIDE {
                    let dx = (x as f64 - cx) / sx;
                    let dy = (y as f64 - cy) / sy;
                    img[y * IMG_SIDE + x] +=
                        (amp * (-0.5 * (dx * dx + dy * dy)).exp()) as f32;
                }
            }
        }
    };
    // Shared base: identical across classes.
    let mut base_rng = Rng::for_stream(seed, 0xDA7A, 0xFFFF, 0);
    add_blobs(&mut base_rng, 4, 0.6, 1.0);
    // Class-specific detail on top.
    let mut class_rng = Rng::for_stream(seed, 0xDA7A, class as u64, 0);
    add_blobs(&mut class_rng, 2 + class % 2, 0.25, 0.45);
    // Normalize peak to 1.
    let mx = img.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
    for p in img.iter_mut() {
        *p /= mx;
    }
    img
}

/// Generate an MNIST-like dataset: `n` samples, balanced classes, per-sample
/// integer shift jitter (±2 px) and Gaussian pixel noise.
///
/// The class prototypes depend on `seed` only; train/test splits of the SAME
/// task use the same seed with different `split` ids (fresh jitter + noise).
pub fn mnist_like_split(n: usize, seed: u64, split: u64) -> Dataset {
    let protos: Vec<Vec<f32>> = (0..NUM_CLASSES).map(|c| prototype(c, seed)).collect();
    let mut rng = Rng::for_stream(seed, 0xDA7A, 1, split);
    let mut images = Vec::with_capacity(n * IMG_PIXELS);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % NUM_CLASSES;
        let dx = rng.below(7) as i64 - 3;
        let dy = rng.below(7) as i64 - 3;
        let proto = &protos[class];
        for y in 0..IMG_SIDE as i64 {
            for x in 0..IMG_SIDE as i64 {
                let sx = x - dx;
                let sy = y - dy;
                let base = if (0..IMG_SIDE as i64).contains(&sx)
                    && (0..IMG_SIDE as i64).contains(&sy)
                {
                    proto[(sy as usize) * IMG_SIDE + sx as usize]
                } else {
                    0.0
                };
                let noisy = base + (rng.normal() * 0.25) as f32;
                images.push(noisy.clamp(0.0, 1.0));
            }
        }
        labels.push(class as u8);
    }
    // Shuffle sample order (keeping image/label pairing).
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut s_images = Vec::with_capacity(images.len());
    let mut s_labels = Vec::with_capacity(n);
    for &i in &order {
        s_images.extend_from_slice(&images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]);
        s_labels.push(labels[i]);
    }
    Dataset { images: s_images, labels: s_labels }
}

/// Train-split convenience (`split = 0`).
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    mnist_like_split(n, seed, 0)
}

/// Label-skew (non-IID) partition of `ds` into `n` client shards via
/// Dirichlet(`alpha`) proportions per class — the standard federated-learning
/// heterogeneity model. Small `alpha` concentrates each class on few
/// clients; large `alpha` approaches the IID balanced split.
///
/// Deterministic in (`seed`, `n`, `alpha`); every sample lands in exactly one
/// shard and every shard is non-empty (requires `ds.len() >= n`).
pub fn dirichlet_shards(ds: &Dataset, n: usize, alpha: f64, seed: u64) -> Vec<Dataset> {
    assert!(n >= 1 && alpha > 0.0, "need n >= 1 and alpha > 0");
    assert!(ds.len() >= n, "need at least one sample per client");
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); NUM_CLASSES];
    for (i, &l) in ds.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    let mut assign: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (c, idxs) in by_class.iter().enumerate() {
        if idxs.is_empty() {
            continue;
        }
        let mut rng = Rng::for_stream(seed, 0xD141, c as u64, 0);
        let props: Vec<f64> = (0..n).map(|_| rng.gamma(alpha).max(1e-12)).collect();
        let total: f64 = props.iter().sum();
        // Contiguous proportional split of this class's sample list.
        let mut acc = 0.0f64;
        let mut start = 0usize;
        for (j, p) in props.iter().enumerate() {
            acc += p / total;
            let end = if j + 1 == n {
                idxs.len()
            } else {
                ((acc * idxs.len() as f64).round() as usize).clamp(start, idxs.len())
            };
            assign[j].extend_from_slice(&idxs[start..end]);
            start = end;
        }
    }
    // Guarantee non-empty shards: steal one sample from the largest donor.
    for j in 0..n {
        if assign[j].is_empty() {
            let donor = (0..n)
                .filter(|&k| k != j)
                .max_by_key(|&k| assign[k].len())
                .expect("n >= 2 when a shard can be empty");
            let steal = assign[donor].pop().expect("donor has samples");
            assign[j].push(steal);
        }
    }
    assign
        .into_iter()
        .map(|mut idxs| {
            idxs.sort_unstable();
            let mut images = Vec::with_capacity(idxs.len() * IMG_PIXELS);
            let mut labels = Vec::with_capacity(idxs.len());
            for &i in &idxs {
                images.extend_from_slice(ds.image(i));
                labels.push(ds.labels[i]);
            }
            Dataset { images, labels }
        })
        .collect()
}

/// Deterministic batch sampler over a shard: reshuffles every epoch.
pub struct BatchSampler {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchSampler {
    /// A sampler over `len` samples on the client's dedicated RNG stream.
    pub fn new(len: usize, seed: u64, client: u64) -> Self {
        let mut rng = Rng::for_stream(seed, 0xBA7C, client, 0);
        let mut order: Vec<usize> = (0..len).collect();
        rng.shuffle(&mut order);
        BatchSampler { order, cursor: 0, rng }
    }

    /// Next batch of indices (wraps with a reshuffle at epoch end).
    pub fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Snapshot the sampler's mutable state — the current epoch permutation,
    /// the cursor into it, and the reshuffle RNG — so a rejoining worker or
    /// a resumed checkpoint continues the exact batch sequence an
    /// uninterrupted run would have drawn.
    pub fn export_state(&self) -> SamplerState {
        let (rng, spare) = self.rng.state();
        SamplerState {
            order: self.order.clone(),
            cursor: self.cursor,
            rng,
            rng_spare: spare,
        }
    }

    /// Restore a [`BatchSampler::export_state`] snapshot in place.
    pub fn restore_state(&mut self, st: SamplerState) {
        self.order = st.order;
        self.cursor = st.cursor;
        self.rng = Rng::from_state(st.rng, st.rng_spare);
    }
}

/// A [`BatchSampler`]'s complete mutable state (see
/// [`BatchSampler::export_state`]); serialized into worker STATE messages
/// and coordinator checkpoints.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplerState {
    /// The current epoch's shuffled index order.
    pub order: Vec<usize>,
    /// Position of the next sample in `order`.
    pub cursor: usize,
    /// The reshuffle generator's xoshiro words.
    pub rng: [u64; 4],
    /// The reshuffle generator's cached Box-Muller spare (always `None` in
    /// practice — samplers never draw normals — but carried for exactness).
    pub rng_spare: Option<f64>,
}

/// Gather a batch into (x f32[B*784], y f32[B]) buffers for the runtime.
pub fn gather_batch(ds: &Dataset, idxs: &[usize]) -> (Vec<f32>, Vec<f32>) {
    let mut x = Vec::with_capacity(idxs.len() * IMG_PIXELS);
    let mut y = Vec::with_capacity(idxs.len());
    for &i in idxs {
        x.extend_from_slice(ds.image(i));
        y.push(ds.labels[i] as f32);
    }
    (x, y)
}

// ---------------------------------------------------------------------------
// Token corpus (transformer e2e)
// ---------------------------------------------------------------------------

/// Seeded Markov-chain byte corpus over an `alphabet`-symbol subset.
/// Sequences are learnable (entropy well below ln(alphabet)) but not
/// trivially constant.
pub struct MarkovCorpus {
    /// Transition CDF rows: `alphabet x alphabet`.
    cdf: Vec<f64>,
    /// Number of distinct symbols.
    pub alphabet: usize,
}

impl MarkovCorpus {
    /// A seeded corpus over `alphabet` symbols (same seed ⇒ same chain).
    pub fn new(alphabet: usize, seed: u64) -> Self {
        assert!(alphabet >= 2);
        let mut rng = Rng::for_stream(seed, 0xC0DE, alphabet as u64, 0);
        let mut cdf = vec![0.0f64; alphabet * alphabet];
        for r in 0..alphabet {
            // Sparse-ish rows: a few favoured successors per symbol.
            let mut probs = vec![0.05f64 / alphabet as f64; alphabet];
            for _ in 0..3 {
                probs[rng.below(alphabet as u64) as usize] += 0.3 + rng.f64() * 0.4;
            }
            let total: f64 = probs.iter().sum();
            let mut acc = 0.0;
            for c in 0..alphabet {
                acc += probs[c] / total;
                cdf[r * alphabet + c] = acc;
            }
            cdf[r * alphabet + alphabet - 1] = 1.0;
        }
        MarkovCorpus { cdf, alphabet }
    }

    /// Sample a token sequence of length `len` (values < alphabet ≤ 256).
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(len);
        let mut state = rng.below(self.alphabet as u64) as usize;
        out.push(state as f32);
        for _ in 1..len {
            let u = rng.f64();
            let row = &self.cdf[state * self.alphabet..(state + 1) * self.alphabet];
            state = row.partition_point(|&c| c < u).min(self.alphabet - 1);
            out.push(state as f32);
        }
        out
    }

    /// Entropy rate (nats/token) of the chain under its stationary
    /// distribution — the loss floor the LM should approach.
    pub fn entropy_rate(&self) -> f64 {
        // Estimate the stationary distribution by power iteration.
        let a = self.alphabet;
        let mut pi = vec![1.0 / a as f64; a];
        for _ in 0..500 {
            let mut next = vec![0.0f64; a];
            for r in 0..a {
                let mut prev = 0.0;
                for c in 0..a {
                    let p = self.cdf[r * a + c] - prev;
                    prev = self.cdf[r * a + c];
                    next[c] += pi[r] * p;
                }
            }
            pi = next;
        }
        let mut h = 0.0;
        for r in 0..a {
            let mut prev = 0.0;
            for c in 0..a {
                let p = self.cdf[r * a + c] - prev;
                prev = self.cdf[r * a + c];
                if p > 1e-12 {
                    h -= pi[r] * p * p.ln();
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shapes_and_range() {
        let ds = mnist_like(100, 1);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.images.len(), 100 * IMG_PIXELS);
        assert!(ds.images.iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!(ds.labels.iter().all(|&l| (l as usize) < NUM_CLASSES));
    }

    #[test]
    fn dataset_deterministic() {
        let a = mnist_like(50, 7);
        let b = mnist_like(50, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = mnist_like(50, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean intra-class pixel distance must be well below inter-class.
        let ds = mnist_like(400, 3);
        let mut by_class: Vec<Vec<usize>> = vec![vec![]; NUM_CLASSES];
        for i in 0..ds.len() {
            by_class[ds.labels[i] as usize].push(i);
        }
        let dist = |a: usize, b: usize| -> f64 {
            ds.image(a)
                .iter()
                .zip(ds.image(b))
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        // Average over several pairs — single pairs are noisy by design
        // (the task must be hard enough that quantization noise matters).
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n = 0.0;
        for c in 0..NUM_CLASSES {
            for k in 0..4 {
                intra += dist(by_class[c][k], by_class[c][k + 1]);
                inter += dist(by_class[c][k], by_class[(c + 1) % NUM_CLASSES][k]);
                n += 1.0;
            }
        }
        assert!(intra / n < inter / n, "intra {intra} vs inter {inter}");
    }

    #[test]
    fn shards_partition() {
        let ds = mnist_like(103, 1);
        let n = 8;
        let total: usize = (0..n).map(|i| ds.shard(i, n).len()).sum();
        assert_eq!(total, 103);
        let sizes: Vec<usize> = (0..n).map(|i| ds.shard(i, n).len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn dirichlet_shards_partition_and_are_deterministic() {
        let ds = mnist_like(500, 4);
        let shards = dirichlet_shards(&ds, 8, 0.3, 4);
        assert_eq!(shards.len(), 8);
        let total: usize = shards.iter().map(Dataset::len).sum();
        assert_eq!(total, 500, "every sample lands in exactly one shard");
        assert!(shards.iter().all(|s| !s.is_empty()));
        let again = dirichlet_shards(&ds, 8, 0.3, 4);
        for (a, b) in shards.iter().zip(&again) {
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.images, b.images);
        }
    }

    #[test]
    fn dirichlet_alpha_controls_label_skew() {
        // Mean (over shards) max-class share: near 1/NUM_CLASSES for huge
        // alpha (IID-ish), well above it for small alpha (concentrated).
        let ds = mnist_like(2000, 5);
        let max_share = |alpha: f64| -> f64 {
            let shards = dirichlet_shards(&ds, 8, alpha, 5);
            let mut acc = 0.0;
            for s in &shards {
                let mut counts = [0usize; NUM_CLASSES];
                for &l in &s.labels {
                    counts[l as usize] += 1;
                }
                acc += *counts.iter().max().unwrap() as f64 / s.len() as f64;
            }
            acc / shards.len() as f64
        };
        let skewed = max_share(0.1);
        let iidish = max_share(100.0);
        assert!(iidish < 0.2, "alpha=100 should be near-balanced: {iidish}");
        assert!(skewed > 0.3, "alpha=0.1 should concentrate labels: {skewed}");
        assert!(skewed > iidish + 0.1, "{skewed} vs {iidish}");
    }

    #[test]
    fn sampler_covers_epoch() {
        let mut s = BatchSampler::new(10, 1, 0);
        let mut seen = vec![false; 10];
        for _ in 0..5 {
            for i in s.next_batch(2) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sampler_deterministic_per_client() {
        let mut a = BatchSampler::new(100, 1, 3);
        let mut b = BatchSampler::new(100, 1, 3);
        assert_eq!(a.next_batch(32), b.next_batch(32));
        let mut c = BatchSampler::new(100, 1, 4);
        assert_ne!(a.next_batch(32), c.next_batch(32));
    }

    #[test]
    fn gather_batch_shapes() {
        let ds = mnist_like(20, 1);
        let (x, y) = gather_batch(&ds, &[0, 5, 7]);
        assert_eq!(x.len(), 3 * IMG_PIXELS);
        assert_eq!(y.len(), 3);
        assert_eq!(y[1], ds.labels[5] as f32);
    }

    #[test]
    fn markov_tokens_in_alphabet() {
        let c = MarkovCorpus::new(64, 1);
        let mut rng = Rng::new(2);
        let seq = c.sample(500, &mut rng);
        assert!(seq.iter().all(|&t| t >= 0.0 && t < 64.0 && t.fract() == 0.0));
    }

    #[test]
    fn markov_entropy_below_uniform() {
        let c = MarkovCorpus::new(64, 1);
        let h = c.entropy_rate();
        assert!(h > 0.0 && h < (64.0f64).ln(), "h = {h}");
        // Learnability: needs real structure, not near-uniform.
        assert!(h < 0.8 * (64.0f64).ln(), "chain too uniform: {h}");
    }
}
