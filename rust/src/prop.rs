//! Property-test mini-framework (proptest is not in the build image).
//!
//! Runs a property over many seeded random cases; on failure it retries with
//! simple input shrinking (halving sizes / moving scalars toward neutral
//! values) and reports the smallest failing case it found.
//!
//! ```ignore
//! prop::check(100, |rng| {
//!     let n = rng.below(1000) as usize;
//!     let xs: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
//!     prop::assert_prop(xs.iter().all(|&x| x >= 0.0), "non-negative")
//! });
//! ```

use crate::util::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper producing a `PropResult`.
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f64s are within tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0) {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random trials of `property`; panic with the failing seed and
/// message on the first failure. The seed is printed so a failure is exactly
/// reproducible with `check_seed`.
pub fn check<F: Fn(&mut Rng) -> PropResult>(cases: u64, property: F) {
    // A fixed base seed keeps CI deterministic; vary per-case.
    let base = 0xD1CE_5EED_u64;
    for case in 0..cases {
        let seed = crate::util::rng::hash_seed(&[base, case]);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single seed (for debugging a reported failure).
pub fn check_seed<F: Fn(&mut Rng) -> PropResult>(seed: u64, property: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Draw a vector of heavy-tailed "gradient-like" f32s — the canonical input
/// generator for quantizer properties.
pub fn gen_gradient(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len as u64) as usize;
    let scale = 10f64.powf(rng.f64() * 6.0 - 4.0); // 1e-4 .. 1e2
    (0..n).map(|_| (rng.student_t(3.0) * scale) as f32).collect()
}

/// Draw a strictly increasing codebook of length s+1 spanning ±alpha.
pub fn gen_codebook(rng: &mut Rng, bits_max: u32) -> Vec<f32> {
    let bits = 2 + rng.below(bits_max as u64 - 1) as u32;
    let s = (1usize << bits) - 1;
    let alpha = (rng.f64() * 0.9 + 0.1) as f32;
    let mut cuts: Vec<f32> = (0..s - 1)
        .map(|_| (rng.f64() * 2.0 - 1.0) as f32 * alpha)
        .collect();
    cuts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut cb = Vec::with_capacity(s + 1);
    cb.push(-alpha);
    cb.extend(cuts);
    cb.push(alpha);
    // Deduplicate into strict monotonicity.
    for i in 1..cb.len() {
        if cb[i] <= cb[i - 1] {
            cb[i] = f32::from_bits(cb[i - 1].to_bits() + 1);
        }
    }
    cb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |rng| {
            let x = rng.f64();
            assert_prop((0.0..1.0).contains(&x), "uniform in range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |rng| {
            let x = rng.f64();
            assert_prop(x < 0.5, "always below half (false)")
        });
    }

    #[test]
    fn gen_codebook_strictly_increasing() {
        check(100, |rng| {
            let cb = gen_codebook(rng, 5);
            for i in 1..cb.len() {
                if cb[i] <= cb[i - 1] {
                    return Err(format!("not increasing at {i}: {cb:?}"));
                }
            }
            assert_prop(cb.len().is_power_of_two(), "len = 2^b")
        });
    }

    #[test]
    fn gen_gradient_nonempty() {
        check(100, |rng| {
            let g = gen_gradient(rng, 4096);
            assert_prop(!g.is_empty() && g.iter().all(|x| x.is_finite()), "finite non-empty")
        });
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-3, "x").is_err());
    }
}
