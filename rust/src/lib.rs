//! # tqsgd — Truncated Quantization for Heavy-Tailed Gradients in Distributed SGD
//!
//! Production-quality reproduction of *"Improved Quantization Strategies for
//! Managing Heavy-tailed Gradients in Distributed Learning"* (Yan, Li, Xiao,
//! Hou, Song — cs.LG 2024).
//!
//! The library implements the paper's two-stage quantizer `Q_λs[T_α(·)]`
//! (truncation + stochastic quantization) with the three densities the paper
//! analyses — uniform (**TQSGD**), optimal non-uniform `p(g)^{1/3}`
//! (**TNQSGD**, Eq. 18) and BiScaled (**TBQSGD**, Appendix D) — plus the
//! baselines it compares against (QSGD, NQSGD, TernGrad, Top-k, oracle DSGD),
//! a power-law tail estimator (§V), the fixed-point solvers for the optimal
//! truncation threshold (Eqs. 12/19/33), the closed-form convergence-bound
//! calculators (Lemma 1/2, Theorems 1–3), and a multi-threaded distributed
//! SGD coordinator whose compute (model fwd/bwd, Pallas quantizer kernels)
//! is AOT-compiled JAX executed through PJRT — python never runs at train
//! time.
//!
//! ## Layer map
//!
//! | Layer | Where | What |
//! |-------|-------|------|
//! | L3 | [`coordinator`], [`train`], [`quant`] | distributed runtime + wire codecs |
//! | L2 | `python/compile/{model,transformer}.py` → [`runtime`] | model fwd/bwd as HLO |
//! | L1 | `python/compile/kernels/*.py` → [`runtime::QuantExec`] | Pallas quantizer |
//!
//! ## Quickstart
//!
//! ```no_run
//! use tqsgd::config::ExperimentConfig;
//! use tqsgd::train::Trainer;
//!
//! let cfg = ExperimentConfig::preset("cnn_tnqsgd_b3").unwrap();
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final test accuracy: {:.4}", report.final_accuracy);
//! ```

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod metrics;
pub mod optim;
pub mod prop;
pub mod quant;
pub mod runtime;
pub mod solver;
pub mod tail;
pub mod theory;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
