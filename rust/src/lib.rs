//! # tqsgd — Truncated Quantization for Heavy-Tailed Gradients in Distributed SGD
//!
//! Production-quality reproduction of *"Improved Quantization Strategies for
//! Managing Heavy-tailed Gradients in Distributed Learning"* (Yan, Li, Xiao,
//! Hou, Song — cs.LG 2024).
//!
//! The library implements the paper's two-stage quantizer `Q_λs[T_α(·)]`
//! (truncation + stochastic quantization) with the three densities the paper
//! analyses — uniform (**TQSGD**), optimal non-uniform `p(g)^{1/3}`
//! (**TNQSGD**, Eq. 18) and BiScaled (**TBQSGD**, Appendix D) — plus the
//! baselines it compares against (QSGD, NQSGD, TernGrad, Top-k, oracle DSGD),
//! a power-law tail estimator (§V), the fixed-point solvers for the optimal
//! truncation threshold (Eqs. 12/19/33), the closed-form convergence-bound
//! calculators (Lemma 1/2, Theorems 1–3), and a multi-threaded distributed
//! SGD coordinator whose compute (model fwd/bwd, quantizer kernels) runs on
//! a pluggable [`runtime::Backend`].
//!
//! ## Layer map
//!
//! | Layer | Where | What |
//! |-------|-------|------|
//! | L3 | [`coordinator`], [`train`], [`quant`] | distributed runtime + wire codecs |
//! | L2 | [`runtime::Backend`] — [`runtime::NativeBackend`] (default) or PJRT (`--features pjrt`, from `python/compile/{model,transformer}.py` HLO) | model fwd/bwd |
//! | L1 | [`runtime::QuantKernel`] — runtime-dispatched kernels in [`quant::kernels`] (AVX2/SSE2/NEON via [`quant::simd`], scalar fallback; default) or AOT Pallas via PJRT | quantizer kernels |
//!
//! ## Backends and feature flags
//!
//! * **default** — [`runtime::NativeBackend`]: pure Rust, zero dependencies
//!   beyond the vendored `anyhow`; builds, tests and trains from a clean
//!   checkout with no Python/JAX installed.
//! * **`pjrt`** — compiles the PJRT/XLA execution path ([`runtime::pjrt`])
//!   for AOT artifacts produced by `python/compile/aot.py`. Without real
//!   xla-rs bindings linked, it compiles against an in-tree stub and reports
//!   a clear error at runtime (see `runtime/xla_stub.rs`).
//!
//! Backend selection is per-experiment via `ExperimentConfig::backend`
//! (`"auto"` | `"native"` | `"pjrt"`) or the CLI's `--backend` flag; `auto`
//! uses PJRT only when it is compiled in AND `artifacts/manifest.json`
//! exists.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tqsgd::config::ExperimentConfig;
//! use tqsgd::train::Trainer;
//!
//! let cfg = ExperimentConfig::preset("cnn_tnqsgd_b3").unwrap();
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final test accuracy: {:.4}", report.final_accuracy);
//! ```
//!
//! Local commands mirroring CI (see `.github/workflows/ci.yml`):
//!
//! ```text
//! cargo build --release          # default = native backend
//! cargo test -q
//! cargo build --release --features pjrt
//! cargo clippy --all-targets -- -D warnings -A missing_docs
//! cargo fmt --all --check
//! cargo bench --no-run           # compile-only smoke gate for benches
//! cargo run --release --example quickstart
//! ```

// `unsafe` is denied crate-wide with ONE audited exception: the SIMD
// intrinsics in `quant::simd` (which opts back in via `#![allow(unsafe_code)]`
// and documents a SAFETY argument per entry point). Everything else —
// including every public API — is safe Rust.
#![deny(unsafe_code)]
#![warn(missing_docs)]

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod metrics;
pub mod optim;
pub mod prop;
pub mod quant;
pub mod runtime;
pub mod solver;
pub mod tail;
pub mod theory;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
