//! `tqsgd` CLI — leader entrypoint for the distributed-SGD coordinator.
//!
//! ```text
//! tqsgd train   [--preset cnn_tnqsgd_b3] [--model cnn --scheme tnqsgd --bits 3 ...]
//! tqsgd sweep   --schemes qsgd,tqsgd,tnqsgd --bits-list 2,3,4,5 [...]
//! tqsgd fit-tail [--model cnn --rounds 5]
//! tqsgd solve   --gamma 4.0 --gmin 0.01 --rho 0.1 --bits 3
//! tqsgd info
//! tqsgd perf-check --current BENCH_perf.json [--baseline BENCH_baseline.json]
//! tqsgd serve   --listen 127.0.0.1:7700 [--clients 3 --rounds 5 ...]
//! tqsgd worker  --connect 127.0.0.1:7700 --client-id 0
//! tqsgd launch  [--clients 3 --rounds 5 --verify-digest --chaos ...]
//! tqsgd resume  --checkpoint run.ckpt [--checkpoint-every 1]
//! ```

use std::time::Duration;

use anyhow::{anyhow, bail, Result};
use tqsgd::benchkit::{check_ceiling, check_regression, Report, Table};
use tqsgd::cli::Args;
use tqsgd::config::{ExperimentConfig, PipelineMode, Scheme};
use tqsgd::coordinator::{
    checkpoint, run_worker, scenario::chaos_kill_target, teardown_workers, Coordinator,
    TcpOptions, TcpServer, WorkerExit, WorkerOptions,
};
use tqsgd::metrics::RunLog;
use tqsgd::runtime::make_backend;
use tqsgd::solver;
use tqsgd::tail::{fit_gaussian, fit_laplace, fit_power_law, PowerLawModel};
use tqsgd::train::{Sweep, Trainer};

/// Exit code a worker uses when a seeded chaos fault kills it — `launch`'s
/// respawn monitor treats this (and only this) as "scheduled death".
const EXIT_CHAOS_KILL: i32 = 17;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("fit-tail") => cmd_fit_tail(&args),
        Some("solve") => cmd_solve(&args),
        Some("info") => cmd_info(&args),
        Some("perf-check") => cmd_perf_check(&args),
        Some("serve") => cmd_serve(&args),
        Some("worker") => cmd_worker(&args),
        Some("launch") => cmd_launch(&args),
        Some("resume") => cmd_resume(&args),
        Some(other) => {
            bail!(
                "unknown subcommand {other:?}; try: train sweep fit-tail solve info \
                 perf-check serve worker launch resume"
            )
        }
        None => {
            println!(
                "tqsgd — truncated quantization for heavy-tailed gradients in distributed SGD\n\n\
                 subcommands:\n\
                 \x20 train     run one distributed training experiment\n\
                 \x20 sweep     scheme x bits sweep (communication-learning tradeoff)\n\
                 \x20 fit-tail  fit power-law/gaussian/laplace to real model gradients\n\
                 \x20 solve     print optimal quantizer parameters for a tail model\n\
                 \x20 info      show the selected backend and its models\n\
                 \x20 perf-check  gate a bench JSON report against the committed baseline\n\
                 \x20 serve     coordinator server: wait for TCP workers, then train\n\
                 \x20 worker    client worker process: connect to a coordinator\n\
                 \x20 launch    spawn N local workers + coordinator, run, tear down\n\
                 \x20 resume    continue a run from a --checkpoint file (bit-exact)\n\n\
                 common flags: --model --scheme --bits --clients --rounds --lr --seed\n\
                 \x20             --backend (auto|native|pjrt) --error-feedback\n\
                 \x20             --drop-client --artifacts --preset\n\
                 \x20             --agg-shards (server aggregation fan-out; 0 = auto)\n\
                 \x20             --encode-threads (barrier encode pool; 0 = auto; bit-identical)\n\
                 \x20             --pipeline (barrier|streaming round engine; bit-identical)\n\
                 \x20             --cohort-k (clients sampled per round; 0 = all, K >= N = all)\n\
                 \x20             --agg-tiers (1 = flat aggregation; 2 = two-tier re-encoded tree)\n\
                 \x20             --bit-budget (fleet uplink bytes/round; 0 = scheduler off;\n\
                 \x20              pairs well with --scheme multiscale, which re-rates per round)\n\
                 \x20             --checkpoint PATH --checkpoint-every N (periodic resumable\n\
                 \x20              snapshots; continue with `tqsgd resume --checkpoint PATH`)\n\
                 scenario flags: --scenario (clean|straggler|lossy|churn|stale|noniid|bandwidth|chaos)\n\
                 \x20             --straggler-frac --straggler-mult --loss-prob --max-retries\n\
                 \x20             --dropout-prob --rejoin-prob --stale-k --stale-decay\n\
                 \x20             --noniid-alpha\n\
                 \x20             --uplink-cap --uplink-cap-frac (per-client byte caps; the\n\
                 \x20              bandwidth preset draws seeded caps in [frac*cap, cap])\n\
                 \x20             --chaos-corrupt-prob --chaos-corrupt-bytes --chaos-kill-round\n\
                 \x20             --chaos-stall-prob --chaos-stall-secs (seeded fault injection;\n\
                 \x20              `launch --chaos` kills + respawns a real worker process)"
            );
            Ok(())
        }
    }
}

fn base_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("preset") {
        Some(p) => ExperimentConfig::preset(p)?,
        None => match args.get("config") {
            Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
            None => ExperimentConfig::default(),
        },
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    println!("config: {}", cfg.id());
    if !cfg.scenario.is_clean() {
        println!("scenario: {} (seeded, bit-reproducible)", cfg.scenario.name);
    }
    if cfg.bit_budget > 0 {
        println!("bit budget: {} uplink bytes/round (adaptive per-group rates)", cfg.bit_budget);
    }
    let mut trainer = Trainer::new(cfg.clone())?;
    if let Some(path) = args.get("checkpoint") {
        let every = args.usize_or("checkpoint-every", 1)?;
        if every == 0 {
            bail!("--checkpoint-every must be >= 1");
        }
        println!("checkpointing to {path} every {every} round(s)");
        trainer.checkpoint_to(std::path::PathBuf::from(path), every);
    }
    let report = trainer.run_verbose(true)?;
    println!(
        "\nfinal: acc {:.4} (best {:.4}) train_loss {:.4} bytes_up {} ({:.2} bits/param/round)",
        report.final_accuracy,
        report.best_accuracy,
        report.final_train_loss,
        report.total_bytes_up,
        report.bits_per_param
    );
    let retrans: u64 = report.log.records.iter().map(|r| r.retransmitted_bytes).sum();
    let max_dropped =
        report.log.records.iter().map(|r| r.dropped_clients).max().unwrap_or(0);
    if retrans > 0 || max_dropped > 0 {
        println!(
            "scenario: {retrans} retransmitted bytes, \
             max {max_dropped} clients dropped in a round"
        );
    }
    if let Some(out) = args.get("out") {
        report.log.save_csv(std::path::Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let schemes: Vec<Scheme> = args
        .str_or("schemes", "qsgd,nqsgd,tqsgd,tnqsgd,tbqsgd")
        .split(',')
        .map(Scheme::parse)
        .collect::<Result<_>>()?;
    let bits: Vec<u32> = args
        .str_or("bits-list", "2,3,4,5")
        .split(',')
        .map(|b| b.parse::<u32>().map_err(Into::into))
        .collect::<Result<_>>()?;
    // Honor --backend (apply_args already validated it) rather than always
    // auto-selecting like Sweep::new does.
    let sweep = Sweep::with_backend(make_backend(&cfg)?);
    let mut table =
        Table::new(&["scheme", "bits", "final acc", "best acc", "MB up", "bits/param"]);
    for &scheme in &schemes {
        for &b in &bits {
            let mut c = cfg.clone();
            c.quant.scheme = scheme;
            c.quant.bits = b;
            let r = sweep.run(c, false)?;
            table.row(&[
                scheme.name().to_string(),
                b.to_string(),
                format!("{:.4}", r.final_accuracy),
                format!("{:.4}", r.best_accuracy),
                format!("{:.2}", r.total_bytes_up as f64 / 1e6),
                format!("{:.2}", r.bits_per_param),
            ]);
        }
    }
    table.print();
    Ok(())
}

/// Train briefly uncompressed, harvest the aggregate gradient, fit all three
/// families per layer group — the Fig. 1 experiment from the CLI.
fn cmd_fit_tail(args: &Args) -> Result<()> {
    let mut cfg = base_config(args)?;
    cfg.quant.scheme = Scheme::Dsgd;
    cfg.rounds = args.usize_or("rounds", 5)?;
    let backend = make_backend(&cfg)?;
    let mut coord = Coordinator::new(cfg.clone(), backend.as_ref())?;
    let spec = coord.model_spec().clone();
    for _ in 0..cfg.rounds {
        coord.step()?;
    }
    let grads = coord.last_aggregate().to_vec();
    let mut table = Table::new(&["group", "family", "params", "KS"]);
    for g in &spec.groups {
        let xs = &grads[g.start..g.end];
        if let Some(pl) = fit_power_law(xs) {
            table.row(&[
                g.group.clone(),
                "power-law".into(),
                format!(
                    "γ={:.2} g_min={:.4} ρ={:.4}",
                    pl.params[0], pl.params[1], pl.params[2]
                ),
                format!("{:.4}", pl.ks),
            ]);
        }
        let ga = fit_gaussian(xs);
        table.row(&[
            g.group.clone(),
            "gaussian".into(),
            format!("µ={:.1e} σ={:.3e}", ga.params[0], ga.params[1]),
            format!("{:.4}", ga.ks),
        ]);
        let la = fit_laplace(xs);
        table.row(&[
            g.group.clone(),
            "laplace".into(),
            format!("µ={:.1e} b={:.3e}", la.params[0], la.params[1]),
            format!("{:.4}", la.ks),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let gamma = args.f64_or("gamma", 4.0)?;
    let g_min = args.f64_or("gmin", 0.01)?;
    let rho = args.f64_or("rho", 0.1)?;
    let bits = args.usize_or("bits", 3)? as u32;
    let m = PowerLawModel::new(gamma, g_min, rho);
    let s = solver::levels_for_bits(bits);
    let au = solver::optimal_alpha_uniform(&m, s);
    let an = solver::optimal_alpha_nonuniform(&m, s);
    let d = solver::solve_biscaled(&m, s);
    println!("model: γ={gamma} g_min={g_min} ρ={rho}  (b={bits}, s={s})");
    println!("TQSGD   α* = {au:.5}   E_TQ = {:.3e}", solver::e_tq_uniform(&m, au, s));
    println!("TNQSGD  α* = {an:.5}   E_TQ = {:.3e}", solver::e_tq_nonuniform(&m, an, s));
    println!(
        "TBQSGD  α* = {:.5} β* = {:.5} (k*={:.3}, s_β={}, s_α={})  E_TQ = {:.3e}",
        d.alpha,
        d.beta,
        d.k,
        d.s_beta,
        d.s_alpha,
        solver::e_tq_biscaled(&m, &d, s)
    );
    println!("\nTNQSGD codebook: {:?}", solver::nonuniform_codebook(&m, an, s));
    println!("TBQSGD codebook: {:?}", d.codebook());
    Ok(())
}

/// CI perf gate: compare a fresh bench JSON report (`perf_hotpath`,
/// `perf_server`, `perf_round`) against the committed `BENCH_baseline.json`
/// and fail (non-zero exit) when a gated metric broke its bound. `--metric`
/// lists higher-is-better metrics (each must stay within `--max-drop` of its
/// baseline floor); `--metric-max` lists lower-is-better metrics like
/// `budget_bytes_per_round` (each must stay within `--max-rise` of its
/// baseline ceiling). Both take comma-separated lists.
fn cmd_perf_check(args: &Args) -> Result<()> {
    let current = args.str_or("current", "BENCH_perf.json");
    let baseline = args.str_or("baseline", "BENCH_baseline.json");
    let metrics = args.str_or("metric", "tqsgd_b4_encode_into_melems_per_s");
    let metrics_max = args.str_or("metric-max", "");
    let max_drop = args.f64_or("max-drop", 0.30)?;
    let max_rise = args.f64_or("max-rise", 0.10)?;
    let cur = Report::load(std::path::Path::new(&current))?;
    let base = Report::load(std::path::Path::new(&baseline))?;
    let mut checked = 0usize;
    for metric in metrics.split(',').map(str::trim).filter(|m| !m.is_empty()) {
        println!(
            "{}",
            check_regression(&cur, &base, metric, max_drop)
                .map_err(|e| e.context(format!("{current} vs {baseline}")))?
        );
        checked += 1;
    }
    for metric in metrics_max.split(',').map(str::trim).filter(|m| !m.is_empty()) {
        println!(
            "{}",
            check_ceiling(&cur, &base, metric, max_rise)
                .map_err(|e| e.context(format!("{current} vs {baseline}")))?
        );
        checked += 1;
    }
    // Empty --metric lists must be a loud failure, not a green no-op gate.
    if checked == 0 {
        bail!(
            "--metric {metrics:?} / --metric-max {metrics_max:?} name no metrics; \
             nothing was gated"
        );
    }
    Ok(())
}

/// Parse a `--<name>-secs` style flag into a [`Duration`].
fn secs_flag(args: &Args, name: &str, default: f64) -> Result<Duration> {
    let secs = args.f64_or(name, default)?;
    if !secs.is_finite() || secs <= 0.0 {
        bail!("--{name} must be a positive number of seconds, got {secs}");
    }
    Ok(Duration::from_secs_f64(secs))
}

fn tcp_options(args: &Args) -> Result<TcpOptions> {
    Ok(TcpOptions {
        io_timeout: secs_flag(args, "io-timeout-secs", 30.0)?,
        accept_timeout: secs_flag(args, "accept-timeout-secs", 60.0)?,
    })
}

/// Shared tail of `serve`/`launch`: summary line, optional digest print, CSV.
fn print_run_summary(args: &Args, log: &RunLog) -> Result<()> {
    println!(
        "\nfinal: acc {:.4} train_loss {:.4} bytes_up {}",
        log.final_accuracy().unwrap_or(0.0),
        log.final_train_loss().unwrap_or(f64::NAN),
        log.total_bytes_up()
    );
    let max_dropped = log.records.iter().map(|r| r.dropped_clients).max().unwrap_or(0);
    if max_dropped > 0 {
        println!("faults: max {max_dropped} clients dropped in a round");
    }
    if args.has("print-digest") {
        println!("replay_digest: {}", log.replay_digest());
    }
    if let Some(out) = args.get("out") {
        log.save_csv(std::path::Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Coordinator server mode: bind a listener, wait for `cfg.clients` worker
/// processes to complete the handshake, then drive the round loop over TCP
/// (wire format in `docs/PROTOCOL.md`).
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    println!("config: {}", cfg.id());
    let listen = args.str_or("listen", "127.0.0.1:7700");
    let server = TcpServer::bind(&listen, &cfg, tcp_options(args)?)?;
    println!("listening on {} for {} workers", server.local_addr()?, cfg.clients);
    let transport = server.accept_workers()?;
    let backend = make_backend(&cfg)?;
    let mut coord = Coordinator::with_transport(cfg, backend.as_ref(), Box::new(transport))?;
    let log = coord.run_remote(true)?;
    print_run_summary(args, &log)
}

/// Client worker mode: connect to a coordinator, receive the experiment
/// config in the handshake, and serve compressed uplinks until told to stop.
fn cmd_worker(args: &Args) -> Result<()> {
    let Some(addr) = args.get("connect") else {
        bail!("worker needs --connect HOST:PORT (the coordinator's listen address)");
    };
    if args.get("client-id").is_none() {
        bail!("worker needs --client-id N (0-based, unique per worker)");
    }
    let client_id = args.usize_or("client-id", 0)?;
    let max_rounds = args
        .get("max-rounds")
        .map(|v| v.parse::<usize>().map_err(|e| anyhow!("--max-rounds {v:?}: {e}")))
        .transpose()?;
    let rejoin_from = args
        .get("rejoin-from")
        .map(|v| v.parse::<usize>().map_err(|e| anyhow!("--rejoin-from {v:?}: {e}")))
        .transpose()?;
    let opts = WorkerOptions {
        connect_timeout: secs_flag(args, "connect-timeout-secs", 30.0)?,
        io_timeout: secs_flag(args, "io-timeout-secs", 120.0)?,
        max_rounds,
        rejoin_from,
    };
    match run_worker(addr, client_id, &opts)? {
        WorkerExit::Clean => Ok(()),
        WorkerExit::ChaosKilled { round } => {
            eprintln!("worker {client_id}: chaos kill after round {round}");
            std::process::exit(EXIT_CHAOS_KILL);
        }
    }
}

/// Orchestrator: bind an ephemeral port, spawn `cfg.clients` local worker
/// processes (this same binary in `worker` mode), run the coordinator
/// in-process, then tear the fleet down with a hard deadline. With
/// `--verify-digest`, re-run the same config in-process with the barrier
/// pipeline and fail unless the two `replay_digest()`s are bit-identical.
///
/// Chaos runs (`--chaos`, or any config with `chaos_kill_round > 0`) get a
/// respawn monitor: the seeded victim worker really dies (exit code 17),
/// and the monitor respawns it with `--rejoin-from` so it re-admits via the
/// REJOIN handshake the next round.
fn cmd_launch(args: &Args) -> Result<()> {
    let mut cfg = base_config(args)?;
    // `--chaos` shorthand for `--scenario chaos`; explicit chaos flags win.
    if args.has("chaos")
        && cfg.scenario.chaos_kill_round == 0
        && cfg.scenario.chaos_corrupt_prob == 0.0
    {
        cfg.scenario = tqsgd::config::ScenarioConfig::preset("chaos")?;
    }
    println!("config: {}", cfg.id());
    let listen = args.str_or("listen", "127.0.0.1:0");
    let server = TcpServer::bind(&listen, &cfg, tcp_options(args)?)?;
    let addr = server.local_addr()?.to_string();
    println!("coordinator on {addr}; spawning {} workers", cfg.clients);
    let exe = std::env::current_exe()?;
    let mut children = Vec::with_capacity(cfg.clients);
    for i in 0..cfg.clients {
        let child = std::process::Command::new(&exe)
            .args(["worker", "--connect", &addr, "--client-id", &i.to_string()])
            .spawn()
            .map_err(|e| anyhow!("spawning worker {i}: {e}"))?;
        children.push(child);
    }
    // Chaos kill: hand the seeded victim's child handle to a monitor thread
    // that waits for the scheduled death and respawns the worker process
    // with `--rejoin-from`, exercising the real REJOIN path end to end.
    let mut monitor = None;
    if cfg.scenario.chaos_kill_round > 0 {
        if let Some(victim) = chaos_kill_target(&cfg.scenario, cfg.seed, cfg.clients) {
            let kill_round = cfg.scenario.chaos_kill_round;
            println!("chaos: worker {victim} dies after round {kill_round}, then rejoins");
            let mut child = children.remove(victim);
            let exe = exe.clone();
            let addr = addr.clone();
            monitor = Some(std::thread::spawn(
                move || -> Result<Option<std::process::Child>> {
                    let status = child.wait()?;
                    if status.code() != Some(EXIT_CHAOS_KILL) {
                        // The run ended (or the worker failed) before the
                        // scheduled kill; nothing to respawn.
                        return Ok(None);
                    }
                    let respawn = std::process::Command::new(&exe)
                        .args([
                            "worker",
                            "--connect",
                            &addr,
                            "--client-id",
                            &victim.to_string(),
                            "--rejoin-from",
                            &kill_round.to_string(),
                        ])
                        .spawn()
                        .map_err(|e| anyhow!("respawning worker {victim}: {e}"))?;
                    Ok(Some(respawn))
                },
            ));
        }
    }
    // Run the round loop, then tear the workers down no matter how it ended.
    let result = {
        let cfg = cfg.clone();
        (move || -> Result<RunLog> {
            let transport = server.accept_workers()?;
            let backend = make_backend(&cfg)?;
            let mut coord =
                Coordinator::with_transport(cfg, backend.as_ref(), Box::new(transport))?;
            coord.run_remote(true)
        })()
    };
    if let Some(m) = monitor {
        match m.join() {
            Ok(Ok(Some(child))) => children.push(child),
            Ok(Ok(None)) => {}
            Ok(Err(e)) => eprintln!("chaos monitor: {e}"),
            Err(_) => eprintln!("chaos monitor thread panicked"),
        }
    }
    let teardown =
        teardown_workers(&mut children, secs_flag(args, "teardown-timeout-secs", 10.0)?);
    let log = result?;
    teardown?;
    let digest = log.replay_digest();
    if args.has("verify-digest") {
        let mut ref_cfg = cfg;
        ref_cfg.pipeline = PipelineMode::Barrier;
        let backend = make_backend(&ref_cfg)?;
        let mut coord = Coordinator::new(ref_cfg, backend.as_ref())?;
        let ref_digest = coord.run(false)?.replay_digest();
        if digest != ref_digest {
            bail!(
                "digest mismatch: multi-process run != in-process barrier\n  \
                 tcp:     {digest}\n  barrier: {ref_digest}"
            );
        }
        println!("digest parity: multi-process == in-process barrier (bit-identical)");
    }
    print_run_summary(args, &log)
}

/// Continue a checkpointed run to its configured round count. With
/// `estimate_every = 1` the continuation is bit-identical — parameters and
/// `replay_digest()` — to the uninterrupted run (DETERMINISM.md
/// invariant 7). `--checkpoint-every N` keeps snapshotting to the same file.
fn cmd_resume(args: &Args) -> Result<()> {
    let Some(path) = args.get("checkpoint") else {
        bail!("resume needs --checkpoint PATH (written by `train --checkpoint`)");
    };
    let path = std::path::PathBuf::from(path);
    let cfg = checkpoint::load_config(&path)?;
    println!("config: {}", cfg.id());
    println!("resuming from {} (continuing to round {})", path.display(), cfg.rounds);
    let backend = make_backend(&cfg)?;
    let mut coord = Coordinator::resume(&path, backend.as_ref())?;
    let every = args.usize_or("checkpoint-every", 0)?;
    if every > 0 {
        coord.checkpoint_to(path.clone(), every);
    }
    let log = coord.run(true)?;
    print_run_summary(args, &log)
}

fn cmd_info(args: &Args) -> Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.apply_args(args)?;
    let backend = make_backend(&cfg)?;
    println!("backend: {}", backend.name());
    let mut table = Table::new(&["model", "kind", "params", "groups", "train B", "eval B"]);
    for name in backend.models() {
        let m = backend.model(&name)?;
        table.row(&[
            name.clone(),
            m.kind.clone(),
            m.param_count.to_string(),
            m.groups
                .iter()
                .map(|g| format!("{}[{}..{})", g.group, g.start, g.end))
                .collect::<Vec<_>>()
                .join(" "),
            m.train_batch.to_string(),
            m.eval_batch.to_string(),
        ]);
    }
    table.print();
    Ok(())
}
