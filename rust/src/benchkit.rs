//! Bench harness substrate (criterion is not in the build image).
//!
//! Provides warmup + repeated timed runs with median/mean/stddev reporting,
//! throughput helpers, and an aligned table printer used by every
//! `benches/*.rs` target to render the paper's figures as text series.

use std::time::Instant;

/// Timing statistics over the measured runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub median_ns: f64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub runs: usize,
}

impl Timing {
    pub fn per_elem_ns(&self, elems: usize) -> f64 {
        self.median_ns / elems as f64
    }

    /// Throughput in GB/s given bytes touched per run.
    pub fn gbps(&self, bytes: usize) -> f64 {
        bytes as f64 / self.median_ns
    }

    pub fn pretty(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f`, returning stats. Warms up `warmup` times, measures `runs` times.
pub fn bench<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var =
        samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / samples.len() as f64;
    Timing { median_ns: median, mean_ns: mean, stddev_ns: var.sqrt(), runs }
}

/// Auto-sizing: pick an iteration count so one measurement takes ≥ `min_ms`.
pub fn calibrate<F: FnMut()>(mut f: F, min_ms: f64) -> usize {
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        if ms >= min_ms || iters >= 1 << 24 {
            return iters;
        }
        iters = (iters as f64 * (min_ms / ms.max(1e-3)).clamp(2.0, 16.0)) as usize;
    }
}

/// Aligned text table (markdown-ish) for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = width + 2));
        }
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

/// Section header used by the bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Env-var override for bench sizing (e.g. `TQSGD_BENCH_ROUNDS=800`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let t = bench(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.median_ns > 0.0 && t.mean_ns > 0.0);
        assert_eq!(t.runs, 10);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn calibrate_scales_up() {
        let iters = calibrate(
            || {
                std::hint::black_box(1 + 1);
            },
            1.0,
        );
        assert!(iters > 1);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
