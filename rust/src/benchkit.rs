//! Bench harness substrate (criterion is not in the build image).
//!
//! Provides warmup + repeated timed runs with median/mean/stddev reporting,
//! throughput helpers, an aligned table printer used by every `benches/*.rs`
//! target to render the paper's figures as text series — and the
//! machine-readable side of the harness:
//!
//! * [`BenchOpts`] — the shared `--quick` / `TQSGD_BENCH_QUICK=1` sizing
//!   switch and the `--json <path>` / `TQSGD_BENCH_JSON` report destination
//!   every bench target honors (no per-target `env_usize` drift),
//! * [`Report`] — captures every printed table plus named numeric metrics
//!   and serializes them to JSON (the `BENCH_*.json` perf trajectory),
//! * [`check_regression`] / [`check_ceiling`] — the CI gates comparing a
//!   fresh report against the committed `BENCH_baseline.json` floors
//!   (throughput, higher is better) and ceilings (bytes, lower is better);
//!   see `tqsgd perf-check`.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::json::{self, Value};

/// Timing statistics over the measured runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Median time per run, in nanoseconds (the headline statistic).
    pub median_ns: f64,
    /// Mean time per run, in nanoseconds.
    pub mean_ns: f64,
    /// Standard deviation across runs, in nanoseconds.
    pub stddev_ns: f64,
    /// Number of measured (post-warmup) runs.
    pub runs: usize,
}

impl Timing {
    /// Build the statistics from raw per-run samples (nanoseconds).
    pub fn from_samples(mut samples: Vec<f64>) -> Timing {
        assert!(!samples.is_empty(), "need at least one sample");
        let runs = samples.len();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / samples.len() as f64;
        Timing { median_ns: median, mean_ns: mean, stddev_ns: var.sqrt(), runs }
    }

    /// Median nanoseconds per element for `elems` elements per run.
    pub fn per_elem_ns(&self, elems: usize) -> f64 {
        self.median_ns / elems as f64
    }

    /// Throughput in GB/s given bytes touched per run.
    pub fn gbps(&self, bytes: usize) -> f64 {
        bytes as f64 / self.median_ns
    }

    /// Throughput in millions of elements per second for `elems` per run.
    pub fn melems_per_s(&self, elems: usize) -> f64 {
        elems as f64 * 1e3 / self.median_ns
    }

    /// The median formatted with a human-readable unit (see [`fmt_ns`]).
    pub fn pretty(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

/// Format a nanosecond count with the largest fitting unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f`, returning stats. Warms up `warmup` times, measures `runs` times.
pub fn bench<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Timing::from_samples(samples)
}

/// Auto-sizing: pick an iteration count so one measurement takes ≥ `min_ms`.
pub fn calibrate<F: FnMut()>(mut f: F, min_ms: f64) -> usize {
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        if ms >= min_ms || iters >= 1 << 24 {
            return iters;
        }
        iters = (iters as f64 * (min_ms / ms.max(1e-3)).clamp(2.0, 16.0)) as usize;
    }
}

// ---------------------------------------------------------------------------
// Shared bench invocation options
// ---------------------------------------------------------------------------

/// Options every bench target parses the same way: the CI-sized `--quick`
/// switch (or `TQSGD_BENCH_QUICK=1`) and the JSON report destination
/// (`--json <path>`, `--json=<path>`, or `TQSGD_BENCH_JSON=<path>`).
/// Unrecognized arguments (e.g. cargo's `--bench`) are ignored.
#[derive(Clone, Debug, Default)]
pub struct BenchOpts {
    /// CI-sized run: small defaults for every [`BenchOpts::size`] knob.
    pub quick: bool,
    /// Where [`Report::finish`] writes the JSON report (None = print only).
    pub json_path: Option<String>,
}

impl BenchOpts {
    /// Parse from the process arguments and environment.
    pub fn from_env_and_args() -> BenchOpts {
        Self::parse(std::env::args().skip(1), |k| std::env::var(k).ok())
    }

    /// Testable core of [`Self::from_env_and_args`]: explicit flags win over
    /// the environment.
    pub fn parse<I, F>(args: I, env: F) -> BenchOpts
    where
        I: IntoIterator<Item = String>,
        F: Fn(&str) -> Option<String>,
    {
        let mut o = BenchOpts::default();
        let mut it = args.into_iter();
        while let Some(a) = it.next() {
            if a == "--quick" {
                o.quick = true;
            } else if a == "--json" {
                if let Some(p) = it.next() {
                    o.json_path = Some(p);
                }
            } else if let Some(p) = a.strip_prefix("--json=") {
                o.json_path = Some(p.to_string());
            }
        }
        if !o.quick {
            o.quick = matches!(
                env("TQSGD_BENCH_QUICK").as_deref(),
                Some("1") | Some("true") | Some("yes")
            );
        }
        if o.json_path.is_none() {
            o.json_path = env("TQSGD_BENCH_JSON").filter(|p| !p.is_empty());
        }
        o
    }

    /// Bench sizing with one convention for every target: an explicit
    /// `env_var` override (e.g. `TQSGD_BENCH_ROUNDS=800`) wins; otherwise
    /// the `quick` or `full` default, by [`Self::quick`].
    pub fn size(&self, env_var: &str, full: usize, quick: usize) -> usize {
        match std::env::var(env_var).ok().and_then(|v| v.parse().ok()) {
            Some(n) => n,
            None if self.quick => quick,
            None => full,
        }
    }
}

// ---------------------------------------------------------------------------
// Tables + machine-readable report
// ---------------------------------------------------------------------------

/// Aligned text table (markdown-ish) for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append one row; panics unless it has one cell per header.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Column headers (for [`Report::table`] capture).
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Row cells (for [`Report::table`] capture).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Print the table with aligned columns.
    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = width + 2));
        }
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

struct ReportTable {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

/// Machine-readable bench report: every table the target printed plus named
/// numeric metrics, serialized as JSON. The committed `BENCH_baseline.json`
/// is one of these; the CI perf gate compares metric-to-metric (see
/// [`check_regression`]).
///
/// Schema:
///
/// ```json
/// {"bench": "perf_hotpath", "mode": "quick" | "full",
///  "metrics": {"tqsgd_b4_encode_into_melems_per_s": 312.4, ...},
///  "tables": [{"title": "...", "headers": ["..."], "rows": [["..."]]}]}
/// ```
pub struct Report {
    name: String,
    quick: bool,
    metrics: Vec<(String, f64)>,
    tables: Vec<ReportTable>,
}

impl Report {
    /// Start a report for the named bench target.
    pub fn new(name: &str, opts: &BenchOpts) -> Report {
        Report { name: name.to_string(), quick: opts.quick, metrics: vec![], tables: vec![] }
    }

    /// The bench target this report belongs to.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Capture a printed table under `title`.
    pub fn table(&mut self, title: &str, t: &Table) {
        self.tables.push(ReportTable {
            title: title.to_string(),
            headers: t.headers().to_vec(),
            rows: t.rows().to_vec(),
        });
    }

    /// Record a named numeric metric (later entries with the same name win
    /// in [`Self::metric_value`] lookups — last write is authoritative).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Look up a recorded metric by name.
    pub fn metric_value(&self, name: &str) -> Option<f64> {
        self.metrics.iter().rev().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Serialize to the JSON schema above.
    pub fn to_value(&self) -> Value {
        let metrics = Value::Obj(
            self.metrics.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
        );
        let tables = Value::Arr(
            self.tables
                .iter()
                .map(|t| {
                    json::obj(vec![
                        ("title", json::s(&t.title)),
                        (
                            "headers",
                            Value::Arr(t.headers.iter().map(|h| json::s(h)).collect()),
                        ),
                        (
                            "rows",
                            Value::Arr(
                                t.rows
                                    .iter()
                                    .map(|r| {
                                        Value::Arr(r.iter().map(|c| json::s(c)).collect())
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        json::obj(vec![
            ("bench", json::s(&self.name)),
            ("mode", json::s(if self.quick { "quick" } else { "full" })),
            ("metrics", metrics),
            ("tables", tables),
        ])
    }

    /// Parse a report back from its JSON form.
    pub fn from_value(v: &Value) -> Result<Report> {
        let name = v.req("bench")?.as_str().ok_or_else(|| anyhow!("bench must be a string"))?;
        let quick = v.req("mode")?.as_str() == Some("quick");
        let mut metrics = Vec::new();
        if let Some(m) = v.get("metrics").and_then(|m| m.as_obj()) {
            for (k, val) in m {
                metrics.push((
                    k.clone(),
                    val.as_f64().ok_or_else(|| anyhow!("metric {k:?} must be numeric"))?,
                ));
            }
        }
        let mut tables = Vec::new();
        if let Some(ts) = v.get("tables").and_then(|t| t.as_arr()) {
            for t in ts {
                let title = t.req("title")?.as_str().unwrap_or_default().to_string();
                let headers = t
                    .req("headers")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("table headers must be an array"))?
                    .iter()
                    .map(|h| h.as_str().unwrap_or_default().to_string())
                    .collect();
                let rows = t
                    .req("rows")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("table rows must be an array"))?
                    .iter()
                    .map(|r| {
                        r.as_arr()
                            .map(|cells| {
                                cells
                                    .iter()
                                    .map(|c| c.as_str().unwrap_or_default().to_string())
                                    .collect()
                            })
                            .ok_or_else(|| anyhow!("table row must be an array"))
                    })
                    .collect::<Result<Vec<Vec<String>>>>()?;
                tables.push(ReportTable { title, headers, rows });
            }
        }
        Ok(Report { name: name.to_string(), quick, metrics, tables })
    }

    /// Load a report from a JSON file.
    pub fn load(path: &std::path::Path) -> Result<Report> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Report::from_value(&Value::parse(&text)?)
    }

    /// Write the report to `opts.json_path` if one was requested.
    pub fn finish(&self, opts: &BenchOpts) -> Result<()> {
        if let Some(p) = &opts.json_path {
            std::fs::write(p, self.to_value().to_json() + "\n")
                .map_err(|e| anyhow!("writing {p}: {e}"))?;
            println!("\nbench report: {p}");
        }
        Ok(())
    }
}

/// CI perf gate: `metric` (higher is better) in `current` may not drop more
/// than `max_drop` (fraction in `[0, 1)`) below `baseline`. Returns a
/// one-line summary on pass, an error describing the regression on fail.
pub fn check_regression(
    current: &Report,
    baseline: &Report,
    metric: &str,
    max_drop: f64,
) -> Result<String> {
    if !(0.0..1.0).contains(&max_drop) {
        bail!("max_drop must be in [0, 1), got {max_drop}");
    }
    let cur = current
        .metric_value(metric)
        .ok_or_else(|| anyhow!("current report has no metric {metric:?}"))?;
    let base = baseline
        .metric_value(metric)
        .ok_or_else(|| anyhow!("baseline report has no metric {metric:?}"))?;
    if base <= 0.0 || base.is_nan() || !cur.is_finite() {
        bail!("non-positive baseline ({base}) or non-finite current ({cur}) for {metric:?}");
    }
    let floor = base * (1.0 - max_drop);
    if cur < floor {
        bail!(
            "perf regression: {metric} = {cur:.2} is below the floor {floor:.2} \
             ({:.0}% of baseline {base:.2})",
            100.0 * (1.0 - max_drop)
        );
    }
    Ok(format!(
        "{metric}: {cur:.2} vs baseline {base:.2} (floor {floor:.2}, {:+.1}%) — OK",
        100.0 * (cur / base - 1.0)
    ))
}

/// CI perf gate for lower-is-better metrics (bytes, latency): `metric` in
/// `current` may not rise more than `max_rise` (fraction in `[0, 1)`) above
/// `baseline`. Returns a one-line summary on pass, an error on fail.
pub fn check_ceiling(
    current: &Report,
    baseline: &Report,
    metric: &str,
    max_rise: f64,
) -> Result<String> {
    if !(0.0..1.0).contains(&max_rise) {
        bail!("max_rise must be in [0, 1), got {max_rise}");
    }
    let cur = current
        .metric_value(metric)
        .ok_or_else(|| anyhow!("current report has no metric {metric:?}"))?;
    let base = baseline
        .metric_value(metric)
        .ok_or_else(|| anyhow!("baseline report has no metric {metric:?}"))?;
    if base <= 0.0 || base.is_nan() || !cur.is_finite() {
        bail!("non-positive baseline ({base}) or non-finite current ({cur}) for {metric:?}");
    }
    let ceiling = base * (1.0 + max_rise);
    if cur > ceiling {
        bail!(
            "perf regression: {metric} = {cur:.2} is above the ceiling {ceiling:.2} \
             ({:.0}% of baseline {base:.2})",
            100.0 * (1.0 + max_rise)
        );
    }
    Ok(format!(
        "{metric}: {cur:.2} vs baseline {base:.2} (ceiling {ceiling:.2}, {:+.1}%) — OK",
        100.0 * (cur / base - 1.0)
    ))
}

/// Section header used by the bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let t = bench(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.median_ns > 0.0 && t.mean_ns > 0.0);
        assert_eq!(t.runs, 10);
    }

    #[test]
    fn from_samples_median_mean_stddev() {
        // Median of an odd-length sorted list is the middle element even
        // when samples arrive shuffled; mean and stddev are exact.
        let t = Timing::from_samples(vec![30.0, 10.0, 20.0, 50.0, 40.0]);
        assert_eq!(t.median_ns, 30.0);
        assert_eq!(t.mean_ns, 30.0);
        assert!((t.stddev_ns - 200.0f64.sqrt()).abs() < 1e-9);
        assert_eq!(t.runs, 5);
        // Even length: upper-middle element (len/2 after sort).
        let t = Timing::from_samples(vec![4.0, 1.0, 2.0, 3.0]);
        assert_eq!(t.median_ns, 3.0);
        // Throughput helpers.
        let t = Timing::from_samples(vec![1000.0]);
        assert_eq!(t.per_elem_ns(100), 10.0);
        assert_eq!(t.gbps(4000), 4.0);
        assert_eq!(t.melems_per_s(1000), 1000.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
        // Boundaries: 999.4 rounds within ns; exactly 1e3/1e6/1e9 promote.
        assert_eq!(fmt_ns(999.0), "999 ns");
        assert_eq!(fmt_ns(1e3), "1.00 µs");
        assert_eq!(fmt_ns(1e6), "1.00 ms");
        assert_eq!(fmt_ns(1e9), "1.00 s");
    }

    #[test]
    fn calibrate_scales_up() {
        let iters = calibrate(
            || {
                std::hint::black_box(1 + 1);
            },
            1.0,
        );
        assert!(iters > 1);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn opts_parse_flags_and_env() {
        let none = |_: &str| None;
        let o = BenchOpts::parse(args(&["--quick", "--json", "out.json"]), none);
        assert!(o.quick);
        assert_eq!(o.json_path.as_deref(), Some("out.json"));
        let o = BenchOpts::parse(args(&["--json=x.json", "--bench"]), none);
        assert!(!o.quick);
        assert_eq!(o.json_path.as_deref(), Some("x.json"));
        // Env fallbacks.
        let env = |k: &str| match k {
            "TQSGD_BENCH_QUICK" => Some("1".to_string()),
            "TQSGD_BENCH_JSON" => Some("env.json".to_string()),
            _ => None,
        };
        let o = BenchOpts::parse(args(&[]), env);
        assert!(o.quick);
        assert_eq!(o.json_path.as_deref(), Some("env.json"));
        // Explicit flag beats env.
        let o = BenchOpts::parse(args(&["--json", "flag.json"]), env);
        assert_eq!(o.json_path.as_deref(), Some("flag.json"));
    }

    #[test]
    fn size_env_override_beats_quick_default() {
        let var = "TQSGD_BENCH_TEST_SIZE_OVERRIDE";
        std::env::remove_var(var);
        let quick = BenchOpts { quick: true, json_path: None };
        let full = BenchOpts::default();
        assert_eq!(quick.size(var, 300, 20), 20);
        assert_eq!(full.size(var, 300, 20), 300);
        std::env::set_var(var, "77");
        assert_eq!(quick.size(var, 300, 20), 77, "env override wins over quick");
        assert_eq!(full.size(var, 300, 20), 77);
        std::env::remove_var(var);
    }

    #[test]
    fn report_json_roundtrip() {
        let opts = BenchOpts { quick: true, json_path: None };
        let mut r = Report::new("unit_bench", &opts);
        let mut t = Table::new(&["codec", "ns"]);
        t.row(&["tqsgd".to_string(), "123".to_string()]);
        t.row(&["qsgd".to_string(), "456".to_string()]);
        r.table("encode", &t);
        r.metric("throughput_melems_per_s", 312.5);
        r.metric("bytes_out", 500006.0);
        let v = r.to_json_roundtrip();
        assert_eq!(v.metric_value("throughput_melems_per_s"), Some(312.5));
        assert_eq!(v.name(), "unit_bench");
        assert!(v.quick);
        assert_eq!(v.tables.len(), 1);
        assert_eq!(v.tables[0].title, "encode");
        assert_eq!(v.tables[0].headers, vec!["codec", "ns"]);
        assert_eq!(v.tables[0].rows[1][1], "456");
        // The serialized forms agree exactly.
        assert_eq!(v.to_value().to_json(), r.to_value().to_json());
    }

    impl Report {
        fn to_json_roundtrip(&self) -> Report {
            let text = self.to_value().to_json();
            Report::from_value(&Value::parse(&text).unwrap()).unwrap()
        }
    }

    #[test]
    fn regression_gate_passes_and_fails() {
        let opts = BenchOpts::default();
        let mut base = Report::new("perf_hotpath", &opts);
        base.metric("enc", 100.0);
        let mut ok = Report::new("perf_hotpath", &opts);
        ok.metric("enc", 71.0);
        assert!(check_regression(&ok, &base, "enc", 0.30).is_ok());
        let mut slow = Report::new("perf_hotpath", &opts);
        slow.metric("enc", 69.0);
        let err = check_regression(&slow, &base, "enc", 0.30).unwrap_err();
        assert!(err.to_string().contains("regression"), "{err}");
        assert!(check_regression(&ok, &base, "missing", 0.30).is_err());
    }

    #[test]
    fn ceiling_gate_passes_and_fails() {
        let opts = BenchOpts::default();
        let mut base = Report::new("perf_round", &opts);
        base.metric("bytes", 1000.0);
        let mut ok = Report::new("perf_round", &opts);
        ok.metric("bytes", 1090.0);
        assert!(check_ceiling(&ok, &base, "bytes", 0.10).is_ok());
        let mut fat = Report::new("perf_round", &opts);
        fat.metric("bytes", 1101.0);
        let err = check_ceiling(&fat, &base, "bytes", 0.10).unwrap_err();
        assert!(err.to_string().contains("ceiling"), "{err}");
        assert!(check_ceiling(&ok, &base, "missing", 0.10).is_err());
        assert!(check_ceiling(&ok, &base, "bytes", 1.0).is_err(), "max_rise must be < 1");
    }
}
