//! Closed-form convergence-bound calculators (Lemmas 1–2, Theorems 1–3).
//!
//! These turn the paper's bounds into numbers so the benches can print
//! *predicted vs measured* for every error term:
//!
//! * Lemma 1 — quantization variance bound `Σ P_k |Δ_k|² / 4` for an
//!   arbitrary codebook,
//! * Lemma 2 / Eq. (21) — MSE decomposition into quantization variance +
//!   truncation bias,
//! * Theorems 1/2/3 — the `E_TQ` convergence-error terms at the optimized
//!   parameters, exposing the `s^{(6−2γ)/(γ−1)}` communication scaling.

use crate::solver;
use crate::tail::PowerLawModel;
use crate::util::math::integrate;

/// Ingredients of the `E_DSGD` term in Eq. (7).
#[derive(Clone, Copy, Debug)]
pub struct DsgdTerm {
    /// F(θ₀) − F(θ*) optimality gap.
    pub f_gap: f64,
    /// Learning rate η.
    pub eta: f64,
    /// Rounds T.
    pub rounds: usize,
    /// Per-sample gradient variance bound σ².
    pub sigma2: f64,
    /// Clients N.
    pub clients: usize,
    /// Batch size B.
    pub batch: usize,
}

impl DsgdTerm {
    /// E_DSGD = 2[F(θ₀) − F(θ*)] / (T η) + σ² / (N B).
    pub fn value(&self) -> f64 {
        2.0 * self.f_gap / (self.rounds as f64 * self.eta)
            + self.sigma2 / (self.clients * self.batch) as f64
    }
}

/// Lemma 1 upper bound on E‖Q[g] − g‖² for a codebook: Σ_k P_k |Δ_k|² / 4,
/// with P_k the model mass of interval k (conditioned on the truncated
/// range, masses outside map to the end intervals).
pub fn lemma1_variance_bound(m: &PowerLawModel, codebook: &[f32]) -> f64 {
    let s = codebook.len() - 1;
    let mut total = 0.0;
    for k in 0..s {
        let lo = codebook[k] as f64;
        let hi = codebook[k + 1] as f64;
        let mut p = m.cdf(hi) - m.cdf(lo);
        // Truncation folds the tails onto the end points: the mass beyond
        // ±α sits exactly ON l_0 / l_s and contributes no quantization
        // variance, so no correction is needed — but mass conservation for
        // the *truncated* variable keeps P_k as-is inside the range.
        if p < 0.0 {
            p = 0.0;
        }
        total += p * (hi - lo) * (hi - lo) / 4.0;
    }
    total
}

/// Lemma 2 / Eq. (21): per-element quantization variance
/// `∫_{−α}^{α} p(g) / (4 λ_s(g)²) dg` for an arbitrary density λ_s given as
/// a closure.
pub fn quantization_variance(
    m: &PowerLawModel,
    alpha: f64,
    lambda: impl Fn(f64) -> f64,
) -> f64 {
    integrate(
        &|g| {
            let l = lambda(g);
            m.pdf(g) / (4.0 * l * l)
        },
        -alpha,
        alpha,
        1e-13,
    )
}

/// Per-element truncation bias `2 ∫_α^∞ (g−α)² p(g) dg` (Eq. 21, right).
pub fn truncation_bias(m: &PowerLawModel, alpha: f64) -> f64 {
    m.truncation_bias(alpha)
}

/// The common Theorem 1/2/3 coefficient
/// `(γ−1) Q^{(γ−3)/(γ−1)} d g_min² (2ρ)^{2/(γ−1)} s^{(6−2γ)/(γ−1)} /
///  (N (γ−3) (γ−2)^{2/(γ−1)})`, parameterized by which Q functional is
/// plugged in (Q_U for Thm 1, Q_N for Thm 2, Q_B for Thm 3).
pub fn theorem_e_tq(m: &PowerLawModel, q: f64, d: usize, n: usize, s: usize) -> f64 {
    let g = m.gamma;
    let inv = 1.0 / (g - 1.0);
    (g - 1.0)
        * q.powf((g - 3.0) * inv)
        * d as f64
        * m.g_min.powi(2)
        * (2.0 * m.rho).powf(2.0 * inv)
        * (s as f64).powf((6.0 - 2.0 * g) * inv)
        / (n as f64 * (g - 3.0) * (g - 2.0).powf(2.0 * inv))
}

/// Theorem 1 (TQSGD): E_TQ with Q = Q_U(α*) at the Eq. (12) threshold.
pub fn theorem1_bound(m: &PowerLawModel, d: usize, n: usize, s: usize) -> f64 {
    let alpha = solver::optimal_alpha_uniform(m, s);
    theorem_e_tq(m, m.q_u(alpha), d, n, s)
}

/// Theorem 2 (TNQSGD): E_TQ with Q = Q_N(α*) at the Eq. (19) threshold.
pub fn theorem2_bound(m: &PowerLawModel, d: usize, n: usize, s: usize) -> f64 {
    let alpha = solver::optimal_alpha_nonuniform(m, s);
    theorem_e_tq(m, m.q_n(alpha), d, n, s)
}

/// Theorem 3 (TBQSGD): E_TQ with Q = Q_B(α*, k*).
pub fn theorem3_bound(m: &PowerLawModel, d: usize, n: usize, s: usize) -> f64 {
    let design = solver::solve_biscaled(m, s);
    theorem_e_tq(m, design.q_b, d, n, s)
}

/// The `ε` gap between Eq. (13) and the Q_U≈1 approximation Eq. (14):
/// ε = (γ−3) Q_U(α') + 2 − (γ−1) Q_U(α)^{(γ−3)/(γ−1)} ≤ 2[1 − Q_U(α')].
pub fn theorem1_approx_gap(m: &PowerLawModel, s: usize) -> (f64, f64) {
    let g = m.gamma;
    let alpha = solver::optimal_alpha_uniform(m, s);
    let alpha_p = solver::approx_alpha_uniform(m, s);
    let eps = (g - 3.0) * m.q_u(alpha_p) + 2.0
        - (g - 1.0) * m.q_u(alpha).powf((g - 3.0) / (g - 1.0));
    let bound = 2.0 * (1.0 - m.q_u(alpha_p));
    (eps, bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{
        nonuniform_codebook, optimal_alpha_nonuniform, optimal_alpha_uniform,
        uniform_codebook,
    };

    fn m() -> PowerLawModel {
        PowerLawModel::new(4.0, 0.01, 0.1)
    }

    #[test]
    fn dsgd_term_decays_with_rounds_and_clients() {
        let base = DsgdTerm { f_gap: 1.0, eta: 0.01, rounds: 100, sigma2: 1.0, clients: 8, batch: 32 };
        let more_rounds = DsgdTerm { rounds: 1000, ..base };
        let more_clients = DsgdTerm { clients: 64, ..base };
        assert!(more_rounds.value() < base.value());
        assert!(more_clients.value() < base.value());
    }

    #[test]
    fn lemma1_bound_uniform_codebook_form() {
        // For a uniform codebook the bound collapses to Q_U(α) (2α/s)²/4.
        let m = m();
        let (alpha, s) = (0.05, 7usize);
        let cb = uniform_codebook(alpha, s);
        let b = lemma1_variance_bound(&m, &cb);
        let closed = m.q_u(alpha) * (2.0 * alpha / s as f64).powi(2) / 4.0;
        assert!((b - closed).abs() < 1e-9, "{b} vs {closed}");
    }

    #[test]
    fn quantization_variance_uniform_density_matches_closed_form() {
        // λ = s/2α ⇒ ∫ p/(4λ²) = Q_U(α) α²/s².
        let m = m();
        let (alpha, s) = (0.05, 7.0);
        let v = quantization_variance(&m, alpha, |_| s / (2.0 * alpha));
        let closed = m.q_u(alpha) * alpha * alpha / (s * s);
        assert!((v - closed).abs() < 1e-8, "{v} vs {closed}");
    }

    #[test]
    fn nonuniform_density_beats_uniform_variance() {
        // At the same α and s, the p^{1/3} density yields lower variance.
        let m = m();
        let (alpha, s) = (0.05, 15usize);
        let vu = quantization_variance(&m, alpha, |_| s as f64 / (2.0 * alpha));
        let norm = m.int_p_cbrt(alpha);
        let vn = quantization_variance(&m, alpha, |g| {
            s as f64 * m.pdf(g).cbrt() / norm
        });
        assert!(vn < vu, "nonuniform {vn} vs uniform {vu}");
    }

    #[test]
    fn lemma1_on_solver_codebook_close_to_integral() {
        // Discrete Σ P_k Δ_k²/4 over the built codebook should approximate
        // the continuous ∫ p/(4λ²).
        let m = m();
        let s = 31;
        let alpha = optimal_alpha_nonuniform(&m, s);
        let cb = nonuniform_codebook(&m, alpha, s);
        let discrete = lemma1_variance_bound(&m, &cb);
        let norm = m.int_p_cbrt(alpha);
        let continuous =
            quantization_variance(&m, alpha, |g| s as f64 * m.pdf(g).cbrt() / norm);
        let rel = (discrete - continuous).abs() / continuous;
        assert!(rel < 0.15, "discrete {discrete} vs continuous {continuous}");
    }

    #[test]
    fn theorem_bounds_ordering() {
        // Thm2 ≤ Thm1 and Thm3 ≤ Thm1 (Hölder corollaries).
        let m = m();
        for &s in &[7usize, 15, 31] {
            let t1 = theorem1_bound(&m, 1000, 8, s);
            let t2 = theorem2_bound(&m, 1000, 8, s);
            let t3 = theorem3_bound(&m, 1000, 8, s);
            assert!(t2 <= t1 + 1e-15, "s={s}");
            assert!(t3 <= t1 + 1e-15, "s={s}");
        }
    }

    #[test]
    fn theorem1_equals_e_tq_at_optimum() {
        // The Thm 1 coefficient equals d/N * E_TQ(α*) by construction.
        let m = m();
        let (d, n, s) = (100usize, 8usize, 7usize);
        let alpha = optimal_alpha_uniform(&m, s);
        let direct = d as f64 / n as f64 * solver::e_tq_uniform(&m, alpha, s);
        let thm = theorem1_bound(&m, d, n, s);
        assert!((direct - thm).abs() < 1e-4 * thm.max(1e-300), "{direct} vs {thm}");
    }

    #[test]
    fn communication_scaling_exponent() {
        // E_TQ(s) should scale like s^{(6−2γ)/(γ−1)}: check the log-log
        // slope between s=7 and s=31.
        let m = m();
        let t_a = theorem1_bound(&m, 1, 1, 7);
        let t_b = theorem1_bound(&m, 1, 1, 31);
        let slope = (t_b / t_a).ln() / (31.0f64 / 7.0).ln();
        let expected = (6.0 - 2.0 * m.gamma) / (m.gamma - 1.0);
        assert!((slope - expected).abs() < 0.05, "slope {slope} vs {expected}");
    }

    #[test]
    fn approx_gap_small_and_bounded() {
        let m = m();
        let (eps, bound) = theorem1_approx_gap(&m, 7);
        assert!(eps.abs() <= bound + 0.05, "eps {eps} bound {bound}");
        assert!(bound < 0.2, "Q_U(α') should be near 1; bound {bound}");
    }
}
