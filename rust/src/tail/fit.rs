//! Distribution fitting: the paper's power-law tail MLE (Sec. V) with
//! Clauset-style g_min selection, plus Gaussian/Laplace MLE fits and KS
//! distances for the Fig. 1 comparison.

use super::model::PowerLawModel;
use crate::util::math::{laplace_cdf, normal_cdf};

/// Result of fitting one family to a gradient sample.
#[derive(Clone, Debug)]
pub struct FitReport {
    /// Fitted family name: `"power-law"`, `"gaussian"` or `"laplace"`.
    pub family: &'static str,
    /// Family parameters: power-law (γ, g_min, ρ); gaussian (μ, σ);
    /// laplace (μ, b).
    pub params: Vec<f64>,
    /// KS distance between the |g| sample and the fitted |g| distribution
    /// (power-law: tail-only above g_min, as in Clauset et al.).
    pub ks: f64,
}

/// MLE of the tail index on the sample of |g| above a fixed g_min (paper
/// Sec. V):  γ̂ = 1 + n [ Σ ln(g_j / g_min) ]^{-1}.
pub fn gamma_mle(abs_values: &[f32], g_min: f64) -> Option<(f64, usize)> {
    let mut n = 0usize;
    let mut sum_log = 0.0f64;
    for &v in abs_values {
        let a = v as f64;
        if a > g_min {
            n += 1;
            sum_log += (a / g_min).ln();
        }
    }
    if n < 10 || sum_log <= 0.0 {
        return None;
    }
    Some((1.0 + n as f64 / sum_log, n))
}

/// KS distance between the empirical CDF of `sorted` and a model CDF.
pub fn ks_distance(sorted: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let n = sorted.len() as f64;
    let mut worst: f64 = 0.0;
    for (i, &x) in sorted.iter().enumerate() {
        let m = cdf(x);
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        worst = worst.max((m - lo).abs()).max((m - hi).abs());
    }
    worst
}

/// Deterministic sample cap the codecs use for per-round refits (see
/// [`fit_power_law_sampled`]): large enough that γ̂'s sampling error is a
/// few hundredths at the widest tail, small enough that a refit of a
/// million-element layer group touches the quantile machinery on ~16k
/// points instead of all of them.
pub const REFIT_SAMPLE_CAP: usize = 16_384;

/// Clauset-style power-law fit of the |g| tail: scan g_min candidates over
/// quantiles of |g|, take the MLE γ̂ at each, keep the candidate minimizing
/// the KS distance of the tail above g_min against the fitted Pareto.
///
/// Returns the fit plus a KS report. The scan range is bounded so at least
/// `min_tail_frac` of the sample stays in the tail (the estimator needs
/// enough tail points) and at most `max_tail_frac` (the power law only holds
/// in the tail). Equivalent to `fit_power_law_sampled(values, usize::MAX)` —
/// every point participates; this is the reference the sampled refit path is
/// regression-tested against.
pub fn fit_power_law(values: &[f32]) -> Option<FitReport> {
    fit_power_law_sampled(values, usize::MAX)
}

/// [`fit_power_law`] over a deterministic subsample of at most `max_sample`
/// nonzero |g| points — the codec refit path (see [`REFIT_SAMPLE_CAP`]).
///
/// Two things keep the per-refit cost ~O(d) instead of the former full-sort
/// O(d log d):
///
/// * **Deterministic stride subsample.** When more than `max_sample` points
///   survive the zero filter, every `ceil(n / max_sample)`-th one (in
///   arrival order, fixed phase 0) is kept — no RNG, so refits stay
///   bit-reproducible for a given gradient.
/// * **Select-nth quantiles.** All g_min candidates live in the top
///   `max_tail_frac` of the sample, so one `select_nth_unstable` partition
///   at that boundary followed by a sort of ONLY the tail half replaces the
///   full sort; the body below the widest candidate is never ordered.
///
/// With `max_sample >= n` the result is bit-identical to the pre-sampling
/// full-sort fit: the partition point and everything above it order exactly
/// as they would in the fully sorted array, and ρ falls back to the
/// original full-count expression.
pub fn fit_power_law_sampled(values: &[f32], max_sample: usize) -> Option<FitReport> {
    let mut abs: Vec<f64> = values.iter().map(|v| (*v as f64).abs()).filter(|a| *a > 0.0).collect();
    let nonzero = abs.len();
    if nonzero < 100 {
        return None;
    }
    let max_sample = max_sample.max(100);
    if nonzero > max_sample {
        let stride = nonzero.div_ceil(max_sample);
        let mut kept = 0usize;
        let mut i = 0usize;
        while i < nonzero {
            abs[kept] = abs[i];
            kept += 1;
            i += stride;
        }
        abs.truncate(kept);
    }
    let n = abs.len();
    let min_tail_frac = 0.005;
    let max_tail_frac = 0.5;

    // Partition at the widest-tail boundary and sort only the tail: every
    // candidate index below is >= idx0, so sorted order above idx0 is all
    // the scan needs.
    let idx0 = (((1.0 - max_tail_frac) * n as f64) as usize).min(n - 2);
    abs.select_nth_unstable_by(idx0, |a, b| a.partial_cmp(b).unwrap());
    abs[idx0..].sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut best: Option<(f64, f64, f64)> = None; // (ks, gamma, g_min)
    // Candidate g_min values at 40 quantiles of the allowed range.
    for qi in 0..40 {
        let frac = max_tail_frac
            - (max_tail_frac - min_tail_frac) * qi as f64 / 39.0;
        let idx = ((1.0 - frac) * n as f64) as usize;
        let g_min = abs[idx.min(n - 2)];
        if g_min <= 0.0 {
            continue;
        }
        let tail = &abs[idx..];
        let Some((gamma, _)) = gamma_mle(
            &tail.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            g_min,
        ) else {
            continue;
        };
        if gamma <= 1.5 {
            continue;
        }
        // Pareto CDF of the tail above g_min.
        let ks = ks_distance(
            &tail.iter().copied().filter(|&x| x > g_min).collect::<Vec<_>>(),
            |x| 1.0 - (x / g_min).powf(1.0 - gamma),
        );
        if best.map_or(true, |(bks, _, _)| ks < bks) {
            best = Some((ks, gamma, g_min));
        }
    }
    let (ks, gamma, g_min) = best?;
    // rho is ONE-SIDED tail mass: |g|>g_min counts both tails, halve it.
    // On the sampled path, scale the in-sample tail fraction by the overall
    // nonzero fraction; unsampled, keep the original expression bit-for-bit.
    let count = abs.iter().filter(|&&a| a > g_min).count() as f64;
    let rho = if n == nonzero {
        count / (values.len() as f64) / 2.0
    } else {
        (count / n as f64) * (nonzero as f64 / values.len() as f64) / 2.0
    };
    Some(FitReport { family: "power-law", params: vec![gamma, g_min, rho], ks })
}

/// Convert a power-law FitReport into the model struct.
pub fn report_to_model(r: &FitReport) -> PowerLawModel {
    assert_eq!(r.family, "power-law");
    PowerLawModel::new(r.params[0], r.params[1], r.params[2].min(0.5))
}

/// Gaussian MLE fit (μ, σ) with KS over the signed sample.
pub fn fit_gaussian(values: &[f32]) -> FitReport {
    let n = values.len() as f64;
    let mu = values.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = values.iter().map(|&v| (v as f64 - mu).powi(2)).sum::<f64>() / n;
    let sigma = var.sqrt().max(1e-300);
    let mut sorted: Vec<f64> = values.iter().map(|&v| v as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ks = ks_distance(&sorted, |x| normal_cdf(x, mu, sigma));
    FitReport { family: "gaussian", params: vec![mu, sigma], ks }
}

/// Laplace MLE fit (μ = median, b = mean |x − μ|) with KS over the signed
/// sample. The paper's Fig. 1 scales the Laplace to the gradient variance;
/// MLE gives it the best possible chance — the tail still loses.
pub fn fit_laplace(values: &[f32]) -> FitReport {
    let mut sorted: Vec<f64> = values.iter().map(|&v| v as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mu = sorted[sorted.len() / 2];
    let b = (sorted.iter().map(|&x| (x - mu).abs()).sum::<f64>() / sorted.len() as f64)
        .max(1e-300);
    let ks = ks_distance(&sorted, |x| laplace_cdf(x, mu, b));
    FitReport { family: "laplace", params: vec![mu, b], ks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gamma_mle_recovers_pareto_index() {
        let mut rng = Rng::new(1);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.pareto(0.01, 4.2) as f32).collect();
        let (gamma, n) = gamma_mle(&xs, 0.01).unwrap();
        assert!(n == xs.len());
        assert!((gamma - 4.2).abs() < 0.06, "{gamma}");
    }

    #[test]
    fn gamma_mle_rejects_tiny_samples() {
        assert!(gamma_mle(&[0.02; 5], 0.01).is_none());
        assert!(gamma_mle(&[], 0.01).is_none());
    }

    #[test]
    fn ks_distance_zero_for_own_cdf() {
        // Large uniform sample vs uniform CDF has small KS.
        let mut rng = Rng::new(2);
        let mut xs: Vec<f64> = (0..20_000).map(|_| rng.f64()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(ks_distance(&xs, |x| x.clamp(0.0, 1.0)) < 0.02);
    }

    #[test]
    fn fit_power_law_on_synthetic_tail() {
        let mut rng = Rng::new(3);
        let (g_min, gamma, rho2) = (0.01, 4.0, 0.2); // rho2 = both-sides mass
        let xs: Vec<f32> =
            (0..80_000).map(|_| rng.power_law_gradient(g_min, gamma, rho2) as f32).collect();
        let fit = fit_power_law(&xs).unwrap();
        let ghat = fit.params[0];
        assert!((ghat - gamma).abs() < 0.5, "gamma {ghat}");
        assert!(fit.ks < 0.05, "ks {}", fit.ks);
    }

    /// The pre-select-nth fit, kept verbatim as an independent reference:
    /// full sort of |g|, then the identical 40-candidate scan. The shipped
    /// fit must reproduce it bit-for-bit when no sampling kicks in.
    fn full_sort_reference(values: &[f32]) -> Option<(Vec<f64>, f64)> {
        let mut abs: Vec<f64> =
            values.iter().map(|v| (*v as f64).abs()).filter(|a| *a > 0.0).collect();
        if abs.len() < 100 {
            return None;
        }
        abs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = abs.len();
        let min_tail_frac = 0.005;
        let max_tail_frac = 0.5;
        let mut best: Option<(f64, f64, f64)> = None;
        for qi in 0..40 {
            let frac = max_tail_frac - (max_tail_frac - min_tail_frac) * qi as f64 / 39.0;
            let idx = ((1.0 - frac) * n as f64) as usize;
            let g_min = abs[idx.min(n - 2)];
            if g_min <= 0.0 {
                continue;
            }
            let tail = &abs[idx..];
            let Some((gamma, _)) =
                gamma_mle(&tail.iter().map(|&x| x as f32).collect::<Vec<_>>(), g_min)
            else {
                continue;
            };
            if gamma <= 1.5 {
                continue;
            }
            let ks = ks_distance(
                &tail.iter().copied().filter(|&x| x > g_min).collect::<Vec<_>>(),
                |x| 1.0 - (x / g_min).powf(1.0 - gamma),
            );
            if best.map_or(true, |(bks, _, _)| ks < bks) {
                best = Some((ks, gamma, g_min));
            }
        }
        let (ks, gamma, g_min) = best?;
        let rho =
            abs.iter().filter(|&&a| a > g_min).count() as f64 / (values.len() as f64) / 2.0;
        Some((vec![gamma, g_min, rho], ks))
    }

    #[test]
    fn select_nth_fit_is_bit_identical_to_full_sort_reference() {
        // With no sampling in play the shipped select-nth fit must land on
        // EXACTLY the old full-sort fit's (γ, g_min, ρ, KS): the partition
        // point and everything above it order as in the fully sorted array.
        let mut rng = Rng::new(21);
        for &(gamma, rho2, n) in &[(4.0, 0.2, 20_000usize), (3.4, 0.1, 5_000), (4.8, 0.35, 997)]
        {
            let xs: Vec<f32> =
                (0..n).map(|_| rng.power_law_gradient(0.01, gamma, rho2) as f32).collect();
            let (ref_params, ref_ks) = full_sort_reference(&xs).unwrap();
            let fit = fit_power_law(&xs).unwrap();
            assert_eq!(fit.params, ref_params, "γ={gamma} n={n}");
            assert_eq!(fit.ks, ref_ks, "γ={gamma} n={n}");
            let capped = fit_power_law_sampled(&xs, xs.len()).unwrap();
            assert_eq!(capped.params, ref_params, "γ={gamma} n={n} (cap == n)");
        }
    }

    #[test]
    fn sampled_fit_selects_same_design_as_full_fit() {
        // The codec-selection regression gate: on seeded power-law draws the
        // sampled refit must land on the same (γ, α) quantizer design as the
        // full-sort fit within tolerance, for both the uniform (Eq. 12) and
        // non-uniform (Eq. 19) truncation solvers.
        let mut rng = Rng::new(22);
        for &(gamma, rho2) in &[(3.6, 0.15), (4.0, 0.2), (4.6, 0.3)] {
            let xs: Vec<f32> = (0..60_000)
                .map(|_| rng.power_law_gradient(0.01, gamma, rho2) as f32)
                .collect();
            let full = fit_power_law(&xs).unwrap();
            let samp = fit_power_law_sampled(&xs, super::REFIT_SAMPLE_CAP).unwrap();
            let (gf, gs) = (full.params[0], samp.params[0]);
            assert!((gf - gs).abs() < 0.45, "γ={gamma}: full γ̂ {gf} vs sampled {gs}");
            let mf = report_to_model(&full);
            let ms = report_to_model(&samp);
            for s in [3usize, 7, 15] {
                let af = crate::solver::optimal_alpha_uniform(&mf, s);
                let a_s = crate::solver::optimal_alpha_uniform(&ms, s);
                assert!(
                    (af - a_s).abs() <= 0.25 * af.max(a_s),
                    "γ={gamma} s={s}: uniform α {af} vs {a_s}"
                );
                let nf = crate::solver::optimal_alpha_nonuniform(&mf, s);
                let ns = crate::solver::optimal_alpha_nonuniform(&ms, s);
                assert!(
                    (nf - ns).abs() <= 0.25 * nf.max(ns),
                    "γ={gamma} s={s}: non-uniform α {nf} vs {ns}"
                );
            }
        }
    }

    #[test]
    fn sampled_fit_still_recovers_gamma_under_the_cap() {
        let mut rng = Rng::new(23);
        let xs: Vec<f32> =
            (0..120_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let fit = fit_power_law_sampled(&xs, 8192).unwrap();
        assert!((fit.params[0] - 4.0).abs() < 0.6, "γ̂ {}", fit.params[0]);
        assert!(fit.ks < 0.08, "ks {}", fit.ks);
        // ρ scaling: roughly half the two-sided tail mass at the cutoff.
        assert!(fit.params[2] > 0.0 && fit.params[2] <= 0.5);
    }

    #[test]
    fn heavy_tail_beats_gaussian_and_laplace_in_the_tail() {
        // The Fig. 1 claim, as a test: Gaussian/Laplace tails are far too
        // thin.  Full-sample KS is dominated by the body (where Laplace is
        // fine), so we test what the figure actually shows — the TAIL mass:
        // the power-law fit predicts P(|g| > t) to within ~2x for a deep
        // tail threshold, while Gaussian and Laplace undershoot it by an
        // order of magnitude or more.
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = (0..60_000).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        let pl = fit_power_law(&xs).unwrap();
        let ga = fit_gaussian(&xs);
        let la = fit_laplace(&xs);
        let sigma = ga.params[1];
        let t = 6.0 * sigma;
        let emp = xs.iter().filter(|&&x| (x as f64).abs() > t).count() as f64
            / xs.len() as f64;
        assert!(emp > 0.0, "need a real tail for the comparison");
        // Model-predicted P(|g| > t).
        let (gamma, g_min, rho) = (pl.params[0], pl.params[1], pl.params[2]);
        let p_pl = 2.0 * rho * (t / g_min).powf(1.0 - gamma);
        let p_ga = 2.0 * (1.0 - normal_cdf(t, ga.params[0], sigma));
        let p_la = 2.0 * (1.0 - laplace_cdf(t, la.params[0], la.params[1]));
        assert!(p_pl / emp > 0.4 && p_pl / emp < 2.5, "power-law {p_pl} vs emp {emp}");
        assert!(p_ga < emp / 10.0, "gaussian tail should be >10x too thin: {p_ga} vs {emp}");
        assert!(p_la < emp / 2.0, "laplace tail should be clearly too thin: {p_la} vs {emp}");
        // And the power-law tail-KS itself is a good fit.
        assert!(pl.ks < 0.05, "tail KS {}", pl.ks);
    }

    #[test]
    fn gaussian_fit_recovers_moments() {
        let mut rng = Rng::new(5);
        let xs: Vec<f32> = (0..50_000).map(|_| (rng.normal() * 2.0 + 1.0) as f32).collect();
        let f = fit_gaussian(&xs);
        assert!((f.params[0] - 1.0).abs() < 0.05);
        assert!((f.params[1] - 2.0).abs() < 0.05);
        assert!(f.ks < 0.01);
    }

    #[test]
    fn laplace_fit_recovers_scale() {
        let mut rng = Rng::new(6);
        // Laplace via difference of exponentials: b ln(u1/u2).
        let xs: Vec<f32> = (0..50_000)
            .map(|_| {
                let e1 = -rng.f64().max(1e-12).ln();
                let e2 = -rng.f64().max(1e-12).ln();
                (0.5 * (e1 - e2)) as f32
            })
            .collect();
        let f = fit_laplace(&xs);
        assert!(f.params[0].abs() < 0.02, "mu {}", f.params[0]);
        assert!((f.params[1] - 0.5).abs() < 0.02, "b {}", f.params[1]);
        assert!(f.ks < 0.01);
    }
}
