//! Gradient-distribution estimation: the power-law tail model of the paper
//! (Definition 1 / Eq. 10) plus the Gaussian/Laplace comparison fits of
//! Fig. 1 and the KS machinery to decide which fits best.

pub mod fit;
pub mod model;

pub use fit::{
    fit_gaussian, fit_laplace, fit_power_law, fit_power_law_sampled, ks_distance, FitReport,
    REFIT_SAMPLE_CAP,
};
pub use model::PowerLawModel;

/// Log-spaced histogram of |g| — the Fig. 1 density plot substrate.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// Bin edges, length `bins + 1`, log-spaced on [lo, hi].
    pub edges: Vec<f64>,
    /// Counts per bin.
    pub counts: Vec<u64>,
    /// Samples below `lo` (not binned).
    pub underflow: u64,
    /// Samples above `hi` (not binned).
    pub overflow: u64,
    /// Total samples seen (binned + underflow + overflow).
    pub total: u64,
}

impl LogHistogram {
    /// An empty histogram with `bins` log-spaced bins on `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && bins > 0);
        let l0 = lo.ln();
        let l1 = hi.ln();
        let edges = (0..=bins)
            .map(|i| (l0 + (l1 - l0) * i as f64 / bins as f64).exp())
            .collect();
        LogHistogram { edges, counts: vec![0; bins], underflow: 0, overflow: 0, total: 0 }
    }

    /// Bin one sample by |x|.
    pub fn add(&mut self, x: f64) {
        let a = x.abs();
        self.total += 1;
        let lo = self.edges[0];
        let hi = *self.edges.last().unwrap();
        if a < lo {
            self.underflow += 1;
            return;
        }
        if a >= hi {
            self.overflow += 1;
            return;
        }
        let bins = self.counts.len() as f64;
        let t = (a.ln() - lo.ln()) / (hi.ln() - lo.ln());
        let mut i = (t * bins) as usize;
        i = i.min(self.counts.len() - 1);
        // Guard against FP edge effects.
        while a < self.edges[i] {
            i -= 1;
        }
        while a >= self.edges[i + 1] {
            i += 1;
        }
        self.counts[i] += 1;
    }

    /// Bin every sample in `xs`.
    pub fn extend(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x as f64);
        }
    }

    /// Empirical density of |g| at each bin center: count / (total * width).
    pub fn density(&self) -> Vec<(f64, f64)> {
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let w = self.edges[i + 1] - self.edges[i];
                let center = (self.edges[i] * self.edges[i + 1]).sqrt();
                (center, c as f64 / (self.total as f64 * w))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_everything_in_range() {
        let mut h = LogHistogram::new(1e-4, 1.0, 16);
        for i in 1..1000 {
            h.add(i as f64 * 1e-3);
        }
        assert_eq!(h.total, 999);
        assert_eq!(h.counts.iter().sum::<u64>() + h.underflow + h.overflow, 999);
        assert_eq!(h.overflow, 0);
    }

    #[test]
    fn histogram_density_integrates_to_mass() {
        let mut h = LogHistogram::new(1e-3, 10.0, 64);
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..200_000 {
            h.add(rng.pareto(0.01, 4.0));
        }
        let mass: f64 = h
            .density()
            .iter()
            .enumerate()
            .map(|(i, (_, d))| d * (h.edges[i + 1] - h.edges[i]))
            .sum();
        let expected = 1.0 - (h.underflow + h.overflow) as f64 / h.total as f64;
        assert!((mass - expected).abs() < 1e-9, "{mass} vs {expected}");
    }
}
