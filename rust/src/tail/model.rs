//! The paper's gradient-distribution model (Definition 1 / Eq. 10):
//!
//! ```text
//! p(g) = rho * (gamma-1) / g_min^{1-gamma} * |g|^{-gamma}   for |g| > g_min
//! ```
//!
//! with one-sided tail mass `rho = ∫_{g_min}^∞ p(g) dg` and `3 < gamma <= 5`.
//! Below the cutoff the paper leaves `p` unspecified; we close the model with
//! a uniform body on `[-g_min, g_min]` carrying the remaining mass
//! `1 - 2 rho` — the minimal symmetric completion, and exactly what the
//! synthetic sampler `Rng::power_law_gradient` draws.
//!
//! All the paper's distribution functionals live here: `Q_U(α)` (Eq. 11),
//! the `∫ p^{1/3}` integrals behind `Q_N(α)` (Thm. 2) and `Q_B(α,k)`
//! (Appendix D), and the closed-form truncation bias.

use crate::util::math::integrate;

/// The paper's symmetric power-law gradient model (Definition 1 / Eq. 10).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerLawModel {
    /// Tail index γ (paper assumes 3 < γ ≤ 5 for finite E_TQ).
    pub gamma: f64,
    /// Lower cutoff of power-law behaviour.
    pub g_min: f64,
    /// One-sided tail mass ρ = P(g > g_min) = P(g < -g_min).
    pub rho: f64,
}

impl PowerLawModel {
    /// A model with tail index `gamma`, cutoff `g_min` and tail mass `rho`;
    /// panics on parameters outside the paper's admissible ranges.
    pub fn new(gamma: f64, g_min: f64, rho: f64) -> Self {
        assert!(gamma > 1.0, "gamma must exceed 1, got {gamma}");
        assert!(g_min > 0.0, "g_min must be positive");
        assert!((0.0..=0.5).contains(&rho), "one-sided rho in [0, 0.5], got {rho}");
        PowerLawModel { gamma, g_min, rho }
    }

    /// Tail normalization c with p(g) = c |g|^{-γ} for |g| > g_min.
    #[inline]
    pub fn tail_coeff(&self) -> f64 {
        self.rho * (self.gamma - 1.0) * self.g_min.powf(self.gamma - 1.0)
    }

    /// Symmetric density p(g) (body closed uniformly — see module docs).
    pub fn pdf(&self, g: f64) -> f64 {
        let a = g.abs();
        if a > self.g_min {
            self.tail_coeff() * a.powf(-self.gamma)
        } else {
            (1.0 - 2.0 * self.rho) / (2.0 * self.g_min)
        }
    }

    /// CDF P(G <= g).
    pub fn cdf(&self, g: f64) -> f64 {
        if g < 0.0 {
            return 1.0 - self.cdf(-g);
        }
        if g <= self.g_min {
            0.5 + g * (1.0 - 2.0 * self.rho) / (2.0 * self.g_min)
        } else {
            1.0 - self.rho * (g / self.g_min).powf(1.0 - self.gamma)
        }
    }

    /// One-sided tail mass above x (x >= g_min): ∫_x^∞ p = ρ (x/g_min)^{1-γ}.
    pub fn tail_mass(&self, x: f64) -> f64 {
        assert!(x >= self.g_min);
        self.rho * (x / self.g_min).powf(1.0 - self.gamma)
    }

    /// Q_U(α) = ∫_{-α}^{α} p(g) dg = 1 - 2 ρ (α/g_min)^{1-γ}  (α ≥ g_min).
    pub fn q_u(&self, alpha: f64) -> f64 {
        1.0 - 2.0 * self.tail_mass(alpha)
    }

    /// ∫_{-α}^{α} p(g)^{1/3} dg — the numerator behind Eq. (18) and Q_N.
    /// Closed form: body 2 g_min p_b^{1/3}; tail 2 c^{1/3} ∫ g^{-γ/3}.
    pub fn int_p_cbrt(&self, alpha: f64) -> f64 {
        assert!(alpha >= self.g_min);
        let p_body = (1.0 - 2.0 * self.rho) / (2.0 * self.g_min);
        let body = 2.0 * self.g_min * p_body.cbrt();
        let c3 = self.tail_coeff().cbrt();
        let e = 1.0 - self.gamma / 3.0; // exponent of the antiderivative
        let tail = if e.abs() < 1e-12 {
            2.0 * c3 * (alpha / self.g_min).ln()
        } else {
            2.0 * c3 * (alpha.powf(e) - self.g_min.powf(e)) / e
        };
        body + tail
    }

    /// Q_N(α) = [ ∫_{-α}^{α} p^{1/3} (1/2α)^{2/3} dg ]^3  (Thm. 2).
    pub fn q_n(&self, alpha: f64) -> f64 {
        let i = self.int_p_cbrt(alpha) * (1.0 / (2.0 * alpha)).powf(2.0 / 3.0);
        i.powi(3)
    }

    /// Q_B(α, k) of Appendix D:
    /// [ (2∫_{kα}^{α} p)^{1/3} (1-k)^{2/3} + (2∫_0^{kα} p)^{1/3} k^{2/3} ]^3.
    pub fn q_b(&self, alpha: f64, k: f64) -> f64 {
        assert!((0.0..=1.0).contains(&k));
        let beta = k * alpha;
        let inner2 = (self.cdf(beta) - self.cdf(-beta)).max(0.0); // 2∫_0^{kα} p
        let outer2 = (self.cdf(alpha) - self.cdf(beta)) * 2.0; // 2∫_{kα}^{α} p
        let t = outer2.max(0.0).cbrt() * (1.0 - k).powf(2.0 / 3.0)
            + inner2.cbrt() * k.powf(2.0 / 3.0);
        t.powi(3)
    }

    /// Per-element truncation bias 2 ∫_α^∞ (g-α)² p(g) dg
    /// = 4 ρ g_min^{γ-1} α^{3-γ} / ((γ-2)(γ-3))   (Eq. 11, needs γ > 3).
    pub fn truncation_bias(&self, alpha: f64) -> f64 {
        assert!(self.gamma > 3.0, "bias finite only for gamma > 3");
        4.0 * self.rho * self.g_min.powf(self.gamma - 1.0) * alpha.powf(3.0 - self.gamma)
            / ((self.gamma - 2.0) * (self.gamma - 3.0))
    }

    /// Same bias via numerical quadrature — cross-check for tests/benches.
    pub fn truncation_bias_numeric(&self, alpha: f64) -> f64 {
        let c = self.tail_coeff();
        // Integrate to a far horizon; integrand decays like g^{2-γ}.
        let hi = alpha * 1e5;
        2.0 * integrate(&|g| (g - alpha).powi(2) * c * g.powf(-self.gamma), alpha, hi, 1e-14)
    }

    /// Second moment E[g²] (finite for γ > 3).
    pub fn second_moment(&self) -> f64 {
        let body = (1.0 - 2.0 * self.rho) * self.g_min.powi(2) / 3.0;
        // 2 ∫_{g_min}^∞ g² c g^{-γ} dg = 2 c g_min^{3-γ}/(γ-3)
        let tail =
            2.0 * self.tail_coeff() * self.g_min.powf(3.0 - self.gamma) / (self.gamma - 3.0);
        body + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> PowerLawModel {
        PowerLawModel::new(4.0, 0.01, 0.1)
    }

    #[test]
    fn pdf_integrates_to_one() {
        let m = m();
        let body = integrate(&|g| m.pdf(g), -m.g_min, m.g_min, 1e-12);
        let tail = 2.0 * integrate(&|g| m.pdf(g), m.g_min, 100.0, 1e-12);
        assert!((body + tail - 1.0).abs() < 1e-6, "{}", body + tail);
    }

    #[test]
    fn cdf_consistent_with_pdf() {
        let m = m();
        for &x in &[0.005, 0.01, 0.02, 0.05, 0.2] {
            let num = 0.5 + integrate(&|g| m.pdf(g), 0.0, x, 1e-12);
            assert!((m.cdf(x) - num).abs() < 1e-8, "x={x}");
        }
        assert!((m.cdf(-0.02) - (1.0 - m.cdf(0.02))).abs() < 1e-12);
    }

    #[test]
    fn q_u_matches_tail_mass() {
        let m = m();
        let alpha = 0.05;
        let direct = m.cdf(alpha) - m.cdf(-alpha);
        assert!((m.q_u(alpha) - direct).abs() < 1e-12);
    }

    #[test]
    fn int_p_cbrt_matches_quadrature() {
        let m = m();
        for &alpha in &[0.01, 0.03, 0.1] {
            let num = integrate(&|g| m.pdf(g).cbrt(), -alpha, alpha, 1e-12);
            let cf = m.int_p_cbrt(alpha);
            assert!((num - cf).abs() < 1e-6 * cf, "alpha={alpha}: {num} vs {cf}");
        }
    }

    #[test]
    fn truncation_bias_closed_form_matches_numeric() {
        let m = m();
        for &alpha in &[0.02, 0.05, 0.1] {
            let cf = m.truncation_bias(alpha);
            let num = m.truncation_bias_numeric(alpha);
            assert!((cf - num).abs() < 1e-4 * cf, "alpha={alpha}: {cf} vs {num}");
        }
    }

    #[test]
    fn holder_q_n_le_q_u() {
        // Thm. 2 corollary: Q_N(α) ≤ Q_U(α) by Hölder.
        let m = m();
        for &alpha in &[0.02, 0.05, 0.2] {
            assert!(m.q_n(alpha) <= m.q_u(alpha) + 1e-12, "alpha={alpha}");
        }
    }

    #[test]
    fn holder_q_b_le_one() {
        // Thm. 3 corollary: Q_B(α, k) ≤ 1.
        let m = m();
        for &k in &[0.1, 0.3, 0.5, 0.9] {
            assert!(m.q_b(0.05, k) <= 1.0 + 1e-12, "k={k}");
        }
    }

    #[test]
    fn q_b_at_k_limits_degenerates_to_q_u_form() {
        // k→0 or k→1 collapses to single-region: Q_B → 2∫ p over that region.
        let m = m();
        let alpha = 0.05;
        assert!((m.q_b(alpha, 0.0) - m.q_u(alpha)).abs() < 1e-9);
        assert!((m.q_b(alpha, 1.0) - m.q_u(alpha)).abs() < 1e-9);
    }

    #[test]
    fn second_moment_positive_and_scales() {
        let m = m();
        assert!(m.second_moment() > 0.0);
        let m2 = PowerLawModel::new(4.0, 0.02, 0.1);
        assert!(m2.second_moment() > m.second_moment());
    }

    #[test]
    fn sampler_matches_model_cdf() {
        // Empirical CDF of Rng::power_law_gradient vs model.cdf (KS-style).
        let m = m();
        let mut rng = crate::util::Rng::new(11);
        let n = 100_000;
        let mut xs: Vec<f64> =
            (0..n).map(|_| rng.power_law_gradient(m.g_min, m.gamma, 2.0 * m.rho)).collect();
        // NOTE: power_law_gradient takes the TOTAL tail probability (both
        // sides), while rho here is one-sided.
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut worst: f64 = 0.0;
        for (i, &x) in xs.iter().enumerate().step_by(997) {
            let emp = (i + 1) as f64 / n as f64;
            worst = worst.max((emp - m.cdf(x)).abs());
        }
        assert!(worst < 0.01, "KS distance {worst}");
    }
}
