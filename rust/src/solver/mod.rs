//! Optimal quantizer parameter design (paper Sec. IV + Appendix D).
//!
//! Given the fitted tail model and the bit budget `b` (⇒ `s = 2^b − 1`
//! intervals), these solvers produce the truncation threshold α and the
//! codebook realizing the chosen density λ_s:
//!
//! * [`optimal_alpha_uniform`] — Eq. (12) fixed point (TQSGD),
//! * [`optimal_alpha_nonuniform`] — Eq. (19) fixed point (TNQSGD),
//! * [`nonuniform_codebook`] — CDF-inversion of λ_s(g) ∝ p(g)^{1/3} (Eq. 18),
//! * [`solve_biscaled`] — k*, s_α/s_β split and α of Eqs. (29)–(33) (TBQSGD).

use crate::tail::PowerLawModel;
use crate::util::math::{fixed_point, golden_min};

/// s = 2^b − 1 quantization intervals for a b-bit budget.
pub fn levels_for_bits(bits: u32) -> usize {
    (1usize << bits) - 1
}

/// Eq. (12): α = g_min [ 2ρ s² / ((γ−2) Q_U(α)) ]^{1/(γ−1)}, solved by the
/// paper's "alternating iterations" (damped fixed point; Q_U ≈ 1 makes this
/// contract very fast).
pub fn optimal_alpha_uniform(m: &PowerLawModel, s: usize) -> f64 {
    let s2 = (s * s) as f64;
    let step = |alpha: f64| {
        let qu = m.q_u(alpha.max(m.g_min)).max(1e-9);
        m.g_min * (2.0 * m.rho * s2 / ((m.gamma - 2.0) * qu)).powf(1.0 / (m.gamma - 1.0))
    };
    let x0 = step(m.g_min * 4.0);
    fixed_point(step, x0, 1.0, 1e-10, 200).max(m.g_min)
}

/// Closed-form approximation α' with Q_U ≈ 1 (discussion below Thm. 1).
pub fn approx_alpha_uniform(m: &PowerLawModel, s: usize) -> f64 {
    let s2 = (s * s) as f64;
    m.g_min * (2.0 * m.rho * s2 / (m.gamma - 2.0)).powf(1.0 / (m.gamma - 1.0))
}

/// Eq. (19): same fixed point with Q_N(α) in the denominator (TNQSGD).
pub fn optimal_alpha_nonuniform(m: &PowerLawModel, s: usize) -> f64 {
    let s2 = (s * s) as f64;
    let step = |alpha: f64| {
        let qn = m.q_n(alpha.max(m.g_min)).max(1e-9);
        m.g_min * (2.0 * m.rho * s2 / ((m.gamma - 2.0) * qn)).powf(1.0 / (m.gamma - 1.0))
    };
    let x0 = optimal_alpha_uniform(m, s); // Q_N ≤ Q_U ⇒ final α is larger
    fixed_point(step, x0, 0.8, 1e-10, 300).max(m.g_min)
}

/// Build the non-uniform codebook realizing λ_s(g) = s p(g)^{1/3} / ∫ p^{1/3}
/// (Eq. 18) on [−α, α]: level l_k solves ∫_{−α}^{l_k} λ_s = k, i.e. the
/// codebook is the inverse of the (normalized) cumulative of p^{1/3}.
///
/// The cumulative has closed form for the body+tail model; we invert each of
/// the three segments analytically and stitch them.
pub fn nonuniform_codebook(m: &PowerLawModel, alpha: f64, s: usize) -> Vec<f32> {
    assert!(alpha >= m.g_min, "alpha {alpha} below g_min {}", m.g_min);
    assert!(s >= 1);
    // Cumulative of p^{1/3} from 0 to x (one side), x in [0, alpha].
    let p_body_cbrt = ((1.0 - 2.0 * m.rho) / (2.0 * m.g_min)).cbrt();
    let c3 = m.tail_coeff().cbrt();
    let e = 1.0 - m.gamma / 3.0;
    let cum_body = |x: f64| p_body_cbrt * x; // x <= g_min
    let cum_tail = |x: f64| {
        // g_min < x: body full + tail part
        cum_body(m.g_min)
            + if e.abs() < 1e-12 {
                c3 * (x / m.g_min).ln()
            } else {
                c3 * (x.powf(e) - m.g_min.powf(e)) / e
            }
    };
    let half_total = cum_tail(alpha);
    let body_cum = cum_body(m.g_min);
    // Invert the one-sided cumulative.
    let inv = |t: f64| -> f64 {
        if t <= body_cum {
            t / p_body_cbrt
        } else if e.abs() < 1e-12 {
            m.g_min * ((t - body_cum) / c3).exp()
        } else {
            ((t - body_cum) * e / c3 + m.g_min.powf(e)).powf(1.0 / e)
        }
    };
    let mut cb = Vec::with_capacity(s + 1);
    for k in 0..=s {
        // Symmetric target in [-half_total, half_total].
        let t = -half_total + 2.0 * half_total * k as f64 / s as f64;
        let x = if t >= 0.0 { inv(t) } else { -inv(-t) };
        cb.push(x as f32);
    }
    // Pin exact end points and enforce strict monotonicity against FP noise.
    cb[0] = -alpha as f32;
    cb[s] = alpha as f32;
    for i in 1..cb.len() {
        if cb[i] <= cb[i - 1] {
            cb[i] = f32::from_bits(cb[i - 1].to_bits() + 1);
        }
    }
    cb
}

/// Uniform codebook on [−α, α] with s intervals.
pub fn uniform_codebook(alpha: f64, s: usize) -> Vec<f32> {
    (0..=s)
        .map(|k| (-alpha + 2.0 * alpha * k as f64 / s as f64) as f32)
        .collect()
}

/// The solved BiScaled design (Appendix D).
#[derive(Clone, Debug)]
pub struct BiScaledDesign {
    /// Truncation threshold α*.
    pub alpha: f64,
    /// Inner/outer scale split point β*.
    pub beta: f64,
    /// Optimal interval-allocation ratio k*.
    pub k: f64,
    /// Inner intervals on [−β, β].
    pub s_beta: usize,
    /// Outer intervals, split evenly across [−α,−β] and [β,α] (even).
    pub s_alpha: usize,
    /// Q_B(α, k*) at the solution.
    pub q_b: f64,
}

impl BiScaledDesign {
    /// Materialize the piecewise-uniform codebook.
    pub fn codebook(&self) -> Vec<f32> {
        let half = self.s_alpha / 2;
        let mut cb = Vec::with_capacity(self.s_beta + self.s_alpha + 1);
        for i in 0..half {
            cb.push(
                (-self.alpha + (self.alpha - self.beta) * i as f64 / half as f64) as f32,
            );
        }
        for i in 0..=self.s_beta {
            cb.push((-self.beta + 2.0 * self.beta * i as f64 / self.s_beta as f64) as f32);
        }
        for i in 1..=half {
            cb.push((self.beta + (self.alpha - self.beta) * i as f64 / half as f64) as f32);
        }
        for i in 1..cb.len() {
            assert!(cb[i] > cb[i - 1], "biscaled codebook not increasing: {cb:?}");
        }
        cb
    }
}

/// Solve the TBQSGD design: one step of alternating minimization as the
/// paper prescribes — k* = argmin_k Q_B(α, k) by golden search, then α from
/// the Eq. (33) fixed point, iterated to mutual consistency; finally the
/// level split of Eqs. (29)/(30) rounded to integers (s_α even ≥ 2,
/// s_β ≥ 1, s_α + s_β = s).
pub fn solve_biscaled(m: &PowerLawModel, s: usize) -> BiScaledDesign {
    assert!(s >= 3, "biscaled needs at least 3 intervals, got {s}");
    let mut alpha = optimal_alpha_uniform(m, s);
    let mut k = 0.5;
    for _ in 0..20 {
        let a = alpha;
        k = golden_min(|kk| m.q_b(a, kk), 1e-3, 1.0 - 1e-3, 1e-6);
        let qb = m.q_b(alpha, k).max(1e-9);
        let next = m.g_min
            * (2.0 * m.rho * (s * s) as f64 / ((m.gamma - 2.0) * qb))
                .powf(1.0 / (m.gamma - 1.0));
        if (next - alpha).abs() < 1e-10 * alpha {
            alpha = next;
            break;
        }
        alpha = next.max(m.g_min);
    }
    let beta = k * alpha;
    // Eqs. (29)/(30): split s by cube-root average densities.
    let p1 = ((m.cdf(beta) - m.cdf(0.0)) / beta).max(1e-300); // avg density inner
    let p2 = ((m.cdf(alpha) - m.cdf(beta)) / (alpha - beta)).max(1e-300); // outer
    let denom = p2.cbrt() * (1.0 - k) + p1.cbrt() * k;
    let s_alpha_f = p2.cbrt() * (1.0 - k) / denom * s as f64;
    // Round s_alpha to the nearest even >= 2, keep s_beta >= 1.
    let mut s_alpha = ((s_alpha_f / 2.0).round() as usize * 2).max(2);
    if s_alpha > s - 1 {
        s_alpha = if s % 2 == 0 { s - 2 } else { s - 1 };
        s_alpha = s_alpha.max(2);
    }
    let s_beta = s - s_alpha;
    BiScaledDesign { alpha, beta, k, s_beta, s_alpha, q_b: m.q_b(alpha, k) }
}

/// Per-element truncated-quantization error E_TQ (Eq. 11 without d/N):
/// uniform density. `quant = Q_U(α) α² / s²`, `bias` from the model.
pub fn e_tq_uniform(m: &PowerLawModel, alpha: f64, s: usize) -> f64 {
    m.q_u(alpha) * alpha * alpha / (s * s) as f64 + m.truncation_bias(alpha)
}

/// Per-element E_TQ for the optimal non-uniform density (Eq. 15 with Eq. 18
/// substituted): quantization variance becomes Q_N(α) α² / s².
pub fn e_tq_nonuniform(m: &PowerLawModel, alpha: f64, s: usize) -> f64 {
    m.q_n(alpha) * alpha * alpha / (s * s) as f64 + m.truncation_bias(alpha)
}

/// Per-element E_TQ for a BiScaled design (Eq. 31).
pub fn e_tq_biscaled(m: &PowerLawModel, d: &BiScaledDesign, s: usize) -> f64 {
    m.q_b(d.alpha, d.k) * d.alpha * d.alpha / (s * s) as f64 + m.truncation_bias(d.alpha)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> PowerLawModel {
        PowerLawModel::new(4.0, 0.01, 0.1)
    }

    #[test]
    fn levels() {
        assert_eq!(levels_for_bits(2), 3);
        assert_eq!(levels_for_bits(3), 7);
        assert_eq!(levels_for_bits(5), 31);
    }

    #[test]
    fn alpha_uniform_satisfies_fixed_point() {
        let m = m();
        for &s in &[3usize, 7, 15, 31] {
            let a = optimal_alpha_uniform(&m, s);
            let rhs = m.g_min
                * (2.0 * m.rho * (s * s) as f64 / ((m.gamma - 2.0) * m.q_u(a)))
                    .powf(1.0 / (m.gamma - 1.0));
            assert!((a - rhs).abs() < 1e-6 * a, "s={s}: {a} vs {rhs}");
        }
    }

    #[test]
    fn alpha_grows_with_budget() {
        let m = m();
        let a3 = optimal_alpha_uniform(&m, 7);
        let a5 = optimal_alpha_uniform(&m, 31);
        assert!(a5 > a3);
    }

    #[test]
    fn alpha_shrinks_with_thinner_tail() {
        // Larger gamma ⇒ thinner tail ⇒ smaller alpha (paper's intuition).
        let a_heavy = optimal_alpha_uniform(&PowerLawModel::new(3.5, 0.01, 0.1), 7);
        let a_thin = optimal_alpha_uniform(&PowerLawModel::new(5.0, 0.01, 0.1), 7);
        assert!(a_thin < a_heavy, "{a_thin} vs {a_heavy}");
    }

    #[test]
    fn approx_alpha_close_to_exact() {
        let m = m();
        let exact = optimal_alpha_uniform(&m, 7);
        let approx = approx_alpha_uniform(&m, 7);
        assert!((exact - approx).abs() / exact < 0.05, "{exact} vs {approx}");
    }

    #[test]
    fn nonuniform_alpha_ge_uniform_alpha() {
        // Q_N ≤ Q_U ⇒ TNQSGD's α is larger (end of Sec. IV-B).
        let m = m();
        for &s in &[7usize, 15] {
            let au = optimal_alpha_uniform(&m, s);
            let an = optimal_alpha_nonuniform(&m, s);
            assert!(an >= au, "s={s}: {an} < {au}");
        }
    }

    #[test]
    fn alpha_near_optimal_for_e_tq() {
        // Eq. (12) comes from a first-order condition that treats Q_U(α) as
        // locally constant, so the fixed point is a *near*-minimizer of
        // E_TQ: within 2% of the scanned optimum, and far better than
        // naive choices like α = g_min or α = 10 α*.
        let m = m();
        let s = 7;
        let a_star = optimal_alpha_uniform(&m, s);
        let e_star = e_tq_uniform(&m, a_star, s);
        let mut best = f64::INFINITY;
        for i in 1..=400 {
            let a = m.g_min * (1.0 + i as f64 * 0.05);
            best = best.min(e_tq_uniform(&m, a, s));
        }
        assert!(e_star <= best * 1.02, "e* {e_star} vs scanned best {best}");
        assert!(e_star < 0.5 * e_tq_uniform(&m, 10.0 * a_star, s));
        assert!(e_star <= e_tq_uniform(&m, m.g_min, s));
    }

    #[test]
    fn codebook_monotone_with_exact_endpoints() {
        let m = m();
        let alpha = optimal_alpha_nonuniform(&m, 7);
        let cb = nonuniform_codebook(&m, alpha, 7);
        assert_eq!(cb.len(), 8);
        assert_eq!(cb[0], -alpha as f32);
        assert_eq!(cb[7], alpha as f32);
        for i in 1..cb.len() {
            assert!(cb[i] > cb[i - 1]);
        }
    }

    #[test]
    fn codebook_denser_near_zero() {
        // λ ∝ p^{1/3} puts more levels where p is larger: central interval
        // must be narrower than the outermost interval.
        let m = m();
        let alpha = optimal_alpha_nonuniform(&m, 7);
        let cb = nonuniform_codebook(&m, alpha, 7);
        let central = cb[4] - cb[3];
        let outer = cb[7] - cb[6];
        assert!(central < outer, "central {central} outer {outer}");
    }

    #[test]
    fn codebook_realizes_density() {
        // Each interval should carry equal ∫ λ mass ⇒ ∫ p^{1/3} over every
        // interval is equal.
        let m = m();
        let alpha = 0.05;
        let s = 15;
        let cb = nonuniform_codebook(&m, alpha, s);
        let masses: Vec<f64> = (0..s)
            .map(|k| {
                crate::util::math::integrate(
                    &|g| m.pdf(g).cbrt(),
                    cb[k] as f64,
                    cb[k + 1] as f64,
                    1e-12,
                )
            })
            .collect();
        let avg: f64 = masses.iter().sum::<f64>() / s as f64;
        for (k, ms) in masses.iter().enumerate() {
            assert!((ms - avg).abs() < 0.05 * avg, "interval {k}: {ms} vs {avg}");
        }
    }

    #[test]
    fn uniform_codebook_even() {
        let cb = uniform_codebook(0.06, 3);
        assert_eq!(cb.len(), 4);
        assert!((cb[1] - cb[0] - (cb[2] - cb[1])).abs() < 1e-7);
    }

    #[test]
    fn biscaled_design_consistent() {
        let m = m();
        let d = solve_biscaled(&m, 7);
        assert!(d.beta > 0.0 && d.beta < d.alpha);
        assert_eq!(d.s_alpha + d.s_beta, 7);
        assert!(d.s_alpha % 2 == 0 && d.s_alpha >= 2);
        let cb = d.codebook();
        assert_eq!(cb.len(), 8);
        assert!((cb[0] + d.alpha as f32).abs() < 1e-6);
    }

    #[test]
    fn biscaled_q_b_le_one_and_improves_on_uniform() {
        let m = m();
        let d = solve_biscaled(&m, 7);
        assert!(d.q_b <= 1.0 + 1e-9);
        // Q_B(α, k*) ≤ Q_U(α): two regions can only help.
        assert!(d.q_b <= m.q_u(d.alpha) + 1e-9);
    }

    #[test]
    fn e_tq_ordering_matches_theory() {
        // E_TQ(TNQSGD) ≤ E_TQ(TQSGD) at each method's own optimum.
        let m = m();
        for &s in &[7usize, 15, 31] {
            let eu = e_tq_uniform(&m, optimal_alpha_uniform(&m, s), s);
            let en = e_tq_nonuniform(&m, optimal_alpha_nonuniform(&m, s), s);
            let d = solve_biscaled(&m, s);
            let eb = e_tq_biscaled(&m, &d, s);
            assert!(en <= eu + 1e-15, "s={s}: nonuniform {en} vs uniform {eu}");
            assert!(eb <= eu + 1e-15, "s={s}: biscaled {eb} vs uniform {eu}");
        }
    }
}
