//! Metrics: per-round records, wire-byte accounting, CSV/JSONL sinks.
//!
//! Every training run produces a [`RunLog`] the benches and examples render
//! (and optionally persist) — this is the data behind Figs. 3 and 4.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::json::{self, Value};

/// One communication round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Round index (0-based).
    pub round: usize,
    /// Mean training loss over the round's participating clients.
    pub train_loss: f64,
    /// Bytes shipped client→server this round (all clients, goodput).
    pub bytes_up: u64,
    /// Evaluation loss (if evaluation ran this round).
    pub test_loss: Option<f64>,
    /// Evaluation accuracy (if evaluation ran this round).
    pub test_accuracy: Option<f64>,
    /// Wall-clock seconds spent in this round.
    pub secs: f64,
    /// Simulated network seconds (bandwidth/latency model), if enabled.
    pub net_secs: f64,
    /// Wall-clock seconds in the gradient-compute stage.
    pub compute_secs: f64,
    /// Wall-clock seconds in the encode stage — under the streaming
    /// pipeline this window also covers the overlapped server decode, so
    /// `encode_secs + agg_secs` shrinking versus barrier mode IS the
    /// measured overlap.
    pub encode_secs: f64,
    /// Wall-clock seconds in the weighted-apply + optimizer stage.
    pub agg_secs: f64,
    /// Scenario: clients that did not contribute a frame this round
    /// (churned out or lost after retransmit budget).
    pub dropped_clients: usize,
    /// Scenario: extra bytes burned on lost uplink attempts — retransmitted
    /// copies of delivered frames plus every attempt of frames that never
    /// arrived at all (including corrupt transmissions re-sent after a
    /// CRC32 trailer mismatch).
    pub retransmitted_bytes: u64,
    /// Fault tolerance: workers re-admitted this round after a chaos kill
    /// (REJOIN handshake). Logged, but outside `replay_digest` — a
    /// cooperative kill + rejoin is digest-transparent by design.
    pub rejoined_clients: u32,
    /// Fault tolerance: uplink messages that failed wire integrity (CRC32
    /// trailer mismatch) this round and took the retransmit path. Outside
    /// `replay_digest`; the corruption's digest-visible cost rides
    /// `retransmitted_bytes`.
    pub corrupt_frames: u32,
    /// Scenario: histogram of applied-frame staleness — index s holds the
    /// number of frames applied this round that were s rounds old. Empty
    /// and `vec![k]` both mean "k fresh frames, nothing late".
    pub staleness_hist: Vec<u32>,
    /// Mean resident bytes of mutable per-client server-side state at the
    /// end of the round (EF residuals — dense, or parked as quantized
    /// frames for non-cohort clients — plus pooled frame-arena buffers).
    /// The million-client memory-capacity metric; logged, but deliberately
    /// outside `replay_digest` (it tracks allocator capacities, not the
    /// training trajectory).
    pub bytes_per_client: u64,
}

/// Full run log.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    /// One record per completed round, in round order.
    pub records: Vec<RoundRecord>,
    /// Short config id (see `ExperimentConfig::id`) stamped on JSONL rows.
    pub config_id: String,
}

impl RunLog {
    /// Append one round's record.
    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// Total client→server goodput bytes across all rounds.
    pub fn total_bytes_up(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_up).sum()
    }

    /// The most recent evaluation accuracy, if any round evaluated.
    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_accuracy)
    }

    /// The best evaluation accuracy seen across the run.
    pub fn best_accuracy(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.test_accuracy)
            .fold(None, |m, a| Some(m.map_or(a, |m: f64| m.max(a))))
    }

    /// The last round's training loss.
    pub fn final_train_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.train_loss)
    }

    /// (round, accuracy) series for plotting Fig. 3.
    pub fn accuracy_series(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.test_accuracy.map(|a| (r.round, a)))
            .collect()
    }

    /// Render every record as CSV (header + one line per round).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,train_loss,bytes_up,test_loss,test_accuracy,secs,net_secs,\
             compute_secs,encode_secs,agg_secs,\
             dropped_clients,retransmitted_bytes,rejoined_clients,corrupt_frames,\
             staleness_hist,bytes_per_client\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.round,
                r.train_loss,
                r.bytes_up,
                r.test_loss.map_or(String::new(), |v| v.to_string()),
                r.test_accuracy.map_or(String::new(), |v| v.to_string()),
                r.secs,
                r.net_secs,
                r.compute_secs,
                r.encode_secs,
                r.agg_secs,
                r.dropped_clients,
                r.retransmitted_bytes,
                r.rejoined_clients,
                r.corrupt_frames,
                fmt_staleness_hist(&r.staleness_hist),
                r.bytes_per_client,
            ));
        }
        s
    }

    /// Render every record as one JSON object per line.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            let mut pairs = vec![
                ("round", json::num(r.round as f64)),
                ("train_loss", json::num(r.train_loss)),
                ("bytes_up", json::num(r.bytes_up as f64)),
                ("secs", json::num(r.secs)),
                ("net_secs", json::num(r.net_secs)),
                ("compute_secs", json::num(r.compute_secs)),
                ("encode_secs", json::num(r.encode_secs)),
                ("agg_secs", json::num(r.agg_secs)),
                ("dropped_clients", json::num(r.dropped_clients as f64)),
                ("retransmitted_bytes", json::num(r.retransmitted_bytes as f64)),
                ("rejoined_clients", json::num(r.rejoined_clients as f64)),
                ("corrupt_frames", json::num(r.corrupt_frames as f64)),
                (
                    "staleness_hist",
                    json::arr(
                        r.staleness_hist.iter().map(|&c| json::num(c as f64)).collect(),
                    ),
                ),
                ("bytes_per_client", json::num(r.bytes_per_client as f64)),
                ("config", json::s(&self.config_id)),
            ];
            if let Some(l) = r.test_loss {
                pairs.push(("test_loss", json::num(l)));
            }
            if let Some(a) = r.test_accuracy {
                pairs.push(("test_accuracy", json::num(a)));
            }
            s.push_str(&json::obj(pairs).to_json());
            s.push('\n');
        }
        s
    }

    /// Exact digest of every deterministic per-round quantity (losses,
    /// bytes, drop/retransmit counts, simulated network time, staleness).
    /// Two runs of the same seed + scenario must produce identical digests;
    /// wall-clock `secs` is deliberately excluded. Floats are folded in by
    /// bit pattern, so this is bit-for-bit, not approximately-equal.
    pub fn replay_digest(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            s.push_str(&format!(
                "{}:{:016x}:{}:{}:{}:{:016x}:{};",
                r.round,
                r.train_loss.to_bits(),
                r.bytes_up,
                r.dropped_clients,
                r.retransmitted_bytes,
                r.net_secs.to_bits(),
                fmt_staleness_hist(&r.staleness_hist),
            ));
        }
        s
    }

    /// Write [`RunLog::to_csv`] to `path`.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Render a staleness histogram as compact `s:count` pairs (`0:6|1:2`);
/// empty histogram renders as `-`.
pub fn fmt_staleness_hist(hist: &[u32]) -> String {
    let parts: Vec<String> = hist
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(s, &c)| format!("{s}:{c}"))
        .collect();
    if parts.is_empty() {
        "-".into()
    } else {
        parts.join("|")
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    /// Seconds elapsed since [`Timer::start`].
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Parse a JSONL metrics line back (used by tests and tooling).
pub fn parse_jsonl_line(line: &str) -> Result<Value> {
    Value::parse(line)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> RunLog {
        let mut log = RunLog { config_id: "cnn/tnqsgd/b3/N8".into(), ..Default::default() };
        log.push(RoundRecord {
            round: 0,
            train_loss: 2.3,
            bytes_up: 1000,
            test_loss: None,
            test_accuracy: None,
            secs: 0.1,
            net_secs: 0.0,
            compute_secs: 0.04,
            encode_secs: 0.03,
            agg_secs: 0.02,
            dropped_clients: 0,
            retransmitted_bytes: 0,
            rejoined_clients: 0,
            corrupt_frames: 0,
            staleness_hist: Vec::new(),
            bytes_per_client: 0,
        });
        log.push(RoundRecord {
            round: 1,
            train_loss: 1.9,
            bytes_up: 1000,
            test_loss: Some(1.8),
            test_accuracy: Some(0.55),
            secs: 0.1,
            net_secs: 0.0,
            compute_secs: 0.05,
            encode_secs: 0.0625,
            agg_secs: 0.0125,
            dropped_clients: 2,
            retransmitted_bytes: 333,
            rejoined_clients: 1,
            corrupt_frames: 2,
            staleness_hist: vec![6, 2],
            bytes_per_client: 4096,
        });
        log
    }

    #[test]
    fn accounting() {
        let log = sample_log();
        assert_eq!(log.total_bytes_up(), 2000);
        assert_eq!(log.final_accuracy(), Some(0.55));
        assert_eq!(log.best_accuracy(), Some(0.55));
        assert_eq!(log.accuracy_series(), vec![(1, 0.55)]);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample_log().to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("round,"));
        assert!(csv.contains("0.55"));
        assert!(csv.lines().next().unwrap().contains("staleness_hist"));
        assert!(csv.contains(",333,"), "retransmitted bytes column");
        assert!(csv.contains("0:6|1:2"), "staleness histogram column");
        let header = csv.lines().next().unwrap();
        for col in [
            "compute_secs",
            "encode_secs",
            "agg_secs",
            "rejoined_clients",
            "corrupt_frames",
            "bytes_per_client",
        ] {
            assert!(header.contains(col), "missing column {col}");
        }
        assert!(csv.contains(",333,1,2,"), "fault columns follow retransmitted_bytes");
        assert!(csv.contains(",0.05,0.0625,0.0125,"), "stage columns in row order");
        assert!(csv.contains("0:6|1:2,4096"), "bytes_per_client trails the histogram");
    }

    #[test]
    fn jsonl_roundtrips_stage_timings() {
        let jl = sample_log().to_jsonl();
        let v = parse_jsonl_line(jl.lines().nth(1).unwrap()).unwrap();
        assert_eq!(v.get("compute_secs").unwrap().as_f64(), Some(0.05));
        assert_eq!(v.get("encode_secs").unwrap().as_f64(), Some(0.0625));
        assert_eq!(v.get("agg_secs").unwrap().as_f64(), Some(0.0125));
    }

    #[test]
    fn staleness_hist_formatting() {
        assert_eq!(fmt_staleness_hist(&[]), "-");
        assert_eq!(fmt_staleness_hist(&[4]), "0:4");
        assert_eq!(fmt_staleness_hist(&[6, 0, 1]), "0:6|2:1");
    }

    #[test]
    fn replay_digest_is_exact_and_ignores_wall_clock() {
        let a = sample_log();
        let mut b = sample_log();
        b.records[0].secs = 99.0; // wall clock may differ between runs
        b.records[0].compute_secs = 1.0; // stage clocks are wall clock too
        b.records[0].encode_secs = 2.0;
        b.records[0].agg_secs = 3.0;
        assert_eq!(a.replay_digest(), b.replay_digest());
        let mut c = sample_log();
        c.records[1].retransmitted_bytes += 1;
        assert_ne!(a.replay_digest(), c.replay_digest());
        let mut d = sample_log();
        d.records[0].train_loss += 1e-12; // even ULP-level drift must show
        assert_ne!(a.replay_digest(), d.replay_digest());
        let mut e = sample_log();
        // Memory-capacity metric tracks allocator capacities, not the
        // training trajectory — it must stay outside the digest.
        e.records[1].bytes_per_client = 1;
        assert_eq!(a.replay_digest(), e.replay_digest());
        let mut f = sample_log();
        // Fault-tolerance counters are observability, not trajectory: a
        // chaos kill + rejoin and a corrupt-then-retransmitted frame must
        // leave the digest untouched (the corruption's cost is already
        // visible through retransmitted_bytes).
        f.records[1].rejoined_clients += 1;
        f.records[1].corrupt_frames += 1;
        assert_eq!(a.replay_digest(), f.replay_digest());
    }

    #[test]
    fn jsonl_carries_bytes_per_client() {
        let jl = sample_log().to_jsonl();
        let v = parse_jsonl_line(jl.lines().nth(1).unwrap()).unwrap();
        assert_eq!(v.get("bytes_per_client").unwrap().as_f64(), Some(4096.0));
    }

    #[test]
    fn jsonl_roundtrips() {
        let jl = sample_log().to_jsonl();
        for line in jl.lines() {
            let v = parse_jsonl_line(line).unwrap();
            assert!(v.get("round").is_some());
            assert_eq!(v.get("config").unwrap().as_str(), Some("cnn/tnqsgd/b3/N8"));
        }
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }
}
