//! Error-feedback wrapper (extension beyond the paper).
//!
//! Classic EF-SGD memory: compress `g + residual`, keep the compression
//! error as next round's residual. Truncation makes the paper's quantizers
//! *biased*; error feedback converts that bias into a vanishing residual,
//! which is the natural "future work" knob — the `fig4` bench includes an
//! ablation of it.

use crate::config::Scheme;
use crate::util::Rng;

use super::codecs::Compressor;
use super::wire;

/// Wraps any codec with an error-feedback residual buffer.
pub struct ErrorFeedback {
    inner: Box<dyn Compressor>,
    residual: Vec<f32>,
    /// Scratch: `g + residual`, reused across rounds (zero steady-state
    /// allocations on the encode path).
    adjusted: Vec<f32>,
    /// Scratch: own-frame decode target, reused across rounds.
    decoded: Vec<f32>,
    /// Dormant-client parking: the residual quantized through the inner
    /// codec into one wire frame, replacing the dense f32 working set
    /// while the client sits outside the round cohort. `None` = live.
    parked: Option<Vec<u8>>,
}

impl ErrorFeedback {
    /// Wrap `inner` with a zero residual (sized lazily on first compress).
    pub fn new(inner: Box<dyn Compressor>) -> Self {
        ErrorFeedback {
            inner,
            residual: Vec::new(),
            adjusted: Vec::new(),
            decoded: Vec::new(),
            parked: None,
        }
    }

    /// Compress with feedback into a caller-provided frame buffer — the
    /// EF mirror of [`Compressor::compress_into`]. Needs `&mut self` for
    /// the residual, so this sits outside the `Compressor` trait and the
    /// coordinator calls it directly when `error_feedback` is enabled.
    pub fn compress_with_feedback_into(
        &mut self,
        grads: &[f32],
        rng: &mut Rng,
        out: &mut Vec<u8>,
    ) {
        // The lazy resize below would silently replace a parked residual
        // with zeros — a dormant client must be unparked before it encodes.
        assert!(self.parked.is_none(), "unpark the EF residual before compressing");
        if self.residual.len() != grads.len() {
            self.residual = vec![0.0; grads.len()];
        }
        self.adjusted.clear();
        self.adjusted.extend(grads.iter().zip(&self.residual).map(|(&g, &r)| g + r));
        self.inner.compress_into(&self.adjusted, rng, out);
        wire::decode_dequantize_into(out, &mut self.decoded).expect("own frame decodes");
        for ((r, &a), &d) in self.residual.iter_mut().zip(&self.adjusted).zip(&self.decoded) {
            *r = a - d;
        }
    }

    /// Allocating wrapper over [`Self::compress_with_feedback_into`].
    pub fn compress_with_feedback(&mut self, grads: &[f32], rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::new();
        self.compress_with_feedback_into(grads, rng, &mut out);
        out
    }

    /// Undo a transmission the network ultimately lost: fold the frame's
    /// decoded values back into the residual so the gradient mass is carried
    /// into the next round instead of silently vanishing. Restores the
    /// conservation invariant `Σ delivered + residual == Σ g` under packet
    /// loss.
    pub fn restore_lost(&mut self, frame: &[u8]) {
        wire::decode_dequantize_into(frame, &mut self.decoded).expect("own frame decodes");
        if self.residual.len() != self.decoded.len() {
            self.residual = vec![0.0; self.decoded.len()];
        }
        for (r, &d) in self.residual.iter_mut().zip(&self.decoded) {
            *r += d;
        }
    }

    /// Re-estimate the inner codec's tail model (see [`Compressor::refit`]).
    pub fn refit(&mut self, grads: &[f32]) {
        self.inner.refit(grads);
    }

    /// The inner codec's scheme.
    pub fn scheme(&self) -> Scheme {
        self.inner.scheme()
    }

    /// Human-readable codec description, marked as EF-wrapped.
    pub fn describe(&self) -> String {
        format!("ef[{}]", self.inner.describe())
    }

    /// The current residual vector (empty until the first compression).
    /// Invariant: after T rounds, `residual == Σ_t g_t − Σ_t decoded_t` up
    /// to f32 accumulation error — the conservation law the property suite
    /// pins down.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// L2 norm of the residual (observability for tests/benches).
    pub fn residual_norm(&self) -> f64 {
        self.residual.iter().map(|&r| (r as f64) * (r as f64)).sum::<f64>().sqrt()
    }

    /// Overwrite the residual with an externally restored dense vector —
    /// the inverse of reading [`Self::residual`], used by the worker rejoin
    /// and checkpoint-resume paths. The restored residual is live by
    /// definition, so any parked frame is discarded.
    pub fn set_residual(&mut self, residual: Vec<f32>) {
        self.residual = residual;
        self.parked = None;
    }

    /// The parked residual frame bytes, when the residual is currently
    /// parked (checkpoints serialize the frame verbatim to avoid a second
    /// lossy round trip).
    pub fn parked_frame(&self) -> Option<&[u8]> {
        self.parked.as_deref()
    }

    /// Restore a parked residual frame verbatim (checkpoint resume path —
    /// the exact bytes [`Self::parked_frame`] exposed). Replaces any live
    /// dense residual, mirroring the state [`Self::park`] leaves behind.
    pub fn set_parked_frame(&mut self, frame: Vec<u8>) {
        self.parked = Some(frame);
        self.residual = Vec::new();
        self.adjusted = Vec::new();
        self.decoded = Vec::new();
    }

    // -- dormant-client parking ---------------------------------------------

    /// Park the residual as one quantized wire frame, freeing the dense f32
    /// working set (residual + both scratch buffers ≈ 12 bytes/element →
    /// one b-bit frame). Compresses into the caller-provided `frame` buffer
    /// (arena-recycled by `Client`); hands it back untouched when there is
    /// nothing to park (already parked, or no residual yet).
    ///
    /// Parking is **lossy** by design — the residual is itself quantization
    /// error, so re-quantizing it (after a refit onto its own scale) keeps
    /// the bulk of the mass while dropping the memory by ~the codec's
    /// compression ratio. The tradeoff only arises for clients outside the
    /// cohort; full-participation runs never park and keep exact residuals.
    pub fn park(&mut self, rng: &mut Rng, mut frame: Vec<u8>) -> Option<Vec<u8>> {
        if self.parked.is_some() || self.residual.is_empty() {
            return Some(frame);
        }
        // Refit onto the residual's own scale: without this, a truncating
        // codec fitted to *gradient* range would clamp the tail mass the
        // residual exists to preserve.
        self.inner.refit(&self.residual);
        self.inner.compress_into(&self.residual, rng, &mut frame);
        self.parked = Some(frame);
        self.residual = Vec::new();
        self.adjusted = Vec::new();
        self.decoded = Vec::new();
        None
    }

    /// Restore a parked residual to its dense form. Returns the spent frame
    /// buffer for arena recycling (`None` when nothing was parked). The
    /// next [`Self::compress_with_feedback_into`] then proceeds exactly as
    /// if the residual had stayed dense (modulo the documented parking
    /// quantization).
    pub fn unpark(&mut self) -> anyhow::Result<Option<Vec<u8>>> {
        let Some(frame) = self.parked.take() else { return Ok(None) };
        wire::decode_dequantize_into(&frame, &mut self.residual)?;
        Ok(Some(frame))
    }

    /// Is the residual currently parked as a quantized frame?
    pub fn is_parked(&self) -> bool {
        self.parked.is_some()
    }

    /// Resident bytes of this wrapper's state: the dense f32 working set
    /// when live, the quantized frame when parked (the `bytes_per_client`
    /// metric's EF term).
    pub fn state_bytes(&self) -> usize {
        let dense = 4 * (self.residual.capacity() + self.adjusted.capacity()
            + self.decoded.capacity());
        dense + self.parked.as_ref().map_or(0, |f| f.capacity())
    }
}

/// EF as a [`Compressor`]: the encode path runs through the feedback loop,
/// everything else delegates to the wrapped codec. This is what lets
/// [`GroupCodec`](super::GroupCodec) drive Plain and EF codecs through one
/// `&mut dyn Compressor` without per-variant match arms.
impl Compressor for ErrorFeedback {
    fn scheme(&self) -> Scheme {
        self.inner.scheme()
    }

    fn refit(&mut self, grads: &[f32]) {
        self.inner.refit(grads);
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        self.compress_with_feedback_into(grads, rng, out);
    }

    fn rate(&self) -> u32 {
        self.inner.rate()
    }

    fn set_rate(&mut self, bits: u32) {
        self.inner.set_rate(bits);
    }

    fn describe(&self) -> String {
        format!("ef[{}]", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantConfig;
    use crate::quant::codecs::make_compressor;
    use crate::quant::wire::Payload;

    #[test]
    fn residual_reaches_plateau_under_truncation() {
        // Truncation keeps swallowing tail mass, so the EF residual grows at
        // first — but it drains at ~alpha per coordinate per round, so it
        // must PLATEAU rather than grow without bound.
        let mut rng = Rng::new(1);
        let mut ef = ErrorFeedback::new(make_compressor(&QuantConfig {
            scheme: Scheme::Tqsgd,
            bits: 3,
            ..Default::default()
        }));
        let fitg: Vec<f32> =
            (0..40_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        ef.refit(&fitg);
        let mut norms = Vec::new();
        for _ in 0..300 {
            let g: Vec<f32> =
                (0..2048).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
            let _ = ef.compress_with_feedback(&g, &mut rng);
            norms.push(ef.residual_norm());
        }
        let mid: f64 = norms[150..170].iter().sum::<f64>() / 20.0;
        let late: f64 = norms[280..].iter().sum::<f64>() / 20.0;
        assert!(late < 1.5 * mid + 1.0, "no plateau: mid {mid} late {late}");
        assert!(late.is_finite() && late > 0.0);
    }

    #[test]
    fn restore_lost_refolds_frame_into_residual() {
        // compress (residual := a − d) then restore (residual += d) must
        // leave residual == a = g + r0, i.e. the lost round transmitted
        // nothing on net.
        let mut rng = Rng::new(3);
        let mut ef = ErrorFeedback::new(make_compressor(&QuantConfig {
            scheme: Scheme::Qsgd,
            bits: 3,
            ..Default::default()
        }));
        let g: Vec<f32> = (0..256).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        let frame = ef.compress_with_feedback(&g, &mut rng);
        ef.restore_lost(&frame);
        for (i, (&r, &gi)) in ef.residual().iter().zip(&g).enumerate() {
            assert!(
                (r - gi).abs() < 1e-5,
                "elem {i}: residual {r} should equal the undelivered gradient {gi}"
            );
        }
    }

    #[test]
    fn park_roundtrip_compacts_and_approximately_preserves_residual() {
        let mut rng = Rng::new(5);
        let mut ef = ErrorFeedback::new(make_compressor(&QuantConfig {
            scheme: Scheme::Qsgd,
            bits: 8,
            ..Default::default()
        }));
        let g: Vec<f32> = (0..512).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        let _ = ef.compress_with_feedback(&g, &mut rng);
        let before = ef.residual().to_vec();
        let live_bytes = ef.state_bytes();
        assert!(ef.park(&mut rng, Vec::new()).is_none(), "first park consumes the buffer");
        assert!(ef.is_parked());
        assert!(
            ef.state_bytes() * 4 < live_bytes,
            "parked state {} must be a small fraction of live state {live_bytes}",
            ef.state_bytes()
        );
        // Parking twice is a no-op that hands the spare buffer back.
        assert!(ef.park(&mut rng, Vec::new()).is_some());
        let frame = ef.unpark().unwrap().expect("a parked frame comes back for recycling");
        assert!(!frame.is_empty());
        assert!(!ef.is_parked());
        // 8-bit re-quantization after a residual-scale refit: the restored
        // residual tracks the original within a couple of quantization bins.
        assert_eq!(ef.residual().len(), before.len());
        let scale = before.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (i, (&r, &b)) in ef.residual().iter().zip(&before).enumerate() {
            assert!(
                (r - b).abs() <= scale * 0.02 + 1e-6,
                "elem {i}: parked {b} restored {r} (scale {scale})"
            );
        }
        // Unparking an already-live wrapper is a no-op.
        assert!(ef.unpark().unwrap().is_none());
        // And the wrapper keeps working after the round trip.
        let _ = ef.compress_with_feedback(&g, &mut rng);
    }

    #[test]
    fn feedback_recovers_full_magnitude_with_adaptive_range() {
        // EF needs a contractive compressor. QSGD's range adapts to
        // max|g + residual|, so a constant gradient's full magnitude is
        // eventually transmitted: the running mean of decoded updates
        // approaches the true g. (With hard truncation at a fixed alpha the
        // compressor is NOT contractive for |g| > alpha — that failure mode
        // is exactly why the paper's quantizers keep the bias analysis.)
        let mut rng = Rng::new(2);
        let mut ef = ErrorFeedback::new(make_compressor(&QuantConfig {
            scheme: Scheme::Qsgd,
            bits: 3,
            ..Default::default()
        }));
        let g = vec![0.2f32; 64];
        let rounds = 200;
        let mut sum = vec![0.0f64; 64];
        for _ in 0..rounds {
            let out = Payload::decode(&ef.compress_with_feedback(&g, &mut rng))
                .unwrap()
                .dequantize();
            for (s, &o) in sum.iter_mut().zip(&out) {
                *s += o as f64;
            }
        }
        let mean = sum.iter().sum::<f64>() / (64.0 * rounds as f64);
        assert!((mean - 0.2).abs() < 0.02, "EF mean {mean} should approach 0.2");
    }
}
