//! Wire format: the exact bytes a client ships to the server.
//!
//! Every compressed gradient is one self-describing frame; the simulated
//! network transmits these bytes and the byte count IS the paper's
//! communication cost (`ceil(d·b/8)` payload + a few header bytes).
//!
//! Layout (little-endian):
//!
//! ```text
//! [0..2)  magic 0x5154 ("TQ")
//! [2]     payload kind: 0 raw | 1 uniform | 2 codebook | 3 sparse | 4 multiscale
//! [3]     bits per index (uniform/codebook/multiscale; 0 otherwise)
//! [4..8)  d: element count u32
//! then kind-specific:
//!   raw:        d * f32
//!   uniform:    alpha f32, s u16, packed indices
//!   codebook:   len u16, len * f32 levels, packed indices
//!   sparse:     k u32, k * (u32 index), k * (f32 value)
//!   multiscale: alpha f32, beta f32, s_hi u16, s_lo u16, packed indices
//! ```
//!
//! A multiscale frame (kind 4) ships only the two scales and interval
//! counts; both ends rebuild the merged two-scale codebook with
//! [`multiscale_codebook`], so the level table never crosses the wire.

use anyhow::{anyhow, bail, Result};

use super::bitpack;
use crate::config::MAX_BITS;

const MAGIC: u16 = 0x5154;

/// On-the-wire kind byte of a sparse (Top-k) frame.
pub const KIND_SPARSE: u8 = 3;

/// On-the-wire kind byte of a multiscale (two-scale) frame.
pub const KIND_MULTISCALE: u8 = 4;

/// Peek a frame's payload-kind byte (header offset 2) without decoding —
/// used by the streaming pipeline to route sparse frames to the fused
/// scatter path instead of densifying them. `None` when the bytes are
/// shorter than a frame header.
pub fn frame_kind(bytes: &[u8]) -> Option<u8> {
    bytes.get(2).copied()
}

/// Decoded frame payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Uncompressed f32s (DSGD oracle).
    Raw(Vec<f32>),
    /// Uniform codebook on [−α, α] with s intervals; values are indices.
    Uniform { alpha: f32, s: u16, idx: Vec<u32> },
    /// Explicit codebook levels; values are indices into it.
    Codebook { levels: Vec<f32>, idx: Vec<u32> },
    /// Sparse (index, value) pairs over a d-element vector (Top-k).
    Sparse { d: u32, pairs: Vec<(u32, f32)> },
    /// Two-scale quantizer (Vineeth 2021): a coarse grid with `s_hi`
    /// intervals on [−α, α] merged with a fine grid of `s_lo` intervals on
    /// [−β, β] (0 < β ≤ α); indices address the merged, sorted,
    /// deduplicated codebook from [`multiscale_codebook`].
    Multiscale { alpha: f32, beta: f32, s_hi: u16, s_lo: u16, idx: Vec<u32> },
}

impl Payload {
    /// Number of gradient elements this frame reconstructs.
    pub fn len(&self) -> usize {
        match self {
            Payload::Raw(v) => v.len(),
            Payload::Uniform { idx, .. } => idx.len(),
            Payload::Codebook { idx, .. } => idx.len(),
            Payload::Sparse { d, .. } => *d as usize,
            Payload::Multiscale { idx, .. } => idx.len(),
        }
    }

    /// Whether the frame reconstructs zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize with the given index bit width (uniform/codebook).
    pub fn encode(&self, bits: u32) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.len() / 2);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        match self {
            Payload::Raw(v) => {
                out.push(0u8);
                out.push(0u8);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::Uniform { alpha, s, idx } => {
                out.push(1u8);
                out.push(bits as u8);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                out.extend_from_slice(&alpha.to_le_bytes());
                out.extend_from_slice(&s.to_le_bytes());
                out.extend_from_slice(&bitpack::pack(idx, bits));
            }
            Payload::Codebook { levels, idx } => {
                out.push(2u8);
                out.push(bits as u8);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                out.extend_from_slice(&(levels.len() as u16).to_le_bytes());
                for l in levels {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                out.extend_from_slice(&bitpack::pack(idx, bits));
            }
            Payload::Sparse { d, pairs } => {
                out.push(3u8);
                out.push(0u8);
                out.extend_from_slice(&d.to_le_bytes());
                out.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
                for (i, _) in pairs {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for (_, v) in pairs {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Payload::Multiscale { alpha, beta, s_hi, s_lo, idx } => {
                out.push(4u8);
                out.push(bits as u8);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                out.extend_from_slice(&alpha.to_le_bytes());
                out.extend_from_slice(&beta.to_le_bytes());
                out.extend_from_slice(&s_hi.to_le_bytes());
                out.extend_from_slice(&s_lo.to_le_bytes());
                out.extend_from_slice(&bitpack::pack(idx, bits));
            }
        }
        out
    }

    /// Parse a frame produced by [`Payload::encode`]: validates the magic,
    /// kind byte and every length field before allocating. The byte layout
    /// per kind is specified normatively in `docs/PROTOCOL.md`.
    pub fn decode(bytes: &[u8]) -> Result<Payload> {
        let mut r = Reader { b: bytes, i: 0 };
        if r.u16()? != MAGIC {
            bail!("bad frame magic");
        }
        let kind = r.u8()?;
        let bits = r.u8()? as u32;
        let d = r.u32()? as usize;
        check_bits(kind, bits)?;
        Ok(match kind {
            0 => {
                let mut v = Vec::with_capacity(d);
                for _ in 0..d {
                    v.push(r.f32()?);
                }
                Payload::Raw(v)
            }
            1 => {
                let alpha = r.f32()?;
                let s = r.u16()?;
                let idx = bitpack::unpack(r.rest(), bits, d);
                Payload::Uniform { alpha, s, idx }
            }
            2 => {
                let n = r.u16()? as usize;
                let mut levels = Vec::with_capacity(n);
                for _ in 0..n {
                    levels.push(r.f32()?);
                }
                let idx = bitpack::unpack(r.rest(), bits, d);
                Payload::Codebook { levels, idx }
            }
            3 => {
                let k = r.u32()? as usize;
                let mut is = Vec::with_capacity(k);
                for _ in 0..k {
                    is.push(r.u32()?);
                }
                let mut pairs = Vec::with_capacity(k);
                for &i in &is {
                    pairs.push((i, r.f32()?));
                }
                Payload::Sparse { d: d as u32, pairs }
            }
            4 => {
                let alpha = r.f32()?;
                let beta = r.f32()?;
                let s_hi = r.u16()?;
                let s_lo = r.u16()?;
                check_multiscale(alpha, beta, s_hi, s_lo)?;
                let idx = bitpack::unpack(r.rest(), bits, d);
                Payload::Multiscale { alpha, beta, s_hi, s_lo, idx }
            }
            k => bail!("unknown payload kind {k}"),
        })
    }

    /// Reconstruct the dense gradient vector (the server-side dequantize).
    pub fn dequantize(&self) -> Vec<f32> {
        match self {
            Payload::Raw(v) => v.clone(),
            Payload::Uniform { alpha, s, idx } => {
                let step = 2.0f32 * alpha / *s as f32;
                idx.iter().map(|&k| -alpha + k as f32 * step).collect()
            }
            Payload::Codebook { levels, idx } => {
                idx.iter().map(|&k| levels[k as usize]).collect()
            }
            Payload::Sparse { d, pairs } => {
                let mut v = vec![0.0f32; *d as usize];
                for &(i, x) in pairs {
                    v[i as usize] = x;
                }
                v
            }
            Payload::Multiscale { alpha, beta, s_hi, s_lo, idx } => {
                let levels = multiscale_codebook(*alpha, *beta, *s_hi, *s_lo);
                idx.iter().map(|&k| levels[k as usize]).collect()
            }
        }
    }
}

/// Reject index bit-widths no decoder handles: quantized kinds
/// (uniform/codebook/multiscale) must carry 1..=[`MAX_BITS`] — anything
/// wider is corruption, and letting it through would shift-overflow the
/// unpack masks.
fn check_bits(kind: u8, bits: u32) -> Result<()> {
    if matches!(kind, 1 | 2 | 4) && !(1..=MAX_BITS).contains(&bits) {
        bail!("frame bits {bits} outside the packed range 1..={MAX_BITS}");
    }
    Ok(())
}

/// Validate a multiscale frame's scale parameters before building the
/// merged codebook from them.
fn check_multiscale(alpha: f32, beta: f32, s_hi: u16, s_lo: u16) -> Result<()> {
    if s_hi == 0 || s_lo == 0 {
        bail!("multiscale frame with zero interval count");
    }
    if !alpha.is_finite() || !beta.is_finite() || !(beta > 0.0 && beta <= alpha) {
        bail!("multiscale scales must satisfy 0 < beta <= alpha, got alpha={alpha} beta={beta}");
    }
    Ok(())
}

/// The merged two-scale codebook a multiscale frame's indices address:
/// the coarse grid of `s_hi` even intervals on [−α, α] unioned with the
/// fine grid of `s_lo` even intervals on [−β, β], sorted ascending and
/// deduplicated (even interval counts make both grids hit exactly 0.0, so
/// the merged table has at most `s_hi + s_lo + 1` levels). Levels are
/// computed in f64 and cast once, like `solver::uniform_codebook`, so the
/// encoder and every decoder reconstruct bit-identical tables.
pub fn multiscale_codebook(alpha: f32, beta: f32, s_hi: u16, s_lo: u16) -> Vec<f32> {
    let mut levels = Vec::with_capacity(s_hi as usize + s_lo as usize + 2);
    for k in 0..=s_hi {
        levels.push((-(alpha as f64) + 2.0 * alpha as f64 * k as f64 / s_hi as f64) as f32);
    }
    for k in 0..=s_lo {
        levels.push((-(beta as f64) + 2.0 * beta as f64 * k as f64 / s_lo as f64) as f32);
    }
    levels.sort_by(f32::total_cmp);
    levels.dedup();
    levels
}

/// Extract the truncation threshold a quantized frame encodes: α for
/// uniform and multiscale frames, the largest |level| for codebook frames,
/// `None` for raw/sparse frames (untruncated) or anything too short to
/// carry one. This is the bit-budget scheduler's observation channel — the
/// server reads the fit-driven α from the frames it already receives, so
/// scheduling needs no extra uplink traffic.
pub fn frame_alpha(bytes: &[u8]) -> Option<f32> {
    if bytes.len() < 8 || bytes[0..2] != MAGIC.to_le_bytes() {
        return None;
    }
    match bytes[2] {
        1 | 4 => bytes.get(8..12).map(|b| f32::from_le_bytes(b.try_into().unwrap())),
        2 => {
            let n = u16::from_le_bytes(bytes.get(8..10)?.try_into().unwrap()) as usize;
            if n == 0 {
                return None;
            }
            let mut m = 0.0f32;
            for k in 0..n {
                let off = 10 + 4 * k;
                let l = f32::from_le_bytes(bytes.get(off..off + 4)?.try_into().unwrap());
                m = m.max(l.abs());
            }
            Some(m)
        }
        _ => None,
    }
}

/// Start a uniform frame in a caller-provided buffer: clears `out`, reserves
/// the full frame size and writes the header; the packed indices follow via
/// [`super::kernels::quantize_uniform_pack_into`]. Byte-identical to
/// `Payload::Uniform{..}.encode(bits)` once the payload is appended.
pub fn begin_uniform_frame(out: &mut Vec<u8>, alpha: f32, s: u16, d: u32, bits: u32) {
    out.clear();
    out.reserve(14 + super::bitpack::packed_len(d as usize, bits));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(1u8);
    out.push(bits as u8);
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&alpha.to_le_bytes());
    out.extend_from_slice(&s.to_le_bytes());
}

/// Start a codebook frame in a caller-provided buffer (see
/// [`begin_uniform_frame`] for the contract).
pub fn begin_codebook_frame(out: &mut Vec<u8>, levels: &[f32], d: u32, bits: u32) {
    out.clear();
    out.reserve(10 + 4 * levels.len() + super::bitpack::packed_len(d as usize, bits));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(2u8);
    out.push(bits as u8);
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&(levels.len() as u16).to_le_bytes());
    for l in levels {
        out.extend_from_slice(&l.to_le_bytes());
    }
}

/// Start a multiscale frame (kind 4) in a caller-provided buffer (see
/// [`begin_uniform_frame`] for the contract). `bits` is the packed index
/// width of the merged codebook — `bits_for(n − 1)` where `n` is the
/// length of [`multiscale_codebook`]`(alpha, beta, s_hi, s_lo)`.
pub fn begin_multiscale_frame(
    out: &mut Vec<u8>,
    alpha: f32,
    beta: f32,
    s_hi: u16,
    s_lo: u16,
    d: u32,
    bits: u32,
) {
    out.clear();
    out.reserve(20 + super::bitpack::packed_len(d as usize, bits));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(KIND_MULTISCALE);
    out.push(bits as u8);
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&alpha.to_le_bytes());
    out.extend_from_slice(&beta.to_le_bytes());
    out.extend_from_slice(&s_hi.to_le_bytes());
    out.extend_from_slice(&s_lo.to_le_bytes());
}

/// Encode a raw (DSGD) frame straight from the borrowed gradient slice into
/// `out` — byte-identical to `Payload::Raw(grads.to_vec()).encode(0)` with
/// neither the dense copy nor the frame allocation.
pub fn encode_raw_into(grads: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(8 + 4 * grads.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(0u8);
    out.push(0u8);
    out.extend_from_slice(&(grads.len() as u32).to_le_bytes());
    for x in grads {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a sparse (Top-k) frame into `out` — byte-identical to
/// `Payload::Sparse{..}.encode(0)`.
pub fn encode_sparse_into(d: u32, pairs: &[(u32, f32)], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(12 + 8 * pairs.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(3u8);
    out.push(0u8);
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
    for (i, _) in pairs {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for (_, v) in pairs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Build a uniform frame directly from pre-packed indices (allocating
/// wrapper kept for tests and one-shot callers).
pub fn encode_uniform_packed(alpha: f32, s: u16, d: u32, bits: u32, packed: &[u8]) -> Vec<u8> {
    debug_assert_eq!(packed.len(), super::bitpack::packed_len(d as usize, bits));
    let mut out = Vec::new();
    begin_uniform_frame(&mut out, alpha, s, d, bits);
    out.extend_from_slice(packed);
    out
}

/// Build a codebook frame directly from pre-packed indices.
pub fn encode_codebook_packed(levels: &[f32], d: u32, bits: u32, packed: &[u8]) -> Vec<u8> {
    debug_assert_eq!(packed.len(), super::bitpack::packed_len(d as usize, bits));
    let mut out = Vec::new();
    begin_codebook_frame(&mut out, levels, d, bits);
    out.extend_from_slice(packed);
    out
}

/// Fused decode → dense gradient into a caller-provided buffer (cleared
/// first): skips the intermediate index vector for uniform/codebook frames
/// AND, with a recycled `out`, the dense-buffer allocation. Error feedback
/// and the benches still decode through here; the coordinator's server path
/// now goes one step further and folds the weighted accumulate into the
/// same walk — see [`decode_dequantize_accumulate_into`].
pub fn decode_dequantize_into(bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
    out.clear();
    let mut r = Reader { b: bytes, i: 0 };
    if r.u16()? != MAGIC {
        bail!("bad frame magic");
    }
    let kind = r.u8()?;
    let bits = r.u8()? as u32;
    let d = r.u32()? as usize;
    check_bits(kind, bits)?;
    match kind {
        1 => {
            let alpha = r.f32()?;
            let s = r.u16()?;
            let step = 2.0f32 * alpha / s as f32;
            let packed = r.rest();
            if packed.len() < super::bitpack::packed_len(d, bits) {
                bail!("truncated uniform payload");
            }
            out.reserve(d);
            if bits > 8 {
                // Wide indices: the inline two-byte window below only covers
                // bits + offset ≤ 16 when bits ≤ 8; stage through unpack.
                for idx in super::bitpack::unpack(packed, bits, d) {
                    out.push(-alpha + idx as f32 * step);
                }
                return Ok(());
            }
            let mask = (1u32 << bits) - 1;
            let mut bitpos = 0usize;
            for _ in 0..d {
                let byte = bitpos >> 3;
                let off = (bitpos & 7) as u32;
                let mut wide = packed[byte] as u32;
                if let Some(&b1) = packed.get(byte + 1) {
                    wide |= (b1 as u32) << 8;
                }
                let idx = (wide >> off) & mask;
                out.push(-alpha + idx as f32 * step);
                bitpos += bits as usize;
            }
            Ok(())
        }
        2 => {
            let n = r.u16()? as usize;
            let mut levels = Vec::with_capacity(n);
            for _ in 0..n {
                levels.push(r.f32()?);
            }
            dequantize_levels_packed_into(r.rest(), bits, d, &levels, out)
        }
        4 => {
            let alpha = r.f32()?;
            let beta = r.f32()?;
            let s_hi = r.u16()?;
            let s_lo = r.u16()?;
            check_multiscale(alpha, beta, s_hi, s_lo)?;
            let levels = multiscale_codebook(alpha, beta, s_hi, s_lo);
            dequantize_levels_packed_into(r.rest(), bits, d, &levels, out)
        }
        // Raw: read the f32s straight into the reused dense buffer (the
        // decode mirror of `encode_raw_into` — no staging Vec, no clone).
        0 => {
            out.reserve(d);
            for _ in 0..d {
                out.push(r.f32()?);
            }
            Ok(())
        }
        // Sparse: zero-fill then scatter, walking the index and value
        // arrays with two cursors instead of materializing (idx, val) pairs.
        3 => {
            let k = r.u32()? as usize;
            let mut vals = Reader { b: r.b, i: r.i + 4 * k };
            out.resize(d, 0.0);
            for _ in 0..k {
                let i = r.u32()? as usize;
                let v = vals.f32()?;
                *out.get_mut(i).ok_or_else(|| anyhow!("sparse index {i} out of range"))? = v;
            }
            Ok(())
        }
        k => bail!("unknown payload kind {k}"),
    }
}

/// Shared level-table decode tail for codebook-shaped payloads
/// (kinds 2 and 4): validate the packed length, then walk the bitstream
/// pushing `levels[idx]` — inline two-byte window for bits ≤ 8, staged
/// `bitpack::unpack` for the wide 9..=[`MAX_BITS`] widths.
fn dequantize_levels_packed_into(
    packed: &[u8],
    bits: u32,
    d: usize,
    levels: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    if packed.len() < bitpack::packed_len(d, bits) {
        bail!("truncated codebook payload");
    }
    out.reserve(d);
    if bits > 8 {
        for idx in bitpack::unpack(packed, bits, d) {
            let idx = idx as usize;
            out.push(*levels.get(idx).ok_or_else(|| anyhow!("index {idx} out of codebook"))?);
        }
        return Ok(());
    }
    let mask = (1u32 << bits) - 1;
    let mut bitpos = 0usize;
    for _ in 0..d {
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u32;
        let mut wide = packed[byte] as u32;
        if let Some(&b1) = packed.get(byte + 1) {
            wide |= (b1 as u32) << 8;
        }
        let idx = ((wide >> off) & mask) as usize;
        out.push(*levels.get(idx).ok_or_else(|| anyhow!("index {idx} out of codebook"))?);
        bitpos += bits as usize;
    }
    Ok(())
}

/// Allocating wrapper over [`decode_dequantize_into`].
pub fn decode_dequantize(bytes: &[u8]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    decode_dequantize_into(bytes, &mut out)?;
    Ok(out)
}

/// Fused decode → dequantize → weighted accumulate: `acc[i] += w * d_i`
/// where `d` is the frame's dense reconstruction, in ONE walk over the
/// bitstream — the dense scratch write + re-read pass of
/// `decode_dequantize_into` followed by a `zip` accumulate disappears
/// entirely. For uniform/codebook/multiscale frames with bits ≤ 8 and at
/// most 256 levels the per-level products `w * level_k` are precomputed
/// into a 256-entry LUT, so the inner loop is an unpack, a table load and
/// an add — executed by the runtime-dispatched
/// [`super::kernels::accumulate_packed_wlut`] (SIMD gather-add where the
/// CPU supports it, bit-identical to scalar; see [`super::simd`]); wider
/// frames (legal up to [`MAX_BITS`]) fall back to a staged unpack with the
/// identical per-element f32 operations.
///
/// Bit-identity contract (the server's sharded aggregation relies on it,
/// property-tested across schemes × bits): every element receives exactly
/// the f32 operations of the two-pass path — `d_k` computed per level as
/// before, one `w * d_k` product, one `+=` — in the same element order.
/// Sparse frames scatter-add only their stored pairs; skipped elements
/// would have received `+= w * 0.0`, which is the identity on every value
/// the accumulator can hold (a chain of f32 adds seeded from +0.0 never
/// produces −0.0). Sparse indices must be unique, as the Top-k encoder
/// guarantees: a duplicate would accumulate where the dense path overwrote.
///
/// `acc` must be exactly the frame's element count (the coordinator's
/// per-layer-group slice) — a mismatch is the old "frame length != group
/// size" error, now caught inside the kernel.
pub fn decode_dequantize_accumulate_into(bytes: &[u8], w: f32, acc: &mut [f32]) -> Result<()> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.u16()? != MAGIC {
        bail!("bad frame magic");
    }
    let kind = r.u8()?;
    let bits = r.u8()? as u32;
    let d = r.u32()? as usize;
    if d != acc.len() {
        bail!("frame length {} != accumulator size {}", d, acc.len());
    }
    check_bits(kind, bits)?;
    match kind {
        1 => {
            let alpha = r.f32()?;
            let s = r.u16()?;
            let packed = r.rest();
            if packed.len() < super::bitpack::packed_len(d, bits) {
                bail!("truncated uniform payload");
            }
            let step = 2.0f32 * alpha / s as f32;
            if bits > 8 {
                // Wide indices overflow the 256-entry LUT: compute `d_k`
                // per element instead — the same f32 expression and the
                // same single `w * d` product, so bit-identity holds.
                for (a, idx) in acc.iter_mut().zip(super::bitpack::unpack(packed, bits, d)) {
                    let dk = -alpha + idx as f32 * step;
                    *a += w * dk;
                }
                return Ok(());
            }
            let mask = (1usize << bits) - 1;
            let mut wlut = [0.0f32; 256];
            for (k, slot) in wlut.iter_mut().enumerate().take(mask + 1) {
                // Same f32 dequantize expression as the two-pass path, then
                // the same single `w * d` product — per level, not per elem.
                let dk = -alpha + k as f32 * step;
                *slot = w * dk;
            }
            // n_levels = 256: every index the mask can produce dequantizes,
            // exactly like the unfused uniform decoder.
            super::kernels::accumulate_packed_wlut(packed, bits, 256, &wlut, acc)
                .map_err(|idx| anyhow!("uniform index {idx} unrepresentable"))?;
            Ok(())
        }
        2 => {
            let n = r.u16()? as usize;
            if n <= 256 && bits <= 8 {
                let mut wlut = [0.0f32; 256];
                for slot in wlut.iter_mut().take(n) {
                    *slot = w * r.f32()?;
                }
                let packed = r.rest();
                if packed.len() < super::bitpack::packed_len(d, bits) {
                    bail!("truncated codebook payload");
                }
                super::kernels::accumulate_packed_wlut(packed, bits, n, &wlut, acc)
                    .map_err(|idx| anyhow!("index {idx} out of codebook"))?;
                return Ok(());
            }
            // Wide path (9..=MAX_BITS-bit indices or an oversized level
            // table): read the levels, then accumulate per element —
            // `w * level` is the very product the LUT precomputes.
            let mut levels = Vec::with_capacity(n);
            for _ in 0..n {
                levels.push(r.f32()?);
            }
            accumulate_levels_packed(r.rest(), bits, d, &levels, w, acc)
        }
        4 => {
            let alpha = r.f32()?;
            let beta = r.f32()?;
            let s_hi = r.u16()?;
            let s_lo = r.u16()?;
            check_multiscale(alpha, beta, s_hi, s_lo)?;
            let levels = multiscale_codebook(alpha, beta, s_hi, s_lo);
            let n = levels.len();
            if n <= 256 && bits <= 8 {
                let mut wlut = [0.0f32; 256];
                for (slot, &l) in wlut.iter_mut().zip(&levels) {
                    *slot = w * l;
                }
                let packed = r.rest();
                if packed.len() < super::bitpack::packed_len(d, bits) {
                    bail!("truncated multiscale payload");
                }
                super::kernels::accumulate_packed_wlut(packed, bits, n, &wlut, acc)
                    .map_err(|idx| anyhow!("index {idx} out of codebook"))?;
                return Ok(());
            }
            accumulate_levels_packed(r.rest(), bits, d, &levels, w, acc)
        }
        // Raw: accumulate straight from the byte stream.
        0 => {
            for a in acc.iter_mut() {
                *a += w * r.f32()?;
            }
            Ok(())
        }
        // Sparse: scatter-add the stored pairs (see the contract above).
        3 => {
            let k = r.u32()? as usize;
            let mut vals = Reader { b: r.b, i: r.i + 4 * k };
            for _ in 0..k {
                let i = r.u32()? as usize;
                let v = vals.f32()?;
                *acc.get_mut(i).ok_or_else(|| anyhow!("sparse index {i} out of range"))? +=
                    w * v;
            }
            Ok(())
        }
        k => bail!("unknown payload kind {k}"),
    }
}

/// Staged accumulate tail for codebook-shaped frames that don't fit the
/// 256-entry w·LUT: unpack, bounds-check each index, `acc += w * level`.
fn accumulate_levels_packed(
    packed: &[u8],
    bits: u32,
    d: usize,
    levels: &[f32],
    w: f32,
    acc: &mut [f32],
) -> Result<()> {
    if packed.len() < bitpack::packed_len(d, bits) {
        bail!("truncated codebook payload");
    }
    for (a, idx) in acc.iter_mut().zip(bitpack::unpack(packed, bits, d)) {
        let idx = idx as usize;
        let l = *levels.get(idx).ok_or_else(|| anyhow!("index {idx} out of codebook"))?;
        *a += w * l;
    }
    Ok(())
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated frame at offset {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.i..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip() {
        let p = Payload::Raw(vec![1.0, -2.5, 0.0]);
        let q = Payload::decode(&p.encode(0)).unwrap();
        assert_eq!(p, q);
        assert_eq!(q.dequantize(), vec![1.0, -2.5, 0.0]);
    }

    #[test]
    fn uniform_roundtrip_and_size() {
        let idx: Vec<u32> = (0..1000).map(|i| i % 8).collect();
        let p = Payload::Uniform { alpha: 0.05, s: 7, idx };
        let bytes = p.encode(3);
        // header 8 + alpha 4 + s 2 + ceil(1000*3/8)
        assert_eq!(bytes.len(), 8 + 4 + 2 + 375);
        let q = Payload::decode(&bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn uniform_dequantize_endpoints() {
        let p = Payload::Uniform { alpha: 1.0, s: 4, idx: vec![0, 2, 4] };
        assert_eq!(p.dequantize(), vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn codebook_roundtrip() {
        let p = Payload::Codebook {
            levels: vec![-0.5, -0.1, 0.0, 0.1, 0.5],
            idx: vec![4, 0, 2, 2, 3],
        };
        let q = Payload::decode(&p.encode(3)).unwrap();
        assert_eq!(p, q);
        assert_eq!(q.dequantize()[0], 0.5);
    }

    #[test]
    fn sparse_roundtrip() {
        let p = Payload::Sparse { d: 10, pairs: vec![(3, 1.5), (7, -0.25)] };
        let q = Payload::decode(&p.encode(0)).unwrap();
        assert_eq!(p, q);
        let dense = q.dequantize();
        assert_eq!(dense.len(), 10);
        assert_eq!(dense[3], 1.5);
        assert_eq!(dense[0], 0.0);
    }

    #[test]
    fn multiscale_codebook_merges_sorted_dedup() {
        // Even interval counts put 0.0 on both grids exactly once.
        let cb = multiscale_codebook(1.0, 0.25, 2, 2);
        assert_eq!(cb, vec![-1.0, -0.25, 0.0, 0.25, 1.0]);
        // A fine grid nested strictly inside the coarse one keeps all
        // s_hi + s_lo + 1 distinct levels, strictly increasing.
        let cb = multiscale_codebook(0.1, 0.02, 4, 2);
        assert_eq!(cb.len(), 7);
        assert!(cb.windows(2).all(|w| w[0] < w[1]), "{cb:?}");
        assert_eq!(cb[0], -0.1);
        assert_eq!(cb[6], 0.1);
    }

    #[test]
    fn multiscale_roundtrip() {
        let cb = multiscale_codebook(0.1, 0.02, 4, 2);
        let idx: Vec<u32> = (0..100).map(|i| i % cb.len() as u32).collect();
        let p = Payload::Multiscale { alpha: 0.1, beta: 0.02, s_hi: 4, s_lo: 2, idx };
        let bytes = p.encode(3);
        // header 8 + alpha 4 + beta 4 + s_hi 2 + s_lo 2 + ceil(100*3/8)
        assert_eq!(bytes.len(), 20 + 38);
        let q = Payload::decode(&bytes).unwrap();
        assert_eq!(p, q);
        let dense = q.dequantize();
        assert_eq!(dense[0], cb[0]);
        assert_eq!(dense[3], cb[3]);
    }

    #[test]
    fn multiscale_golden_bytes() {
        // Hand-computed fixture; docs/PROTOCOL.md §4.5 restates these bytes.
        let p = Payload::Multiscale {
            alpha: 1.0,
            beta: 0.25,
            s_hi: 2,
            s_lo: 2,
            idx: vec![0, 4, 2],
        };
        let want: Vec<u8> = vec![
            0x54, 0x51, // magic
            0x04, // kind = multiscale
            0x03, // bits = 3
            0x03, 0x00, 0x00, 0x00, // d = 3
            0x00, 0x00, 0x80, 0x3F, // alpha = 1.0
            0x00, 0x00, 0x80, 0x3E, // beta = 0.25
            0x02, 0x00, // s_hi = 2
            0x02, 0x00, // s_lo = 2
            0xA0, 0x00, // indices 0,4,2 packed LSB-first
        ];
        assert_eq!(p.encode(3), want);
        // Merged codebook {−1, −0.25, 0, 0.25, 1}: indices 0/4/2 hit the
        // endpoints and the shared zero level.
        assert_eq!(decode_dequantize(&want).unwrap(), vec![-1.0, 1.0, 0.0]);
    }

    #[test]
    fn frame_alpha_extraction() {
        let u = Payload::Uniform { alpha: 0.07, s: 7, idx: vec![0, 3] }.encode(3);
        assert_eq!(frame_alpha(&u), Some(0.07));
        let m = Payload::Multiscale { alpha: 0.5, beta: 0.1, s_hi: 4, s_lo: 2, idx: vec![0] }
            .encode(3);
        assert_eq!(frame_alpha(&m), Some(0.5));
        let c = Payload::Codebook { levels: vec![-0.3, 0.0, 0.2], idx: vec![1] }.encode(2);
        assert_eq!(frame_alpha(&c), Some(0.3));
        let r = Payload::Raw(vec![9.0]).encode(0);
        assert_eq!(frame_alpha(&r), None);
        let s = Payload::Sparse { d: 4, pairs: vec![(0, 2.0)] }.encode(0);
        assert_eq!(frame_alpha(&s), None);
        assert_eq!(frame_alpha(&[0x54]), None);
    }

    #[test]
    fn fused_decode_equals_general_path() {
        // decode_dequantize (hot path) must produce exactly what
        // Payload::decode().dequantize() (reference path) produces, for
        // every payload kind and bit width.
        crate::prop::check(100, |rng| {
            let d = 1 + rng.below(3000) as usize;
            let bits = 2 + rng.below(4) as u32;
            let s = (1u32 << bits) - 1;
            let kind = rng.below(5);
            let bytes = match kind {
                0 => Payload::Raw((0..d).map(|_| rng.f32() - 0.5).collect()).encode(0),
                1 => {
                    let idx: Vec<u32> = (0..d).map(|_| rng.below(s as u64 + 1) as u32).collect();
                    Payload::Uniform { alpha: 0.1, s: s as u16, idx }.encode(bits)
                }
                2 => {
                    let cb = crate::prop::gen_codebook(rng, 5);
                    let n = cb.len() as u64;
                    let idx: Vec<u32> = (0..d).map(|_| rng.below(n) as u32).collect();
                    let b = 32 - (cb.len() as u32 - 1).leading_zeros();
                    Payload::Codebook { levels: cb, idx }.encode(b)
                }
                3 => {
                    let k = 1 + rng.below(d as u64) as usize;
                    let mut pairs: Vec<(u32, f32)> =
                        (0..k).map(|i| (i as u32, rng.f32())).collect();
                    pairs.dedup_by_key(|p| p.0);
                    Payload::Sparse { d: d as u32, pairs }.encode(0)
                }
                _ => {
                    let n = multiscale_codebook(0.1, 0.02, 4, 2).len() as u64;
                    let idx: Vec<u32> = (0..d).map(|_| rng.below(n) as u32).collect();
                    let b = 32 - (n as u32 - 1).leading_zeros();
                    Payload::Multiscale { alpha: 0.1, beta: 0.02, s_hi: 4, s_lo: 2, idx }
                        .encode(b)
                }
            };
            let fused = decode_dequantize(&bytes).map_err(|e| e.to_string())?;
            let general = Payload::decode(&bytes).map_err(|e| e.to_string())?.dequantize();
            crate::prop::assert_prop(fused == general, format!("kind {kind} mismatch"))
        });
    }

    #[test]
    fn fused_accumulate_is_bit_identical_to_two_pass() {
        // decode_dequantize_accumulate_into must reproduce EXACTLY the bits
        // of decode_dequantize_into + `acc += w * d` for every payload kind,
        // bit width and weight — including on a dirty accumulator.
        crate::prop::check(100, |rng| {
            let d = 1 + rng.below(2000) as usize;
            let bits = 1 + rng.below(8) as u32;
            let s = (1u32 << bits) - 1;
            let w = (rng.f64() * 1.5) as f32;
            let kind = rng.below(5);
            let bytes = match kind {
                0 => Payload::Raw((0..d).map(|_| rng.f32() - 0.5).collect()).encode(0),
                1 => {
                    let idx: Vec<u32> = (0..d).map(|_| rng.below(s as u64 + 1) as u32).collect();
                    Payload::Uniform { alpha: 0.1, s: s as u16, idx }.encode(bits)
                }
                2 => {
                    let cb = crate::prop::gen_codebook(rng, 5);
                    let n = cb.len() as u64;
                    let idx: Vec<u32> = (0..d).map(|_| rng.below(n) as u32).collect();
                    let b = 32 - (cb.len() as u32 - 1).leading_zeros();
                    Payload::Codebook { levels: cb, idx }.encode(b)
                }
                3 => {
                    let k = 1 + rng.below(d as u64) as usize;
                    let mut pairs: Vec<(u32, f32)> =
                        (0..k).map(|i| (i as u32, rng.f32())).collect();
                    pairs.dedup_by_key(|p| p.0);
                    Payload::Sparse { d: d as u32, pairs }.encode(0)
                }
                _ => {
                    let n = multiscale_codebook(0.1, 0.02, 4, 2).len() as u64;
                    let idx: Vec<u32> = (0..d).map(|_| rng.below(n) as u32).collect();
                    let b = 32 - (n as u32 - 1).leading_zeros();
                    Payload::Multiscale { alpha: 0.1, beta: 0.02, s_hi: 4, s_lo: 2, idx }
                        .encode(b)
                }
            };
            let base: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
            let mut want = base.clone();
            let mut scratch = Vec::new();
            decode_dequantize_into(&bytes, &mut scratch).map_err(|e| e.to_string())?;
            for (a, &dv) in want.iter_mut().zip(&scratch) {
                *a += w * dv;
            }
            let mut got = base;
            decode_dequantize_accumulate_into(&bytes, w, &mut got)
                .map_err(|e| e.to_string())?;
            let same = got.iter().map(|x| x.to_bits()).eq(want.iter().map(|x| x.to_bits()));
            crate::prop::assert_prop(same, format!("kind {kind} bits {bits}: bit mismatch"))
        });
    }

    #[test]
    fn fused_accumulate_rejects_bad_frames() {
        let idx: Vec<u32> = (0..100).map(|i| i % 8).collect();
        let bytes = Payload::Uniform { alpha: 0.1, s: 7, idx }.encode(3);
        let mut acc = vec![0.0f32; 100];
        // Truncated payload and wrong accumulator length both error.
        assert!(decode_dequantize_accumulate_into(&bytes[..bytes.len() - 5], 1.0, &mut acc)
            .is_err());
        let mut short = vec![0.0f32; 99];
        assert!(decode_dequantize_accumulate_into(&bytes, 1.0, &mut short).is_err());
        // Codebook index beyond the level table errors (idx 2 of 2 levels).
        let cb = Payload::Codebook { levels: vec![-1.0, 1.0], idx: vec![0, 1, 2] }.encode(2);
        let mut acc3 = vec![0.0f32; 3];
        assert!(decode_dequantize_accumulate_into(&cb, 1.0, &mut acc3).is_err());
    }

    #[test]
    fn fused_decode_rejects_truncated_payloads() {
        let idx: Vec<u32> = (0..100).map(|i| i % 8).collect();
        let bytes = Payload::Uniform { alpha: 0.1, s: 7, idx }.encode(3);
        assert!(decode_dequantize(&bytes[..bytes.len() - 5]).is_err());
        let cb = Payload::Codebook { levels: vec![-1.0, 0.0, 1.0], idx: vec![0, 2, 1] }.encode(2);
        assert!(decode_dequantize(&cb[..cb.len() - 1]).is_err());
    }

    #[test]
    fn packed_encoders_match_payload_encode() {
        // encode_uniform_packed / encode_codebook_packed must be
        // byte-identical to the Payload enum encoders.
        let idx: Vec<u32> = (0..500).map(|i| i % 8).collect();
        let packed = super::super::bitpack::pack(&idx, 3);
        let a = encode_uniform_packed(0.07, 7, 500, 3, &packed);
        let b = Payload::Uniform { alpha: 0.07, s: 7, idx: idx.clone() }.encode(3);
        assert_eq!(a, b);
        let levels = vec![-0.1f32, -0.02, 0.0, 0.02, 0.05, 0.07, 0.08, 0.1];
        let c = encode_codebook_packed(&levels, 500, 3, &packed);
        let d = Payload::Codebook { levels, idx }.encode(3);
        assert_eq!(c, d);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(Payload::decode(&[]).is_err());
        assert!(Payload::decode(&[0x54, 0x51, 9, 0, 0, 0, 0, 0]).is_err());
        let p = Payload::Raw(vec![1.0; 4]).encode(0);
        assert!(Payload::decode(&p[..p.len() - 2]).is_err());
        let mut bad = p.clone();
        bad[0] ^= 0xFF;
        assert!(Payload::decode(&bad).is_err());
    }

    #[test]
    fn rejects_bits_outside_max_bits() {
        // A hostile bits byte must error in every decoder, not shift-overflow.
        let mut frame = Payload::Uniform { alpha: 0.1, s: 7, idx: vec![0, 1, 2] }.encode(3);
        frame[3] = 200;
        assert!(Payload::decode(&frame).is_err());
        assert!(decode_dequantize(&frame).is_err());
        let mut acc = vec![0.0f32; 3];
        assert!(decode_dequantize_accumulate_into(&frame, 1.0, &mut acc).is_err());
        frame[3] = 0;
        assert!(Payload::decode(&frame).is_err(), "quantized kinds need bits >= 1");
    }

    #[test]
    fn rejects_bad_multiscale_params() {
        let good = Payload::Multiscale { alpha: 0.1, beta: 0.02, s_hi: 4, s_lo: 2, idx: vec![0] };
        assert!(Payload::decode(&good.encode(3)).is_ok());
        for (alpha, beta, s_hi, s_lo) in [
            (0.1f32, 0.02f32, 0u16, 2u16), // zero coarse intervals
            (0.1, 0.02, 4, 0),             // zero fine intervals
            (0.02, 0.1, 4, 2),             // beta > alpha
            (0.1, 0.0, 4, 2),              // beta = 0
            (f32::NAN, 0.02, 4, 2),        // non-finite scale
        ] {
            let p = Payload::Multiscale { alpha, beta, s_hi, s_lo, idx: vec![0] };
            let bytes = p.encode(3);
            assert!(
                Payload::decode(&bytes).is_err(),
                "alpha={alpha} beta={beta} s_hi={s_hi} s_lo={s_lo} must be rejected"
            );
            assert!(decode_dequantize(&bytes).is_err());
        }
    }

    #[test]
    fn wide_bits_fallback_matches_reference() {
        // 9..=16-bit frames take the staged (non-LUT) decode paths; they
        // must agree bit-for-bit with the generic reference path.
        let s = 4095u32;
        let bits = 12u32;
        let idx: Vec<u32> = (0..777).map(|i| (i * 37) % (s + 1)).collect();
        let uni = Payload::Uniform { alpha: 0.1, s: s as u16, idx: idx.clone() }.encode(bits);
        let levels: Vec<f32> = (0..600).map(|k| (k as f32 - 300.0) * 1e-4).collect();
        let cbi: Vec<u32> = (0..777).map(|i| (i * 13) % 600).collect();
        let cb = Payload::Codebook { levels, idx: cbi }.encode(10);
        for bytes in [&uni, &cb] {
            let fused = decode_dequantize(bytes).unwrap();
            let general = Payload::decode(bytes).unwrap().dequantize();
            assert_eq!(fused, general);
            let base: Vec<f32> = (0..777).map(|i| i as f32 * 0.01 - 3.0).collect();
            let mut want = base.clone();
            for (a, &dv) in want.iter_mut().zip(&general) {
                *a += 0.3 * dv;
            }
            let mut got = base;
            decode_dequantize_accumulate_into(bytes, 0.3, &mut got).unwrap();
            let same = got.iter().map(|x| x.to_bits()).eq(want.iter().map(|x| x.to_bits()));
            assert!(same, "wide-bit accumulate diverged from two-pass");
        }
    }
}
