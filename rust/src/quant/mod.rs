//! Gradient compression: the paper's two-stage quantizers, baselines, and
//! the wire format they serialize to.
//!
//! * [`kernels`] — scalar/slice quantization primitives (mirror ref.py),
//!   plus the fused quantize→pack streaming kernels of the encode hot path,
//! * [`simd`] — runtime-dispatched SIMD implementations of the hot kernels
//!   (AVX2/SSE2/NEON, bit-identical to scalar; see [`simd::KernelDispatch`]),
//! * [`bitpack`] — tight n-bit index packing,
//! * [`wire`] — self-describing frames (the bytes on the simulated network),
//! * [`codecs`] — TQSGD / TNQSGD / TBQSGD + QSGD / NQSGD / TernGrad / Top-k /
//!   multiscale, the [`CodecBuilder`] construction point and the
//!   [`GroupCodec`] per-(client, group) state,
//! * [`arena`] — recycled frame buffers (zero-allocation steady state),
//! * [`error_feedback`] — optional EF wrapper (extension),
//! * [`budget`] — the adaptive per-round bit-rate scheduler (extension).

pub mod arena;
pub mod bitpack;
pub mod budget;
pub mod codecs;
pub mod error_feedback;
pub mod kernels;
pub mod simd;
pub mod wire;

pub use arena::FrameArena;
pub use budget::{BitBudget, RatePlan};
pub use codecs::{make_compressor, CodecBuilder, Compressor, GroupCodec};
pub use error_feedback::ErrorFeedback;
pub use wire::Payload;

/// Convenience: compress → decode → dequantize (used by tests/benches to
/// measure pure quantization error without a network in the loop).
pub fn roundtrip(
    c: &mut dyn Compressor,
    grads: &[f32],
    rng: &mut crate::util::Rng,
) -> crate::Result<Vec<f32>> {
    Ok(Payload::decode(&c.compress(grads, rng))?.dequantize())
}

/// Mean squared error between two equally sized vectors.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{QuantConfig, Scheme};
    use crate::util::Rng;

    #[test]
    fn roundtrip_helper_works() {
        let mut rng = Rng::new(1);
        let g: Vec<f32> = (0..100).map(|_| rng.f32() - 0.5).collect();
        let mut c = make_compressor(&QuantConfig { scheme: Scheme::Dsgd, ..Default::default() });
        let out = roundtrip(c.as_mut(), &g, &mut rng).unwrap();
        assert_eq!(out, g);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mse(&[0.0], &[2.0]), 4.0);
    }
}
