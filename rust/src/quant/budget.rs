//! Adaptive bit-budget scheduler (extension beyond the paper).
//!
//! DQ-SGD (arxiv 2107.14575) shows that under a total communication budget
//! the right move is to spend bits where the gradient variance is — and the
//! truncation thresholds the paper's codecs already fit per round are
//! exactly that signal. [`BitBudget`] watches the α each uplink frame
//! carries (via [`wire::frame_alpha`] — no extra wire traffic), and each
//! round runs a deterministic greedy water-filling pass that assigns a
//! bit-width to every (client, layer-group) pair such that the fleet's
//! summed frame bytes fit a per-round budget and optional per-client
//! uplink caps.
//!
//! The allocator maximizes marginal MSE reduction per extra byte: for a
//! uniform s-level grid the quantization error scales as α²·d/s², so the
//! benefit of moving a pair from b to b+1 bits is
//! `α²·d·(1/s_b² − 1/s_{b+1}²)` with `s_b = 2^b − 1`, and the cost is the
//! frame-byte delta from the wire-format model below. Pairs start at the
//! scheme's minimum admissible width and are upgraded best-first until the
//! budget binds or every pair reaches the configured ceiling.
//!
//! Everything is deterministic: observations are keyed by round
//! (newest-wins, so transport arrival order is irrelevant) and heap ties
//! break on a seeded per-(client, group) stream (`ROLE_BUDGET`), never on
//! float identity or iteration order.

use std::collections::BinaryHeap;

use crate::config::{ExperimentConfig, Scheme, MAX_BITS};
use crate::util::Rng;

use super::wire;

/// RNG role for allocator tie-breaking (see `util::rng` role registry).
const ROLE_BUDGET: u64 = 0xB1D6;

/// The bit-widths one round's scheduler pass assigned.
///
/// `clients` holds the active client ids in ascending order; `bits[i][g]`
/// is the width for `clients[i]`'s layer group `g`. The coordinator applies
/// a plan via `Client::set_rates` (in-process) or ships it in ROUND_START
/// (remote workers).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RatePlan {
    /// Active client ids, ascending.
    pub clients: Vec<usize>,
    /// Per-client, per-layer-group bit-widths, aligned with `clients`.
    pub bits: Vec<Vec<u32>>,
}

impl RatePlan {
    /// The bit row for `client`, if it is part of this plan.
    pub fn rates_for(&self, client: usize) -> Option<&[u32]> {
        let i = self.clients.binary_search(&client).ok()?;
        Some(&self.bits[i])
    }
}

/// Heap entry for the greedy upgrade pass. Ordered by score bits first
/// (nonnegative finite f64, so the raw bit pattern preserves order), then
/// the seeded tiebreak, then (client, group) as a last resort — a total
/// order with no float comparisons.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Upgrade {
    score_bits: u64,
    tie: u64,
    client: usize,
    group: usize,
}

/// Per-round adaptive bit-rate scheduler. See the module docs for the
/// allocation model; construction happens once in the coordinator when
/// `bit_budget > 0` or the scenario sets per-client uplink caps.
pub struct BitBudget {
    /// Total per-round uplink budget in bytes (0 = no fleet-wide bound,
    /// per-client caps only).
    budget: u64,
    /// Per-client uplink caps in bytes (0 = uncapped), indexed by client.
    caps: Vec<u64>,
    scheme: Scheme,
    /// Highest width the allocator may assign (`cfg.quant.bits`).
    ceiling: u32,
    topk_frac: f64,
    /// Element count per layer group.
    dims: Vec<usize>,
    seed: u64,
    /// Newest observed (round, α²) per (client, layer group).
    obs: Vec<Vec<Option<(usize, f64)>>>,
}

impl BitBudget {
    /// Scheduler for `cfg` over layer groups of the given element counts,
    /// with per-client uplink caps (empty = uncapped; from
    /// `ScenarioEngine::uplink_cap`).
    pub fn new(cfg: &ExperimentConfig, dims: Vec<usize>, caps: Vec<u64>) -> BitBudget {
        let n_groups = dims.len();
        BitBudget {
            budget: cfg.bit_budget,
            caps,
            scheme: cfg.quant.scheme,
            ceiling: cfg.quant.bits.clamp(min_bits(cfg.quant.scheme), MAX_BITS),
            topk_frac: cfg.quant.topk_frac,
            dims,
            seed: cfg.seed,
            obs: vec![vec![None; n_groups]; cfg.clients],
        }
    }

    /// Record the truncation thresholds a delivered uplink message carried.
    /// Keyed by the message's round with newest-wins, so transport arrival
    /// order (streaming reorders, staleness) cannot change the next plan.
    pub fn observe(&mut self, client: usize, round: usize, frames: &[(usize, Vec<u8>)]) {
        let Some(row) = self.obs.get_mut(client) else { return };
        for (gi, frame) in frames {
            let Some(alpha) = wire::frame_alpha(frame) else { continue };
            let v = (alpha as f64) * (alpha as f64);
            if !v.is_finite() {
                continue;
            }
            if let Some(slot) = row.get_mut(*gi) {
                match slot {
                    Some((r, _)) if *r > round => {}
                    _ => *slot = Some((round, v)),
                }
            }
        }
    }

    /// Allocate this round's bit-widths for the active clients (ascending
    /// ids). Deterministic in (config, seed, round, observations). When the
    /// budget is infeasible even at minimum widths the plan is best-effort:
    /// every pair stays at its minimum.
    pub fn plan(&self, round: u64, active: &[usize]) -> RatePlan {
        let floor = min_bits(self.scheme);
        let mut clients = active.to_vec();
        clients.sort_unstable();
        let floor_row = vec![floor; self.dims.len()];
        let mut bits: Vec<Vec<u32>> = clients.iter().map(|_| floor_row.clone()).collect();

        // Message cost at the floor allocation (full wire cost including
        // the message envelope, so "Σ bytes ≤ budget" holds on the wire).
        let floor_cost = self.message_bytes_at(&floor_row);
        let mut client_cost: Vec<u64> = vec![floor_cost; clients.len()];
        let mut total: u64 = client_cost.iter().sum();

        if !self.scheme.rate_adaptive() || self.ceiling <= floor {
            return RatePlan { clients, bits };
        }
        if self.budget > 0 && total > self.budget {
            // Infeasible: nothing to upgrade, ship the minima.
            return RatePlan { clients, bits };
        }

        let mut heap = BinaryHeap::new();
        for (i, &c) in clients.iter().enumerate() {
            for g in 0..self.dims.len() {
                if let Some(u) = self.upgrade_entry(c, g, floor, round) {
                    heap.push((u, i));
                }
            }
        }

        while let Some((u, i)) = heap.pop() {
            let b = bits[i][u.group];
            if b >= self.ceiling {
                continue;
            }
            let extra = self.frame_bytes(u.group, b + 1) - self.frame_bytes(u.group, b);
            if self.budget > 0 && total + extra > self.budget {
                continue; // other (smaller-frame) upgrades may still fit
            }
            let cap = self.caps.get(u.client).copied().unwrap_or(0);
            if cap > 0 && client_cost[i] + extra > cap {
                continue; // this client is saturated; drop the chain
            }
            bits[i][u.group] = b + 1;
            client_cost[i] += extra;
            total += extra;
            if let Some(next) = self.upgrade_entry(u.client, u.group, b + 1, round) {
                heap.push((next, i));
            }
        }

        RatePlan { clients, bits }
    }

    /// The heap entry for upgrading (client, group) from `b` to `b+1`, or
    /// `None` at the ceiling.
    fn upgrade_entry(&self, client: usize, group: usize, b: u32, round: u64) -> Option<Upgrade> {
        if b >= self.ceiling {
            return None;
        }
        let v = match self.obs.get(client).and_then(|row| row.get(group)) {
            Some(Some((_, v))) => *v,
            _ => 1.0, // no observation yet (round 0): uniform priority
        };
        let s_lo = ((1u64 << b) - 1) as f64;
        let s_hi = ((1u64 << (b + 1)) - 1) as f64;
        let benefit = v * self.dims[group] as f64 * (1.0 / (s_lo * s_lo) - 1.0 / (s_hi * s_hi));
        let extra = (self.frame_bytes(group, b + 1) - self.frame_bytes(group, b)).max(1);
        let score = benefit / extra as f64;
        let tie = Rng::for_stream(
            self.seed,
            ROLE_BUDGET,
            (client * 1031 + group) as u64,
            round,
        )
        .next_u64();
        Some(Upgrade { score_bits: score.to_bits(), tie, client, group })
    }

    /// Upper-bound wire bytes of one frame for layer group `g` at width
    /// `bits`, per the frame layouts in `quant::wire` (codebook frames may
    /// dedup below the bound; the planner never undercounts).
    fn frame_bytes(&self, g: usize, bits: u32) -> u64 {
        let d = self.dims[g] as u64;
        let packed = |b: u32| (d * b as u64).div_ceil(8);
        match self.scheme {
            Scheme::Dsgd => 8 + 4 * d,
            Scheme::Qsgd | Scheme::Tqsgd => 14 + packed(bits),
            Scheme::Nqsgd | Scheme::Tnqsgd | Scheme::Tbqsgd => {
                10 + 4 * (1u64 << bits) + packed(bits)
            }
            Scheme::Terngrad => 14 + packed(2),
            Scheme::Topk => {
                let k = ((d as f64 * self.topk_frac).ceil() as u64).clamp(1, d);
                12 + 8 * k
            }
            Scheme::Multiscale => 20 + packed(bits),
        }
    }

    /// Full wire bytes of one client's message at the given per-group
    /// widths: the 16-byte message envelope plus 4 bytes framing per frame.
    fn message_bytes_at(&self, bits: &[u32]) -> u64 {
        16 + (0..self.dims.len())
            .map(|g| 4 + self.frame_bytes(g, bits[g]))
            .sum::<u64>()
    }

    /// Upper-bound wire bytes of one client's message under `plan` (the
    /// pinned budget test checks the *actual* bytes against this bound).
    pub fn planned_message_bytes(&self, plan: &RatePlan, client: usize) -> Option<u64> {
        plan.rates_for(client).map(|bits| self.message_bytes_at(bits))
    }

    /// Snapshot the observation table — the scheduler's only mutable state
    /// (checkpoint serialization path). Entries are the newest
    /// `(round, α²)` per (client, layer group), `None` where nothing has
    /// been observed yet.
    pub fn export_obs(&self) -> Vec<Vec<Option<(usize, f64)>>> {
        self.obs.clone()
    }

    /// Restore an [`Self::export_obs`] snapshot (checkpoint resume path).
    /// The table shape must match this scheduler's (clients × groups).
    pub fn import_obs(&mut self, obs: Vec<Vec<Option<(usize, f64)>>>) {
        assert_eq!(obs.len(), self.obs.len(), "budget obs client count mismatch");
        for (row, cur) in obs.iter().zip(&self.obs) {
            assert_eq!(row.len(), cur.len(), "budget obs group count mismatch");
        }
        self.obs = obs;
    }
}

/// Smallest admissible width per scheme: BiScaled needs s ≥ 3 (2 bits),
/// multiscale needs both grids (3 bits), everything else packs down to 1.
fn min_bits(scheme: Scheme) -> u32 {
    match scheme {
        Scheme::Multiscale => 3,
        Scheme::Tbqsgd => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(scheme: Scheme, bits: u32, budget: u64) -> ExperimentConfig {
        ExperimentConfig {
            clients: 4,
            bit_budget: budget,
            quant: crate::config::QuantConfig { scheme, bits, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn unconstrained_plan_reaches_the_ceiling() {
        let b = BitBudget::new(&cfg(Scheme::Tqsgd, 6, 0), vec![1000, 500], vec![]);
        let plan = b.plan(0, &[0, 1, 2, 3]);
        assert_eq!(plan.clients, vec![0, 1, 2, 3]);
        for row in &plan.bits {
            assert_eq!(row, &vec![6, 6]);
        }
    }

    #[test]
    fn fleet_budget_is_respected_and_binding() {
        let dims = vec![4000usize, 2000];
        let c = cfg(Scheme::Tqsgd, 8, 9000);
        let b = BitBudget::new(&c, dims, vec![]);
        let plan = b.plan(3, &[0, 1, 2, 3]);
        let total: u64 = plan
            .clients
            .iter()
            .map(|&cl| b.planned_message_bytes(&plan, cl).unwrap())
            .sum();
        assert!(total <= 9000, "planned {total} > budget");
        // Binding: at least one pair sits strictly between floor and ceiling
        // bounds of an unconstrained plan.
        assert!(
            plan.bits.iter().flatten().any(|&bi| bi < 8),
            "budget did not bind: {:?}",
            plan.bits
        );
        assert!(
            plan.bits.iter().flatten().any(|&bi| bi > 1),
            "nothing upgraded: {:?}",
            plan.bits
        );
    }

    #[test]
    fn per_client_caps_bind_individually() {
        let dims = vec![4000usize];
        let c = cfg(Scheme::Tqsgd, 8, 0);
        // Client 1 capped tightly, others uncapped.
        let b = BitBudget::new(&c, dims, vec![0, 1600, 0, 0]);
        let plan = b.plan(0, &[0, 1, 2, 3]);
        assert!(b.planned_message_bytes(&plan, 1).unwrap() <= 1600);
        assert_eq!(plan.bits[0], vec![8], "uncapped client must hit the ceiling");
        assert!(plan.bits[1][0] < 8, "capped client must stay below the ceiling");
    }

    #[test]
    fn observations_steer_bits_toward_hot_groups() {
        let dims = vec![1000usize, 1000];
        let c = cfg(Scheme::Tqsgd, 8, 0);
        let mut b = BitBudget::new(&c, dims, vec![]);
        // Group 0 has 10x the truncation threshold of group 1 for client 0.
        let hot = crate::quant::wire::Payload::Uniform { alpha: 1.0, s: 7, idx: vec![0; 4] }
            .encode(3);
        let cold = crate::quant::wire::Payload::Uniform { alpha: 0.1, s: 7, idx: vec![0; 4] }
            .encode(3);
        b.observe(0, 5, &[(0, hot), (1, cold)]);
        // A budget that cannot afford the ceiling everywhere must favor the
        // hot group.
        let tight = BitBudget { budget: 2 * b.message_bytes_at(&[4, 4]), ..b };
        let plan = tight.plan(6, &[0, 1]);
        let row0 = &plan.bits[plan.clients.iter().position(|&x| x == 0).unwrap()];
        assert!(
            row0[0] > row0[1],
            "hot group should get more bits: {:?}",
            plan.bits
        );
    }

    #[test]
    fn newest_observation_wins_regardless_of_arrival_order() {
        let dims = vec![100usize];
        let c = cfg(Scheme::Tqsgd, 8, 0);
        let mk = |alpha: f32| {
            crate::quant::wire::Payload::Uniform { alpha, s: 7, idx: vec![0; 4] }.encode(3)
        };
        let mut early_then_late = BitBudget::new(&c, dims.clone(), vec![]);
        early_then_late.observe(0, 3, &[(0, mk(0.5))]);
        early_then_late.observe(0, 7, &[(0, mk(2.0))]);
        let mut late_then_early = BitBudget::new(&c, dims, vec![]);
        late_then_early.observe(0, 7, &[(0, mk(2.0))]);
        late_then_early.observe(0, 3, &[(0, mk(0.5))]);
        assert_eq!(early_then_late.obs, late_then_early.obs);
        assert_eq!(early_then_late.obs[0][0], Some((7, 4.0)));
    }

    #[test]
    fn plans_ignore_active_list_order() {
        let dims = vec![300usize, 300];
        let c = cfg(Scheme::Tnqsgd, 8, 3000);
        let b = BitBudget::new(&c, dims, vec![]);
        let p1 = b.plan(2, &[0, 1, 2, 3]);
        let p2 = b.plan(2, &[3, 2, 1, 0]); // order of `active` is irrelevant
        assert_eq!(p1, p2);
    }

    #[test]
    fn infeasible_budget_falls_back_to_minimum_widths() {
        let dims = vec![4000usize];
        let c = cfg(Scheme::Tqsgd, 8, 10); // cannot fit even 1-bit frames
        let b = BitBudget::new(&c, dims, vec![]);
        let plan = b.plan(0, &[0, 1]);
        assert!(plan.bits.iter().flatten().all(|&bi| bi == 1), "{:?}", plan.bits);
    }

    #[test]
    fn fixed_rate_schemes_get_flat_plans() {
        for scheme in [Scheme::Dsgd, Scheme::Terngrad, Scheme::Topk] {
            let b = BitBudget::new(&cfg(scheme, 3, 1 << 20), vec![100], vec![]);
            let plan = b.plan(0, &[0]);
            assert_eq!(plan.bits[0], vec![min_bits(scheme)], "{scheme:?}");
        }
    }

    #[test]
    fn frame_model_never_undercounts_real_frames() {
        // Encode real frames at several widths and check the planner's
        // byte model is an upper bound (exact for uniform/multiscale).
        use crate::quant::codecs::make_compressor;
        use crate::config::QuantConfig;
        let mut rng = Rng::new(9);
        let g: Vec<f32> =
            (0..3000).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        for scheme in Scheme::all() {
            for bits in [2u32, 3, 5, 8] {
                if scheme == Scheme::Multiscale && bits < 3 {
                    continue;
                }
                let mut c = make_compressor(&QuantConfig { scheme, bits, ..Default::default() });
                c.refit(&g);
                let frame = c.compress(&g, &mut rng);
                let b = BitBudget::new(&cfg(scheme, bits, 0), vec![g.len()], vec![]);
                assert!(
                    frame.len() as u64 <= b.frame_bytes(0, bits),
                    "{scheme:?} bits={bits}: frame {} > model {}",
                    frame.len(),
                    b.frame_bytes(0, bits)
                );
            }
        }
    }
}
