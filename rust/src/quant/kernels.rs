//! Quantization kernels — the rust-native hot path.
//!
//! The scalar implementations (`*_scalar`) mirror `python/compile/kernels/
//! ref.py` operation-for-operation in f32 so that, given the same uniforms,
//! the rust codec, the pure-jnp oracle and the Pallas kernel produce
//! IDENTICAL indices (verified by the parity integration test through PJRT).
//!
//! The public functions in this module are thin wrappers that route every
//! call through the process-wide [`KernelDispatch`](super::simd::KernelDispatch)
//! table (resolved once from runtime CPU-feature detection — see
//! [`super::simd`]). The SIMD implementations are required to be
//! **bit-identical** to the scalar reference on every input — same
//! truncation-floor rounding, same NaN behavior, same packed bytes — which
//! the `simd_matches_scalar` property in `tests/quant_props.rs` pins for
//! every scheme × bits 1..=16 × ragged length.

/// Largest |g| over a gradient slice (dispatched; see [`super::simd`]).
///
/// `max` is commutative/associative and ignores NaN operands on either
/// side, so every lane width reduces to the same f32 as the sequential
/// fold, for every input — pinned by `max_abs_nan_and_negzero_parity`.
pub fn max_abs(grads: &[f32]) -> f32 {
    (super::simd::active_kernels().max_abs)(grads)
}

/// Scalar `max_abs`: 4 independent accumulator lanes so the reduction has
/// no loop-carried dependency chain and autovectorizes (a sequential `fold`
/// forces one `max` per element in order).
pub(crate) fn max_abs_scalar(grads: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 4];
    let mut chunks = grads.chunks_exact(4);
    for c in &mut chunks {
        lanes[0] = lanes[0].max(c[0].abs());
        lanes[1] = lanes[1].max(c[1].abs());
        lanes[2] = lanes[2].max(c[2].abs());
        lanes[3] = lanes[3].max(c[3].abs());
    }
    let mut m = lanes[0].max(lanes[1]).max(lanes[2].max(lanes[3]));
    for &g in chunks.remainder() {
        m = m.max(g.abs());
    }
    m
}

/// Fused unpack → LUT dequantize → weighted accumulate over a packed index
/// payload: `acc[i] += wlut[idx_i]`, where `wlut[k]` is the caller's
/// precomputed `w * level_k` table (identical f32 product to the unfused
/// `acc += w * levels[idx]`, computed once per level instead of once per
/// element). This is the server-side decode hot path: one bitstream walk,
/// no dense scratch buffer between decode and accumulate. Dispatched (see
/// [`super::simd`]); every ISA path produces bit-identical `acc` contents,
/// including the partially-written prefix on the error path.
///
/// `packed` must hold at least `bitpack::packed_len(acc.len(), bits)` bytes
/// (the wire-layer caller checks before dispatching) and `bits` must be in
/// 1..=8 so each index fits one LUT byte. Indices `>= n_levels` abort with
/// `Err(idx)` so corrupt codebook frames are rejected exactly like the
/// unfused decoder; uniform callers pass `n_levels = 256` (every index the
/// mask can produce is representable).
pub fn accumulate_packed_wlut(
    packed: &[u8],
    bits: u32,
    n_levels: usize,
    wlut: &[f32; 256],
    acc: &mut [f32],
) -> Result<(), u32> {
    debug_assert!((1..=8).contains(&bits));
    debug_assert!(packed.len() >= super::bitpack::packed_len(acc.len(), bits));
    (super::simd::active_kernels().accumulate_packed_wlut)(packed, bits, n_levels, wlut, acc)
}

/// Scalar `accumulate_packed_wlut` over the whole payload.
pub(crate) fn accumulate_packed_wlut_scalar(
    packed: &[u8],
    bits: u32,
    n_levels: usize,
    wlut: &[f32; 256],
    acc: &mut [f32],
) -> Result<(), u32> {
    accumulate_packed_wlut_from(packed, bits, n_levels, wlut, acc, 0)
}

/// Scalar accumulate walk starting at element `start` — the shared tail for
/// the SIMD block paths, which hand over here for the ragged end of the
/// stream (and to reproduce the exact partial-write + `Err` semantics when
/// a block contains an out-of-range index).
pub(crate) fn accumulate_packed_wlut_from(
    packed: &[u8],
    bits: u32,
    n_levels: usize,
    wlut: &[f32; 256],
    acc: &mut [f32],
    start: usize,
) -> Result<(), u32> {
    let mask = (1u32 << bits) - 1;
    let mut bitpos = start * bits as usize;
    for a in acc[start..].iter_mut() {
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u32;
        let mut wide = packed[byte] as u32;
        if let Some(&b1) = packed.get(byte + 1) {
            wide |= (b1 as u32) << 8;
        }
        let idx = (wide >> off) & mask;
        if idx as usize >= n_levels {
            return Err(idx);
        }
        *a += wlut[idx as usize];
        bitpos += bits as usize;
    }
    Ok(())
}

/// Truncated uniform stochastic quantization of one element.
/// Returns the level index in [0, s].
#[inline(always)]
pub fn quantize_uniform_elem(g: f32, u: f32, alpha: f32, s: u32) -> u32 {
    let g = g.clamp(-alpha, alpha);
    let step = 2.0f32 * alpha / s as f32;
    let x = (g + alpha) / step;
    let lo = x.floor().clamp(0.0, (s - 1) as f32);
    let frac = x - lo;
    let mut idx = lo + f32::from(u < frac);
    if idx > s as f32 {
        idx = s as f32;
    }
    idx as u32
}

/// Dequantize a uniform level index.
#[inline(always)]
pub fn dequantize_uniform_elem(idx: u32, alpha: f32, s: u32) -> f32 {
    let step = 2.0f32 * alpha / s as f32;
    -alpha + idx as f32 * step
}

/// Truncated codebook stochastic quantization of one element.
/// `codebook` is strictly increasing with s+1 levels; returns index in [0, s].
///
/// Interval lookup matches ref.py's ladder semantics (k = #{j in 1..s :
/// g >= l_j}) via `partition_point` — O(log s) instead of O(s).
#[inline(always)]
pub fn quantize_codebook_elem(g: f32, u: f32, codebook: &[f32]) -> u32 {
    let s = codebook.len() - 1;
    let g = g.clamp(codebook[0], codebook[s]);
    // Count interior boundaries l_1..l_{s-1} that are <= g.
    let k = codebook[1..s].partition_point(|&b| b <= g);
    let lower = codebook[k];
    let upper = codebook[k + 1];
    let width = upper - lower;
    let frac = if width > 0.0 { (g - lower) / width } else { 0.0 };
    (k + usize::from(u < frac)) as u32
}

/// Vectorized uniform quantization into a preallocated index buffer
/// (dispatched; the table currently maps this Pallas-parity reference
/// surface to the scalar implementation on every ISA).
/// `uniforms` must have the same length as `grads`.
pub fn quantize_uniform_slice(
    grads: &[f32],
    uniforms: &[f32],
    alpha: f32,
    s: u32,
    out: &mut Vec<u32>,
) {
    (super::simd::active_kernels().quantize_uniform_slice)(grads, uniforms, alpha, s, out)
}

/// Scalar `quantize_uniform_slice` — the reference index computation.
pub(crate) fn quantize_uniform_slice_scalar(
    grads: &[f32],
    uniforms: &[f32],
    alpha: f32,
    s: u32,
    out: &mut Vec<u32>,
) {
    assert_eq!(grads.len(), uniforms.len());
    out.clear();
    out.reserve(grads.len());
    // Hoist the reciprocal: idx math is the throughput limiter at b<=5.
    let step = 2.0f32 * alpha / s as f32;
    let inv_step = 1.0f32 / step;
    let s_m1 = (s - 1) as f32;
    for (&g, &u) in grads.iter().zip(uniforms) {
        let g = g.clamp(-alpha, alpha);
        let x = (g + alpha) * inv_step;
        let lo = x.floor().min(s_m1).max(0.0);
        let idx = lo + f32::from(u < x - lo);
        out.push(idx.min(s as f32) as u32);
    }
}

/// Streaming LSB-first bit writer: accumulates ≤ 8-bit indices in a u64 and
/// flushes whole bytes, so the fused pack loops (scalar and SIMD) share one
/// copy of the flush arithmetic. Output is bit-identical to `bitpack::pack`.
pub(crate) struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl<'a> BitWriter<'a> {
    #[inline(always)]
    pub(crate) fn new(out: &'a mut Vec<u8>) -> Self {
        BitWriter { out, acc: 0, nbits: 0 }
    }

    /// Append the low `bits` (≤ 8) of `idx`.
    #[inline(always)]
    pub(crate) fn push(&mut self, idx: u64, bits: u32) {
        self.acc |= idx << self.nbits;
        self.nbits += bits;
        if self.nbits >= 56 {
            // Flush 7 whole bytes; ≤ 7 bits stay in the accumulator, so the
            // next `idx << nbits` (bits ≤ 8) can never overflow 64 bits.
            self.out.extend_from_slice(&self.acc.to_le_bytes()[..7]);
            self.acc >>= 56;
            self.nbits -= 56;
        }
    }

    /// Drain the remaining bits, zero-padded to whole bytes.
    pub(crate) fn finish(mut self) {
        while self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits = self.nbits.saturating_sub(8);
        }
    }
}

/// Fused quantize + bit-pack for the uniform quantizer, appending the
/// packed indices to `out`: consumes uniforms straight from `rng` (one
/// `f32` per element, same stream order as the unfused path) and streams
/// `bits`-wide indices through a u64 bit-accumulator — no intermediate
/// 4 B/elem index or uniform buffers, no pre-zeroed packed buffer, and no
/// per-byte read-modify-write.
///
/// With a recycled `out` of sufficient capacity this performs zero heap
/// allocation; it is the production hot path behind
/// [`Compressor::compress_into`](super::Compressor::compress_into).
/// Dispatched (see [`super::simd`]): the SIMD block paths quantize 4–8
/// elements per iteration and are bit-identical to the scalar kernel —
/// same RNG stream order, same indices, same packed bytes. The unfused
/// slice functions remain the reference and the Pallas-parity surface.
///
/// Widths above 8 bits (legal up to [`crate::config::MAX_BITS`]) take a
/// staged cold path — quantize into an index buffer, then `bitpack::pack`
/// — because the streaming `BitWriter`'s flush-at-56 arithmetic is only
/// safe for ≤ 8-bit pushes. Same RNG stream, same indices, same bytes.
pub fn quantize_uniform_pack_into(
    grads: &[f32],
    rng: &mut crate::util::Rng,
    alpha: f32,
    s: u32,
    bits: u32,
    out: &mut Vec<u8>,
) {
    debug_assert!((1..=crate::config::MAX_BITS).contains(&bits));
    debug_assert!(s < (1 << bits));
    (super::simd::active_kernels().quantize_uniform_pack_into)(grads, rng, alpha, s, bits, out)
}

/// Scalar fused uniform quantize + pack (the dispatch fallback and the
/// bit-exactness reference for every SIMD path).
pub(crate) fn quantize_uniform_pack_into_scalar(
    grads: &[f32],
    rng: &mut crate::util::Rng,
    alpha: f32,
    s: u32,
    bits: u32,
    out: &mut Vec<u8>,
) {
    out.reserve(super::bitpack::packed_len(grads.len(), bits));
    let step = 2.0f32 * alpha / s as f32;
    let inv_step = 1.0f32 / step;
    let s_m1 = (s - 1) as f32;
    if bits > 8 {
        let mut idx = Vec::with_capacity(grads.len());
        for &g in grads {
            let u = rng.f32();
            let gc = g.clamp(-alpha, alpha);
            let x = (gc + alpha) * inv_step;
            let lo = x.min(s_m1) as u32;
            idx.push((lo + u32::from(u < x - lo as f32)).min(s));
        }
        out.extend_from_slice(&super::bitpack::pack(&idx, bits));
        return;
    }
    // NOTE(perf): a two-uniforms-per-u64 variant (Rng::f32_pair) was tried
    // and measured <1% faster — the RNG is not the bottleneck — so the
    // simple one-f32-per-element stream (identical to the unfused reference
    // path) is kept. See EXPERIMENTS.md §Perf iteration log.
    let mut w = BitWriter::new(out);
    for &g in grads {
        let u = rng.f32();
        let gc = g.clamp(-alpha, alpha);
        let x = (gc + alpha) * inv_step;
        // x >= 0 for finite inputs, so after the f32 clamp integer
        // truncation == floor without the libm call — and `f32::min`
        // returns the other operand on NaN, exactly like the reference's
        // `floor().min(s_m1).max(0.0)` chain, so indices match the unfused
        // path for EVERY input including NaN.
        let lo = x.min(s_m1) as u32;
        let idx = (lo + u32::from(u < x - lo as f32)).min(s);
        w.push(idx as u64, bits);
    }
    w.finish();
}

/// Fused quantize + bit-pack for a codebook quantizer (same contract,
/// accumulator scheme, and staged >8-bit cold path as
/// [`quantize_uniform_pack_into`]; dispatched, see [`super::simd`]).
pub fn quantize_codebook_pack_into(
    grads: &[f32],
    rng: &mut crate::util::Rng,
    codebook: &[f32],
    bits: u32,
    out: &mut Vec<u8>,
) {
    debug_assert!((1..=crate::config::MAX_BITS).contains(&bits));
    debug_assert!(codebook.len() - 1 < (1 << bits));
    (super::simd::active_kernels().quantize_codebook_pack_into)(grads, rng, codebook, bits, out)
}

/// Scalar fused codebook quantize + pack (dispatch fallback and SIMD
/// reference; also serves wide codebooks the block paths delegate back).
pub(crate) fn quantize_codebook_pack_into_scalar(
    grads: &[f32],
    rng: &mut crate::util::Rng,
    codebook: &[f32],
    bits: u32,
    out: &mut Vec<u8>,
) {
    let s = codebook.len() - 1;
    out.reserve(super::bitpack::packed_len(grads.len(), bits));
    let lo_bound = codebook[0];
    let hi_bound = codebook[s];
    let interior = &codebook[1..s];
    if bits > 8 {
        let mut idx = Vec::with_capacity(grads.len());
        for &g in grads {
            let gc = g.clamp(lo_bound, hi_bound);
            let k = interior.partition_point(|&b| b <= gc);
            let lower = codebook[k];
            let width = codebook[k + 1] - lower;
            let frac = if width > 0.0 { (gc - lower) / width } else { 0.0 };
            idx.push((k + usize::from(rng.f32() < frac)) as u32);
        }
        out.extend_from_slice(&super::bitpack::pack(&idx, bits));
        return;
    }
    let mut w = BitWriter::new(out);
    for &g in grads {
        let gc = g.clamp(lo_bound, hi_bound);
        let k = interior.partition_point(|&b| b <= gc);
        let lower = codebook[k];
        let width = codebook[k + 1] - lower;
        let frac = if width > 0.0 { (gc - lower) / width } else { 0.0 };
        let idx = (k + usize::from(rng.f32() < frac)) as u64;
        w.push(idx, bits);
    }
    w.finish();
}

/// Vectorized codebook quantization (dispatched; the table currently maps
/// this reference surface to the scalar implementation on every ISA).
pub fn quantize_codebook_slice(
    grads: &[f32],
    uniforms: &[f32],
    codebook: &[f32],
    out: &mut Vec<u32>,
) {
    (super::simd::active_kernels().quantize_codebook_slice)(grads, uniforms, codebook, out)
}

/// Scalar `quantize_codebook_slice`.
pub(crate) fn quantize_codebook_slice_scalar(
    grads: &[f32],
    uniforms: &[f32],
    codebook: &[f32],
    out: &mut Vec<u32>,
) {
    assert_eq!(grads.len(), uniforms.len());
    out.clear();
    out.reserve(grads.len());
    for (&g, &u) in grads.iter().zip(uniforms) {
        out.push(quantize_codebook_elem(g, u, codebook));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::util::Rng;

    #[test]
    fn max_abs_matches_sequential_fold() {
        // The 4-lane reduction must agree with the reference fold for every
        // length (remainder handling) and ignore NaNs the same way.
        let mut rng = Rng::new(41);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 1023, 4096] {
            let g: Vec<f32> = (0..n).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
            let want = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            assert_eq!(max_abs(&g), want, "n={n}");
        }
        let mut g = vec![0.5f32, f32::NAN, -3.0, 1.0, f32::NAN];
        assert_eq!(max_abs(&g), 3.0);
        g.truncate(2);
        assert_eq!(max_abs(&g), 0.5);
    }

    #[test]
    fn max_abs_nan_and_negzero_parity() {
        // Pin the NaN/−0.0 contract on BOTH dispatch paths: a NaN candidate
        // is ignored (scalar `f32::max` returns the non-NaN operand; the
        // SIMD paths must place the accumulator in the NaN-ignoring operand
        // position), −0.0 folds to +0.0, and the result is bitwise equal
        // between the forced-scalar and the detected table for every ragged
        // length around the widest lane boundary.
        let sc = crate::quant::simd::scalar_kernels();
        let dt = crate::quant::simd::detected_kernels();
        for n in 0..=33usize {
            // NaNs sprinkled at every position in turn, plus signed zeros.
            for nan_at in 0..=n {
                let mut g: Vec<f32> = (0..n)
                    .map(|i| if i % 3 == 0 { -0.0 } else { (i as f32 - 7.0) * 0.25 })
                    .collect();
                if nan_at < n {
                    g[nan_at] = f32::NAN;
                }
                let a = (sc.max_abs)(&g);
                let b = (dt.max_abs)(&g);
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} nan_at={nan_at} ({a} vs {b})");
                let want = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                assert_eq!(a.to_bits(), want.to_bits(), "n={n} nan_at={nan_at}");
            }
        }
        // All-NaN and all-(−0.0) inputs collapse to +0.0 on both paths.
        for g in [vec![f32::NAN; 9], vec![-0.0f32; 9]] {
            assert_eq!((sc.max_abs)(&g).to_bits(), 0.0f32.to_bits());
            assert_eq!((dt.max_abs)(&g).to_bits(), 0.0f32.to_bits());
        }
    }

    #[test]
    fn accumulate_packed_wlut_matches_unpack_then_add() {
        let mut rng = Rng::new(42);
        for bits in 1..=8u32 {
            let n_levels = 1usize << bits;
            let n = 1 + rng.below(500) as usize;
            let idx: Vec<u32> = (0..n).map(|_| rng.below(n_levels as u64) as u32).collect();
            let packed = crate::quant::bitpack::pack(&idx, bits);
            let mut wlut = [0.0f32; 256];
            for (k, slot) in wlut.iter_mut().enumerate().take(n_levels) {
                *slot = 0.25 * (k as f32 - 2.0);
            }
            let mut acc: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut want = acc.clone();
            for (a, &k) in want.iter_mut().zip(&idx) {
                *a += wlut[k as usize];
            }
            accumulate_packed_wlut(&packed, bits, n_levels, &wlut, &mut acc).unwrap();
            assert_eq!(acc, want, "bits={bits}");
        }
    }

    #[test]
    fn accumulate_packed_wlut_rejects_out_of_codebook_indices() {
        // 3-bit indices but only 5 codebook levels: index 7 must error.
        let packed = crate::quant::bitpack::pack(&[0, 4, 7, 1], 3);
        let wlut = [0.0f32; 256];
        let mut acc = vec![0.0f32; 4];
        assert_eq!(accumulate_packed_wlut(&packed, 3, 5, &wlut, &mut acc), Err(7));
    }

    #[test]
    fn uniform_elem_exact_cases() {
        // g at a level with u anything -> that level.
        let (alpha, s) = (1.0f32, 4u32);
        assert_eq!(quantize_uniform_elem(-1.0, 0.99, alpha, s), 0);
        assert_eq!(quantize_uniform_elem(1.0, 0.0, alpha, s), 4);
        assert_eq!(quantize_uniform_elem(0.0, 0.5, alpha, s), 2);
        // Midpoint of interval 0: rounds up iff u < 0.5.
        assert_eq!(quantize_uniform_elem(-0.75, 0.49, alpha, s), 1);
        assert_eq!(quantize_uniform_elem(-0.75, 0.51, alpha, s), 0);
    }

    #[test]
    fn uniform_truncates_outliers() {
        let idx = quantize_uniform_elem(99.0, 0.3, 0.05, 7);
        assert_eq!(idx, 7);
        let idx = quantize_uniform_elem(-99.0, 0.3, 0.05, 7);
        assert_eq!(idx, 0);
    }

    #[test]
    fn codebook_elem_matches_uniform_on_even_grid() {
        // A uniform codebook must agree with the closed-form uniform path.
        let (alpha, s) = (0.08f32, 7u32);
        let cb: Vec<f32> = (0..=s)
            .map(|k| -alpha + 2.0 * alpha * k as f32 / s as f32)
            .collect();
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            let g = (rng.student_t(3.0) * 0.03) as f32;
            let u = rng.f32();
            let a = quantize_uniform_elem(g, u, alpha, s);
            let b = quantize_codebook_elem(g, u, &cb);
            // The two index computations may differ by FP rounding exactly at
            // boundaries; dequantized values must still agree.
            let da = dequantize_uniform_elem(a, alpha, s);
            let db = cb[b as usize];
            assert!(
                (da - db).abs() <= 2.0 * alpha / s as f32 + 1e-7,
                "g={g} u={u}: {a}({da}) vs {b}({db})"
            );
        }
    }

    #[test]
    fn slice_matches_elem() {
        let mut rng = Rng::new(2);
        let g: Vec<f32> = (0..4096).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        let u: Vec<f32> = (0..4096).map(|_| rng.f32()).collect();
        let mut out = Vec::new();
        quantize_uniform_slice(&g, &u, 0.04, 7, &mut out);
        for i in 0..g.len() {
            assert_eq!(out[i], quantize_uniform_elem(g[i], u[i], 0.04, 7), "i={i}");
        }
    }

    #[test]
    fn packed_matches_unfused_uniform() {
        // Same RNG stream ⇒ identical indices ⇒ identical packed bytes.
        // The >8-bit rows exercise the staged (non-BitWriter) cold path.
        let mut rng = Rng::new(11);
        let g: Vec<f32> = (0..10_000).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        for &(s, bits) in &[(3u32, 2u32), (7, 3), (15, 4), (31, 5), (511, 9), (4095, 12)] {
            let mut r1 = Rng::new(77);
            let mut packed = Vec::new();
            quantize_uniform_pack_into(&g, &mut r1, 0.03, s, bits, &mut packed);
            let mut r2 = Rng::new(77);
            let u: Vec<f32> = (0..g.len()).map(|_| r2.f32()).collect();
            let mut idx = Vec::new();
            quantize_uniform_slice(&g, &u, 0.03, s, &mut idx);
            assert_eq!(packed, crate::quant::bitpack::pack(&idx, bits), "s={s}");
        }
    }

    #[test]
    fn packed_matches_unfused_codebook() {
        let mut rng = Rng::new(12);
        let g: Vec<f32> = (0..10_000).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect();
        let cb: Vec<f32> = vec![-0.05, -0.01, -0.002, 0.0, 0.002, 0.01, 0.02, 0.05];
        for bits in [3u32, 9] {
            let mut r1 = Rng::new(88);
            let mut packed = Vec::new();
            quantize_codebook_pack_into(&g, &mut r1, &cb, bits, &mut packed);
            let mut r2 = Rng::new(88);
            let u: Vec<f32> = (0..g.len()).map(|_| r2.f32()).collect();
            let mut idx = Vec::new();
            quantize_codebook_slice(&g, &u, &cb, &mut idx);
            assert_eq!(packed, crate::quant::bitpack::pack(&idx, bits), "bits={bits}");
        }
    }

    #[test]
    fn property_unbiased_uniform() {
        // Monte-Carlo unbiasedness of the stochastic rounding (Lemma 1).
        prop::check(20, |rng| {
            let alpha = 0.1f32;
            let s = 7u32;
            let g = ((rng.f64() * 1.8 - 0.9) * alpha as f64) as f32;
            let n = 20_000;
            let mean: f64 = (0..n)
                .map(|_| {
                    let idx = quantize_uniform_elem(g, rng.f32(), alpha, s);
                    dequantize_uniform_elem(idx, alpha, s) as f64
                })
                .sum::<f64>()
                / n as f64;
            let step = 2.0 * alpha as f64 / s as f64;
            // CLT: |mean - g| should be within ~4 sigma of the rounding noise.
            let tol = 4.0 * step / (n as f64).sqrt();
            prop::assert_prop((mean - g as f64).abs() < tol, format!("mean {mean} vs g {g} (tol {tol})"))
        });
    }

    #[test]
    fn property_codebook_idx_valid_and_brackets() {
        prop::check(100, |rng| {
            let cb = prop::gen_codebook(rng, 5);
            let s = cb.len() - 1;
            for _ in 0..200 {
                let g = (rng.student_t(3.0) * 0.3) as f32;
                let u = rng.f32();
                let idx = quantize_codebook_elem(g, u, &cb) as usize;
                if idx > s {
                    return Err(format!("idx {idx} out of range"));
                }
                let gc = g.clamp(cb[0], cb[s]);
                let val = cb[idx];
                // Q[g] must be one of the two levels bracketing g.
                let k = cb[1..s].partition_point(|&b| b <= gc);
                if (val - cb[k]).abs() > 1e-9 && (val - cb[k + 1]).abs() > 1e-9 {
                    return Err(format!("value {val} not bracketing g={g}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_variance_within_lemma1_bound() {
        // E(Q[g]-g)^2 <= Δ²/4 per element for the interval containing g.
        prop::check(10, |rng| {
            let alpha = 0.05f32;
            let s = 7u32;
            let g = ((rng.f64() * 2.0 - 1.0) * alpha as f64 * 0.99) as f32;
            let n = 30_000;
            let var: f64 = (0..n)
                .map(|_| {
                    let idx = quantize_uniform_elem(g, rng.f32(), alpha, s);
                    let d = dequantize_uniform_elem(idx, alpha, s) as f64 - g as f64;
                    d * d
                })
                .sum::<f64>()
                / n as f64;
            let step = 2.0 * alpha as f64 / s as f64;
            prop::assert_prop(var <= step * step / 4.0 * 1.05, format!("var {var} vs bound {}", step * step / 4.0))
        });
    }
}
