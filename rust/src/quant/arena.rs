//! Frame-buffer arena: the allocation-recycling substrate of the
//! zero-allocation hot path.
//!
//! Every round each client emits one wire frame per layer group. Allocating
//! those `Vec<u8>`s fresh costs an mmap + page-fault + zero per ~0.5 MB
//! frame at CNN scale — comparable to the quantization work itself. The
//! [`FrameArena`] instead pools returned buffers: [`FrameArena::take`]
//! hands back a cleared buffer whose capacity survived the previous round,
//! and [`FrameArena::put`] recycles it once the server has aggregated the
//! frame (or the network lost it).
//!
//! Each [`Client`](crate::coordinator::Client) owns one arena, so the
//! per-client codec threads spawned by `Coordinator::step`'s
//! `std::thread::scope` fan-out never contend on a shared pool. The arena
//! counts how many `take` calls had to heap-allocate — the debug counter
//! behind `Coordinator::frame_allocs` and the steady-state
//! zero-allocation test in the integration suite.

/// Recycling pool of wire-frame byte buffers (LIFO: the most recently
/// returned buffer — warmest in cache, largest capacity — is reused first).
#[derive(Debug, Default)]
pub struct FrameArena {
    free: Vec<Vec<u8>>,
    fresh: u64,
}

impl FrameArena {
    /// An empty arena; the first `groups`-many takes per client allocate,
    /// everything after reuses.
    pub fn new() -> FrameArena {
        FrameArena::default()
    }

    /// Take a cleared buffer, reusing a recycled one when available.
    /// A pool miss allocates fresh and bumps the [`Self::fresh_allocs`]
    /// counter — in steady state this never happens.
    pub fn take(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(buf) => buf,
            None => {
                self.fresh += 1;
                Vec::new()
            }
        }
    }

    /// Return a buffer for reuse; contents are cleared, capacity is kept.
    pub fn put(&mut self, mut buf: Vec<u8>) {
        buf.clear();
        self.free.push(buf);
    }

    /// How many [`Self::take`] calls had to heap-allocate a fresh buffer
    /// (the steady-state zero-allocation invariant's debug counter).
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh
    }

    /// Buffers currently sitting in the pool.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// Total capacity (bytes) held by pooled buffers — the arena's term of
    /// the `bytes_per_client` memory metric.
    pub fn pooled_bytes(&self) -> usize {
        self.free.iter().map(Vec::capacity).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles_capacity_and_counts_fresh() {
        let mut a = FrameArena::new();
        let mut b = a.take();
        assert_eq!(a.fresh_allocs(), 1);
        b.extend_from_slice(&[1, 2, 3, 4]);
        let cap = b.capacity();
        a.put(b);
        assert_eq!(a.pooled(), 1);
        let b2 = a.take();
        assert_eq!(a.fresh_allocs(), 1, "reuse must not count as fresh");
        assert!(b2.is_empty(), "recycled buffers come back cleared");
        assert_eq!(b2.capacity(), cap, "capacity survives the round trip");
    }

    #[test]
    fn lifo_order_and_pool_accounting() {
        let mut a = FrameArena::new();
        let mut x = a.take();
        let y = a.take();
        assert_eq!(a.fresh_allocs(), 2);
        x.push(7);
        let x_cap = x.capacity();
        a.put(y);
        a.put(x);
        assert_eq!(a.pooled(), 2);
        // Most recently returned (x, with capacity) comes out first.
        let first = a.take();
        assert_eq!(first.capacity(), x_cap);
        assert_eq!(a.pooled(), 1);
    }

    #[test]
    fn pooled_bytes_sums_capacities() {
        let mut a = FrameArena::new();
        assert_eq!(a.pooled_bytes(), 0);
        let mut x = a.take();
        x.extend_from_slice(&[0u8; 64]);
        let cap = x.capacity();
        a.put(x);
        assert_eq!(a.pooled_bytes(), cap);
    }
}
