//! Runtime-dispatched SIMD implementations of the quantization hot kernels.
//!
//! The three hot loops (`quantize_uniform_pack_into`,
//! `quantize_codebook_pack_into`, `accumulate_packed_wlut`) plus `max_abs`
//! get explicit `std::arch` implementations — AVX2 and SSE2 on x86_64, NEON
//! on aarch64 — selected **once per process** into a [`KernelDispatch`]
//! table so the per-call overhead is a single indirect call. Everything
//! else (the unfused slice surfaces the Pallas-parity tests exercise) is
//! routed through the same table but currently maps to the scalar
//! reference on every ISA.
//!
//! # Bit-identity contract
//!
//! Every entry must produce **bit-identical** results to the scalar
//! reference in [`super::kernels`] on every input — same truncation-floor
//! rounding, same NaN behavior, same packed bytes, same RNG stream order
//! (one `f32` draw per element, in element order), same partial-write +
//! `Err` semantics on corrupt codebook frames. The load-bearing intrinsic
//! facts, each pinned by `simd_matches_scalar` in `tests/quant_props.rs`:
//!
//! * x86 `MINPS`/`MAXPS` return the **second** operand when either input
//!   is NaN (and on ±0.0 ties). Bounds go in the first operand and the
//!   value in the second to reproduce scalar `clamp`; `x` goes first in
//!   `min(x, s_m1)` to reproduce `f32::min`'s return-the-other-operand
//!   NaN rule.
//! * NEON `FMIN`/`FMAX` **propagate** NaN instead, so the NEON paths
//!   select on a `x == x` self-compare mask where the scalar semantics
//!   require the non-NaN operand.
//! * Ordered compares (`_CMP_LT_OQ`/`CMPLTPS`/`FCMLT`) are false on NaN,
//!   matching scalar `<`.
//! * `CVTTPS2DQ`/`FCVTZU` truncate toward zero, matching `as u32` for the
//!   in-range [0, 65534] values the index math produces.
//!
//! # Dispatch override
//!
//! Setting `TQSGD_FORCE_SCALAR` to anything other than empty/`0` pins the
//! process to the scalar table — CI runs the whole test suite once per
//! mode. Tests that want both tables side by side in one process use
//! [`scalar_kernels`]/[`detected_kernels`] directly instead of the env
//! knob (the [`active_kernels`] choice is latched on first use).
//!
//! This module is the crate's single exception to `deny(unsafe_code)`:
//! every `unsafe` block is a `std::arch` intrinsic call (or the raw
//! pointer loads/stores feeding it) guarded by the runtime feature
//! detection that installed the containing function into a table.
#![allow(unsafe_code)]

use std::sync::OnceLock;

use super::kernels;
use crate::util::Rng;

/// Resolved kernel table: one function pointer per dispatched kernel.
///
/// Obtain one via [`active_kernels`] (honors `TQSGD_FORCE_SCALAR`),
/// [`detected_kernels`] (best ISA for this CPU) or [`scalar_kernels`]
/// (portable reference). All entries of all tables are safe to call on the
/// machine that produced the table.
pub struct KernelDispatch {
    /// Short ISA tag for logs and bench reports: `"scalar"`, `"sse2"`,
    /// `"avx2"` or `"neon"`.
    pub isa: &'static str,
    /// Largest |g| over a slice — see [`kernels::max_abs`].
    pub max_abs: fn(&[f32]) -> f32,
    /// Fused unpack → LUT dequantize → weighted accumulate — see
    /// [`kernels::accumulate_packed_wlut`].
    pub accumulate_packed_wlut:
        fn(&[u8], u32, usize, &[f32; 256], &mut [f32]) -> Result<(), u32>,
    /// Fused uniform quantize + bit-pack — see
    /// [`kernels::quantize_uniform_pack_into`].
    pub quantize_uniform_pack_into: fn(&[f32], &mut Rng, f32, u32, u32, &mut Vec<u8>),
    /// Fused codebook quantize + bit-pack — see
    /// [`kernels::quantize_codebook_pack_into`].
    pub quantize_codebook_pack_into: fn(&[f32], &mut Rng, &[f32], u32, &mut Vec<u8>),
    /// Unfused uniform quantize into an index buffer (Pallas-parity
    /// reference surface; scalar on every ISA today).
    pub quantize_uniform_slice: fn(&[f32], &[f32], f32, u32, &mut Vec<u32>),
    /// Unfused codebook quantize into an index buffer (reference surface;
    /// scalar on every ISA today).
    pub quantize_codebook_slice: fn(&[f32], &[f32], &[f32], &mut Vec<u32>),
}

static SCALAR: KernelDispatch = KernelDispatch {
    isa: "scalar",
    max_abs: kernels::max_abs_scalar,
    accumulate_packed_wlut: kernels::accumulate_packed_wlut_scalar,
    quantize_uniform_pack_into: kernels::quantize_uniform_pack_into_scalar,
    quantize_codebook_pack_into: kernels::quantize_codebook_pack_into_scalar,
    quantize_uniform_slice: kernels::quantize_uniform_slice_scalar,
    quantize_codebook_slice: kernels::quantize_codebook_slice_scalar,
};

#[cfg(target_arch = "x86_64")]
static AVX2: KernelDispatch = KernelDispatch {
    isa: "avx2",
    max_abs: x86::avx2::max_abs,
    accumulate_packed_wlut: x86::avx2::accumulate_packed_wlut,
    quantize_uniform_pack_into: x86::avx2::quantize_uniform_pack_into,
    quantize_codebook_pack_into: x86::avx2::quantize_codebook_pack_into,
    quantize_uniform_slice: kernels::quantize_uniform_slice_scalar,
    quantize_codebook_slice: kernels::quantize_codebook_slice_scalar,
};

#[cfg(target_arch = "x86_64")]
static SSE2: KernelDispatch = KernelDispatch {
    isa: "sse2",
    max_abs: x86::sse2::max_abs,
    accumulate_packed_wlut: x86::sse2::accumulate_packed_wlut,
    quantize_uniform_pack_into: x86::sse2::quantize_uniform_pack_into,
    quantize_codebook_pack_into: x86::sse2::quantize_codebook_pack_into,
    quantize_uniform_slice: kernels::quantize_uniform_slice_scalar,
    quantize_codebook_slice: kernels::quantize_codebook_slice_scalar,
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelDispatch = KernelDispatch {
    isa: "neon",
    max_abs: neon::max_abs,
    accumulate_packed_wlut: neon::accumulate_packed_wlut,
    quantize_uniform_pack_into: neon::quantize_uniform_pack_into,
    quantize_codebook_pack_into: neon::quantize_codebook_pack_into,
    quantize_uniform_slice: kernels::quantize_uniform_slice_scalar,
    quantize_codebook_slice: kernels::quantize_codebook_slice_scalar,
};

/// The portable scalar reference table (always available, never SIMD).
pub fn scalar_kernels() -> &'static KernelDispatch {
    &SCALAR
}

/// The best table runtime CPU-feature detection allows on this machine,
/// ignoring the `TQSGD_FORCE_SCALAR` override: AVX2 if detected, else the
/// x86_64-baseline SSE2 on x86_64; NEON (architecturally mandatory) on
/// aarch64; scalar elsewhere.
pub fn detected_kernels() -> &'static KernelDispatch {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            &AVX2
        } else {
            &SSE2
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        &NEON
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        &SCALAR
    }
}

/// The process-wide table every public kernel wrapper routes through.
///
/// Resolved exactly once, on first use: [`detected_kernels`] unless the
/// `TQSGD_FORCE_SCALAR` environment variable is set to something other
/// than empty or `0`, in which case the scalar table is pinned (the CI
/// test matrix runs both modes; digests are identical by the bit-identity
/// contract, see `docs/DETERMINISM.md` §8).
pub fn active_kernels() -> &'static KernelDispatch {
    static ACTIVE: OnceLock<&'static KernelDispatch> = OnceLock::new();
    ACTIVE.get_or_init(|| match std::env::var("TQSGD_FORCE_SCALAR") {
        Ok(v) if !v.is_empty() && v != "0" => scalar_kernels(),
        _ => detected_kernels(),
    })
}

/// Codebooks wider than this many interior boundaries fall back to the
/// scalar binary search: the SIMD path counts boundaries linearly (one
/// vector compare per boundary per block), which beats `partition_point`'s
/// branchy O(log s) walk only while the codebook is small. Production
/// codebooks at b ≤ 5 have ≤ 30 interior boundaries.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const CB_SIMD_MAX_INTERIOR: usize = 32;

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 (8-lane) and SSE2 (4-lane, x86_64 baseline) kernel bodies.
    //!
    //! Both reuse the scalar expressions verbatim for ragged tails and
    //! delegate to `accumulate_packed_wlut_from` for the accumulate tail,
    //! so every non-block element goes through literally the same code as
    //! the scalar table.

    pub(crate) mod avx2 {
        use crate::quant::bitpack;
        use crate::quant::kernels::{self, BitWriter};
        use crate::util::Rng;
        use std::arch::x86_64::*;

        /// Broadcast constants for the uniform block math.
        #[derive(Clone, Copy)]
        struct UniC {
            alpha: __m256,
            neg_alpha: __m256,
            inv_step: __m256,
            s_m1: __m256,
        }

        /// Quantize 8 elements: indices (pre-`.min(s)`) into `ibuf`.
        #[inline]
        #[target_feature(enable = "avx2")]
        unsafe fn uniform_idx8(gp: *const f32, ubuf: &[f32; 8], c: UniC, ibuf: &mut [u32; 8]) {
            let vg = _mm256_loadu_ps(gp);
            // clamp(-alpha, alpha): bounds in the FIRST operand, value in
            // the SECOND — MINPS/MAXPS return the second operand on NaN
            // and on ±0.0 ties, which reproduces scalar `clamp` exactly
            // (NaN g stays NaN, g's zero sign survives).
            let gc = _mm256_min_ps(c.alpha, _mm256_max_ps(c.neg_alpha, vg));
            let x = _mm256_mul_ps(_mm256_add_ps(gc, c.alpha), c.inv_step);
            // x.min(s_m1): x first, so NaN x yields s_m1 — scalar
            // `f32::min` returns the other operand on NaN.
            let xc = _mm256_min_ps(x, c.s_m1);
            // xc ∈ [0, s-1], s ≤ 65535 ≪ 2^24: CVTT truncation == `as u32`
            // and the round-trip back to f32 is exact.
            let lo_i = _mm256_cvttps_epi32(xc);
            let lo_f = _mm256_cvtepi32_ps(lo_i);
            // frac from the ORIGINAL x (not xc), like the scalar kernel.
            let frac = _mm256_sub_ps(x, lo_f);
            let u = _mm256_loadu_ps(ubuf.as_ptr());
            // Ordered compare: false on NaN frac, like scalar `u < frac`.
            let bump = _mm256_cmp_ps::<_CMP_LT_OQ>(u, frac);
            // True lanes are all-ones (−1): subtracting adds the bump.
            let idx = _mm256_sub_epi32(lo_i, _mm256_castps_si256(bump));
            _mm256_storeu_si256(ibuf.as_mut_ptr().cast(), idx);
        }

        #[target_feature(enable = "avx2")]
        unsafe fn uniform_pack_imp(
            grads: &[f32],
            rng: &mut Rng,
            alpha: f32,
            s: u32,
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            out.reserve(bitpack::packed_len(grads.len(), bits));
            let step = 2.0f32 * alpha / s as f32;
            let inv_step = 1.0f32 / step;
            let s_m1 = (s - 1) as f32;
            let c = UniC {
                alpha: _mm256_set1_ps(alpha),
                neg_alpha: _mm256_set1_ps(-alpha),
                inv_step: _mm256_set1_ps(inv_step),
                s_m1: _mm256_set1_ps(s_m1),
            };
            let n = grads.len();
            // Uniforms are drawn 8-at-a-time into a stack buffer in element
            // order, so the RNG stream is identical to the scalar loop's
            // one-draw-per-element order.
            let mut ubuf = [0.0f32; 8];
            let mut ibuf = [0u32; 8];
            let mut i = 0usize;
            if bits > 8 {
                // Staged cold path (wide indices): SIMD quantize into the
                // index buffer, then the shared bitpack.
                let mut idx = Vec::with_capacity(n);
                while i + 8 <= n {
                    for u in ubuf.iter_mut() {
                        *u = rng.f32();
                    }
                    uniform_idx8(grads.as_ptr().add(i), &ubuf, c, &mut ibuf);
                    for &k in &ibuf {
                        idx.push(k.min(s));
                    }
                    i += 8;
                }
                for &g in &grads[i..] {
                    let u = rng.f32();
                    let gc = g.clamp(-alpha, alpha);
                    let x = (gc + alpha) * inv_step;
                    let lo = x.min(s_m1) as u32;
                    idx.push((lo + u32::from(u < x - lo as f32)).min(s));
                }
                out.extend_from_slice(&bitpack::pack(&idx, bits));
                return;
            }
            let mut w = BitWriter::new(out);
            while i + 8 <= n {
                for u in ubuf.iter_mut() {
                    *u = rng.f32();
                }
                uniform_idx8(grads.as_ptr().add(i), &ubuf, c, &mut ibuf);
                for &k in &ibuf {
                    w.push(u64::from(k.min(s)), bits);
                }
                i += 8;
            }
            for &g in &grads[i..] {
                let u = rng.f32();
                let gc = g.clamp(-alpha, alpha);
                let x = (gc + alpha) * inv_step;
                let lo = x.min(s_m1) as u32;
                let idx = (lo + u32::from(u < x - lo as f32)).min(s);
                w.push(u64::from(idx), bits);
            }
            w.finish();
        }

        pub(crate) fn quantize_uniform_pack_into(
            grads: &[f32],
            rng: &mut Rng,
            alpha: f32,
            s: u32,
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            // SAFETY: this entry is only installed in the AVX2 table,
            // selected after `is_x86_feature_detected!("avx2")` succeeded.
            unsafe { uniform_pack_imp(grads, rng, alpha, s, bits, out) }
        }

        #[target_feature(enable = "avx2")]
        unsafe fn codebook_pack_imp(
            grads: &[f32],
            rng: &mut Rng,
            codebook: &[f32],
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            let s = codebook.len() - 1;
            out.reserve(bitpack::packed_len(grads.len(), bits));
            let lo_bound = codebook[0];
            let hi_bound = codebook[s];
            let interior = &codebook[1..s];
            let vlo = _mm256_set1_ps(lo_bound);
            let vhi = _mm256_set1_ps(hi_bound);
            let n = grads.len();
            let mut kbuf = [0u32; 8];
            let mut gbuf = [0.0f32; 8];
            let mut w = BitWriter::new(out);
            let mut i = 0usize;
            while i + 8 <= n {
                let vg = _mm256_loadu_ps(grads.as_ptr().add(i));
                // Same operand discipline as the uniform clamp.
                let gc = _mm256_min_ps(vhi, _mm256_max_ps(vlo, vg));
                // k = #{interior boundaries ≤ gc}: a linear compare-count,
                // equal to scalar `partition_point` on a sorted codebook
                // (and 0 for NaN gc — ordered compares are false on NaN).
                let mut kv = _mm256_setzero_si256();
                for &b in interior {
                    let le = _mm256_cmp_ps::<_CMP_LE_OQ>(_mm256_set1_ps(b), gc);
                    kv = _mm256_sub_epi32(kv, _mm256_castps_si256(le));
                }
                _mm256_storeu_si256(kbuf.as_mut_ptr().cast(), kv);
                _mm256_storeu_ps(gbuf.as_mut_ptr(), gc);
                // Per-lane epilogue in element order: the interpolation
                // draws its uniform AFTER k is known and k consumes no
                // RNG, so the stream order matches the scalar loop.
                for (&k32, &gcj) in kbuf.iter().zip(&gbuf) {
                    let k = k32 as usize;
                    let lower = codebook[k];
                    let width = codebook[k + 1] - lower;
                    let frac = if width > 0.0 { (gcj - lower) / width } else { 0.0 };
                    let idx = (k + usize::from(rng.f32() < frac)) as u64;
                    w.push(idx, bits);
                }
                i += 8;
            }
            for &g in &grads[i..] {
                let gc = g.clamp(lo_bound, hi_bound);
                let k = interior.partition_point(|&b| b <= gc);
                let lower = codebook[k];
                let width = codebook[k + 1] - lower;
                let frac = if width > 0.0 { (gc - lower) / width } else { 0.0 };
                let idx = (k + usize::from(rng.f32() < frac)) as u64;
                w.push(idx, bits);
            }
            w.finish();
        }

        pub(crate) fn quantize_codebook_pack_into(
            grads: &[f32],
            rng: &mut Rng,
            codebook: &[f32],
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            if bits > 8 || codebook.len().saturating_sub(2) > super::super::CB_SIMD_MAX_INTERIOR {
                return kernels::quantize_codebook_pack_into_scalar(grads, rng, codebook, bits, out);
            }
            // SAFETY: installed only in the AVX2 table (runtime-detected).
            unsafe { codebook_pack_imp(grads, rng, codebook, bits, out) }
        }

        #[target_feature(enable = "avx2")]
        unsafe fn accumulate_imp(
            packed: &[u8],
            bits: u32,
            n_levels: usize,
            wlut: &[f32; 256],
            acc: &mut [f32],
        ) -> Result<(), u32> {
            let mask = (1u64 << bits) - 1;
            let n = acc.len();
            let mut e = 0usize;
            // 8-element blocks start on a byte boundary for every bits in
            // 1..=8 (8·bits ≡ 0 mod 8), so each block is one u64 window.
            'blocks: while e + 8 <= n {
                let byte = (e * bits as usize) >> 3;
                let Some(win) = packed.get(byte..byte + 8) else { break };
                let word = u64::from_le_bytes(win.try_into().unwrap());
                let mut ib = [0u32; 8];
                for (j, slot) in ib.iter_mut().enumerate() {
                    let idx = ((word >> (j as u32 * bits)) & mask) as u32;
                    if idx as usize >= n_levels {
                        // Hand the whole block to the scalar walk so the
                        // partially-written prefix and the Err(first_bad)
                        // match it bit-for-bit.
                        break 'blocks;
                    }
                    *slot = idx;
                }
                let vi = _mm256_loadu_si256(ib.as_ptr().cast());
                let lut = _mm256_i32gather_ps::<4>(wlut.as_ptr(), vi);
                let a = _mm256_loadu_ps(acc.as_ptr().add(e));
                _mm256_storeu_ps(acc.as_mut_ptr().add(e), _mm256_add_ps(a, lut));
                e += 8;
            }
            kernels::accumulate_packed_wlut_from(packed, bits, n_levels, wlut, acc, e)
        }

        pub(crate) fn accumulate_packed_wlut(
            packed: &[u8],
            bits: u32,
            n_levels: usize,
            wlut: &[f32; 256],
            acc: &mut [f32],
        ) -> Result<(), u32> {
            if bits > 8 {
                // An 8-element block only fits the u64 window for bits ≤ 8
                // (callers with a 256-entry LUT never exceed it, but the
                // table entry must not rely on that).
                return kernels::accumulate_packed_wlut_scalar(packed, bits, n_levels, wlut, acc);
            }
            // SAFETY: installed only in the AVX2 table (runtime-detected).
            unsafe { accumulate_imp(packed, bits, n_levels, wlut, acc) }
        }

        #[target_feature(enable = "avx2")]
        unsafe fn max_abs_imp(grads: &[f32]) -> f32 {
            let sign = _mm256_set1_ps(-0.0);
            let mut acc = _mm256_setzero_ps();
            let mut chunks = grads.chunks_exact(8);
            for c in &mut chunks {
                let v = _mm256_loadu_ps(c.as_ptr());
                // abs via sign-bit clear; MAXPS with the candidate FIRST
                // returns the accumulator (second operand) when the
                // candidate is NaN — NaN elements are ignored exactly like
                // scalar `f32::max`. The accumulator itself is never NaN.
                acc = _mm256_max_ps(_mm256_andnot_ps(sign, v), acc);
            }
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
            // All lanes are non-NaN and ≥ +0.0, so any reduction order
            // gives the identical f32.
            let mut m = lanes.iter().fold(0.0f32, |m, &x| m.max(x));
            for &g in chunks.remainder() {
                m = m.max(g.abs());
            }
            m
        }

        pub(crate) fn max_abs(grads: &[f32]) -> f32 {
            // SAFETY: installed only in the AVX2 table (runtime-detected).
            unsafe { max_abs_imp(grads) }
        }
    }

    pub(crate) mod sse2 {
        use crate::quant::bitpack;
        use crate::quant::kernels::{self, BitWriter};
        use crate::util::Rng;
        use std::arch::x86_64::*;

        /// Broadcast constants for the uniform block math (4-lane).
        #[derive(Clone, Copy)]
        struct UniC {
            alpha: __m128,
            neg_alpha: __m128,
            inv_step: __m128,
            s_m1: __m128,
        }

        /// Quantize 4 elements: indices (pre-`.min(s)`) into `ibuf`.
        /// Operand-order rules are identical to the AVX2 block — SSE
        /// MINPS/MAXPS/CMPLTPS share the AVX NaN and tie semantics.
        #[inline]
        #[target_feature(enable = "sse2")]
        unsafe fn uniform_idx4(gp: *const f32, ubuf: &[f32; 4], c: UniC, ibuf: &mut [u32; 4]) {
            let vg = _mm_loadu_ps(gp);
            let gc = _mm_min_ps(c.alpha, _mm_max_ps(c.neg_alpha, vg));
            let x = _mm_mul_ps(_mm_add_ps(gc, c.alpha), c.inv_step);
            let xc = _mm_min_ps(x, c.s_m1);
            let lo_i = _mm_cvttps_epi32(xc);
            let lo_f = _mm_cvtepi32_ps(lo_i);
            let frac = _mm_sub_ps(x, lo_f);
            let u = _mm_loadu_ps(ubuf.as_ptr());
            let bump = _mm_cmplt_ps(u, frac);
            let idx = _mm_sub_epi32(lo_i, _mm_castps_si128(bump));
            _mm_storeu_si128(ibuf.as_mut_ptr().cast(), idx);
        }

        #[target_feature(enable = "sse2")]
        unsafe fn uniform_pack_imp(
            grads: &[f32],
            rng: &mut Rng,
            alpha: f32,
            s: u32,
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            out.reserve(bitpack::packed_len(grads.len(), bits));
            let step = 2.0f32 * alpha / s as f32;
            let inv_step = 1.0f32 / step;
            let s_m1 = (s - 1) as f32;
            let c = UniC {
                alpha: _mm_set1_ps(alpha),
                neg_alpha: _mm_set1_ps(-alpha),
                inv_step: _mm_set1_ps(inv_step),
                s_m1: _mm_set1_ps(s_m1),
            };
            let n = grads.len();
            let mut ubuf = [0.0f32; 4];
            let mut ibuf = [0u32; 4];
            let mut i = 0usize;
            if bits > 8 {
                let mut idx = Vec::with_capacity(n);
                while i + 4 <= n {
                    for u in ubuf.iter_mut() {
                        *u = rng.f32();
                    }
                    uniform_idx4(grads.as_ptr().add(i), &ubuf, c, &mut ibuf);
                    for &k in &ibuf {
                        idx.push(k.min(s));
                    }
                    i += 4;
                }
                for &g in &grads[i..] {
                    let u = rng.f32();
                    let gc = g.clamp(-alpha, alpha);
                    let x = (gc + alpha) * inv_step;
                    let lo = x.min(s_m1) as u32;
                    idx.push((lo + u32::from(u < x - lo as f32)).min(s));
                }
                out.extend_from_slice(&bitpack::pack(&idx, bits));
                return;
            }
            let mut w = BitWriter::new(out);
            while i + 4 <= n {
                for u in ubuf.iter_mut() {
                    *u = rng.f32();
                }
                uniform_idx4(grads.as_ptr().add(i), &ubuf, c, &mut ibuf);
                for &k in &ibuf {
                    w.push(u64::from(k.min(s)), bits);
                }
                i += 4;
            }
            for &g in &grads[i..] {
                let u = rng.f32();
                let gc = g.clamp(-alpha, alpha);
                let x = (gc + alpha) * inv_step;
                let lo = x.min(s_m1) as u32;
                let idx = (lo + u32::from(u < x - lo as f32)).min(s);
                w.push(u64::from(idx), bits);
            }
            w.finish();
        }

        pub(crate) fn quantize_uniform_pack_into(
            grads: &[f32],
            rng: &mut Rng,
            alpha: f32,
            s: u32,
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            // SAFETY: SSE2 is part of the x86_64 baseline; this table is
            // only constructed on x86_64.
            unsafe { uniform_pack_imp(grads, rng, alpha, s, bits, out) }
        }

        #[target_feature(enable = "sse2")]
        unsafe fn codebook_pack_imp(
            grads: &[f32],
            rng: &mut Rng,
            codebook: &[f32],
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            let s = codebook.len() - 1;
            out.reserve(bitpack::packed_len(grads.len(), bits));
            let lo_bound = codebook[0];
            let hi_bound = codebook[s];
            let interior = &codebook[1..s];
            let vlo = _mm_set1_ps(lo_bound);
            let vhi = _mm_set1_ps(hi_bound);
            let n = grads.len();
            let mut kbuf = [0u32; 4];
            let mut gbuf = [0.0f32; 4];
            let mut w = BitWriter::new(out);
            let mut i = 0usize;
            while i + 4 <= n {
                let vg = _mm_loadu_ps(grads.as_ptr().add(i));
                let gc = _mm_min_ps(vhi, _mm_max_ps(vlo, vg));
                let mut kv = _mm_setzero_si128();
                for &b in interior {
                    let le = _mm_cmple_ps(_mm_set1_ps(b), gc);
                    kv = _mm_sub_epi32(kv, _mm_castps_si128(le));
                }
                _mm_storeu_si128(kbuf.as_mut_ptr().cast(), kv);
                _mm_storeu_ps(gbuf.as_mut_ptr(), gc);
                for (&k32, &gcj) in kbuf.iter().zip(&gbuf) {
                    let k = k32 as usize;
                    let lower = codebook[k];
                    let width = codebook[k + 1] - lower;
                    let frac = if width > 0.0 { (gcj - lower) / width } else { 0.0 };
                    let idx = (k + usize::from(rng.f32() < frac)) as u64;
                    w.push(idx, bits);
                }
                i += 4;
            }
            for &g in &grads[i..] {
                let gc = g.clamp(lo_bound, hi_bound);
                let k = interior.partition_point(|&b| b <= gc);
                let lower = codebook[k];
                let width = codebook[k + 1] - lower;
                let frac = if width > 0.0 { (gc - lower) / width } else { 0.0 };
                let idx = (k + usize::from(rng.f32() < frac)) as u64;
                w.push(idx, bits);
            }
            w.finish();
        }

        pub(crate) fn quantize_codebook_pack_into(
            grads: &[f32],
            rng: &mut Rng,
            codebook: &[f32],
            bits: u32,
            out: &mut Vec<u8>,
        ) {
            if bits > 8 || codebook.len().saturating_sub(2) > super::super::CB_SIMD_MAX_INTERIOR {
                return kernels::quantize_codebook_pack_into_scalar(grads, rng, codebook, bits, out);
            }
            // SAFETY: SSE2 is part of the x86_64 baseline.
            unsafe { codebook_pack_imp(grads, rng, codebook, bits, out) }
        }

        #[target_feature(enable = "sse2")]
        unsafe fn accumulate_imp(
            packed: &[u8],
            bits: u32,
            n_levels: usize,
            wlut: &[f32; 256],
            acc: &mut [f32],
        ) -> Result<(), u32> {
            let mask = (1u64 << bits) - 1;
            let n = acc.len();
            let mut e = 0usize;
            // Still 8 elements per block (two 4-lane halves) so block
            // starts stay byte-aligned for every bits in 1..=8. SSE2 has
            // no gather: the LUT reads stay scalar, the adds vectorize.
            'blocks: while e + 8 <= n {
                let byte = (e * bits as usize) >> 3;
                let Some(win) = packed.get(byte..byte + 8) else { break };
                let word = u64::from_le_bytes(win.try_into().unwrap());
                let mut lut = [0.0f32; 8];
                for (j, slot) in lut.iter_mut().enumerate() {
                    let idx = ((word >> (j as u32 * bits)) & mask) as usize;
                    if idx >= n_levels {
                        break 'blocks;
                    }
                    *slot = wlut[idx];
                }
                let a0 = _mm_loadu_ps(acc.as_ptr().add(e));
                let a1 = _mm_loadu_ps(acc.as_ptr().add(e + 4));
                _mm_storeu_ps(acc.as_mut_ptr().add(e), _mm_add_ps(a0, _mm_loadu_ps(lut.as_ptr())));
                _mm_storeu_ps(
                    acc.as_mut_ptr().add(e + 4),
                    _mm_add_ps(a1, _mm_loadu_ps(lut.as_ptr().add(4))),
                );
                e += 8;
            }
            kernels::accumulate_packed_wlut_from(packed, bits, n_levels, wlut, acc, e)
        }

        pub(crate) fn accumulate_packed_wlut(
            packed: &[u8],
            bits: u32,
            n_levels: usize,
            wlut: &[f32; 256],
            acc: &mut [f32],
        ) -> Result<(), u32> {
            if bits > 8 {
                // The 8-element u64 window requires bits ≤ 8.
                return kernels::accumulate_packed_wlut_scalar(packed, bits, n_levels, wlut, acc);
            }
            // SAFETY: SSE2 is part of the x86_64 baseline.
            unsafe { accumulate_imp(packed, bits, n_levels, wlut, acc) }
        }

        #[target_feature(enable = "sse2")]
        unsafe fn max_abs_imp(grads: &[f32]) -> f32 {
            let sign = _mm_set1_ps(-0.0);
            let mut acc = _mm_setzero_ps();
            let mut chunks = grads.chunks_exact(4);
            for c in &mut chunks {
                let v = _mm_loadu_ps(c.as_ptr());
                // Candidate first: MAXPS returns the accumulator on NaN.
                acc = _mm_max_ps(_mm_andnot_ps(sign, v), acc);
            }
            let mut lanes = [0.0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut m = lanes.iter().fold(0.0f32, |m, &x| m.max(x));
            for &g in chunks.remainder() {
                m = m.max(g.abs());
            }
            m
        }

        pub(crate) fn max_abs(grads: &[f32]) -> f32 {
            // SAFETY: SSE2 is part of the x86_64 baseline.
            unsafe { max_abs_imp(grads) }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON (4-lane) kernel bodies. NEON is architecturally mandatory on
    //! AArch64, so no runtime probe is needed. The key divergence from the
    //! x86 paths: `FMIN`/`FMAX` PROPAGATE NaN, so wherever the scalar
    //! semantics require returning the non-NaN operand, these paths select
    //! explicitly on a `v == v` self-compare mask.

    use crate::quant::bitpack;
    use crate::quant::kernels::{self, BitWriter};
    use crate::util::Rng;
    use std::arch::aarch64::*;

    /// Broadcast constants for the uniform block math.
    #[derive(Clone, Copy)]
    struct UniC {
        alpha: float32x4_t,
        neg_alpha: float32x4_t,
        inv_step: float32x4_t,
        s_m1: float32x4_t,
    }

    /// Quantize 4 elements: indices (pre-`.min(s)`) into `ibuf`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn uniform_idx4(gp: *const f32, ubuf: &[f32; 4], c: UniC, ibuf: &mut [u32; 4]) {
        let vg = vld1q_f32(gp);
        // Scalar `clamp` propagates NaN — FMIN/FMAX do too, and their
        // ±0.0 ordering (-0.0 < +0.0) never changes an in-range value.
        let gc = vminq_f32(c.alpha, vmaxq_f32(c.neg_alpha, vg));
        let x = vmulq_f32(vaddq_f32(gc, c.alpha), c.inv_step);
        // Scalar `x.min(s_m1)` returns s_m1 on NaN x, but FMIN would
        // propagate the NaN — select on x==x (false only for NaN lanes).
        let not_nan = vceqq_f32(x, x);
        let xc = vbslq_f32(not_nan, vminq_f32(x, c.s_m1), c.s_m1);
        // FCVTZU truncates toward zero (and −0.0 → 0), matching `as u32`.
        let lo_i = vcvtq_u32_f32(xc);
        let lo_f = vcvtq_f32_u32(lo_i);
        let frac = vsubq_f32(x, lo_f);
        let u = vld1q_f32(ubuf.as_ptr());
        // FCMLT is false on NaN, like scalar `<`.
        let bump = vcltq_f32(u, frac);
        // True lanes are all-ones (−1 wrapping): subtract adds the bump.
        let idx = vsubq_u32(lo_i, bump);
        vst1q_u32(ibuf.as_mut_ptr(), idx);
    }

    #[target_feature(enable = "neon")]
    unsafe fn uniform_pack_imp(
        grads: &[f32],
        rng: &mut Rng,
        alpha: f32,
        s: u32,
        bits: u32,
        out: &mut Vec<u8>,
    ) {
        out.reserve(bitpack::packed_len(grads.len(), bits));
        let step = 2.0f32 * alpha / s as f32;
        let inv_step = 1.0f32 / step;
        let s_m1 = (s - 1) as f32;
        let c = UniC {
            alpha: vdupq_n_f32(alpha),
            neg_alpha: vdupq_n_f32(-alpha),
            inv_step: vdupq_n_f32(inv_step),
            s_m1: vdupq_n_f32(s_m1),
        };
        let n = grads.len();
        let mut ubuf = [0.0f32; 4];
        let mut ibuf = [0u32; 4];
        let mut i = 0usize;
        if bits > 8 {
            let mut idx = Vec::with_capacity(n);
            while i + 4 <= n {
                for u in ubuf.iter_mut() {
                    *u = rng.f32();
                }
                uniform_idx4(grads.as_ptr().add(i), &ubuf, c, &mut ibuf);
                for &k in &ibuf {
                    idx.push(k.min(s));
                }
                i += 4;
            }
            for &g in &grads[i..] {
                let u = rng.f32();
                let gc = g.clamp(-alpha, alpha);
                let x = (gc + alpha) * inv_step;
                let lo = x.min(s_m1) as u32;
                idx.push((lo + u32::from(u < x - lo as f32)).min(s));
            }
            out.extend_from_slice(&bitpack::pack(&idx, bits));
            return;
        }
        let mut w = BitWriter::new(out);
        while i + 4 <= n {
            for u in ubuf.iter_mut() {
                *u = rng.f32();
            }
            uniform_idx4(grads.as_ptr().add(i), &ubuf, c, &mut ibuf);
            for &k in &ibuf {
                w.push(u64::from(k.min(s)), bits);
            }
            i += 4;
        }
        for &g in &grads[i..] {
            let u = rng.f32();
            let gc = g.clamp(-alpha, alpha);
            let x = (gc + alpha) * inv_step;
            let lo = x.min(s_m1) as u32;
            let idx = (lo + u32::from(u < x - lo as f32)).min(s);
            w.push(u64::from(idx), bits);
        }
        w.finish();
    }

    pub(crate) fn quantize_uniform_pack_into(
        grads: &[f32],
        rng: &mut Rng,
        alpha: f32,
        s: u32,
        bits: u32,
        out: &mut Vec<u8>,
    ) {
        // SAFETY: NEON is mandatory on AArch64; this table only exists
        // there.
        unsafe { uniform_pack_imp(grads, rng, alpha, s, bits, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn codebook_pack_imp(
        grads: &[f32],
        rng: &mut Rng,
        codebook: &[f32],
        bits: u32,
        out: &mut Vec<u8>,
    ) {
        let s = codebook.len() - 1;
        out.reserve(bitpack::packed_len(grads.len(), bits));
        let lo_bound = codebook[0];
        let hi_bound = codebook[s];
        let interior = &codebook[1..s];
        let vlo = vdupq_n_f32(lo_bound);
        let vhi = vdupq_n_f32(hi_bound);
        let n = grads.len();
        let mut kbuf = [0u32; 4];
        let mut gbuf = [0.0f32; 4];
        let mut w = BitWriter::new(out);
        let mut i = 0usize;
        while i + 4 <= n {
            let vg = vld1q_f32(grads.as_ptr().add(i));
            // clamp: NaN propagates through FMIN/FMAX like scalar clamp.
            let gc = vminq_f32(vhi, vmaxq_f32(vlo, vg));
            // Linear boundary count (== partition_point on sorted input);
            // FCMLE is false on NaN, so NaN gc counts 0 like scalar.
            let mut kv = vdupq_n_u32(0);
            for &b in interior {
                let le = vcleq_f32(vdupq_n_f32(b), gc);
                kv = vsubq_u32(kv, le);
            }
            vst1q_u32(kbuf.as_mut_ptr(), kv);
            vst1q_f32(gbuf.as_mut_ptr(), gc);
            for (&k32, &gcj) in kbuf.iter().zip(&gbuf) {
                let k = k32 as usize;
                let lower = codebook[k];
                let width = codebook[k + 1] - lower;
                let frac = if width > 0.0 { (gcj - lower) / width } else { 0.0 };
                let idx = (k + usize::from(rng.f32() < frac)) as u64;
                w.push(idx, bits);
            }
            i += 4;
        }
        for &g in &grads[i..] {
            let gc = g.clamp(lo_bound, hi_bound);
            let k = interior.partition_point(|&b| b <= gc);
            let lower = codebook[k];
            let width = codebook[k + 1] - lower;
            let frac = if width > 0.0 { (gc - lower) / width } else { 0.0 };
            let idx = (k + usize::from(rng.f32() < frac)) as u64;
            w.push(idx, bits);
        }
        w.finish();
    }

    pub(crate) fn quantize_codebook_pack_into(
        grads: &[f32],
        rng: &mut Rng,
        codebook: &[f32],
        bits: u32,
        out: &mut Vec<u8>,
    ) {
        if bits > 8 || codebook.len().saturating_sub(2) > super::CB_SIMD_MAX_INTERIOR {
            return kernels::quantize_codebook_pack_into_scalar(grads, rng, codebook, bits, out);
        }
        // SAFETY: NEON is mandatory on AArch64.
        unsafe { codebook_pack_imp(grads, rng, codebook, bits, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn accumulate_imp(
        packed: &[u8],
        bits: u32,
        n_levels: usize,
        wlut: &[f32; 256],
        acc: &mut [f32],
    ) -> Result<(), u32> {
        let mask = (1u64 << bits) - 1;
        let n = acc.len();
        let mut e = 0usize;
        // 8 elements per block (two 4-lane halves) so block starts stay
        // byte-aligned for every bits in 1..=8; LUT reads stay scalar.
        'blocks: while e + 8 <= n {
            let byte = (e * bits as usize) >> 3;
            let Some(win) = packed.get(byte..byte + 8) else { break };
            let word = u64::from_le_bytes(win.try_into().unwrap());
            let mut lut = [0.0f32; 8];
            for (j, slot) in lut.iter_mut().enumerate() {
                let idx = ((word >> (j as u32 * bits)) & mask) as usize;
                if idx >= n_levels {
                    break 'blocks;
                }
                *slot = wlut[idx];
            }
            let a0 = vld1q_f32(acc.as_ptr().add(e));
            let a1 = vld1q_f32(acc.as_ptr().add(e + 4));
            vst1q_f32(acc.as_mut_ptr().add(e), vaddq_f32(a0, vld1q_f32(lut.as_ptr())));
            vst1q_f32(acc.as_mut_ptr().add(e + 4), vaddq_f32(a1, vld1q_f32(lut.as_ptr().add(4))));
            e += 8;
        }
        kernels::accumulate_packed_wlut_from(packed, bits, n_levels, wlut, acc, e)
    }

    pub(crate) fn accumulate_packed_wlut(
        packed: &[u8],
        bits: u32,
        n_levels: usize,
        wlut: &[f32; 256],
        acc: &mut [f32],
    ) -> Result<(), u32> {
        if bits > 8 {
            // The 8-element u64 window requires bits ≤ 8.
            return kernels::accumulate_packed_wlut_scalar(packed, bits, n_levels, wlut, acc);
        }
        // SAFETY: NEON is mandatory on AArch64.
        unsafe { accumulate_imp(packed, bits, n_levels, wlut, acc) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn max_abs_imp(grads: &[f32]) -> f32 {
        let mut acc = vdupq_n_f32(0.0);
        let mut chunks = grads.chunks_exact(4);
        for c in &mut chunks {
            let a = vabsq_f32(vld1q_f32(c.as_ptr()));
            // FMAX propagates NaN: replace NaN candidates with the current
            // accumulator first, matching scalar `f32::max`'s NaN-ignore.
            let cand = vbslq_f32(vceqq_f32(a, a), a, acc);
            acc = vmaxq_f32(acc, cand);
        }
        let mut lanes = [0.0f32; 4];
        vst1q_f32(lanes.as_mut_ptr(), acc);
        let mut m = lanes.iter().fold(0.0f32, |m, &x| m.max(x));
        for &g in chunks.remainder() {
            m = m.max(g.abs());
        }
        m
    }

    pub(crate) fn max_abs(grads: &[f32]) -> f32 {
        // SAFETY: NEON is mandatory on AArch64.
        unsafe { max_abs_imp(grads) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitpack;

    // The exhaustive scheme × bits 1..=16 × ragged-length sweep lives in
    // tests/quant_props.rs (`simd_matches_scalar`); these are fast inline
    // smoke checks that every table entry executes and agrees.

    fn tables() -> Vec<&'static KernelDispatch> {
        vec![scalar_kernels(), detected_kernels(), active_kernels()]
    }

    #[test]
    fn every_table_round_trips_the_uniform_pack() {
        let mut seed_rng = Rng::new(7);
        let g: Vec<f32> = (0..1000).map(|_| (seed_rng.student_t(3.0) * 0.01) as f32).collect();
        let mut want = Vec::new();
        let mut r = Rng::new(5);
        (scalar_kernels().quantize_uniform_pack_into)(&g, &mut r, 0.03, 15, 4, &mut want);
        for t in tables() {
            let mut out = Vec::new();
            let mut r = Rng::new(5);
            (t.quantize_uniform_pack_into)(&g, &mut r, 0.03, 15, 4, &mut out);
            assert_eq!(out, want, "isa={}", t.isa);
        }
    }

    #[test]
    fn every_table_agrees_on_accumulate_and_max_abs() {
        let mut rng = Rng::new(8);
        let n = 777usize;
        let idx: Vec<u32> = (0..n).map(|_| rng.below(8) as u32).collect();
        let packed = bitpack::pack(&idx, 3);
        let mut wlut = [0.0f32; 256];
        for (k, slot) in wlut.iter_mut().enumerate().take(8) {
            *slot = 0.125 * (k as f32 - 3.0);
        }
        let base: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let mut want = base.clone();
        (scalar_kernels().accumulate_packed_wlut)(&packed, 3, 8, &wlut, &mut want).unwrap();
        for t in tables() {
            let mut acc = base.clone();
            (t.accumulate_packed_wlut)(&packed, 3, 8, &wlut, &mut acc).unwrap();
            assert_eq!(acc, want, "isa={}", t.isa);
            assert_eq!(
                (t.max_abs)(&base).to_bits(),
                (scalar_kernels().max_abs)(&base).to_bits(),
                "isa={}",
                t.isa
            );
        }
    }

    #[test]
    fn detected_isa_is_plausible_for_this_arch() {
        let isa = detected_kernels().isa;
        #[cfg(target_arch = "x86_64")]
        assert!(isa == "avx2" || isa == "sse2", "{isa}");
        #[cfg(target_arch = "aarch64")]
        assert_eq!(isa, "neon");
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert_eq!(isa, "scalar");
        // The scalar table is always reachable regardless.
        assert_eq!(scalar_kernels().isa, "scalar");
    }
}
