//! Bit-packing substrate: n-bit unsigned integers ⇄ bytes, LSB-first.
//!
//! The wire format stores one level index per gradient element at exactly
//! `b` bits — this is where the paper's "b bits per parameter" communication
//! budget is realized, so the packing must be tight: `ceil(d*b/8)` bytes.

/// Pack `values[i] < 2^bits` into little-endian bytes, LSB-first bit order.
pub fn pack(values: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=32).contains(&bits));
    let total_bits = values.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &v in values {
        debug_assert!(bits == 32 || v < (1u32 << bits), "value {v} exceeds {bits} bits");
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u32;
        // A value spans at most 5 bytes (32 bits + 7 offset).
        let wide = (v as u64) << off;
        let mut w = wide;
        let mut i = byte;
        while w != 0 {
            out[i] |= (w & 0xFF) as u8;
            w >>= 8;
            i += 1;
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `count` values of `bits` bits each.
pub fn unpack(bytes: &[u8], bits: u32, count: usize) -> Vec<u32> {
    assert!((1..=32).contains(&bits));
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u32;
        let mut wide = 0u64;
        // Read up to 5 bytes covering the span.
        for k in 0..5 {
            if let Some(&b) = bytes.get(byte + k) {
                wide |= (b as u64) << (8 * k as u32);
            }
        }
        out.push(((wide >> off) & mask) as u32);
        bitpos += bits as usize;
    }
    out
}

/// Exact packed size in bytes for `count` values of `bits` bits.
pub fn packed_len(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn roundtrip_3bit() {
        let vals: Vec<u32> = (0..100).map(|i| i % 8).collect();
        let packed = pack(&vals, 3);
        assert_eq!(packed.len(), packed_len(100, 3));
        assert_eq!(packed.len(), (100 * 3 + 7) / 8);
        assert_eq!(unpack(&packed, 3, 100), vals);
    }

    #[test]
    fn roundtrip_every_width() {
        for bits in 1..=32u32 {
            let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let vals: Vec<u32> = (0..50u32).map(|i| i.wrapping_mul(0x9E37_79B9) & max).collect();
            let packed = pack(&vals, bits);
            assert_eq!(unpack(&packed, bits, 50), vals, "bits={bits}");
        }
    }

    #[test]
    fn empty_input() {
        assert!(pack(&[], 3).is_empty());
        assert!(unpack(&[], 3, 0).is_empty());
    }

    #[test]
    fn property_roundtrip_random() {
        prop::check(200, |rng| {
            let bits = 1 + rng.below(16) as u32;
            let n = rng.below(2000) as usize;
            let max = (1u64 << bits) as u32;
            let vals: Vec<u32> = (0..n).map(|_| rng.below(max as u64) as u32).collect();
            let packed = pack(&vals, bits);
            if packed.len() != packed_len(n, bits) {
                return Err("size mismatch".into());
            }
            prop::assert_prop(unpack(&packed, bits, n) == vals, "roundtrip")
        });
    }

    #[test]
    fn bytes_per_element_matches_budget() {
        // The paper's communication accounting: b bits per element.
        for b in [2u32, 3, 4, 5] {
            let d = 37_610; // CNN parameter count
            assert_eq!(packed_len(d, b), (d * b as usize).div_ceil(8));
        }
    }
}
