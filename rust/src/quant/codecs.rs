//! Gradient compressors: the paper's three truncated quantizers and every
//! baseline it compares against (Sec. V), all producing wire frames.
//!
//! | Codec | Paper role | Density | Truncation |
//! |-------|-----------|---------|------------|
//! | [`DsgdCodec`]     | oracle        | —                 | — |
//! | [`QsgdCodec`]     | baseline [5]  | uniform           | none (range = max\|g\|) |
//! | [`NqsgdCodec`]    | baseline      | p^{1/3}           | none (range = max\|g\|) |
//! | [`TqsgdCodec`]    | Thm. 1        | uniform           | α from Eq. (12) |
//! | [`TnqsgdCodec`]   | Thm. 2        | p^{1/3} (Eq. 18)  | α from Eq. (19) |
//! | [`TbqsgdCodec`]   | Thm. 3/App. D | BiScaled (Eq. 25) | α from Eq. (33) |
//! | [`TerngradCodec`] | baseline [17] | ternary           | none |
//! | [`TopkCodec`]     | baseline [3]  | sparse            | — |
//!
//! Distribution-aware codecs (`Nqsgd`, `Tqsgd`, `Tnqsgd`, `Tbqsgd`) carry a
//! fitted [`PowerLawModel`]; [`Compressor::refit`] re-estimates it from the
//! latest local gradient (the coordinator calls this every
//! `estimate_every` rounds per layer group, mirroring the paper's per-layer
//! γ MLE).

use crate::config::{QuantConfig, Scheme, MAX_BITS};
use crate::solver;
use crate::tail::{fit::report_to_model, fit_power_law_sampled, PowerLawModel, REFIT_SAMPLE_CAP};
use crate::util::Rng;

use super::kernels::{max_abs, quantize_codebook_pack_into, quantize_uniform_pack_into};
use super::wire;

/// A gradient compressor: stateful (distribution estimates), one per
/// (client, layer-group).
pub trait Compressor: Send {
    /// Which compression scheme this codec implements.
    fn scheme(&self) -> Scheme;

    /// Update distribution state from a fresh local gradient.
    fn refit(&mut self, grads: &[f32]);

    /// Compress into a caller-provided frame buffer (cleared first). `rng`
    /// drives the stochastic rounding. This is the steady-state hot path:
    /// with a recycled `out` of sufficient capacity (see
    /// [`FrameArena`](super::FrameArena)) it performs zero heap allocation.
    /// `&mut self` lets codecs keep internal scratch (e.g. Top-k's
    /// selection buffers); distribution state only changes via `refit`.
    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>);

    /// Convenience wrapper over [`Compressor::compress_into`] that allocates
    /// a fresh frame — byte- and RNG-stream-identical to the in-place path.
    /// Kept as a documented test convenience; production call sites go
    /// through `compress_into` with a recycled buffer.
    fn compress(&mut self, grads: &[f32], rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::new();
        self.compress_into(grads, rng, &mut out);
        out
    }

    /// Current per-element wire bit-width: the packed index width for the
    /// quantized codecs, 32 for DSGD's raw f32s, 2 for TernGrad, 0 for
    /// Top-k (whose cost is set by `frac`, not an index width).
    fn rate(&self) -> u32;

    /// Re-target the per-element bit-width from the STANDING distribution
    /// fit, without a refit: re-derives the truncation threshold (and any
    /// codebook) from the stored model at the new density. Codecs clamp
    /// `bits` to their admissible range (e.g. BiScaled needs ≥ 2,
    /// multiscale ≥ 3) — callers read back [`Compressor::rate`] for the
    /// width actually in effect. Fixed-rate codecs (DSGD, TernGrad, Top-k
    /// — see [`Scheme::rate_adaptive`]) ignore the call.
    fn set_rate(&mut self, bits: u32);

    /// One-line description of current state (for logs).
    fn describe(&self) -> String;
}

/// Build the codec for a scheme.
pub fn make_compressor(cfg: &QuantConfig) -> Box<dyn Compressor> {
    let s = solver::levels_for_bits(cfg.bits) as u32;
    match cfg.scheme {
        Scheme::Dsgd => Box::new(DsgdCodec),
        Scheme::Qsgd => Box::new(QsgdCodec { s }),
        Scheme::Nqsgd => Box::new(NqsgdCodec { s, model: None }),
        Scheme::Tqsgd => Box::new(TqsgdCodec { s, state: None }),
        Scheme::Tnqsgd => Box::new(TnqsgdCodec { s, state: None }),
        Scheme::Tbqsgd => Box::new(TbqsgdCodec { s, state: None }),
        Scheme::Terngrad => Box::new(TerngradCodec),
        Scheme::Topk => Box::new(TopkCodec::new(cfg.topk_frac)),
        Scheme::Multiscale => Box::new(MultiscaleCodec::new(cfg.bits)),
    }
}

/// Smallest index bit-width that can hold levels 0..=s.
fn bits_for(s: u32) -> u32 {
    32 - s.leading_zeros()
}

// ---------------------------------------------------------------------------
// Oracle
// ---------------------------------------------------------------------------

/// DSGD: uncompressed 32-bit gradients.
pub struct DsgdCodec;

impl Compressor for DsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Dsgd
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], _rng: &mut Rng, out: &mut Vec<u8>) {
        // Straight from the borrowed slice — no `grads.to_vec()` staging copy.
        wire::encode_raw_into(grads, out);
    }

    fn rate(&self) -> u32 {
        32
    }

    fn set_rate(&mut self, _bits: u32) {}

    fn describe(&self) -> String {
        "dsgd(fp32)".into()
    }
}

// ---------------------------------------------------------------------------
// Untruncated baselines
// ---------------------------------------------------------------------------

/// QSGD: uniform stochastic quantization over the FULL range [−max|g|,
/// max|g|] — no truncation, so one outlier stretches every interval.  This
/// is exactly why it collapses at b = 3 on heavy-tailed gradients (Fig. 3).
pub struct QsgdCodec {
    s: u32,
}

impl Compressor for QsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Qsgd
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
        let bits = bits_for(self.s);
        wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
        quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
    }

    fn rate(&self) -> u32 {
        bits_for(self.s)
    }

    fn set_rate(&mut self, bits: u32) {
        self.s = solver::levels_for_bits(bits.clamp(1, MAX_BITS)) as u32;
    }

    fn describe(&self) -> String {
        format!("qsgd(s={}, range=max|g|)", self.s)
    }
}

/// NQSGD: non-uniform (p^{1/3}) quantization over the full range, no
/// truncation. Needs a fitted tail model to shape the codebook; before the
/// first refit it degrades to QSGD.
pub struct NqsgdCodec {
    s: u32,
    model: Option<PowerLawModel>,
}

impl Compressor for NqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Nqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(rep) = fit_power_law_sampled(grads, REFIT_SAMPLE_CAP) {
            self.model = Some(report_to_model(&rep));
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let range = max_abs(grads).max(f32::MIN_POSITIVE) as f64;
        let bits = bits_for(self.s);
        match &self.model {
            Some(m) if range > m.g_min => {
                let cb = solver::nonuniform_codebook(m, range, self.s as usize);
                wire::begin_codebook_frame(out, &cb, grads.len() as u32, bits);
                quantize_codebook_pack_into(grads, rng, &cb, bits, out);
            }
            _ => {
                wire::begin_uniform_frame(
                    out,
                    range as f32,
                    self.s as u16,
                    grads.len() as u32,
                    bits,
                );
                quantize_uniform_pack_into(grads, rng, range as f32, self.s, bits, out);
            }
        }
    }

    fn rate(&self) -> u32 {
        bits_for(self.s)
    }

    fn set_rate(&mut self, bits: u32) {
        // The codebook is shaped per compress call from max|g|; only the
        // density changes.
        self.s = solver::levels_for_bits(bits.clamp(1, MAX_BITS)) as u32;
    }

    fn describe(&self) -> String {
        match &self.model {
            Some(m) => format!("nqsgd(s={}, γ̂={:.2})", self.s, m.gamma),
            None => format!("nqsgd(s={}, unfitted→qsgd)", self.s),
        }
    }
}

// ---------------------------------------------------------------------------
// The paper's truncated quantizers
// ---------------------------------------------------------------------------

struct TruncState {
    model: PowerLawModel,
    alpha: f64,
    /// Materialized codebook (None for the uniform TQSGD).
    codebook: Option<Vec<f32>>,
}

/// Fit the tail model, clamping γ into the paper's admissible (3, 5] range —
/// the Eq. (11) error terms are only finite for γ > 3, and empirical fits of
/// conv-layer gradients occasionally stray below. Uses the deterministic
/// sampled fit (capped at [`REFIT_SAMPLE_CAP`] points), so a per-round refit
/// costs ~O(d) instead of the full-sort O(d log d).
fn fit_clamped(grads: &[f32]) -> Option<PowerLawModel> {
    let rep = fit_power_law_sampled(grads, REFIT_SAMPLE_CAP)?;
    let mut m = report_to_model(&rep);
    m.gamma = m.gamma.clamp(3.05, 5.0);
    Some(m)
}

/// TQSGD (Thm. 1): truncation at the Eq. (12) α, uniform density.
pub struct TqsgdCodec {
    s: u32,
    state: Option<TruncState>,
}

impl Compressor for TqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Tqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            let alpha = solver::optimal_alpha_uniform(&model, self.s as usize);
            self.state = Some(TruncState { model, alpha, codebook: None });
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let alpha = match &self.state {
            Some(st) => st.alpha as f32,
            None => max_abs(grads).max(f32::MIN_POSITIVE), // pre-fit fallback
        };
        let bits = bits_for(self.s);
        wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
        quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
    }

    fn rate(&self) -> u32 {
        bits_for(self.s)
    }

    fn set_rate(&mut self, bits: u32) {
        self.s = solver::levels_for_bits(bits.clamp(1, MAX_BITS)) as u32;
        // Eq. (12)'s optimum depends on s: re-solve from the standing model
        // without touching the fit itself.
        if let Some(st) = &mut self.state {
            st.alpha = solver::optimal_alpha_uniform(&st.model, self.s as usize);
        }
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "tqsgd(s={}, α={:.4}, γ̂={:.2})",
                self.s, st.alpha, st.model.gamma
            ),
            None => format!("tqsgd(s={}, unfitted)", self.s),
        }
    }
}

/// TNQSGD (Thm. 2): truncation at the Eq. (19) α, p^{1/3} density (Eq. 18).
pub struct TnqsgdCodec {
    s: u32,
    state: Option<TruncState>,
}

impl Compressor for TnqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Tnqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            let alpha = solver::optimal_alpha_nonuniform(&model, self.s as usize);
            let cb = solver::nonuniform_codebook(&model, alpha, self.s as usize);
            self.state = Some(TruncState { model, alpha, codebook: Some(cb) });
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let bits = bits_for(self.s);
        match &self.state {
            Some(st) => {
                let cb = st.codebook.as_ref().unwrap();
                wire::begin_codebook_frame(out, cb, grads.len() as u32, bits);
                quantize_codebook_pack_into(grads, rng, cb, bits, out);
            }
            None => {
                let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
                wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
                quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
            }
        }
    }

    fn rate(&self) -> u32 {
        bits_for(self.s)
    }

    fn set_rate(&mut self, bits: u32) {
        self.s = solver::levels_for_bits(bits.clamp(1, MAX_BITS)) as u32;
        if let Some(st) = &mut self.state {
            st.alpha = solver::optimal_alpha_nonuniform(&st.model, self.s as usize);
            st.codebook =
                Some(solver::nonuniform_codebook(&st.model, st.alpha, self.s as usize));
        }
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "tnqsgd(s={}, α={:.4}, γ̂={:.2})",
                self.s, st.alpha, st.model.gamma
            ),
            None => format!("tnqsgd(s={}, unfitted)", self.s),
        }
    }
}

/// TBQSGD (Thm. 3 / Appendix D): BiScaled two-region density.
pub struct TbqsgdCodec {
    s: u32,
    state: Option<TruncState>,
}

impl Compressor for TbqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Tbqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            let design = solver::solve_biscaled(&model, self.s as usize);
            let cb = design.codebook();
            self.state =
                Some(TruncState { model, alpha: design.alpha, codebook: Some(cb) });
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let bits = bits_for(self.s);
        match &self.state {
            Some(st) => {
                let cb = st.codebook.as_ref().unwrap();
                wire::begin_codebook_frame(out, cb, grads.len() as u32, bits);
                quantize_codebook_pack_into(grads, rng, cb, bits, out);
            }
            None => {
                let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
                wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
                quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
            }
        }
    }

    fn rate(&self) -> u32 {
        bits_for(self.s)
    }

    fn set_rate(&mut self, bits: u32) {
        // BiScaled needs s >= 3 intervals, i.e. at least 2 bits.
        self.s = solver::levels_for_bits(bits.clamp(2, MAX_BITS)) as u32;
        if let Some(st) = &mut self.state {
            let design = solver::solve_biscaled(&st.model, self.s as usize);
            st.alpha = design.alpha;
            st.codebook = Some(design.codebook());
        }
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "tbqsgd(s={}, α={:.4}, γ̂={:.2})",
                self.s, st.alpha, st.model.gamma
            ),
            None => format!("tbqsgd(s={}, unfitted)", self.s),
        }
    }
}

// ---------------------------------------------------------------------------
// Other baselines
// ---------------------------------------------------------------------------

/// TernGrad (Wen et al. 2017): stochastic ternary levels {−m, 0, +m} with
/// m = max|g| — equivalently the uniform stochastic quantizer with s = 2.
pub struct TerngradCodec;

impl Compressor for TerngradCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Terngrad
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
        wire::begin_uniform_frame(out, alpha, 2, grads.len() as u32, 2);
        quantize_uniform_pack_into(grads, rng, alpha, 2, 2, out);
    }

    fn rate(&self) -> u32 {
        2
    }

    fn set_rate(&mut self, _bits: u32) {}

    fn describe(&self) -> String {
        "terngrad(s=2)".into()
    }
}

/// Top-k sparsification: keep the `frac` largest-|g| entries exactly.
pub struct TopkCodec {
    frac: f64,
    /// Selection scratch, reused across rounds (zero steady-state allocs).
    order: Vec<u32>,
    /// (index, value) scratch, reused across rounds.
    pairs: Vec<(u32, f32)>,
}

impl TopkCodec {
    /// Codec keeping the `frac` largest-|g| entries.
    pub fn new(frac: f64) -> TopkCodec {
        TopkCodec { frac, order: Vec::new(), pairs: Vec::new() }
    }
}

impl Compressor for TopkCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Topk
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], _rng: &mut Rng, out: &mut Vec<u8>) {
        let k = ((grads.len() as f64 * self.frac).ceil() as usize)
            .clamp(1, grads.len());
        self.order.clear();
        self.order.extend(0..grads.len() as u32);
        self.order.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            grads[b as usize]
                .abs()
                .partial_cmp(&grads[a as usize].abs())
                .unwrap()
        });
        self.pairs.clear();
        self.pairs.extend(self.order[..k].iter().map(|&i| (i, grads[i as usize])));
        self.pairs.sort_unstable_by_key(|&(i, _)| i);
        wire::encode_sparse_into(grads.len() as u32, &self.pairs, out);
    }

    fn rate(&self) -> u32 {
        0
    }

    fn set_rate(&mut self, _bits: u32) {}

    fn describe(&self) -> String {
        format!("topk({:.2}%)", self.frac * 100.0)
    }
}

// ---------------------------------------------------------------------------
// Extension: multiscale (Vineeth 2021, arxiv 2109.12497)
// ---------------------------------------------------------------------------

struct MsState {
    model: PowerLawModel,
    /// Coarse-grid truncation threshold (Eq. (12) optimum at `s_hi`).
    alpha: f64,
    /// Fine-grid half-range covering the distribution body.
    beta: f64,
    /// Merged sorted level set the wire frame implies (f32, so it matches
    /// the decoder's reconstruction bit-exactly).
    codebook: Vec<f32>,
}

/// Unbiased two-scale quantizer: a coarse uniform grid on [-α, α] capturing
/// the heavy tail, overlaid with a fine uniform grid on [-β, β] (β ≪ α)
/// resolving the body where most mass sits. The merged level set is encoded
/// with stochastic interval rounding, so the codec stays unbiased inside
/// [-α, α] while reaching effective rates between the two grids — the
/// property the [`BitBudget`](super::budget::BitBudget) scheduler relies on
/// when it assigns fractional-feeling budgets.
pub struct MultiscaleCodec {
    bits: u32,
    state: Option<MsState>,
}

impl MultiscaleCodec {
    /// Codec targeting `bits`-wide packed indices (clamped to 3..=MAX_BITS;
    /// below 3 bits the two grids cannot both exist).
    pub fn new(bits: u32) -> MultiscaleCodec {
        MultiscaleCodec { bits: bits.clamp(3, MAX_BITS), state: None }
    }

    /// Grid densities at the current rate: both even so the two grids share
    /// level 0 and the merged codebook stays within 2^bits entries.
    fn grids(&self) -> (u16, u16) {
        ((1u32 << (self.bits - 1)) as u16, ((1u32 << (self.bits - 1)) - 2) as u16)
    }

    /// Re-derive α, β, and the merged codebook from the standing fit.
    fn rederive(&mut self) {
        let (s_hi, s_lo) = self.grids();
        if let Some(st) = &mut self.state {
            st.alpha = solver::optimal_alpha_uniform(&st.model, s_hi as usize);
            // β from the closed-form threshold at the fine density, kept
            // well inside the coarse range so the overlay resolves the body.
            st.beta = solver::approx_alpha_uniform(&st.model, s_lo as usize)
                .clamp(st.alpha * 0.05, st.alpha * 0.5);
            st.codebook =
                wire::multiscale_codebook(st.alpha as f32, st.beta as f32, s_hi, s_lo);
        }
    }
}

impl Compressor for MultiscaleCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Multiscale
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            self.state =
                Some(MsState { model, alpha: 0.0, beta: 0.0, codebook: Vec::new() });
            self.rederive();
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let (s_hi, s_lo) = self.grids();
        match &self.state {
            Some(st) => {
                let pack_bits = bits_for(st.codebook.len() as u32 - 1);
                wire::begin_multiscale_frame(
                    out,
                    st.alpha as f32,
                    st.beta as f32,
                    s_hi,
                    s_lo,
                    grads.len() as u32,
                    pack_bits,
                );
                quantize_codebook_pack_into(grads, rng, &st.codebook, pack_bits, out);
            }
            None => {
                // Unfitted fallback: coarse range from max|g|, body at a
                // fixed quarter of it.
                let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
                let beta = alpha / 4.0;
                let cb = wire::multiscale_codebook(alpha, beta, s_hi, s_lo);
                let pack_bits = bits_for(cb.len() as u32 - 1);
                wire::begin_multiscale_frame(
                    out,
                    alpha,
                    beta,
                    s_hi,
                    s_lo,
                    grads.len() as u32,
                    pack_bits,
                );
                quantize_codebook_pack_into(grads, rng, &cb, pack_bits, out);
            }
        }
    }

    fn rate(&self) -> u32 {
        self.bits
    }

    fn set_rate(&mut self, bits: u32) {
        self.bits = bits.clamp(3, MAX_BITS);
        self.rederive();
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "multiscale(b={}, α={:.4}, β={:.4}, γ̂={:.2})",
                self.bits, st.alpha, st.beta, st.model.gamma
            ),
            None => format!("multiscale(b={}, unfitted)", self.bits),
        }
    }
}

// ---------------------------------------------------------------------------
// Codec construction
// ---------------------------------------------------------------------------

/// Per-(client, layer-group) compression state: plain codec or EF-wrapped.
/// Both variants drive through one `dyn Compressor` view — EF's trait impl
/// routes `compress_into` through the feedback loop — so the per-variant
/// match arms live here once, not at every call site.
pub enum GroupCodec {
    /// The bare codec.
    Plain(Box<dyn Compressor>),
    /// Wrapped with an error-feedback residual.
    Ef(super::error_feedback::ErrorFeedback),
}

impl GroupCodec {
    fn as_compressor(&mut self) -> &mut dyn Compressor {
        match self {
            GroupCodec::Plain(c) => c.as_mut(),
            GroupCodec::Ef(c) => c,
        }
    }

    fn as_compressor_ref(&self) -> &dyn Compressor {
        match self {
            GroupCodec::Plain(c) => c.as_ref(),
            GroupCodec::Ef(c) => c,
        }
    }

    /// Update distribution state from a fresh local gradient.
    pub fn refit(&mut self, grads: &[f32]) {
        self.as_compressor().refit(grads);
    }

    /// The uniform encode entry point every call site (client fan-out,
    /// mid-tier re-encode, worker rebuild) goes through: plain codecs
    /// compress directly, EF codecs run the feedback loop.
    pub fn encode(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        self.as_compressor().compress_into(grads, rng, out);
    }

    /// Current per-element wire bit-width (see [`Compressor::rate`]).
    pub fn rate(&self) -> u32 {
        self.as_compressor_ref().rate()
    }

    /// Re-target the bit-width from the standing fit (see
    /// [`Compressor::set_rate`]).
    pub fn set_rate(&mut self, bits: u32) {
        self.as_compressor().set_rate(bits);
    }

    /// The network lost this frame for good: EF codecs fold it back into
    /// the residual (plain codecs have no state to repair).
    pub fn restore_lost(&mut self, frame: &[u8]) {
        if let GroupCodec::Ef(c) = self {
            c.restore_lost(frame);
        }
    }

    /// The error-feedback wrapper, when this codec carries one — the
    /// read side of the worker STATE hand-off and checkpoint serializers.
    pub fn ef(&self) -> Option<&super::error_feedback::ErrorFeedback> {
        match self {
            GroupCodec::Plain(_) => None,
            GroupCodec::Ef(c) => Some(c),
        }
    }

    /// Mutable access to the error-feedback wrapper (rejoin/resume state
    /// restore).
    pub fn ef_mut(&mut self) -> Option<&mut super::error_feedback::ErrorFeedback> {
        match self {
            GroupCodec::Plain(_) => None,
            GroupCodec::Ef(c) => Some(c),
        }
    }

    /// Resident bytes of mutable codec state (plain codecs keep only their
    /// fit parameters — O(1), counted as 0 here; EF keeps the residual
    /// working set or its parked frame).
    pub fn state_bytes(&self) -> usize {
        match self {
            GroupCodec::Plain(_) => 0,
            GroupCodec::Ef(c) => c.state_bytes(),
        }
    }

    /// One-line description of current state (for logs).
    pub fn describe(&self) -> String {
        self.as_compressor_ref().describe()
    }
}

/// The single construction point for the scheme × bits × error-feedback
/// wiring. The client fan-out, the mid-tier re-encode, the worker-side
/// rebuild in `run_worker`, and the [`BitBudget`](super::budget::BitBudget)
/// scheduler all build codecs through this builder instead of hand-rolling
/// the `make_compressor` + EF-wrap dance.
#[derive(Clone)]
pub struct CodecBuilder {
    quant: QuantConfig,
}

impl CodecBuilder {
    /// Builder seeded from an experiment's quantization config.
    pub fn from_quant(q: &QuantConfig) -> CodecBuilder {
        CodecBuilder { quant: q.clone() }
    }

    /// Override the per-element bit-width.
    pub fn bits(mut self, bits: u32) -> CodecBuilder {
        self.quant.bits = bits;
        self
    }

    /// Override whether the codec gets an error-feedback wrapper (the
    /// mid-tier re-encode always disables it — partial sums are transient).
    pub fn error_feedback(mut self, ef: bool) -> CodecBuilder {
        self.quant.error_feedback = ef;
        self
    }

    /// Build one codec, EF-wrapped if configured.
    pub fn build(&self) -> GroupCodec {
        let inner = make_compressor(&self.quant);
        if self.quant.error_feedback {
            GroupCodec::Ef(super::error_feedback::ErrorFeedback::new(inner))
        } else {
            GroupCodec::Plain(inner)
        }
    }

    /// Build a bare compressor, ignoring the error-feedback flag.
    pub fn build_plain(&self) -> Box<dyn Compressor> {
        make_compressor(&self.quant)
    }

    /// Build `n` independent codecs (one per layer group).
    pub fn build_many(&self, n: usize) -> Vec<GroupCodec> {
        (0..n).map(|_| self.build()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::quant::wire::Payload;

    fn heavy(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect()
    }

    fn roundtrip(c: &mut dyn Compressor, g: &[f32], rng: &mut Rng) -> Vec<f32> {
        Payload::decode(&c.compress(g, rng)).unwrap().dequantize()
    }

    #[test]
    fn dsgd_is_lossless() {
        let mut rng = Rng::new(1);
        let g = heavy(&mut rng, 1000);
        let out = roundtrip(&mut DsgdCodec, &g, &mut rng);
        assert_eq!(out, g);
    }

    #[test]
    fn all_codecs_preserve_length_and_finiteness() {
        let mut rng = Rng::new(2);
        let g = heavy(&mut rng, 5000);
        let cfgs: Vec<QuantConfig> = Scheme::all()
            .iter()
            .map(|&s| QuantConfig { scheme: s, bits: 3, ..Default::default() })
            .collect();
        for cfg in &cfgs {
            let mut c = make_compressor(cfg);
            c.refit(&g);
            let out = roundtrip(c.as_mut(), &g, &mut rng);
            assert_eq!(out.len(), g.len(), "{}", c.describe());
            assert!(out.iter().all(|x| x.is_finite()), "{}", c.describe());
        }
    }

    #[test]
    fn truncated_schemes_beat_qsgd_mse_on_heavy_tails() {
        // The paper's core claim at the codec level: with b=3 and heavy
        // tails, truncation slashes the quantization MSE.
        let mut rng = Rng::new(3);
        let g: Vec<f32> =
            (0..60_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let mse = |scheme: Scheme| {
            let mut c = make_compressor(&QuantConfig { scheme, bits: 3, ..Default::default() });
            c.refit(&g);
            let mut r = Rng::new(99);
            let out = roundtrip(c.as_mut(), &g, &mut r);
            g.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>()
                / g.len() as f64
        };
        let e_qsgd = mse(Scheme::Qsgd);
        let e_tq = mse(Scheme::Tqsgd);
        let e_tnq = mse(Scheme::Tnqsgd);
        let e_tbq = mse(Scheme::Tbqsgd);
        assert!(e_tq < e_qsgd / 3.0, "tqsgd {e_tq} vs qsgd {e_qsgd}");
        assert!(e_tnq < e_tq * 1.05, "tnqsgd {e_tnq} vs tqsgd {e_tq}");
        assert!(e_tbq < e_qsgd / 3.0, "tbqsgd {e_tbq} vs qsgd {e_qsgd}");
    }

    #[test]
    fn quantized_mean_is_unbiased() {
        // Averaging many independent compressions approaches the true mean
        // when |g| <= alpha (no truncation bias inside the range).
        let mut rng = Rng::new(4);
        let g: Vec<f32> = (0..512).map(|_| (rng.f64() * 0.02 - 0.01) as f32).collect();
        let mut c = TqsgdCodec { s: 7, state: None };
        c.refit(&(0..50_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect::<Vec<_>>());
        let alpha = match &c.state {
            Some(st) => st.alpha,
            None => panic!("fit failed"),
        };
        assert!(alpha > 0.01, "alpha {alpha} should exceed the body");
        let reps = 400;
        let mut acc = vec![0.0f64; g.len()];
        for r in 0..reps {
            let mut rr = Rng::new(1000 + r);
            let out = roundtrip(&mut c, &g, &mut rr);
            for (a, &b) in acc.iter_mut().zip(&out) {
                *a += b as f64;
            }
        }
        let max_err = acc
            .iter()
            .zip(&g)
            .map(|(&a, &b)| (a / reps as f64 - b as f64).abs())
            .fold(0.0f64, f64::max);
        // CLT bound: step/2 / sqrt(reps) * ~4 sigmas.
        let step = 2.0 * alpha / 7.0;
        assert!(max_err < 4.0 * step / (reps as f64).sqrt(), "max_err {max_err}");
    }

    #[test]
    fn topk_keeps_largest() {
        let g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let mut c = TopkCodec::new(0.4);
        let mut rng = Rng::new(5);
        let out = roundtrip(&mut c, &g, &mut rng);
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn terngrad_levels_are_ternary() {
        let mut rng = Rng::new(6);
        let g = heavy(&mut rng, 2000);
        let m = max_abs(&g);
        let out = roundtrip(&mut TerngradCodec, &g, &mut rng);
        for &v in &out {
            assert!(
                v == 0.0 || (v.abs() - m).abs() < 1e-6,
                "non-ternary value {v} (m={m})"
            );
        }
    }

    #[test]
    fn wire_size_matches_bit_budget() {
        let mut rng = Rng::new(7);
        let g = heavy(&mut rng, 10_000);
        for bits in [2u32, 3, 4, 5] {
            let mut c = make_compressor(&QuantConfig {
                scheme: Scheme::Tnqsgd,
                bits,
                ..Default::default()
            });
            c.refit(&g);
            let frame = c.compress(&g, &mut rng);
            let s = solver::levels_for_bits(bits);
            let payload = (g.len() * bits as usize).div_ceil(8);
            let header = 8 + 2 + 4 * (s + 1); // frame hdr + cb len + levels
            assert_eq!(frame.len(), header + payload, "bits={bits}");
        }
    }

    #[test]
    fn property_compress_into_is_byte_identical() {
        // The in-place hot path must be indistinguishable on the wire from
        // the allocating wrapper: same bytes, same RNG stream consumption,
        // for every scheme at every bit width the frame format carries.
        // The reused `buf` stays dirty between iterations to prove
        // `compress_into` fully overwrites it.
        prop::check(10, |rng| {
            let mut buf = vec![0xAAu8; 13];
            let n = 64 + rng.below(2000) as usize;
            let g = prop::gen_gradient(rng, n);
            let salt = rng.next_u64();
            for scheme in Scheme::all() {
                for bits in 1..=8u32 {
                    if scheme == Scheme::Tbqsgd && bits < 2 {
                        continue; // BiScaled needs s >= 3 intervals
                    }
                    if scheme == Scheme::Multiscale && bits < 3 {
                        continue; // two grids need at least 3 bits
                    }
                    let mut c = make_compressor(&QuantConfig {
                        scheme,
                        bits,
                        ..Default::default()
                    });
                    c.refit(&g);
                    let mut r1 = Rng::new(salt);
                    let frame = c.compress(&g, &mut r1);
                    let mut r2 = Rng::new(salt);
                    c.compress_into(&g, &mut r2, &mut buf);
                    if frame != buf {
                        return Err(format!(
                            "{scheme:?} bits={bits}: compress ({} B) != compress_into ({} B)",
                            frame.len(),
                            buf.len()
                        ));
                    }
                    if r1.next_u64() != r2.next_u64() {
                        return Err(format!(
                            "{scheme:?} bits={bits}: RNG streams diverged"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn multiscale_set_rate_rederives_without_refit() {
        let mut rng = Rng::new(11);
        let g: Vec<f32> =
            (0..50_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let mut c = MultiscaleCodec::new(3);
        c.refit(&g);
        let (a3, b3) = match &c.state {
            Some(st) => (st.alpha, st.beta),
            None => panic!("fit failed"),
        };
        assert!(a3 > 0.0 && b3 > 0.0 && b3 < a3, "α={a3} β={b3}");
        c.set_rate(6);
        assert_eq!(c.rate(), 6);
        let st = c.state.as_ref().unwrap();
        // Denser coarse grid ⇒ the Eq. (12)-style optimum moves outward.
        assert!(st.alpha > a3, "α should grow with s: {} vs {a3}", st.alpha);
        assert!(st.beta < st.alpha, "β={} must stay inside α={}", st.beta, st.alpha);
        // Out-of-range requests clamp to the admissible window.
        c.set_rate(0);
        assert_eq!(c.rate(), 3);
        c.set_rate(99);
        assert_eq!(c.rate(), MAX_BITS);
    }

    #[test]
    fn fixed_rate_codecs_ignore_set_rate() {
        let mut rng = Rng::new(12);
        let g = heavy(&mut rng, 2000);
        for scheme in [Scheme::Dsgd, Scheme::Terngrad, Scheme::Topk] {
            assert!(!scheme.rate_adaptive());
            let mut c = make_compressor(&QuantConfig { scheme, bits: 3, ..Default::default() });
            c.refit(&g);
            let before = c.rate();
            let mut r1 = Rng::new(77);
            let f1 = c.compress(&g, &mut r1);
            c.set_rate(7);
            assert_eq!(c.rate(), before, "{scheme:?}");
            let mut r2 = Rng::new(77);
            let f2 = c.compress(&g, &mut r2);
            assert_eq!(f1, f2, "{scheme:?} frame changed after set_rate");
        }
    }

    #[test]
    fn adaptive_set_rate_matches_fresh_construction_bytes() {
        // set_rate on a fitted codec must land on the same wire bytes as a
        // codec built at that width and refit on the same gradient — the
        // scheduler depends on this equivalence when it re-targets rates
        // mid-run without refitting.
        let mut rng = Rng::new(13);
        let g: Vec<f32> =
            (0..40_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        for scheme in
            [Scheme::Qsgd, Scheme::Tqsgd, Scheme::Tnqsgd, Scheme::Tbqsgd, Scheme::Multiscale]
        {
            let mut retuned =
                make_compressor(&QuantConfig { scheme, bits: 3, ..Default::default() });
            retuned.refit(&g);
            retuned.set_rate(5);
            let mut fresh =
                make_compressor(&QuantConfig { scheme, bits: 5, ..Default::default() });
            fresh.refit(&g);
            let mut r1 = Rng::new(88);
            let mut r2 = Rng::new(88);
            assert_eq!(
                retuned.compress(&g, &mut r1),
                fresh.compress(&g, &mut r2),
                "{scheme:?}: set_rate(5) != fresh bits=5"
            );
        }
    }

    #[test]
    fn property_roundtrip_all_schemes() {
        prop::check(40, |rng| {
            let g = prop::gen_gradient(rng, 4096);
            for &scheme in &[
                Scheme::Dsgd,
                Scheme::Qsgd,
                Scheme::Tqsgd,
                Scheme::Tnqsgd,
                Scheme::Tbqsgd,
                Scheme::Terngrad,
                Scheme::Topk,
                Scheme::Multiscale,
            ] {
                let mut c = make_compressor(&QuantConfig {
                    scheme,
                    bits: 2 + (rng.below(4)) as u32,
                    ..Default::default()
                });
                c.refit(&g);
                let bytes = c.compress(&g, rng);
                let out = Payload::decode(&bytes)
                    .map_err(|e| format!("{scheme:?} decode: {e}"))?
                    .dequantize();
                if out.len() != g.len() {
                    return Err(format!("{scheme:?}: length {} vs {}", out.len(), g.len()));
                }
                if !out.iter().all(|x| x.is_finite()) {
                    return Err(format!("{scheme:?}: non-finite output"));
                }
            }
            Ok(())
        });
    }
}
