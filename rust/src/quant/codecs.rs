//! Gradient compressors: the paper's three truncated quantizers and every
//! baseline it compares against (Sec. V), all producing wire frames.
//!
//! | Codec | Paper role | Density | Truncation |
//! |-------|-----------|---------|------------|
//! | [`DsgdCodec`]     | oracle        | —                 | — |
//! | [`QsgdCodec`]     | baseline [5]  | uniform           | none (range = max\|g\|) |
//! | [`NqsgdCodec`]    | baseline      | p^{1/3}           | none (range = max\|g\|) |
//! | [`TqsgdCodec`]    | Thm. 1        | uniform           | α from Eq. (12) |
//! | [`TnqsgdCodec`]   | Thm. 2        | p^{1/3} (Eq. 18)  | α from Eq. (19) |
//! | [`TbqsgdCodec`]   | Thm. 3/App. D | BiScaled (Eq. 25) | α from Eq. (33) |
//! | [`TerngradCodec`] | baseline [17] | ternary           | none |
//! | [`TopkCodec`]     | baseline [3]  | sparse            | — |
//!
//! Distribution-aware codecs (`Nqsgd`, `Tqsgd`, `Tnqsgd`, `Tbqsgd`) carry a
//! fitted [`PowerLawModel`]; [`Compressor::refit`] re-estimates it from the
//! latest local gradient (the coordinator calls this every
//! `estimate_every` rounds per layer group, mirroring the paper's per-layer
//! γ MLE).

use crate::config::{QuantConfig, Scheme};
use crate::solver;
use crate::tail::{fit::report_to_model, fit_power_law_sampled, PowerLawModel, REFIT_SAMPLE_CAP};
use crate::util::Rng;

use super::kernels::{max_abs, quantize_codebook_pack_into, quantize_uniform_pack_into};
use super::wire;

/// A gradient compressor: stateful (distribution estimates), one per
/// (client, layer-group).
pub trait Compressor: Send {
    /// Which compression scheme this codec implements.
    fn scheme(&self) -> Scheme;

    /// Update distribution state from a fresh local gradient.
    fn refit(&mut self, grads: &[f32]);

    /// Compress into a caller-provided frame buffer (cleared first). `rng`
    /// drives the stochastic rounding. This is the steady-state hot path:
    /// with a recycled `out` of sufficient capacity (see
    /// [`FrameArena`](super::FrameArena)) it performs zero heap allocation.
    /// `&mut self` lets codecs keep internal scratch (e.g. Top-k's
    /// selection buffers); distribution state only changes via `refit`.
    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>);

    /// Convenience wrapper over [`Compressor::compress_into`] that allocates
    /// a fresh frame — byte- and RNG-stream-identical to the in-place path.
    fn compress(&mut self, grads: &[f32], rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::new();
        self.compress_into(grads, rng, &mut out);
        out
    }

    /// One-line description of current state (for logs).
    fn describe(&self) -> String;
}

/// Build the codec for a scheme.
pub fn make_compressor(cfg: &QuantConfig) -> Box<dyn Compressor> {
    let s = solver::levels_for_bits(cfg.bits) as u32;
    match cfg.scheme {
        Scheme::Dsgd => Box::new(DsgdCodec),
        Scheme::Qsgd => Box::new(QsgdCodec { s }),
        Scheme::Nqsgd => Box::new(NqsgdCodec { s, model: None }),
        Scheme::Tqsgd => Box::new(TqsgdCodec { s, state: None }),
        Scheme::Tnqsgd => Box::new(TnqsgdCodec { s, state: None }),
        Scheme::Tbqsgd => Box::new(TbqsgdCodec { s, state: None }),
        Scheme::Terngrad => Box::new(TerngradCodec),
        Scheme::Topk => Box::new(TopkCodec::new(cfg.topk_frac)),
    }
}

/// Smallest index bit-width that can hold levels 0..=s.
fn bits_for(s: u32) -> u32 {
    32 - s.leading_zeros()
}

// ---------------------------------------------------------------------------
// Oracle
// ---------------------------------------------------------------------------

/// DSGD: uncompressed 32-bit gradients.
pub struct DsgdCodec;

impl Compressor for DsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Dsgd
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], _rng: &mut Rng, out: &mut Vec<u8>) {
        // Straight from the borrowed slice — no `grads.to_vec()` staging copy.
        wire::encode_raw_into(grads, out);
    }

    fn describe(&self) -> String {
        "dsgd(fp32)".into()
    }
}

// ---------------------------------------------------------------------------
// Untruncated baselines
// ---------------------------------------------------------------------------

/// QSGD: uniform stochastic quantization over the FULL range [−max|g|,
/// max|g|] — no truncation, so one outlier stretches every interval.  This
/// is exactly why it collapses at b = 3 on heavy-tailed gradients (Fig. 3).
pub struct QsgdCodec {
    s: u32,
}

impl Compressor for QsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Qsgd
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
        let bits = bits_for(self.s);
        wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
        quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
    }

    fn describe(&self) -> String {
        format!("qsgd(s={}, range=max|g|)", self.s)
    }
}

/// NQSGD: non-uniform (p^{1/3}) quantization over the full range, no
/// truncation. Needs a fitted tail model to shape the codebook; before the
/// first refit it degrades to QSGD.
pub struct NqsgdCodec {
    s: u32,
    model: Option<PowerLawModel>,
}

impl Compressor for NqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Nqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(rep) = fit_power_law_sampled(grads, REFIT_SAMPLE_CAP) {
            self.model = Some(report_to_model(&rep));
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let range = max_abs(grads).max(f32::MIN_POSITIVE) as f64;
        let bits = bits_for(self.s);
        match &self.model {
            Some(m) if range > m.g_min => {
                let cb = solver::nonuniform_codebook(m, range, self.s as usize);
                wire::begin_codebook_frame(out, &cb, grads.len() as u32, bits);
                quantize_codebook_pack_into(grads, rng, &cb, bits, out);
            }
            _ => {
                wire::begin_uniform_frame(
                    out,
                    range as f32,
                    self.s as u16,
                    grads.len() as u32,
                    bits,
                );
                quantize_uniform_pack_into(grads, rng, range as f32, self.s, bits, out);
            }
        }
    }

    fn describe(&self) -> String {
        match &self.model {
            Some(m) => format!("nqsgd(s={}, γ̂={:.2})", self.s, m.gamma),
            None => format!("nqsgd(s={}, unfitted→qsgd)", self.s),
        }
    }
}

// ---------------------------------------------------------------------------
// The paper's truncated quantizers
// ---------------------------------------------------------------------------

struct TruncState {
    model: PowerLawModel,
    alpha: f64,
    /// Materialized codebook (None for the uniform TQSGD).
    codebook: Option<Vec<f32>>,
}

/// Fit the tail model, clamping γ into the paper's admissible (3, 5] range —
/// the Eq. (11) error terms are only finite for γ > 3, and empirical fits of
/// conv-layer gradients occasionally stray below. Uses the deterministic
/// sampled fit (capped at [`REFIT_SAMPLE_CAP`] points), so a per-round refit
/// costs ~O(d) instead of the full-sort O(d log d).
fn fit_clamped(grads: &[f32]) -> Option<PowerLawModel> {
    let rep = fit_power_law_sampled(grads, REFIT_SAMPLE_CAP)?;
    let mut m = report_to_model(&rep);
    m.gamma = m.gamma.clamp(3.05, 5.0);
    Some(m)
}

/// TQSGD (Thm. 1): truncation at the Eq. (12) α, uniform density.
pub struct TqsgdCodec {
    s: u32,
    state: Option<TruncState>,
}

impl Compressor for TqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Tqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            let alpha = solver::optimal_alpha_uniform(&model, self.s as usize);
            self.state = Some(TruncState { model, alpha, codebook: None });
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let alpha = match &self.state {
            Some(st) => st.alpha as f32,
            None => max_abs(grads).max(f32::MIN_POSITIVE), // pre-fit fallback
        };
        let bits = bits_for(self.s);
        wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
        quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "tqsgd(s={}, α={:.4}, γ̂={:.2})",
                self.s, st.alpha, st.model.gamma
            ),
            None => format!("tqsgd(s={}, unfitted)", self.s),
        }
    }
}

/// TNQSGD (Thm. 2): truncation at the Eq. (19) α, p^{1/3} density (Eq. 18).
pub struct TnqsgdCodec {
    s: u32,
    state: Option<TruncState>,
}

impl Compressor for TnqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Tnqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            let alpha = solver::optimal_alpha_nonuniform(&model, self.s as usize);
            let cb = solver::nonuniform_codebook(&model, alpha, self.s as usize);
            self.state = Some(TruncState { model, alpha, codebook: Some(cb) });
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let bits = bits_for(self.s);
        match &self.state {
            Some(st) => {
                let cb = st.codebook.as_ref().unwrap();
                wire::begin_codebook_frame(out, cb, grads.len() as u32, bits);
                quantize_codebook_pack_into(grads, rng, cb, bits, out);
            }
            None => {
                let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
                wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
                quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
            }
        }
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "tnqsgd(s={}, α={:.4}, γ̂={:.2})",
                self.s, st.alpha, st.model.gamma
            ),
            None => format!("tnqsgd(s={}, unfitted)", self.s),
        }
    }
}

/// TBQSGD (Thm. 3 / Appendix D): BiScaled two-region density.
pub struct TbqsgdCodec {
    s: u32,
    state: Option<TruncState>,
}

impl Compressor for TbqsgdCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Tbqsgd
    }

    fn refit(&mut self, grads: &[f32]) {
        if let Some(model) = fit_clamped(grads) {
            let design = solver::solve_biscaled(&model, self.s as usize);
            let cb = design.codebook();
            self.state =
                Some(TruncState { model, alpha: design.alpha, codebook: Some(cb) });
        }
    }

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let bits = bits_for(self.s);
        match &self.state {
            Some(st) => {
                let cb = st.codebook.as_ref().unwrap();
                wire::begin_codebook_frame(out, cb, grads.len() as u32, bits);
                quantize_codebook_pack_into(grads, rng, cb, bits, out);
            }
            None => {
                let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
                wire::begin_uniform_frame(out, alpha, self.s as u16, grads.len() as u32, bits);
                quantize_uniform_pack_into(grads, rng, alpha, self.s, bits, out);
            }
        }
    }

    fn describe(&self) -> String {
        match &self.state {
            Some(st) => format!(
                "tbqsgd(s={}, α={:.4}, γ̂={:.2})",
                self.s, st.alpha, st.model.gamma
            ),
            None => format!("tbqsgd(s={}, unfitted)", self.s),
        }
    }
}

// ---------------------------------------------------------------------------
// Other baselines
// ---------------------------------------------------------------------------

/// TernGrad (Wen et al. 2017): stochastic ternary levels {−m, 0, +m} with
/// m = max|g| — equivalently the uniform stochastic quantizer with s = 2.
pub struct TerngradCodec;

impl Compressor for TerngradCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Terngrad
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        let alpha = max_abs(grads).max(f32::MIN_POSITIVE);
        wire::begin_uniform_frame(out, alpha, 2, grads.len() as u32, 2);
        quantize_uniform_pack_into(grads, rng, alpha, 2, 2, out);
    }

    fn describe(&self) -> String {
        "terngrad(s=2)".into()
    }
}

/// Top-k sparsification: keep the `frac` largest-|g| entries exactly.
pub struct TopkCodec {
    frac: f64,
    /// Selection scratch, reused across rounds (zero steady-state allocs).
    order: Vec<u32>,
    /// (index, value) scratch, reused across rounds.
    pairs: Vec<(u32, f32)>,
}

impl TopkCodec {
    /// Codec keeping the `frac` largest-|g| entries.
    pub fn new(frac: f64) -> TopkCodec {
        TopkCodec { frac, order: Vec::new(), pairs: Vec::new() }
    }
}

impl Compressor for TopkCodec {
    fn scheme(&self) -> Scheme {
        Scheme::Topk
    }

    fn refit(&mut self, _grads: &[f32]) {}

    fn compress_into(&mut self, grads: &[f32], _rng: &mut Rng, out: &mut Vec<u8>) {
        let k = ((grads.len() as f64 * self.frac).ceil() as usize)
            .clamp(1, grads.len());
        self.order.clear();
        self.order.extend(0..grads.len() as u32);
        self.order.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            grads[b as usize]
                .abs()
                .partial_cmp(&grads[a as usize].abs())
                .unwrap()
        });
        self.pairs.clear();
        self.pairs.extend(self.order[..k].iter().map(|&i| (i, grads[i as usize])));
        self.pairs.sort_unstable_by_key(|&(i, _)| i);
        wire::encode_sparse_into(grads.len() as u32, &self.pairs, out);
    }

    fn describe(&self) -> String {
        format!("topk({:.2}%)", self.frac * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::quant::wire::Payload;

    fn heavy(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.student_t(3.0) * 0.01) as f32).collect()
    }

    fn roundtrip(c: &mut dyn Compressor, g: &[f32], rng: &mut Rng) -> Vec<f32> {
        Payload::decode(&c.compress(g, rng)).unwrap().dequantize()
    }

    #[test]
    fn dsgd_is_lossless() {
        let mut rng = Rng::new(1);
        let g = heavy(&mut rng, 1000);
        let out = roundtrip(&mut DsgdCodec, &g, &mut rng);
        assert_eq!(out, g);
    }

    #[test]
    fn all_codecs_preserve_length_and_finiteness() {
        let mut rng = Rng::new(2);
        let g = heavy(&mut rng, 5000);
        let cfgs: Vec<QuantConfig> = Scheme::all()
            .iter()
            .map(|&s| QuantConfig { scheme: s, bits: 3, ..Default::default() })
            .collect();
        for cfg in &cfgs {
            let mut c = make_compressor(cfg);
            c.refit(&g);
            let out = roundtrip(c.as_mut(), &g, &mut rng);
            assert_eq!(out.len(), g.len(), "{}", c.describe());
            assert!(out.iter().all(|x| x.is_finite()), "{}", c.describe());
        }
    }

    #[test]
    fn truncated_schemes_beat_qsgd_mse_on_heavy_tails() {
        // The paper's core claim at the codec level: with b=3 and heavy
        // tails, truncation slashes the quantization MSE.
        let mut rng = Rng::new(3);
        let g: Vec<f32> =
            (0..60_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let mse = |scheme: Scheme| {
            let mut c = make_compressor(&QuantConfig { scheme, bits: 3, ..Default::default() });
            c.refit(&g);
            let mut r = Rng::new(99);
            let out = roundtrip(c.as_mut(), &g, &mut r);
            g.iter().zip(&out).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>()
                / g.len() as f64
        };
        let e_qsgd = mse(Scheme::Qsgd);
        let e_tq = mse(Scheme::Tqsgd);
        let e_tnq = mse(Scheme::Tnqsgd);
        let e_tbq = mse(Scheme::Tbqsgd);
        assert!(e_tq < e_qsgd / 3.0, "tqsgd {e_tq} vs qsgd {e_qsgd}");
        assert!(e_tnq < e_tq * 1.05, "tnqsgd {e_tnq} vs tqsgd {e_tq}");
        assert!(e_tbq < e_qsgd / 3.0, "tbqsgd {e_tbq} vs qsgd {e_qsgd}");
    }

    #[test]
    fn quantized_mean_is_unbiased() {
        // Averaging many independent compressions approaches the true mean
        // when |g| <= alpha (no truncation bias inside the range).
        let mut rng = Rng::new(4);
        let g: Vec<f32> = (0..512).map(|_| (rng.f64() * 0.02 - 0.01) as f32).collect();
        let mut c = TqsgdCodec { s: 7, state: None };
        c.refit(&(0..50_000).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect::<Vec<_>>());
        let alpha = match &c.state {
            Some(st) => st.alpha,
            None => panic!("fit failed"),
        };
        assert!(alpha > 0.01, "alpha {alpha} should exceed the body");
        let reps = 400;
        let mut acc = vec![0.0f64; g.len()];
        for r in 0..reps {
            let mut rr = Rng::new(1000 + r);
            let out = roundtrip(&mut c, &g, &mut rr);
            for (a, &b) in acc.iter_mut().zip(&out) {
                *a += b as f64;
            }
        }
        let max_err = acc
            .iter()
            .zip(&g)
            .map(|(&a, &b)| (a / reps as f64 - b as f64).abs())
            .fold(0.0f64, f64::max);
        // CLT bound: step/2 / sqrt(reps) * ~4 sigmas.
        let step = 2.0 * alpha / 7.0;
        assert!(max_err < 4.0 * step / (reps as f64).sqrt(), "max_err {max_err}");
    }

    #[test]
    fn topk_keeps_largest() {
        let g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let mut c = TopkCodec::new(0.4);
        let mut rng = Rng::new(5);
        let out = roundtrip(&mut c, &g, &mut rng);
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn terngrad_levels_are_ternary() {
        let mut rng = Rng::new(6);
        let g = heavy(&mut rng, 2000);
        let m = max_abs(&g);
        let out = roundtrip(&mut TerngradCodec, &g, &mut rng);
        for &v in &out {
            assert!(
                v == 0.0 || (v.abs() - m).abs() < 1e-6,
                "non-ternary value {v} (m={m})"
            );
        }
    }

    #[test]
    fn wire_size_matches_bit_budget() {
        let mut rng = Rng::new(7);
        let g = heavy(&mut rng, 10_000);
        for bits in [2u32, 3, 4, 5] {
            let mut c = make_compressor(&QuantConfig {
                scheme: Scheme::Tnqsgd,
                bits,
                ..Default::default()
            });
            c.refit(&g);
            let frame = c.compress(&g, &mut rng);
            let s = solver::levels_for_bits(bits);
            let payload = (g.len() * bits as usize).div_ceil(8);
            let header = 8 + 2 + 4 * (s + 1); // frame hdr + cb len + levels
            assert_eq!(frame.len(), header + payload, "bits={bits}");
        }
    }

    #[test]
    fn property_compress_into_is_byte_identical() {
        // The in-place hot path must be indistinguishable on the wire from
        // the allocating wrapper: same bytes, same RNG stream consumption,
        // for every scheme at every bit width the frame format carries.
        // The reused `buf` stays dirty between iterations to prove
        // `compress_into` fully overwrites it.
        prop::check(10, |rng| {
            let mut buf = vec![0xAAu8; 13];
            let n = 64 + rng.below(2000) as usize;
            let g = prop::gen_gradient(rng, n);
            let salt = rng.next_u64();
            for scheme in Scheme::all() {
                for bits in 1..=8u32 {
                    if scheme == Scheme::Tbqsgd && bits < 2 {
                        continue; // BiScaled needs s >= 3 intervals
                    }
                    let mut c = make_compressor(&QuantConfig {
                        scheme,
                        bits,
                        ..Default::default()
                    });
                    c.refit(&g);
                    let mut r1 = Rng::new(salt);
                    let frame = c.compress(&g, &mut r1);
                    let mut r2 = Rng::new(salt);
                    c.compress_into(&g, &mut r2, &mut buf);
                    if frame != buf {
                        return Err(format!(
                            "{scheme:?} bits={bits}: compress ({} B) != compress_into ({} B)",
                            frame.len(),
                            buf.len()
                        ));
                    }
                    if r1.next_u64() != r2.next_u64() {
                        return Err(format!(
                            "{scheme:?} bits={bits}: RNG streams diverged"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_roundtrip_all_schemes() {
        prop::check(40, |rng| {
            let g = prop::gen_gradient(rng, 4096);
            for &scheme in &[
                Scheme::Dsgd,
                Scheme::Qsgd,
                Scheme::Tqsgd,
                Scheme::Tnqsgd,
                Scheme::Tbqsgd,
                Scheme::Terngrad,
                Scheme::Topk,
            ] {
                let mut c = make_compressor(&QuantConfig {
                    scheme,
                    bits: 2 + (rng.below(4)) as u32,
                    ..Default::default()
                });
                c.refit(&g);
                let bytes = c.compress(&g, rng);
                let out = Payload::decode(&bytes)
                    .map_err(|e| format!("{scheme:?} decode: {e}"))?
                    .dequantize();
                if out.len() != g.len() {
                    return Err(format!("{scheme:?}: length {} vs {}", out.len(), g.len()));
                }
                if !out.iter().all(|x| x.is_finite()) {
                    return Err(format!("{scheme:?}: non-finite output"));
                }
            }
            Ok(())
        });
    }
}
