//! Server-side optimizer (paper §V: momentum SGD, lr 0.01, momentum 0.9,
//! weight decay 5e-4) over the flat parameter vector, plus LR schedules.
//!
//! The optimizer lives in rust because the coordinator owns the global
//! model: the AOT graph computes (loss, grads) only.

/// Momentum SGD with (decoupled-from-graph) L2 weight decay:
///
/// ```text
/// v ← μ v + (g + λ θ)
/// θ ← θ − η v
/// ```
pub struct MomentumSgd {
    /// Learning rate η.
    pub lr: f64,
    /// Momentum coefficient μ ∈ [0, 1).
    pub momentum: f64,
    /// L2 weight-decay coefficient λ.
    pub weight_decay: f64,
    velocity: Vec<f32>,
}

impl MomentumSgd {
    /// A fresh optimizer for a `dim`-element parameter vector (zero velocity).
    pub fn new(dim: usize, lr: f64, momentum: f64, weight_decay: f64) -> Self {
        assert!(lr > 0.0 && (0.0..1.0).contains(&momentum) && weight_decay >= 0.0);
        MomentumSgd { lr, momentum, weight_decay, velocity: vec![0.0; dim] }
    }

    /// One update in place. `grads.len() == params.len()`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.velocity.len());
        let mu = self.momentum as f32;
        let lr = self.lr as f32;
        let wd = self.weight_decay as f32;
        for ((p, &g), v) in params.iter_mut().zip(grads).zip(self.velocity.iter_mut()) {
            let eff = g + wd * *p;
            *v = mu * *v + eff;
            *p -= lr * *v;
        }
    }

    /// Override the learning rate (the schedule calls this per round).
    pub fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    /// The momentum buffer (checkpoint serialization path).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Restore a momentum buffer snapshot (checkpoint resume path). The
    /// length must match the parameter vector the optimizer was built for.
    pub fn set_velocity(&mut self, velocity: Vec<f32>) {
        assert_eq!(velocity.len(), self.velocity.len(), "velocity length mismatch");
        self.velocity = velocity;
    }
}

/// Learning-rate schedule.
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    /// The base learning rate at every round.
    Constant,
    /// Multiply by `factor` every `every` rounds.
    Step { every: usize, factor: f64 },
    /// Linear warmup for `warmup` rounds then constant.
    Warmup { warmup: usize },
}

impl LrSchedule {
    /// The learning rate this schedule yields at `round` given `base`.
    pub fn lr_at(&self, base: f64, round: usize) -> f64 {
        match *self {
            LrSchedule::Constant => base,
            LrSchedule::Step { every, factor } => {
                base * factor.powi((round / every.max(1)) as i32)
            }
            LrSchedule::Warmup { warmup } => {
                if round < warmup {
                    base * (round + 1) as f64 / warmup as f64
                } else {
                    base
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_descends_quadratic() {
        // Minimize f(θ) = ||θ||² / 2; grad = θ.
        let mut opt = MomentumSgd::new(4, 0.1, 0.9, 0.0);
        let mut p = vec![1.0f32, -2.0, 3.0, -4.0];
        for _ in 0..200 {
            let g = p.clone();
            opt.step(&mut p, &g);
        }
        assert!(p.iter().all(|&x| x.abs() < 1e-3), "{p:?}");
    }

    #[test]
    fn momentum_accelerates() {
        // On an ill-conditioned quadratic, momentum reaches a tighter ball
        // in the same number of steps than plain SGD.
        let run = |mu: f64| {
            let mut opt = MomentumSgd::new(2, 0.02, mu, 0.0);
            let mut p = vec![10.0f32, 10.0];
            for _ in 0..300 {
                let g = vec![p[0] * 0.1, p[1] * 2.0];
                opt.step(&mut p, &g);
            }
            (p[0].abs() + p[1].abs()) as f64
        };
        assert!(run(0.9) < run(0.0), "momentum should help");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = MomentumSgd::new(1, 0.1, 0.0, 0.1);
        let mut p = vec![1.0f32];
        for _ in 0..100 {
            opt.step(&mut p, &[0.0]); // zero gradient: pure decay
        }
        assert!(p[0] < 0.5 && p[0] > 0.0, "{}", p[0]);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut opt = MomentumSgd::new(2, 0.1, 0.9, 0.0);
        let mut p = vec![0.0f32; 2];
        opt.step(&mut p, &[0.0; 3]);
    }

    #[test]
    fn schedules() {
        let s = LrSchedule::Step { every: 10, factor: 0.5 };
        assert_eq!(s.lr_at(1.0, 0), 1.0);
        assert_eq!(s.lr_at(1.0, 10), 0.5);
        assert_eq!(s.lr_at(1.0, 25), 0.25);
        let w = LrSchedule::Warmup { warmup: 4 };
        assert_eq!(w.lr_at(1.0, 0), 0.25);
        assert_eq!(w.lr_at(1.0, 3), 1.0);
        assert_eq!(w.lr_at(1.0, 100), 1.0);
        assert_eq!(LrSchedule::Constant.lr_at(0.3, 99), 0.3);
    }
}
