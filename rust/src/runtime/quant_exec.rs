//! L1↔L3 parity path (cargo feature `pjrt`): run the Pallas quantizer
//! kernels through PJRT.
//!
//! The rust codecs in `quant::kernels` are the production encode path; this
//! wrapper executes the SAME computation through the AOT-compiled Pallas
//! artifact (`quant_uniform_b*`, `quant_nonuniform_b3`, `quant_biscaled_b3`,
//! `tail_stats`) so integration tests and the perf bench can prove the two
//! implementations agree bit-for-bit on indices given identical uniforms.
//! It implements [`QuantKernel`], the same interface the native kernels
//! expose, so parity harnesses are backend-generic.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::backend::QuantKernel;
use super::pjrt::{Executable, Runtime};

/// Pallas quantizer executor over the fixed manifest tile.
pub struct QuantExec {
    exe: Rc<Executable>,
    /// Fixed tile length the artifact was compiled for.
    pub tile: usize,
}

impl QuantExec {
    /// `entry` is e.g. `"quant_uniform_b3"`.
    pub fn new(rt: &Runtime, entry: &str) -> Result<QuantExec> {
        let exe = rt.load(entry)?;
        let tile = exe
            .spec
            .inputs
            .first()
            .and_then(|t| t.shape.first().copied())
            .ok_or_else(|| anyhow!("{entry}: no tile dimension"))?;
        Ok(QuantExec { exe, tile })
    }

    /// Uniform kernel: returns (dequantized, indices) for one tile.
    /// Allocating wrapper over the trait's `run_uniform_into`.
    pub fn run_uniform(&self, g: &[f32], u: &[f32], alpha: f32) -> Result<(Vec<f32>, Vec<u32>)> {
        let mut deq = Vec::new();
        let mut idx = Vec::new();
        QuantKernel::run_uniform_into(self, g, u, alpha, &mut deq, &mut idx)?;
        Ok((deq, idx))
    }

    /// Codebook kernel (`quant_nonuniform_b3`): codebook length must match
    /// the artifact (s+1). Allocating wrapper over `run_codebook_into`.
    pub fn run_codebook(
        &self,
        g: &[f32],
        u: &[f32],
        codebook: &[f32],
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        let mut deq = Vec::new();
        let mut idx = Vec::new();
        QuantKernel::run_codebook_into(self, g, u, codebook, &mut deq, &mut idx)?;
        Ok((deq, idx))
    }

    /// BiScaled kernel (`quant_biscaled_b3`).
    pub fn run_biscaled(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        self.check(g, u)?;
        let out = self.exe.run(&[g, u, &[alpha, beta]])?;
        Ok((out[0].clone(), out[1].iter().map(|&x| x as u32).collect()))
    }

    /// `tail_stats` kernel: [n_tail, sum_log, sum_abs, sum_sq, abs_max].
    pub fn run_stats(&self, g: &[f32], g_min: f32) -> Result<Vec<f32>> {
        if g.len() != self.tile {
            return Err(anyhow!("tile mismatch: {} vs {}", g.len(), self.tile));
        }
        Ok(self.exe.run(&[g, &[g_min]])?.remove(0))
    }

    fn check(&self, g: &[f32], u: &[f32]) -> Result<()> {
        if g.len() != self.tile || u.len() != self.tile {
            return Err(anyhow!(
                "tile mismatch: g={} u={} tile={}",
                g.len(),
                u.len(),
                self.tile
            ));
        }
        Ok(())
    }
}

impl QuantKernel for QuantExec {
    fn tile(&self) -> usize {
        self.tile
    }

    fn run_uniform(&self, g: &[f32], u: &[f32], alpha: f32) -> Result<(Vec<f32>, Vec<u32>)> {
        QuantExec::run_uniform(self, g, u, alpha)
    }

    fn run_uniform_into(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        deq: &mut Vec<f32>,
        idx: &mut Vec<u32>,
    ) -> Result<()> {
        // Mirror of the codec layer's `*_into` discipline: reuse the
        // caller's buffers instead of cloning the PJRT outputs.
        self.check(g, u)?;
        let out = self.exe.run(&[g, u, &[alpha]])?;
        deq.clear();
        deq.extend_from_slice(&out[0]);
        idx.clear();
        idx.extend(out[1].iter().map(|&x| x as u32));
        Ok(())
    }

    fn run_codebook(
        &self,
        g: &[f32],
        u: &[f32],
        codebook: &[f32],
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        QuantExec::run_codebook(self, g, u, codebook)
    }

    fn run_codebook_into(
        &self,
        g: &[f32],
        u: &[f32],
        codebook: &[f32],
        deq: &mut Vec<f32>,
        idx: &mut Vec<u32>,
    ) -> Result<()> {
        self.check(g, u)?;
        let out = self.exe.run(&[g, u, codebook])?;
        deq.clear();
        deq.extend_from_slice(&out[0]);
        idx.clear();
        idx.extend(out[1].iter().map(|&x| x as u32));
        Ok(())
    }

    fn run_biscaled(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        QuantExec::run_biscaled(self, g, u, alpha, beta)
    }

    fn run_stats(&self, g: &[f32], g_min: f32) -> Result<Vec<f32>> {
        QuantExec::run_stats(self, g, g_min)
    }
}
