//! Pure-Rust reference backend: model fwd/bwd and quantizer kernels with no
//! Python, XLA or PJRT anywhere in the loop.
//!
//! This is the default compute path. It ships surrogate architectures under
//! the same model names the AOT manifest exports, so every preset, bench and
//! example runs from a clean checkout:
//!
//! | name        | native architecture                    | groups        |
//! |-------------|----------------------------------------|---------------|
//! | `mlp`       | 784 → 128 → 10 ReLU MLP                | `fc1` / `fc2` |
//! | `mlp_tiny`  | 784 → 16 → 10 ReLU MLP (test-sized)    | `fc1` / `fc2` |
//! | `cnn`       | 784 → 256 → 64 → 10 ReLU MLP           | `conv` / `fc` |
//! | `tfm_small` | factored bigram LM (emb 32, vocab 64)  | `emb` / `fc`  |
//!
//! (`cnn`'s first layer stands in for the conv feature extractor so the
//! paper's per-group conv/fc quantization split is preserved; `tfm_small` is
//! a factored bigram model — the exact Bayes-optimal family for the Markov
//! corpus the LM task trains on.)
//!
//! Forward/backward accumulate in `f64` (params and gradients stay `f32` at
//! the interface), which makes the finite-difference gradient check in the
//! integration suite tight and keeps training bit-deterministic.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::data::{IMG_PIXELS, NUM_CLASSES};
use crate::quant::kernels;
use crate::util::rng::hash_seed;
use crate::util::Rng;

use super::backend::{Backend, EvalResult, GradResult, QuantKernel};
use super::manifest::{GroupRange, ModelSpec};

/// Architecture of a native model.
#[derive(Clone, Debug)]
enum Arch {
    /// Fully-connected ReLU classifier; `dims = [input, hidden.., classes]`.
    Mlp { dims: Vec<usize> },
    /// Factored bigram LM: `logits = W · emb[token] + b`.
    BigramLm { vocab: usize, dim: usize },
}

#[derive(Clone, Debug)]
struct NativeModel {
    spec: ModelSpec,
    arch: Arch,
}

/// The pure-Rust compute backend (see module docs).
pub struct NativeBackend {
    models: BTreeMap<String, NativeModel>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// Build the backend with its built-in model zoo.
    pub fn new() -> NativeBackend {
        let mut models = BTreeMap::new();
        add_mlp(&mut models, "mlp", &[IMG_PIXELS, 128, NUM_CLASSES], 64, 256, ["fc1", "fc2"]);
        add_mlp(&mut models, "mlp_tiny", &[IMG_PIXELS, 16, NUM_CLASSES], 16, 128, ["fc1", "fc2"]);
        add_mlp(&mut models, "cnn", &[IMG_PIXELS, 256, 64, NUM_CLASSES], 64, 256, ["conv", "fc"]);
        add_bigram(&mut models, "tfm_small", 64, 32, 16, 32);
        NativeBackend { models }
    }

    fn get(&self, name: &str) -> Result<&NativeModel> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not available on the native backend (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn add_mlp(
    models: &mut BTreeMap<String, NativeModel>,
    name: &str,
    dims: &[usize],
    train_batch: usize,
    eval_batch: usize,
    group_names: [&str; 2],
) {
    let layer_size = |w: &[usize]| w[0] * w[1] + w[1];
    let first: usize = layer_size(&[dims[0], dims[1]]);
    let rest: usize = dims[1..].windows(2).map(layer_size).sum();
    let spec = ModelSpec {
        kind: "classifier".to_string(),
        param_count: first + rest,
        groups: vec![
            GroupRange { group: group_names[0].to_string(), start: 0, end: first },
            GroupRange { group: group_names[1].to_string(), start: first, end: first + rest },
        ],
        train_batch,
        eval_batch,
        input_dim: dims[0],
        seq_len: 0,
        vocab: *dims.last().unwrap(),
        init_file: String::new(),
        grad_entry: String::new(),
        eval_entry: String::new(),
    };
    let model = NativeModel { spec, arch: Arch::Mlp { dims: dims.to_vec() } };
    models.insert(name.to_string(), model);
}

fn add_bigram(
    models: &mut BTreeMap<String, NativeModel>,
    name: &str,
    vocab: usize,
    dim: usize,
    train_batch: usize,
    seq_len: usize,
) {
    let emb = vocab * dim;
    let fc = dim * vocab + vocab;
    let spec = ModelSpec {
        kind: "lm".to_string(),
        param_count: emb + fc,
        groups: vec![
            GroupRange { group: "emb".to_string(), start: 0, end: emb },
            GroupRange { group: "fc".to_string(), start: emb, end: emb + fc },
        ],
        train_batch,
        eval_batch: train_batch,
        input_dim: 0,
        seq_len,
        vocab,
        init_file: String::new(),
        grad_entry: String::new(),
        eval_entry: String::new(),
    };
    let model = NativeModel { spec, arch: Arch::BigramLm { vocab, dim } };
    models.insert(name.to_string(), model);
}

/// Stable per-model seed so initial parameters are deterministic across
/// processes and independent of the experiment seed (matching the AOT path,
/// where init ships as a fixed artifact).
fn model_seed(name: &str) -> u64 {
    let h = name
        .bytes()
        .fold(0xCBF2_9CE4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3));
    hash_seed(&[h, 0x7E57_AB1E])
}

fn check_params(model: &str, params: &[f32], spec: &ModelSpec) -> Result<()> {
    ensure!(
        params.len() == spec.param_count,
        "{model}: got {} parameters, expected {}",
        params.len(),
        spec.param_count
    );
    Ok(())
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    fn models(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    fn model(&self, name: &str) -> Result<ModelSpec> {
        Ok(self.get(name)?.spec.clone())
    }

    fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let m = self.get(model)?;
        let mut rng = Rng::new(model_seed(model));
        let mut params = Vec::with_capacity(m.spec.param_count);
        match &m.arch {
            Arch::Mlp { dims } => {
                for w in dims.windows(2) {
                    let (n_in, n_out) = (w[0], w[1]);
                    let limit = (6.0 / (n_in + n_out) as f64).sqrt();
                    for _ in 0..n_in * n_out {
                        params.push(((rng.f64() * 2.0 - 1.0) * limit) as f32);
                    }
                    params.extend(std::iter::repeat(0.0f32).take(n_out));
                }
            }
            Arch::BigramLm { vocab, dim } => {
                let e_limit = (6.0 / (vocab + dim) as f64).sqrt();
                for _ in 0..vocab * dim {
                    params.push(((rng.f64() * 2.0 - 1.0) * e_limit) as f32);
                }
                let w_limit = (6.0 / (dim + vocab) as f64).sqrt();
                for _ in 0..dim * vocab {
                    params.push(((rng.f64() * 2.0 - 1.0) * w_limit) as f32);
                }
                params.extend(std::iter::repeat(0.0f32).take(*vocab));
            }
        }
        debug_assert_eq!(params.len(), m.spec.param_count);
        Ok(params)
    }

    fn grad(&self, model: &str, params: &[f32], x: &[f32], y: &[f32]) -> Result<GradResult> {
        let m = self.get(model)?;
        check_params(model, params, &m.spec)?;
        let mut gbuf = vec![0.0f64; params.len()];
        let (loss_sum, denom) = match &m.arch {
            Arch::Mlp { dims } => {
                let (loss_sum, _correct, batch) = mlp_pass(dims, params, x, y, Some(&mut gbuf))?;
                (loss_sum, batch)
            }
            Arch::BigramLm { vocab, dim } => {
                ensure!(y.is_empty(), "{model}: LM grad expects an empty label buffer");
                let (loss_sum, tokens) =
                    bigram_pass(*vocab, *dim, m.spec.seq_len, params, x, Some(&mut gbuf))?;
                (loss_sum, tokens)
            }
        };
        let scale = 1.0 / denom;
        Ok(GradResult {
            loss: (loss_sum * scale) as f32,
            grads: gbuf.iter().map(|&g| (g * scale) as f32).collect(),
        })
    }

    fn eval(&self, model: &str, params: &[f32], x: &[f32], y: &[f32]) -> Result<EvalResult> {
        let m = self.get(model)?;
        check_params(model, params, &m.spec)?;
        match &m.arch {
            Arch::Mlp { dims } => {
                let (loss_sum, correct, _batch) = mlp_pass(dims, params, x, y, None)?;
                Ok(EvalResult { loss_sum, count: correct })
            }
            Arch::BigramLm { vocab, dim } => {
                ensure!(y.is_empty(), "{model}: LM eval expects an empty label buffer");
                let (loss_sum, tokens) =
                    bigram_pass(*vocab, *dim, m.spec.seq_len, params, x, None)?;
                Ok(EvalResult { loss_sum, count: tokens })
            }
        }
    }

    fn quant_kernel(&self, entry: &str) -> Result<Box<dyn QuantKernel>> {
        Ok(Box::new(NativeQuantKernel::parse(entry)?))
    }
}

// ---------------------------------------------------------------------------
// MLP forward/backward
// ---------------------------------------------------------------------------

/// One pass over a classifier batch. Returns `(loss_sum, correct, batch)`;
/// when `grads` is given, accumulates d(loss_sum)/d(params) into it (caller
/// scales by 1/batch for the mean-loss gradient).
fn mlp_pass(
    dims: &[usize],
    params: &[f32],
    x: &[f32],
    y: &[f32],
    mut grads: Option<&mut [f64]>,
) -> Result<(f64, f64, f64)> {
    let d_in = dims[0];
    let classes = *dims.last().unwrap();
    let batch = y.len();
    ensure!(batch > 0, "empty batch");
    ensure!(
        x.len() == batch * d_in,
        "input buffer has {} elements, expected batch {} x input_dim {}",
        x.len(),
        batch,
        d_in
    );

    let nl = dims.len() - 1;
    // (weight offset, bias offset) per layer in the flat parameter vector.
    let mut offs = Vec::with_capacity(nl);
    let mut pos = 0;
    for w in dims.windows(2) {
        offs.push((pos, pos + w[0] * w[1]));
        pos += w[0] * w[1] + w[1];
    }
    debug_assert_eq!(pos, params.len());

    // acts[0] = input, acts[li + 1] = layer li output (ReLU, logits for last).
    let mut acts: Vec<Vec<f64>> = dims.iter().map(|&d| vec![0.0f64; d]).collect();
    let mut deltas: Vec<Vec<f64>> = dims[1..].iter().map(|&d| vec![0.0f64; d]).collect();

    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    for b in 0..batch {
        let label = y[b];
        let yi = label as usize;
        ensure!(
            label >= 0.0 && yi < classes,
            "label {label} out of range for {classes} classes"
        );
        for (a, &v) in acts[0].iter_mut().zip(&x[b * d_in..(b + 1) * d_in]) {
            *a = v as f64;
        }
        // Forward.
        for li in 0..nl {
            let (n_in, n_out) = (dims[li], dims[li + 1]);
            let (w_off, b_off) = offs[li];
            let (prev, rest) = acts.split_at_mut(li + 1);
            let input = &prev[li];
            let out = &mut rest[0];
            let last = li + 1 == nl;
            for o in 0..n_out {
                let row = &params[w_off + o * n_in..w_off + (o + 1) * n_in];
                let mut z = params[b_off + o] as f64;
                for (wv, hv) in row.iter().zip(input.iter()) {
                    z += *wv as f64 * *hv;
                }
                out[o] = if last { z } else { z.max(0.0) };
            }
        }
        // Softmax cross-entropy on the logits.
        let logits = &acts[nl];
        let zmax = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let sumexp: f64 = logits.iter().map(|&z| (z - zmax).exp()).sum();
        let lse = zmax + sumexp.ln();
        loss_sum += lse - logits[yi];
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        if argmax == yi {
            correct += 1.0;
        }

        // Backward.
        if let Some(g) = grads.as_deref_mut() {
            for o in 0..classes {
                deltas[nl - 1][o] =
                    (acts[nl][o] - lse).exp() - if o == yi { 1.0 } else { 0.0 };
            }
            for li in (0..nl).rev() {
                let (n_in, n_out) = (dims[li], dims[li + 1]);
                let (w_off, b_off) = offs[li];
                let (dl, dr) = deltas.split_at_mut(li);
                let dz = &dr[0];
                let input = &acts[li];
                for o in 0..n_out {
                    let d = dz[o];
                    if d != 0.0 {
                        let grow = &mut g[w_off + o * n_in..w_off + (o + 1) * n_in];
                        for (gv, hv) in grow.iter_mut().zip(input.iter()) {
                            *gv += d * *hv;
                        }
                    }
                    g[b_off + o] += d;
                }
                if li > 0 {
                    let dprev = &mut dl[li - 1];
                    for (i, dp) in dprev.iter_mut().enumerate() {
                        // ReLU mask: the stored activation is already max(z, 0).
                        if input[i] > 0.0 {
                            let mut acc = 0.0f64;
                            for o in 0..n_out {
                                acc += params[w_off + o * n_in + i] as f64 * dz[o];
                            }
                            *dp = acc;
                        } else {
                            *dp = 0.0;
                        }
                    }
                }
            }
        }
    }
    Ok((loss_sum, correct, batch as f64))
}

// ---------------------------------------------------------------------------
// Bigram LM forward/backward
// ---------------------------------------------------------------------------

/// One pass over an LM batch of `B * (seq_len + 1)` tokens: each position
/// predicts its successor from the current token's embedding. Returns
/// `(nll_sum, tokens_scored)`; `grads` accumulates d(nll_sum)/d(params).
fn bigram_pass(
    vocab: usize,
    dim: usize,
    seq_len: usize,
    params: &[f32],
    x: &[f32],
    mut grads: Option<&mut [f64]>,
) -> Result<(f64, f64)> {
    let stride = seq_len + 1;
    ensure!(
        !x.is_empty() && x.len() % stride == 0,
        "token buffer has {} elements, expected a multiple of seq_len+1 = {stride}",
        x.len()
    );
    let batch = x.len() / stride;
    let emb_off = 0;
    let w_off = vocab * dim;
    let b_off = w_off + dim * vocab;

    let mut probs = vec![0.0f64; vocab];
    let mut loss_sum = 0.0f64;
    let mut tokens = 0.0f64;
    for b in 0..batch {
        let seq = &x[b * stride..(b + 1) * stride];
        for t in 0..seq_len {
            let tok = seq[t] as usize;
            let tgt = seq[t + 1] as usize;
            ensure!(
                seq[t] >= 0.0 && tok < vocab && seq[t + 1] >= 0.0 && tgt < vocab,
                "token out of range for vocab {vocab}"
            );
            let e = &params[emb_off + tok * dim..emb_off + (tok + 1) * dim];
            // Logits + stable softmax.
            let mut zmax = f64::NEG_INFINITY;
            for (v, p) in probs.iter_mut().enumerate() {
                let row = &params[w_off + v * dim..w_off + (v + 1) * dim];
                let mut z = params[b_off + v] as f64;
                for (wv, ev) in row.iter().zip(e.iter()) {
                    z += *wv as f64 * *ev as f64;
                }
                *p = z;
                zmax = zmax.max(z);
            }
            let sumexp: f64 = probs.iter().map(|&z| (z - zmax).exp()).sum();
            let lse = zmax + sumexp.ln();
            loss_sum += lse - probs[tgt];
            tokens += 1.0;

            if let Some(g) = grads.as_deref_mut() {
                // probs currently holds logits; turn into dz = softmax - onehot.
                for p in probs.iter_mut() {
                    *p = (*p - lse).exp();
                }
                probs[tgt] -= 1.0;
                for (v, &d) in probs.iter().enumerate() {
                    let grow = &mut g[w_off + v * dim..w_off + (v + 1) * dim];
                    for (gv, ev) in grow.iter_mut().zip(e.iter()) {
                        *gv += d * *ev as f64;
                    }
                    g[b_off + v] += d;
                }
                let gemb = emb_off + tok * dim;
                for di in 0..dim {
                    let mut acc = 0.0f64;
                    for (v, &d) in probs.iter().enumerate() {
                        acc += params[w_off + v * dim + di] as f64 * d;
                    }
                    g[gemb + di] += acc;
                }
            }
        }
    }
    Ok((loss_sum, tokens))
}

// ---------------------------------------------------------------------------
// Native quantizer kernels (the L1 surface without PJRT)
// ---------------------------------------------------------------------------

/// Default tile the AOT artifacts use; the native kernels accept any length
/// but advertise the same tile so callers can size buffers identically.
pub const NATIVE_QUANT_TILE: usize = 65536;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum KernelOp {
    Uniform { s: u32 },
    Codebook { s: u32 },
    BiScaled { s: u32 },
    Stats,
}

/// CPU-kernel implementation of [`QuantKernel`], mirroring the Pallas
/// artifact entry points (`quant_uniform_b*`, `quant_nonuniform_b*`,
/// `quant_biscaled_b*`, `tail_stats`). Routes through the
/// runtime-dispatched tables in [`crate::quant::simd`] (like every
/// `quant::kernels` caller), so the slice surface picks up SIMD where the
/// CPU offers it while staying bit-identical to the scalar reference.
pub struct NativeQuantKernel {
    op: KernelOp,
    entry: String,
}

impl NativeQuantKernel {
    /// Parse an artifact entry name into a native kernel.
    pub fn parse(entry: &str) -> Result<NativeQuantKernel> {
        let op = if entry == "tail_stats" {
            KernelOp::Stats
        } else if let Some(b) = entry.strip_prefix("quant_uniform_b") {
            KernelOp::Uniform { s: levels(entry, b)? }
        } else if let Some(b) = entry.strip_prefix("quant_nonuniform_b") {
            KernelOp::Codebook { s: levels(entry, b)? }
        } else if let Some(b) = entry.strip_prefix("quant_biscaled_b") {
            let s = levels(entry, b)?;
            ensure!(s >= 3, "{entry}: biscaled needs at least 2 bits");
            KernelOp::BiScaled { s }
        } else {
            bail!("unknown quantizer kernel entry {entry:?}");
        };
        Ok(NativeQuantKernel { op, entry: entry.to_string() })
    }

    fn check_pair(&self, g: &[f32], u: &[f32]) -> Result<()> {
        ensure!(
            !g.is_empty() && g.len() == u.len(),
            "{}: gradient/uniform length mismatch ({} vs {})",
            self.entry,
            g.len(),
            u.len()
        );
        Ok(())
    }
}

fn levels(entry: &str, bits: &str) -> Result<u32> {
    let b: u32 = bits.parse().map_err(|e| anyhow!("{entry}: bad bit width: {e}"))?;
    ensure!((1..=8).contains(&b), "{entry}: bits must be in 1..=8");
    Ok((1u32 << b) - 1)
}

impl QuantKernel for NativeQuantKernel {
    fn tile(&self) -> usize {
        NATIVE_QUANT_TILE
    }

    fn run_uniform(&self, g: &[f32], u: &[f32], alpha: f32) -> Result<(Vec<f32>, Vec<u32>)> {
        let mut deq = Vec::new();
        let mut idx = Vec::new();
        self.run_uniform_into(g, u, alpha, &mut deq, &mut idx)?;
        Ok((deq, idx))
    }

    fn run_uniform_into(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        deq: &mut Vec<f32>,
        idx: &mut Vec<u32>,
    ) -> Result<()> {
        let KernelOp::Uniform { s } = self.op else {
            bail!("{}: not a uniform kernel", self.entry);
        };
        self.check_pair(g, u)?;
        kernels::quantize_uniform_slice(g, u, alpha, s, idx);
        deq.clear();
        deq.extend(idx.iter().map(|&k| kernels::dequantize_uniform_elem(k, alpha, s)));
        Ok(())
    }

    fn run_codebook(
        &self,
        g: &[f32],
        u: &[f32],
        codebook: &[f32],
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        let mut deq = Vec::new();
        let mut idx = Vec::new();
        self.run_codebook_into(g, u, codebook, &mut deq, &mut idx)?;
        Ok((deq, idx))
    }

    fn run_codebook_into(
        &self,
        g: &[f32],
        u: &[f32],
        codebook: &[f32],
        deq: &mut Vec<f32>,
        idx: &mut Vec<u32>,
    ) -> Result<()> {
        let KernelOp::Codebook { s } = self.op else {
            bail!("{}: not a codebook kernel", self.entry);
        };
        self.check_pair(g, u)?;
        ensure!(
            codebook.len() == s as usize + 1,
            "{}: codebook has {} levels, expected {}",
            self.entry,
            codebook.len(),
            s + 1
        );
        kernels::quantize_codebook_slice(g, u, codebook, idx);
        deq.clear();
        deq.extend(idx.iter().map(|&k| codebook[k as usize]));
        Ok(())
    }

    fn run_biscaled(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        let KernelOp::BiScaled { s } = self.op else {
            bail!("{}: not a biscaled kernel", self.entry);
        };
        self.check_pair(g, u)?;
        ensure!(
            beta > 0.0 && alpha > beta,
            "{}: need alpha > beta > 0 (got alpha={alpha}, beta={beta})",
            self.entry
        );
        let cb = biscaled_codebook(alpha, beta, s);
        let mut idx = Vec::new();
        kernels::quantize_codebook_slice(g, u, &cb, &mut idx);
        let deq = idx.iter().map(|&k| cb[k as usize]).collect();
        Ok((deq, idx))
    }

    fn run_stats(&self, g: &[f32], g_min: f32) -> Result<Vec<f32>> {
        ensure!(self.op == KernelOp::Stats, "{}: not the tail_stats kernel", self.entry);
        ensure!(!g.is_empty(), "{}: empty input", self.entry);
        let mut n = 0.0f64;
        let mut slog = 0.0f64;
        let mut sabs = 0.0f64;
        let mut ssq = 0.0f64;
        let mut amax = 0.0f32;
        for &xv in g {
            let a = xv.abs();
            if a > g_min {
                n += 1.0;
                slog += (a as f64 / g_min as f64).ln();
            }
            sabs += a as f64;
            ssq += xv as f64 * xv as f64;
            amax = amax.max(a);
        }
        Ok(vec![n as f32, slog as f32, sabs as f32, ssq as f32, amax])
    }
}

/// BiScaled codebook for `s + 1` levels: `[-alpha]`, `s - 1` uniform levels
/// across `[-beta, beta]`, `[alpha]` — the layout the `quant_biscaled_b*`
/// artifacts pin (e.g. b=3: s_beta = 5 inner intervals, s_alpha = 2 outer).
fn biscaled_codebook(alpha: f32, beta: f32, s: u32) -> Vec<f32> {
    let s_beta = s - 2;
    let mut cb = Vec::with_capacity(s as usize + 1);
    cb.push(-alpha);
    for i in 0..=s_beta {
        cb.push(-beta + 2.0 * beta * i as f32 / s_beta as f32);
    }
    cb.push(alpha);
    cb
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn model_specs_validate() {
        let b = backend();
        for name in b.models() {
            let spec = b.model(&name).unwrap();
            spec.validate().unwrap();
            let params = b.init_params(&name).unwrap();
            assert_eq!(params.len(), spec.param_count, "{name}");
            assert!(params.iter().all(|p| p.is_finite()), "{name}");
        }
    }

    #[test]
    fn init_is_deterministic_and_model_specific() {
        let b = backend();
        assert_eq!(b.init_params("mlp").unwrap(), b.init_params("mlp").unwrap());
        let mlp = b.init_params("mlp").unwrap();
        let cnn = b.init_params("cnn").unwrap();
        assert_ne!(mlp[..16], cnn[..16], "different models must init differently");
    }

    #[test]
    fn grad_rejects_bad_buffers() {
        let b = backend();
        let spec = b.model("mlp_tiny").unwrap();
        let params = b.init_params("mlp_tiny").unwrap();
        // Wrong param count.
        assert!(b.grad("mlp_tiny", &params[1..], &[0.0; 784], &[0.0]).is_err());
        // Wrong pixel count for the batch.
        assert!(b.grad("mlp_tiny", &params, &[0.0; 7], &[0.0]).is_err());
        // Label out of range.
        let x = vec![0.1f32; spec.input_dim];
        assert!(b.grad("mlp_tiny", &params, &x, &[99.0]).is_err());
        // Unknown model.
        assert!(b.grad("nope", &params, &x, &[0.0]).is_err());
    }

    #[test]
    fn untrained_classifier_loss_near_ln10() {
        let b = backend();
        let params = b.init_params("mlp_tiny").unwrap();
        let x = vec![0.3f32; 4 * IMG_PIXELS];
        let y = vec![0.0f32, 1.0, 2.0, 3.0];
        let out = b.grad("mlp_tiny", &params, &x, &y).unwrap();
        assert!(out.loss.is_finite());
        assert!(
            (out.loss as f64 - (NUM_CLASSES as f64).ln()).abs() < 1.5,
            "init loss {} should be near ln(10)",
            out.loss
        );
        assert_eq!(out.grads.len(), params.len());
        let gnorm: f64 = out.grads.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();
        assert!(gnorm > 0.0 && gnorm.is_finite());
    }

    #[test]
    fn untrained_lm_loss_near_ln_vocab() {
        let b = backend();
        let spec = b.model("tfm_small").unwrap();
        let params = b.init_params("tfm_small").unwrap();
        let mut rng = Rng::new(3);
        let toks: Vec<f32> =
            (0..2 * (spec.seq_len + 1)).map(|_| rng.below(spec.vocab as u64) as f32).collect();
        let out = b.grad("tfm_small", &params, &toks, &[]).unwrap();
        assert!(
            (out.loss as f64 - (spec.vocab as f64).ln()).abs() < 1.0,
            "init NLL {} should be near ln(64)",
            out.loss
        );
        let ev = b.eval("tfm_small", &params, &toks, &[]).unwrap();
        assert_eq!(ev.count, (2 * spec.seq_len) as f64);
    }

    #[test]
    fn quant_kernel_parses_and_validates() {
        let b = backend();
        assert!(b.quant_kernel("quant_uniform_b3").is_ok());
        assert!(b.quant_kernel("quant_nonuniform_b3").is_ok());
        assert!(b.quant_kernel("quant_biscaled_b3").is_ok());
        assert!(b.quant_kernel("tail_stats").is_ok());
        assert!(b.quant_kernel("quant_uniform_b0").is_err());
        assert!(b.quant_kernel("bogus").is_err());
        // Op mismatch is an error, not silent misbehavior.
        let q = b.quant_kernel("tail_stats").unwrap();
        assert!(q.run_uniform(&[0.0], &[0.5], 0.1).is_err());
    }

    #[test]
    fn native_uniform_kernel_matches_scalar_path() {
        let b = backend();
        let q = b.quant_kernel("quant_uniform_b3").unwrap();
        let mut rng = Rng::new(5);
        let g: Vec<f32> = (0..4096).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let u: Vec<f32> = (0..4096).map(|_| rng.f32()).collect();
        let alpha = 0.04f32;
        let (deq, idx) = q.run_uniform(&g, &u, alpha).unwrap();
        for i in 0..g.len() {
            let k = kernels::quantize_uniform_elem(g[i], u[i], alpha, 7);
            assert_eq!(idx[i], k, "i={i}");
            assert_eq!(deq[i], kernels::dequantize_uniform_elem(k, alpha, 7), "i={i}");
        }
    }

    #[test]
    fn native_biscaled_matches_explicit_codebook() {
        let b = backend();
        let q = b.quant_kernel("quant_biscaled_b3").unwrap();
        let (alpha, beta) = (0.05f32, 0.02f32);
        let mut rng = Rng::new(7);
        let g: Vec<f32> = (0..2048).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let u: Vec<f32> = (0..2048).map(|_| rng.f32()).collect();
        let (_deq, idx) = q.run_biscaled(&g, &u, alpha, beta).unwrap();
        // Reference: the same codebook the integration parity test builds.
        let mut cb = vec![-alpha];
        for i in 0..=5 {
            cb.push(-beta + 2.0 * beta * i as f32 / 5.0);
        }
        cb.push(alpha);
        let mut want = Vec::new();
        kernels::quantize_codebook_slice(&g, &u, &cb, &mut want);
        assert_eq!(idx, want);
    }

    #[test]
    fn native_stats_match_direct_computation() {
        let b = backend();
        let q = b.quant_kernel("tail_stats").unwrap();
        let mut rng = Rng::new(8);
        let g: Vec<f32> = (0..8192).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();
        let stats = q.run_stats(&g, 0.01).unwrap();
        assert_eq!(stats.len(), 5);
        let gamma_hat = 1.0 + stats[0] as f64 / stats[1] as f64;
        assert!((gamma_hat - 4.0).abs() < 0.5, "gamma_hat {gamma_hat}");
        let amax = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert_eq!(stats[4], amax);
    }
}
