//! `artifacts/manifest.json` schema — the L2↔L3 contract written by
//! `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::json::Value;

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Parameter name in the exported signature.
    pub name: String,
    /// Element dtype, e.g. `"f32"`.
    pub dtype: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
}

/// One AOT entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Compiled artifact filename, relative to the artifacts directory.
    pub file: String,
    /// Input tensor signature.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor signature.
    pub outputs: Vec<TensorSpec>,
}

/// A contiguous parameter range belonging to one quantization group
/// ("conv" / "fc" / "emb"). The paper quantizes conv and fc gradients
/// independently (Sec. V).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupRange {
    /// Group name, e.g. `"conv"`, `"fc"`, `"emb"`.
    pub group: String,
    /// First parameter index (inclusive).
    pub start: usize,
    /// One past the last parameter index (exclusive).
    pub end: usize,
}

/// One exported model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Model family: `"classifier"` or `"lm"`.
    pub kind: String,
    /// Total flat parameter count.
    pub param_count: usize,
    /// Quantization-group ranges covering `[0, param_count)`.
    pub groups: Vec<GroupRange>,
    /// Per-client training batch size.
    pub train_batch: usize,
    /// Evaluation batch size.
    pub eval_batch: usize,
    /// Classifier: flat input dim. LM: 0.
    pub input_dim: usize,
    /// LM: context length. Classifier: 0.
    pub seq_len: usize,
    /// LM: vocabulary size. Classifier: number of classes.
    pub vocab: usize,
    /// Initial-parameters file, relative to the artifacts directory.
    pub init_file: String,
    /// Artifact name of the (loss, grads) entry point.
    pub grad_entry: String,
    /// Artifact name of the evaluation entry point.
    pub eval_entry: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Compiled entry points by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Exported models by name.
    pub models: BTreeMap<String, ModelSpec>,
    /// Flat tile size for the standalone quantizer artifacts.
    pub quant_tile: usize,
}

fn tensor_list(v: &Value) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensors"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.req("name")?.as_str().unwrap_or_default().to_string(),
                dtype: t.req("dtype")?.as_str().unwrap_or("f32").to_string(),
                shape: t
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("shape must be array"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Parse a manifest from its JSON document.
    pub fn parse(v: &Value) -> Result<Manifest> {
        let mut artifacts = BTreeMap::new();
        for (name, a) in v.req("artifacts")?.as_obj().ok_or_else(|| anyhow!("artifacts must be object"))? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                    inputs: tensor_list(a.req("inputs")?)?,
                    outputs: tensor_list(a.req("outputs")?)?,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().ok_or_else(|| anyhow!("models must be object"))? {
            let geti = |k: &str| m.get(k).and_then(Value::as_usize).unwrap_or(0);
            let gets = |k: &str| m.get(k).and_then(Value::as_str).unwrap_or("").to_string();
            let groups = m
                .req("groups")?
                .as_arr()
                .ok_or_else(|| anyhow!("groups must be array"))?
                .iter()
                .map(|g| {
                    Ok(GroupRange {
                        group: g.req("group")?.as_str().unwrap_or_default().to_string(),
                        start: g.req("start")?.as_usize().unwrap_or(0),
                        end: g.req("end")?.as_usize().unwrap_or(0),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelSpec {
                    kind: gets("kind"),
                    param_count: geti("param_count"),
                    groups,
                    train_batch: geti("train_batch"),
                    eval_batch: geti("eval_batch"),
                    input_dim: geti("input_dim"),
                    seq_len: geti("seq_len"),
                    vocab: geti("vocab"),
                    init_file: gets("init_file"),
                    grad_entry: gets("grad_entry"),
                    eval_entry: gets("eval_entry"),
                },
            );
        }
        let quant_tile = v
            .get("quant")
            .and_then(|q| q.get("tile"))
            .and_then(Value::as_usize)
            .unwrap_or(65536);
        Ok(Manifest { artifacts, models, quant_tile })
    }

    /// Load and parse `manifest.json` from `path`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Manifest::parse(&Value::parse(&text)?)
    }
}

impl ModelSpec {
    /// Sanity-check group ranges tile [0, param_count).
    pub fn validate(&self) -> Result<()> {
        let mut pos = 0;
        for g in &self.groups {
            if g.start != pos || g.end <= g.start {
                return Err(anyhow!("group ranges must tile the params: {:?}", self.groups));
            }
            pos = g.end;
        }
        if pos != self.param_count {
            return Err(anyhow!("groups end at {pos}, params {}", self.param_count));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "m_grad": {
          "file": "m_grad.hlo.txt",
          "inputs": [{"name":"params","dtype":"f32","shape":[10]},
                     {"name":"x","dtype":"f32","shape":[2,4]}],
          "outputs": [{"name":"loss","dtype":"f32","shape":[]},
                      {"name":"grads","dtype":"f32","shape":[10]}]
        }
      },
      "models": {
        "m": {
          "kind": "classifier", "param_count": 10,
          "groups": [{"group":"conv","start":0,"end":4},
                     {"group":"fc","start":4,"end":10}],
          "train_batch": 2, "eval_batch": 4, "input_dim": 4,
          "init_file": "m_init.bin", "grad_entry": "m_grad",
          "eval_entry": "m_eval"
        }
      },
      "quant": {"tile": 1024}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&Value::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.quant_tile, 1024);
        let a = &m.artifacts["m_grad"];
        assert_eq!(a.inputs[1].shape, vec![2, 4]);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        let spec = &m.models["m"];
        assert_eq!(spec.param_count, 10);
        spec.validate().unwrap();
        assert_eq!(spec.groups[1].group, "fc");
    }

    #[test]
    fn validate_rejects_gaps() {
        let mut m = Manifest::parse(&Value::parse(SAMPLE).unwrap()).unwrap();
        let spec = m.models.get_mut("m").unwrap();
        spec.groups[1].start = 5;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn missing_keys_error() {
        assert!(Manifest::parse(&Value::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"));
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.artifacts.contains_key("cnn_grad"));
            let cnn = &m.models["cnn"];
            cnn.validate().unwrap();
            assert_eq!(cnn.groups.len(), 2, "cnn should have conv+fc groups");
        }
    }
}
